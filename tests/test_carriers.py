"""Wire-carrier subsystem (core/carriers.py): dense / sparse / fused carriers
must produce the same g_server trajectories (the wire format is transport, not
semantics), and wire_words accounting must stay honest."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import carriers as carrier_lib
from repro.core import compressors as C
from repro.core import distributed as D
from repro.core import ef, problems, simulate
from repro.optim import optimizer as opt_lib


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


@pytest.fixture
def setup():
    rng = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    x = jax.random.normal(rng, (16, 8))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (8, 4))
    return params, {"x": x, "y": x @ w}


BLOCK_TOPK = C.BlockTopK(block=8, k_per_block=3)


def _trajectory(setup, method, carrier, steps=40):
    """g_server / loss trajectory of the production train step."""
    params, batch = setup
    dp = 4
    efc = D.EFConfig(method=method, carrier=carrier)
    opt = opt_lib.sgd(0.2)
    step = jax.jit(D.make_train_step(loss_fn, efc, opt, dp))
    _, _, g0 = D.per_client_value_and_grad(loss_fn, params, batch, dp)
    p, os_, es = params, opt.init(params), D.init_ef_state(
        efc, params, dp, init_grads=g0)
    rng = jax.random.PRNGKey(1)
    servers = []
    for t in range(steps):
        p, os_, es, m = step(p, os_, es, batch, jax.random.fold_in(rng, t), t)
        servers.append(np.asarray(es["server"]["w"]))
    return np.stack(servers)


@pytest.mark.parametrize("carrier", ["sparse", "fused"])
@pytest.mark.parametrize("method_name", ["ef21_sgdm", "ef21_sgd"])
def test_train_step_g_server_matches_dense(setup, carrier, method_name):
    """Every carrier is a pure transport: the server estimate gᵗ it produces
    over a full training run must equal the dense (paper-faithful) one up to
    float/tie tolerance."""
    kwargs = {"compressor": BLOCK_TOPK}
    if method_name == "ef21_sgdm":
        kwargs["eta"] = 0.3
    method = ef.make(method_name, **kwargs)
    ref = _trajectory(setup, method, "dense")
    got = _trajectory(setup, method, carrier)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("carrier", ["sparse", "fused"])
def test_simulator_matches_dense_on_quadratic(carrier):
    """All three runtimes share one carrier implementation — the vmap
    simulator's whole trajectory on a quadratic problem must match dense."""
    prob = problems.QuadraticT1()
    method = ef.EF21SGDM(compressor=C.BlockTopK(block=2, k_per_block=1),
                         eta=0.2)
    out = {}
    for c in ("dense", carrier):
        cfg = simulate.SimConfig(n=4, batch_size=2, gamma=1e-2, steps=200,
                                 carrier=c)
        out[c] = simulate.run_numpy(prob, method, cfg, seed=0)
    np.testing.assert_allclose(out[carrier]["grad_norm_sq"],
                               out["dense"]["grad_norm_sq"],
                               rtol=1e-4, atol=1e-7)


def test_fused_degrades_to_dense_plan_when_unfusable():
    fused = carrier_lib.make("fused")
    assert fused.plan(ef.EF21SGDM(compressor=C.BlockTopK())) == "fused"
    # TopK is not the kernel's compressor; traced η can't be baked in
    assert fused.plan(ef.EF21SGDM(compressor=C.TopK())) == "dense"
    assert fused.plan(ef.EF21SGDM(compressor=C.BlockTopK()),
                      eta=jnp.float32(0.1)) == "dense"
    assert fused.plan(ef.EF14SGD(compressor=C.BlockTopK())) == "dense"


def test_sparse_plan_respects_wire_is_msg():
    sparse = carrier_lib.make("sparse")
    assert sparse.plan(ef.EF21SGDM(compressor=C.TopK())) == "wire"
    assert sparse.plan(ef.EF21SGDM(compressor=C.BlockTopK())) == "wire"
    # Abs transforms c into γ·c — the wire is not the message
    assert sparse.plan(ef.EF21SGDMAbs(compressor=C.TopK())) == "dense"
    # RandK needs rng in encode; carrier degrades rather than miscompress
    assert sparse.plan(ef.EF21SGDM(compressor=C.RandK())) == "dense"


def test_wire_words_accounting():
    d = 4096
    dense, sparse, fused = (carrier_lib.make(n)
                            for n in ("dense", "sparse", "fused"))
    topk = C.TopK(ratio=0.01)
    btk = C.BlockTopK(block=1024, k_per_block=16)
    # dense/fused all-reduce ships every coordinate regardless of sparsity
    assert dense.wire_words(topk, d) == d
    assert fused.wire_words(btk, d) == d
    # sparse ships values AND int32 indices: 2× the coordinate count
    assert sparse.wire_words(topk, d) == 2 * topk._k(d)
    assert sparse.wire_words(btk, d) == 2 * (d // 1024) * 16
    # Method.coords_per_message delegates when a carrier is named
    m = ef.EF21SGDM(compressor=btk)
    assert m.coords_per_message(d) == (d // 1024) * 16          # paper x-axis
    assert m.coords_per_message(d, carrier="sparse") == \
        sparse.wire_words(btk, d)
    assert m.coords_per_message(d, carrier="dense") == d
    neo = ef.Neolithic(compressor=topk, rounds=4)
    assert neo.coords_per_message(d, carrier="sparse") == \
        4 * sparse.wire_words(topk, d)


def test_simulator_reports_wire_words():
    prob = problems.QuadraticT1()
    method = ef.EF21SGDM(compressor=C.TopK(k=1), eta=0.5)
    for carrier, expect in (("dense", 2.0), ("sparse", 2.0)):
        cfg = simulate.SimConfig(n=2, steps=3, carrier=carrier)
        out = simulate.run_numpy(prob, method, cfg, seed=0)
        # d = 2, n = 2: TopK(k=1) → 1 coord (paper), dense wire = 2 words,
        # sparse wire = 2 words (1 value + 1 index)
        assert out["coords_per_round"] == 1 * 2
        assert out["wire_words_per_round"] == expect * 2


def test_sparse_carrier_roundtrip_matches_compressor():
    """encode→local_c equals the compressor's dense C(x); encode→aggregate
    with one client equals it too (ties aside, none here)."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(50).astype(np.float32))
    sparse = carrier_lib.make("sparse")
    for comp in (C.TopK(ratio=0.2), C.BlockTopK(block=16, k_per_block=4)):
        wire = sparse.encode(comp, x)
        c_loc = sparse.local_c(comp, x, wire)
        np.testing.assert_allclose(np.asarray(c_loc), np.asarray(comp(x)),
                                   rtol=1e-6)
        wire1 = jax.tree_util.tree_map(lambda a: a[None], wire)
        agg = sparse.aggregate(comp, wire1, d=x.size, dtype=x.dtype, dp=1)
        np.testing.assert_allclose(np.asarray(agg), np.asarray(comp(x)),
                                   rtol=1e-6)


def test_sparse_local_c_is_exact_wire_decode():
    """On a tie at the k-th rank, local_c must keep exactly what the wire
    shipped (k entries), not the threshold mask (which would keep both tied
    coordinates and desynchronize client state from the server aggregate)."""
    x = jnp.asarray([1.0, -1.0, 0.5, 0.25], jnp.float32)   # |tie| at rank 1
    comp = C.TopK(k=1)
    sparse = carrier_lib.make("sparse")
    wire = sparse.encode(comp, x)
    c = np.asarray(sparse.local_c(comp, x, wire))
    assert (c != 0).sum() == 1
    vals, idx = (np.asarray(a).reshape(-1) for a in wire)
    np.testing.assert_allclose(c[idx[0]], vals[0])


def test_unknown_carrier_rejected():
    with pytest.raises(ValueError):
        carrier_lib.make("carrier-pigeon")
