"""Wire-carrier subsystem (core/carriers.py): dense / sparse / fused carriers
must produce the same g_server trajectories (the wire format is transport, not
semantics), and wire_words accounting must stay honest."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import carriers as carrier_lib
from repro.core import compressors as C
from repro.core import distributed as D
from repro.core import ef, problems, simulate
from repro.optim import optimizer as opt_lib


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


@pytest.fixture
def setup():
    rng = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    x = jax.random.normal(rng, (16, 8))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (8, 4))
    return params, {"x": x, "y": x @ w}


BLOCK_TOPK = C.BlockTopK(block=8, k_per_block=3)


def _trajectory(setup, method, carrier, steps=40, cache=None):
    """g_server / loss trajectory of the production train step. ``cache`` is
    the session-scoped step_cache fixture: the jitted step for a given
    (method, carrier, dp) compiles once per test session."""
    params, batch = setup
    dp = 4
    lr = 0.2
    efc = D.EFConfig(method=method, carrier=carrier)
    opt = opt_lib.sgd(lr)
    # the key must cover everything the jitted step closes over (step_cache
    # is shared session-wide)
    key = (loss_fn, "sgd", lr, method, carrier, dp)
    if cache is None or key not in cache:
        step = jax.jit(D.make_train_step(loss_fn, efc, opt, dp))
        if cache is not None:
            cache[key] = step
    else:
        step = cache[key]
    _, _, g0 = D.per_client_value_and_grad(loss_fn, params, batch, dp)
    p, os_, es = params, opt.init(params), D.init_ef_state(
        efc, params, dp, init_grads=g0)
    rng = jax.random.PRNGKey(1)
    servers = []
    for t in range(steps):
        p, os_, es, m = step(p, os_, es, batch, jax.random.fold_in(rng, t), t)
        servers.append(np.asarray(es["server"]["w"]))
    return np.stack(servers)


@pytest.mark.parametrize("carrier", ["sparse", "fused"])
@pytest.mark.parametrize("method_name", ["ef21_sgdm", "ef21_sgd"])
def test_train_step_g_server_matches_dense(setup, carrier, method_name,
                                           step_cache):
    """Every carrier is a pure transport: the server estimate gᵗ it produces
    over a full training run must equal the dense (paper-faithful) one up to
    float/tie tolerance."""
    kwargs = {"compressor": BLOCK_TOPK}
    if method_name == "ef21_sgdm":
        kwargs["eta"] = 0.3
    method = ef.make(method_name, **kwargs)
    ref = _trajectory(setup, method, "dense", cache=step_cache)
    got = _trajectory(setup, method, carrier, cache=step_cache)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("carrier", ["sparse", "fused"])
def test_simulator_matches_dense_on_quadratic(carrier):
    """All three runtimes share one carrier implementation — the vmap
    simulator's whole trajectory on a quadratic problem must match dense."""
    prob = problems.QuadraticT1()
    method = ef.EF21SGDM(compressor=C.BlockTopK(block=2, k_per_block=1),
                         eta=0.2)
    out = {}
    for c in ("dense", carrier):
        cfg = simulate.SimConfig(n=4, batch_size=2, gamma=1e-2, steps=200,
                                 carrier=c)
        out[c] = simulate.run_numpy(prob, method, cfg, seed=0)
    np.testing.assert_allclose(out[carrier]["grad_norm_sq"],
                               out["dense"]["grad_norm_sq"],
                               rtol=1e-4, atol=1e-7)


def test_fused_degrades_to_dense_plan_when_unfusable():
    fused = carrier_lib.make("fused")
    assert fused.plan(ef.EF21SGDM(compressor=C.BlockTopK())) == "fused"
    # TopK is not the kernel's compressor; traced η can't be baked in
    assert fused.plan(ef.EF21SGDM(compressor=C.TopK())) == "dense"
    assert fused.plan(ef.EF21SGDM(compressor=C.BlockTopK()),
                      eta=jnp.float32(0.1)) == "dense"
    assert fused.plan(ef.EF14SGD(compressor=C.BlockTopK())) == "dense"


def test_every_method_carrier_pair_roundtrips_or_reports_why():
    """Carrier.plan used to degrade to 'dense' silently — a misconfigured run
    looked identical to a working one in logs. Every (method × carrier) pair
    must now either run the carrier's native plan or return a non-empty
    plan_reason explaining the degradation (launch/build.py warns with it,
    launch/train.py prints it)."""
    comp = C.BlockTopK(block=8, k_per_block=3)
    # each carrier's native (most-fused) plan: a reason is non-empty iff the
    # executed plan is anything less — dense for most carriers; fused_quant
    # additionally reports its fall-back to the unfused quantized 'wire'
    native = {"dense": "dense", "sparse": "wire", "fused": "fused",
              "quant8": "wire", "quant4": "wire",
              "fused_quant8": "fused_wire", "fused_quant4": "fused_wire"}
    assert set(native) == set(carrier_lib.REGISTRY)
    for m_name in ef.REGISTRY:
        method = ef.make(m_name, compressor=comp)
        for c_name in carrier_lib.REGISTRY:
            car = carrier_lib.make(c_name)
            plan, reason = car.plan_with_reason(method)
            assert plan == car.plan(method)
            if plan != native[c_name]:
                assert reason, (m_name, c_name)
            else:
                assert reason == "", (m_name, c_name, reason)


def test_quant_plan_degradations_have_reasons():
    for name in ("quant8", "quant4"):
        car = carrier_lib.make(name)
        assert car.plan(ef.EF21SGDM(compressor=BLOCK_TOPK)) == "wire"
        # dense payload: any deterministic compressor rides the wire
        assert car.plan(ef.EF21SGDM(compressor=C.HardThreshold())) == "wire"
        plan, reason = car.plan_with_reason(
            ef.EF21SGDM(compressor=C.RandK()))
        assert plan == "dense" and "randomness" in reason
        plan, reason = car.plan_with_reason(
            ef.EF21SGDMAbs(compressor=BLOCK_TOPK))
        assert plan == "dense" and "wire_is_msg" in reason


def test_quant_wire_words_fractional_accounting():
    """A 4-bit mantissa is 1/8 word, int8 is 1/4, each block ships one f32
    scale, block-local indices are int16 (1/2 word) when the block fits —
    and at equal K the quantized wires undercut the sparse carrier."""
    d = 4096
    btk = C.BlockTopK(block=1024, k_per_block=16)
    sparse, q8, q4 = (carrier_lib.make(n)
                      for n in ("sparse", "quant8", "quant4"))
    nb, kb = 4, 16
    assert q8.wire_words(btk, d) == nb * (1 + kb * (8 / 32 + 0.5))
    assert q4.wire_words(btk, d) == nb * (1 + kb * (4 / 32 + 0.5))
    assert (q4.wire_words(btk, d) < q8.wire_words(btk, d)
            < sparse.wire_words(btk, d))
    # single-block TopK on a large leaf: indices fall back to a full word
    big = 2 ** 16
    topk = C.TopK(k=8)
    assert q8.wire_words(topk, big) == 1 + 8 * (8 / 32 + 1.0)
    # dense payload: scales + packed mantissas, no indices
    ident = C.Identity()
    nbq = -(-d // q4.qblock)
    assert q4.wire_words(ident, d) == nbq * (1 + q4.qblock * 4 / 32)
    # coords_per_message delegation
    m = ef.EF21SGDM(compressor=btk)
    assert m.coords_per_message(d, carrier="quant4") == \
        q4.wire_words(btk, d)


@pytest.mark.slow
@pytest.mark.parametrize("carrier", ["quant8", "quant4"])
def test_quant_carrier_converges_like_dense_on_quadratic(carrier):
    """Quantization changes the trajectory (unlike sparse/fused, the wire is
    lossy beyond C), but EF re-sends the quantization error, so the simulator
    must reach the same gradient-norm floor as the dense wire."""
    prob = problems.QuadraticT1()
    method = ef.EF21SGDM(compressor=C.BlockTopK(block=2, k_per_block=1),
                         eta=0.2)
    out = {}
    for c in ("dense", carrier):
        cfg = simulate.SimConfig(n=4, batch_size=2, gamma=1e-2, steps=300,
                                 carrier=c)
        out[c] = simulate.run_numpy(prob, method, cfg, seed=0)
    end_d = out["dense"]["grad_norm_sq"][-50:].mean()
    end_q = out[carrier]["grad_norm_sq"][-50:].mean()
    assert end_q < 3 * end_d + 1e-6, (end_q, end_d)


def test_sparse_plan_respects_wire_is_msg():
    sparse = carrier_lib.make("sparse")
    assert sparse.plan(ef.EF21SGDM(compressor=C.TopK())) == "wire"
    assert sparse.plan(ef.EF21SGDM(compressor=C.BlockTopK())) == "wire"
    # Abs transforms c into γ·c — the wire is not the message
    assert sparse.plan(ef.EF21SGDMAbs(compressor=C.TopK())) == "dense"
    # RandK needs rng in encode; carrier degrades rather than miscompress
    assert sparse.plan(ef.EF21SGDM(compressor=C.RandK())) == "dense"


def test_wire_words_accounting():
    d = 4096
    dense, sparse, fused = (carrier_lib.make(n)
                            for n in ("dense", "sparse", "fused"))
    topk = C.TopK(ratio=0.01)
    btk = C.BlockTopK(block=1024, k_per_block=16)
    # dense/fused all-reduce ships every coordinate regardless of sparsity
    assert dense.wire_words(topk, d) == d
    assert fused.wire_words(btk, d) == d
    # sparse ships values AND int32 indices: 2× the coordinate count
    assert sparse.wire_words(topk, d) == 2 * topk._k(d)
    assert sparse.wire_words(btk, d) == 2 * (d // 1024) * 16
    # Method.coords_per_message delegates when a carrier is named
    m = ef.EF21SGDM(compressor=btk)
    assert m.coords_per_message(d) == (d // 1024) * 16          # paper x-axis
    assert m.coords_per_message(d, carrier="sparse") == \
        sparse.wire_words(btk, d)
    assert m.coords_per_message(d, carrier="dense") == d
    neo = ef.Neolithic(compressor=topk, rounds=4)
    assert neo.coords_per_message(d, carrier="sparse") == \
        4 * sparse.wire_words(topk, d)


def test_simulator_reports_wire_words():
    prob = problems.QuadraticT1()
    method = ef.EF21SGDM(compressor=C.TopK(k=1), eta=0.5)
    out = {}
    for carrier, expect in (("dense", 2.0), ("sparse", 2.0),
                            ("quant8", 1.75), ("quant4", 1.625)):
        cfg = simulate.SimConfig(n=2, steps=3, carrier=carrier)
        out[carrier] = simulate.run_numpy(prob, method, cfg, seed=0)
        # d = 2, n = 2: TopK(k=1) → 1 coord (paper), dense wire = 2 words,
        # sparse wire = 2 words (1 value + 1 int32 index), quant wires =
        # 1 scale + quantized value (1/4 | 1/8 word) + int16 index (1/2)
        assert out[carrier]["coords_per_round"] == 1 * 2
        assert out[carrier]["wire_words_per_round"] == expect * 2
    # acceptance: at equal K the quant carriers undercut the sparse wire
    assert (out["quant4"]["wire_words_per_round"]
            < out["quant8"]["wire_words_per_round"]
            < out["sparse"]["wire_words_per_round"])


def test_sparse_carrier_roundtrip_matches_compressor():
    """encode→local_c equals the compressor's dense C(x); encode→aggregate
    with one client equals it too (ties aside, none here)."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(50).astype(np.float32))
    sparse = carrier_lib.make("sparse")
    for comp in (C.TopK(ratio=0.2), C.BlockTopK(block=16, k_per_block=4)):
        wire = sparse.encode(comp, x)
        c_loc = sparse.local_c(comp, x, wire)
        np.testing.assert_allclose(np.asarray(c_loc), np.asarray(comp(x)),
                                   rtol=1e-6)
        wire1 = jax.tree_util.tree_map(lambda a: a[None], wire)
        agg = sparse.aggregate(comp, wire1, d=x.size, dtype=x.dtype, dp=1)
        np.testing.assert_allclose(np.asarray(agg), np.asarray(comp(x)),
                                   rtol=1e-6)


def test_sparse_local_c_is_exact_wire_decode():
    """On a tie at the k-th rank, local_c must keep exactly what the wire
    shipped (k entries), not the threshold mask (which would keep both tied
    coordinates and desynchronize client state from the server aggregate)."""
    x = jnp.asarray([1.0, -1.0, 0.5, 0.25], jnp.float32)   # |tie| at rank 1
    comp = C.TopK(k=1)
    sparse = carrier_lib.make("sparse")
    wire = sparse.encode(comp, x)
    c = np.asarray(sparse.local_c(comp, x, wire))
    assert (c != 0).sum() == 1
    vals, idx = (np.asarray(a).reshape(-1) for a in wire)
    np.testing.assert_allclose(c[idx[0]], vals[0])


def test_unknown_carrier_rejected():
    with pytest.raises(ValueError):
        carrier_lib.make("carrier-pigeon")


# ---------------------------------------------------------------------------
# BlockTopK geometry: sub-block and non-divisible leaves (per-group schedules
# route tiny norm/bias tensors through their own compressors, so the fixed
# full-block K must not degenerate on leaves smaller than one block)
# ---------------------------------------------------------------------------

def test_block_topk_sub_block_leaf_gets_proportional_k():
    """A (64,) leaf under ratio=0.05/block=1024 used to get the full-block
    K = round(0.05·1024) = 51 — keeping 80% of the tensor while reporting
    α = 0.05. The d-aware geometry gives one block of the leaf's own size
    and K = round(0.05·64) = 3."""
    comp = C.BlockTopK(ratio=0.05, block=1024)
    nb, block, kb = comp.geom(64)
    assert (nb, block, kb) == (1, 64, 3)
    x = jnp.asarray(np.random.RandomState(0).randn(64), jnp.float32)
    assert int((np.asarray(comp(x)) != 0).sum()) == 3
    assert comp.alpha(64) == pytest.approx(3 / 64)
    # leaves of at least one block keep the exact legacy geometry
    assert comp.geom(4096) == (4, 1024, 51)
    # explicit k_per_block is capped at the leaf size instead of selecting
    # padding zeros
    small = C.BlockTopK(block=1024, k_per_block=16)
    assert small.geom(8) == (1, 8, 8)
    # k of a 1-element leaf never hits zero
    assert C.BlockTopK(ratio=0.01, block=1024).geom(3) == (1, 3, 1)


@pytest.mark.parametrize("d", [5, 64, 100, 2500])
def test_block_topk_wire_roundtrips_on_odd_sizes(d):
    """Sub-block (d < block) and non-divisible (d % block ≠ 0) leaves:
    encode→local_c must equal the dense C(x), indices must stay in range,
    and wire_words must reflect the d-aware geometry for the sparse AND
    quantized carriers."""
    rng = np.random.RandomState(d)
    x = jnp.asarray(rng.randn(d).astype(np.float32))
    comp = C.BlockTopK(ratio=0.1, block=64)
    nb, block, kb = comp.geom(d)
    assert kb <= block <= max(d, 1)
    sparse = carrier_lib.make("sparse")
    wire = sparse.encode(comp, x)
    c_loc = np.asarray(sparse.local_c(comp, x, wire))
    np.testing.assert_allclose(c_loc, np.asarray(comp(x)), rtol=1e-6)
    assert sparse.wire_words(comp, d) == 2.0 * nb * kb
    vals, idx = wire
    assert int(np.asarray(idx).max()) < block
    # one-client aggregate equals the dense compressor output too
    wire1 = jax.tree_util.tree_map(lambda a: a[None], wire)
    agg = sparse.aggregate(comp, wire1, d=d, dtype=x.dtype, dp=1)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(comp(x)),
                               rtol=1e-6)
    # quantized sparse payload: decode == local_c (the EF invariant), and
    # the word count uses the same d-aware geometry
    quant = carrier_lib.make("quant8")
    qwire = quant.encode(comp, x)
    q_loc = np.asarray(quant.local_c(comp, x, qwire))
    np.testing.assert_allclose(
        q_loc, np.asarray(quant.decode(comp, qwire, d=d, dtype=x.dtype)))
    idx_words = 0.5 if block <= 2 ** 15 - 1 else 1.0
    assert quant.wire_words(comp, d) == nb * (1.0 + kb * (0.25 + idx_words))


def test_fused_carrier_consistent_on_sub_block_leaves(step_cache):
    """The fused kernel now runs each leaf at its d-aware (block, kb) — a
    model with sub-block bias/norm leaves must still match the dense
    trajectory (the b leaf here is smaller than the compressor block)."""
    rng = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    x = jax.random.normal(rng, (16, 8))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (8, 4))
    setup = (params, {"x": x, "y": x @ w})
    method = ef.EF21SGDM(compressor=C.BlockTopK(block=16, k_per_block=3),
                         eta=0.3)
    ref = _trajectory(setup, method, "dense", steps=20, cache=step_cache)
    got = _trajectory(setup, method, "fused", steps=20, cache=step_cache)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
