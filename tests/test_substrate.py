"""Substrate tests: optimizer, schedules, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.optim import optimizer as O


def test_sgd_step():
    opt = O.sgd(0.1)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([1.0, -1.0])}
    upd, _ = opt.update(g, opt.init(p), p, 0)
    p2 = O.apply_updates(p, upd)
    np.testing.assert_allclose(p2["w"], [0.9, 2.1], rtol=1e-6)


def test_sgd_momentum_accumulates():
    opt = O.sgd(1.0, momentum=0.5)
    p = {"w": jnp.zeros(1)}
    st = opt.init(p)
    g = {"w": jnp.ones(1)}
    upd1, st = opt.update(g, st, p, 0)      # m=1 → −1
    upd2, st = opt.update(g, st, p, 1)      # m=1.5 → −1.5
    np.testing.assert_allclose(upd1["w"], [-1.0])
    np.testing.assert_allclose(upd2["w"], [-1.5])


def test_adamw_matches_manual():
    opt = O.adamw(0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0])}
    st = opt.init(p)
    g = {"w": jnp.asarray([0.5])}
    upd, st = opt.update(g, st, p, 0)
    m_hat = 0.5            # (0.1·0.5)/(1−0.9)
    v_hat = 0.25           # (0.01·0.25)/(1−0.99)
    np.testing.assert_allclose(
        upd["w"], [-0.1 * m_hat / (np.sqrt(v_hat) + 1e-8)], rtol=1e-5)


def test_adamw_weight_decay():
    opt = O.adamw(0.1, weight_decay=0.1)
    p = {"w": jnp.asarray([2.0])}
    upd, _ = opt.update({"w": jnp.zeros(1)}, opt.init(p), p, 0)
    np.testing.assert_allclose(upd["w"], [-0.1 * 0.1 * 2.0], atol=1e-7)


def test_clip_by_global_norm():
    opt = O.clip_by_global_norm(O.sgd(1.0), max_norm=1.0)
    g = {"w": jnp.asarray([3.0, 4.0])}      # norm 5 → scaled by 1/5
    upd, _ = opt.update(g, {}, None, 0)
    np.testing.assert_allclose(upd["w"], [-0.6, -0.8], rtol=1e-6)


def test_schedules():
    s = O.cosine_schedule(1.0, warmup=10, total=110)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(110)) < float(s(60)) < float(s(10))
    r = O.rsqrt_schedule(1.0)
    np.testing.assert_allclose(float(r(3)), 0.5)


def test_pipeline_deterministic_and_host_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=1)
    pipe = SyntheticTokens(cfg)
    b1, b2 = pipe.batch(5), pipe.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(np.asarray(pipe.batch(6)["tokens"]),
                              np.asarray(b1["tokens"]))
    # host sharding: two hosts jointly reproduce the single-host batch
    h0 = SyntheticTokens(DataConfig(vocab_size=1000, seq_len=32,
                                    global_batch=8, seed=1, hosts=2, host_id=0))
    h1 = SyntheticTokens(DataConfig(vocab_size=1000, seq_len=32,
                                    global_batch=8, seed=1, hosts=2, host_id=1))
    joined = np.concatenate([h0.batch(5)["tokens"], h1.batch(5)["tokens"]])
    np.testing.assert_array_equal(joined, np.asarray(b1["tokens"]))


def test_pipeline_labels_shifted():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=2, seed=0)
    b = SyntheticTokens(cfg).batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_pipeline_heterogeneous_clients():
    cfg = DataConfig(vocab_size=10_000, seq_len=64, global_batch=8, seed=0,
                     dp_groups=4, heterogeneity=1.0)
    b = SyntheticTokens(cfg).batch(0)
    toks = np.asarray(b["tokens"])
    g0, g3 = toks[:2].ravel(), toks[6:].ravel()
    assert g0.max() < g3.min()       # disjoint token ranges per client


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ck", "step_00000007.npz")
    ckpt.save(path, tree, step=7, meta={"note": "x"})
    restored, meta = ckpt.restore(path, jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, x.dtype), tree))
    assert meta["step"] == 7 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert ckpt.latest(os.path.dirname(path)).endswith("step_00000007.npz")


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((2, 2))}
    path = os.path.join(tmp_path, "c.npz")
    ckpt.save(path, tree)
    with pytest.raises(ValueError):
        ckpt.restore(path, {"a": jnp.ones((3, 2))})
