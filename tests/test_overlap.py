"""Comm/compute overlap (DESIGN.md §10): the double-buffered ppermute ring in
``ef_round_sharded`` is BIT-identical to the blocking all-gather anchor —
overlap may only move the collective in time, never change a single bit — and
the overlap flag survives a kill-and-resume. The multi-device parts run in a
subprocess so the 8-device placeholder flag never leaks into the main test
session (same idiom as tests/test_multidevice.py)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core import carriers as carrier_lib
    from repro.core import compressors as C, distributed as D, ef
    from repro.launch import mesh as mesh_lib

    mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
    dp = 4

    # --- ring_all_gather == lax.all_gather (the bit-identity that makes the
    # overlapped transport an anchor-preserving rewrite). check_rep=False:
    # ppermute-based gathers defeat shard_map's static replication inference.
    x = jnp.arange(4 * 6, dtype=jnp.float32).reshape(4, 6)

    def plain(xs):
        ring = carrier_lib.ring_all_gather(xs, "data")
        ref = jax.lax.all_gather(xs, "data")
        return ring, ref

    sm = shard_map(plain, mesh=mesh, in_specs=P("data", None),
                   out_specs=(P(None, None), P(None, None)), check_rep=False)
    ring, ref = sm(x)
    np.testing.assert_array_equal(np.asarray(ring), np.asarray(ref))

    def with_fn(xs):                      # per-chunk decode hook
        ring = carrier_lib.ring_all_gather(xs, "data", fn=lambda c: c * 2.0)
        ref = jax.lax.all_gather(xs * 2.0, "data")
        return ring, ref

    sm = shard_map(with_fn, mesh=mesh, in_specs=P("data", None),
                   out_specs=(P(None, None), P(None, None)), check_rep=False)
    ring, ref = sm(x)
    np.testing.assert_array_equal(np.asarray(ring), np.asarray(ref))
    print("ring_all_gather OK")

    # --- overlap on/off bit-identity through the production jitted
    # ef_round_sharded, over a (method x carrier) sample grid: message,
    # every client state leaf, and the server estimate must be EQUAL —
    # not close.
    params = {"w": jnp.zeros((8, 4))}
    rng = jax.random.PRNGKey(0)
    grads_t = {"w": jax.random.normal(rng, (dp, 8, 4))}
    gspecs = {"w": P("data", None, None)}
    btk = C.BlockTopK(block=4, k_per_block=2)

    grid = [("ef21_sgdm", c) for c in carrier_lib.REGISTRY] + [
        ("ef21_sgd", "sparse"), ("ef21_sgd", "quant8"),
        ("ef21_sgd", "fused_quant8")]
    for m_name, carrier in grid:
        kwargs = {"compressor": btk}
        if m_name == "ef21_sgdm":
            kwargs["eta"] = 0.3
        method = ef.make(m_name, **kwargs)
        st0 = None
        outs = {}
        for overlap in (False, True):
            efc = D.EFConfig(method=method, carrier=carrier,
                             data_axes=("data",), overlap=overlap)
            st = D.init_ef_state(efc, params, dp, init_grads=grads_t)
            sspecs = {"clients": {k: {"w": P("data", None, None)}
                                  for k in st["clients"]},
                      "server": {"w": P(None, None)}}
            with mesh_lib.mesh_context(mesh):
                outs[overlap] = jax.jit(
                    functools.partial(D.ef_round_sharded, efc, mesh=mesh,
                                      grads_specs=gspecs,
                                      state_specs=sspecs))(
                    grads_t, st, None)
        (g_off, st_off), (g_on, st_on) = outs[False], outs[True]
        np.testing.assert_array_equal(np.asarray(g_off["w"]),
                                      np.asarray(g_on["w"]))
        for key in st_off["clients"]:
            np.testing.assert_array_equal(
                np.asarray(st_off["clients"][key]["w"]),
                np.asarray(st_on["clients"][key]["w"]))
        np.testing.assert_array_equal(np.asarray(st_off["server"]["w"]),
                                      np.asarray(st_on["server"]["w"]))
        print(f"overlap bit-identity {m_name}/{carrier} OK")
    print("OVERLAP_OK")
""")


def test_overlap_is_bit_identical_to_blocking_anchor():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "OVERLAP_OK" in out.stdout, out.stdout + out.stderr


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def test_kill_and_resume_under_overlap(tmp_path):
    """The overlap flag rides the spec hash through checkpointing: a killed
    overlap run resumes with overlap still on and the trajectory is
    bit-identical to the uninterrupted overlap run."""
    from repro.launch.session import Session
    from repro.launch.spec import RunSpec

    base = RunSpec(arch="smollm-360m", smoke=True, clients=2, global_batch=4,
                   seq_len=32, overlap=True)
    unint = Session(base)
    unint.train(4, log_every=1)

    interrupted = Session(dataclasses.replace(base, ckpt_dir=str(tmp_path)))
    interrupted.train(2, log_every=1)
    del interrupted                        # "kill" the process

    resumed = Session.resume(str(tmp_path))
    assert resumed.step == 2
    assert resumed.spec.overlap is True
    assert resumed.spec.spec_hash() == base.spec_hash()
    resumed.train(4, log_every=1)
    assert _leaves_equal(unint.params, resumed.params)
    assert _leaves_equal(unint.ef_state, resumed.ef_state)
