"""Integration tests validating the paper's core claims on its own constructions
(the CPU-scale halves of EXPERIMENTS.md).

Everything here is a multi-thousand-step convergence simulation → the whole
module is `slow` tier: excluded from the PR gate (`pytest -m tier1`), run in
full on main (tests/conftest.py)."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.core import compressors as C
from repro.core import ef, problems, simulate


@pytest.fixture(scope="module")
def t1():
    return problems.QuadraticT1()


def _run(prob, method, seeds=3, **kw):
    cfg = simulate.SimConfig(**kw)
    return [simulate.run_numpy(prob, method, cfg, seed=s) for s in range(seeds)]


def test_theorem1_noise_construction(t1):
    """E[ξ]=0, E‖ξ‖²=σ² but E[Top1(ξ)] ≠ 0 — the compressor bias at the heart
    of Theorem 1."""
    zs = np.asarray(t1._zs(1))
    assert np.allclose(zs.mean(0), 0, atol=1e-7)
    assert np.isclose((zs ** 2).sum(1).mean(), 1.0, atol=1e-5)     # σ² = 1
    top1 = np.zeros_like(zs)
    idx = np.abs(zs).argmax(1)
    top1[np.arange(3), idx] = zs[np.arange(3), idx]
    bias = top1.mean(0)
    assert np.abs(bias).max() > 0.05                               # (0, s/3)


def test_fig1_ef21_sgd_stalls_sgdm_converges(t1):
    """Figure 1: EF21-SGD drifts away from the optimum; EF21-SGDM stays stable
    and ends orders of magnitude lower."""
    kw = dict(n=1, batch_size=1, gamma=1e-3, steps=8000)
    top1 = C.TopK(k=1)
    sgd_runs = _run(t1, ef.EF21SGD(compressor=top1), **kw)
    sgdm_runs = _run(t1, ef.EF21SGDM(compressor=top1, eta=1e-3), **kw)
    end_sgd = np.median([r["grad_norm_sq"][-500:].mean() for r in sgd_runs])
    end_sgdm = np.median([r["grad_norm_sq"][-500:].mean() for r in sgdm_runs])
    start = np.median([r["grad_norm_sq"][0] for r in sgd_runs])
    assert end_sgd > 10 * start          # EF21-SGD moved AWAY from optimum
    assert end_sgdm < end_sgd / 3        # momentum fixes it


def test_fig1b_no_improvement_with_n_for_ef21_sgd(t1):
    """Figure 1b: increasing n does NOT rescue EF21-SGD — for every n the error
    still GROWS away from the optimum (convergence is not restored)."""
    top1 = C.TopK(k=1)
    for n in (1, 8):
        runs = _run(t1, ef.EF21SGD(compressor=top1), seeds=3, n=n,
                    batch_size=1, gamma=1e-3, steps=6000)
        start = np.median([r["grad_norm_sq"][0] for r in runs])
        end = np.median([r["grad_norm_sq"][-500:].mean() for r in runs])
        assert end > 2 * start, (n, start, end)


def test_theorem1_ideal_floor_independent_of_n(t1):
    """Theorem 1 (exact object): EF21-SGD-ideal stalls at
    E‖∇f‖² ≥ min(σ², ‖∇f(x⁰)‖²)/60 for ALL T and all n."""
    m = ef.EF21SGDMIdeal(compressor=C.TopK(k=1), eta=1.0)
    for n in (1, 4):
        runs = _run(t1, m, seeds=4, n=n, batch_size=1, gamma=0.5, steps=4000)
        end = np.median([r["grad_norm_sq"][-500:].mean() for r in runs])
        floor = min(t1.sigma ** 2, float(
            np.sum(np.asarray(t1.full_grad(t1.init_x())) ** 2))) / 60.0
        assert end >= floor, (n, end, floor)


def test_sgdm_improves_with_n(t1):
    """Theorem 3's ησ²/n term: EF21-SGDM *does* improve with n."""
    top1 = C.TopK(k=1)
    ends = []
    for n in (1, 8):
        runs = _run(t1, ef.EF21SGDM(compressor=top1, eta=0.01), seeds=3, n=n,
                    batch_size=1, gamma=2e-3, steps=6000)
        ends.append(np.median([r["grad_norm_sq"][-500:].mean() for r in runs]))
    assert ends[1] < ends[0]


def test_megabatch_rescues_ef21_sgd(t1):
    """Theorem 1 tightness (Prop. 1): B = Θ(σ²/ε²) makes EF21-SGD converge."""
    top1 = C.TopK(k=1)
    small = _run(t1, ef.EF21SGD(compressor=top1), seeds=3,
                 n=1, batch_size=1, gamma=1e-3, steps=5000)
    big = _run(t1, ef.EF21SGD(compressor=top1), seeds=3,
               n=1, batch_size=64, gamma=1e-3, steps=5000)
    end_small = np.median([r["grad_norm_sq"][-500:].mean() for r in small])
    end_big = np.median([r["grad_norm_sq"][-500:].mean() for r in big])
    assert end_big < end_small / 5


def test_logreg_sgdm_never_worse_batchfree():
    """Experiment 1 (qualitative, weakened for synthetic data): at B=1 and equal
    transmitted coordinates EF21-SGDM is never worse than EF21-SGD (≤1.5×).
    The paper's *dramatic* separation needs the adversarial noise structure of
    Theorem 1 (tested exactly above) or real datasets — on synthetic logreg the
    small-batch gradient noise is too benign; recorded in EXPERIMENTS.md §E1."""
    prob = problems.LogisticRegression(n=5, m_per_client=128, l=16, c=5, seed=1)
    topk = C.TopK(k=10)
    kw = dict(n=5, batch_size=1, gamma=0.05, steps=2500, b_init=8)
    sgdm = _run(prob, ef.EF21SGDM(compressor=topk, eta=0.1), seeds=2, **kw)
    esgd = _run(prob, ef.EF21SGD(compressor=topk), seeds=2, **kw)
    m_end = np.median([r["grad_norm_sq"][-200:].mean() for r in sgdm])
    e_end = np.median([r["grad_norm_sq"][-200:].mean() for r in esgd])
    assert m_end < 1.5 * e_end


def test_time_varying_schedule_converges(t1):
    """Appendix J: ηₜ = 1/√(t+1), γₜ = γ·ηₜ needs no tuning and converges."""
    runs = _run(t1, ef.EF21SGDM(compressor=C.TopK(k=1)), seeds=2,
                n=1, batch_size=1, gamma=0.3, steps=6000, time_varying=True)
    end = np.median([r["grad_norm_sq"][-500:].mean() for r in runs])
    start = np.median([r["grad_norm_sq"][:10].mean() for r in runs])
    assert end < max(start, 1e-3)


def test_ef_recovers_quantization_error():
    """The paper's core mechanism on the quantized wire (core/carriers.py):
    EF21-SGDM over a 4-bit block-quantized wire converges to the same ‖∇f‖²
    tolerance as the dense wire — the contraction argument absorbs the wire
    distortion into the residual, which local_c (= decode of the wire)
    re-sends in later rounds. Naive no-EF 4-bit quantized compression
    (ship Q(∇fᵢ) directly) stalls orders of magnitude higher: on
    heterogeneous clients the per-client rounding errors do not cancel and
    there is no residual to re-send them from."""
    prob = problems.RandomQuadratics(n=8, d=40, lam=0.05, sigma=1e-3, seed=0)
    btk = C.BlockTopK(block=8, k_per_block=2)
    kw = dict(n=8, batch_size=1, gamma=5e-2, steps=2500)

    def end(method, carrier="dense"):
        cfg = simulate.SimConfig(carrier=carrier, **kw)
        out = simulate.run_numpy(prob, method, cfg, seed=0)
        return out["grad_norm_sq"][-300:].mean()

    sgdm = ef.EF21SGDM(compressor=btk, eta=0.1)
    end_dense = end(sgdm, "dense")
    end_q4 = end(sgdm, "quant4")
    end_naive = end(ef.SGD(compressor=C.BlockQuant(bits=4, block=8)))
    # same tolerance as the dense wire (both sit on the σ² noise floor)...
    assert end_q4 < 3 * end_dense, (end_q4, end_dense)
    # ...while the no-EF quantized baseline stalls far above it
    assert end_naive > 30 * end_q4, (end_naive, end_q4)


def test_quadratic_generator_spectrum():
    """Algorithm 2: mean matrix min-eigenvalue is normalized to λ."""
    prob = problems.RandomQuadratics(n=8, d=40, lam=0.05, seed=0)
    Q = np.asarray(prob._Q).mean(0)
    assert np.isclose(np.linalg.eigvalsh(Q).min(), 0.05, atol=1e-5)
