"""Property tests for the compressor zoo (Definition 1 / Definition 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compressors as C  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def vec(draw_len, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(draw_len).astype(np.float32))


@given(st.integers(8, 400), st.integers(0, 10_000),
       st.sampled_from(["topk", "block_topk"]), st.floats(0.05, 0.9))
def test_contractive_inequality_deterministic(d, seed, name, ratio):
    """E‖C(x)−x‖² ≤ (1−α)‖x‖² — deterministic compressors satisfy it pointwise."""
    x = vec(d, seed)
    comp = C.make(name, ratio=ratio) if name == "topk" else \
        C.make(name, ratio=ratio, block=64)
    cx = comp(x)
    err = float(jnp.sum((cx - x) ** 2))
    alpha = comp.alpha(d)
    assert err <= (1 - alpha) * float(jnp.sum(x ** 2)) + 1e-5


@given(st.integers(16, 300), st.integers(0, 10_000), st.floats(0.1, 0.9))
def test_randk_contractive_in_expectation(d, seed, ratio):
    x = vec(d, seed)
    comp = C.RandK(ratio=ratio)
    errs = []
    for i in range(30):
        cx = comp(x, jax.random.PRNGKey(seed * 31 + i))
        errs.append(float(jnp.sum((cx - x) ** 2)))
    alpha = comp.alpha(d)
    # 30-sample mean: allow 25% slack over the expectation bound
    assert np.mean(errs) <= 1.25 * (1 - alpha) * float(jnp.sum(x ** 2)) + 1e-5


@given(st.integers(8, 200), st.integers(0, 10_000), st.floats(1e-3, 1.0))
def test_hard_threshold_absolute_bound(d, seed, lam):
    """Definition 2: ‖C(x)−x‖² ≤ Δ² with Δ = λ√d."""
    x = vec(d, seed)
    comp = C.HardThreshold(lam=lam)
    err = float(jnp.sum((comp(x) - x) ** 2))
    assert err <= comp.delta(d) ** 2 + 1e-6


@given(st.integers(8, 200), st.integers(0, 10_000))
def test_natural_compression_contractive(d, seed):
    x = vec(d, seed)
    comp = C.NaturalCompression()
    errs = [float(jnp.sum((comp(x, jax.random.PRNGKey(seed + i)) - x) ** 2))
            for i in range(20)]
    # E‖C(x)−x‖² ≤ (1/8)‖x‖² (α = 7/8)
    assert np.mean(errs) <= 1.3 * 0.125 * float(jnp.sum(x ** 2)) + 1e-6


@given(st.integers(10, 300), st.integers(0, 10_000))
def test_topk_keeps_largest(d, seed):
    x = vec(d, seed)
    comp = C.TopK(k=5)
    cx = np.asarray(comp(x))
    kept = np.nonzero(cx)[0]
    assert len(kept) >= 5
    thresh = np.sort(np.abs(np.asarray(x)))[-5]
    assert (np.abs(np.asarray(x)[kept]) >= thresh - 1e-7).all()


@given(st.integers(16, 300), st.integers(0, 10_000))
def test_sparse_carrier_matches_dense(d, seed):
    """vals/idx carrier scattered == dense C(x) for TopK & BlockTopK."""
    x = vec(d, seed)
    for comp in (C.TopK(k=7), C.BlockTopK(block=32, k_per_block=3)):
        vals, idx = comp.sparse(x)
        dense = np.zeros(max(d, int(np.asarray(idx).max()) + 1), np.float32)
        dense[np.asarray(idx)] = np.asarray(vals)
        cx = np.asarray(comp(x))
        # dense path may keep extra exact ties; every carrier entry must match
        np.testing.assert_allclose(dense[:d][np.asarray(idx)[np.asarray(idx) < d]],
                                   cx[np.asarray(idx)[np.asarray(idx) < d]],
                                   rtol=1e-6)


def test_identity():
    x = vec(64, 0)
    assert (C.Identity()(x) == x).all()
    assert C.Identity().alpha(64) == 1.0


def test_rank1_contractive():
    x = vec(256, 3)
    cx = C.Rank1(rows=16)(x)
    assert float(jnp.sum((cx - x) ** 2)) <= float(jnp.sum(x ** 2)) + 1e-5


def test_registry():
    for name in C.REGISTRY:
        comp = C.make(name)
        assert isinstance(comp, C.Compressor)
    with pytest.raises(ValueError):
        C.make("nope")
