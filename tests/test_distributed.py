"""Distributed EF runtime: per-client grads, carrier equivalence, train loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as C
from repro.core import distributed as D
from repro.core import ef
from repro.optim import optimizer as opt_lib


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


@pytest.fixture
def setup():
    rng = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    x = jax.random.normal(rng, (16, 8))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (8, 4))
    return params, {"x": x, "y": x @ w}


def test_per_client_grads_match_manual(setup):
    params, batch = setup
    dp = 4
    loss, aux, grads = D.per_client_value_and_grad(loss_fn, params, batch, dp)
    for i in range(dp):
        sub = {k: v[i * 4:(i + 1) * 4] for k, v in batch.items()}
        gi = jax.grad(lambda p: loss_fn(p, sub)[0])(params)
        np.testing.assert_allclose(grads["w"][i], gi["w"], rtol=1e-5)


def test_mean_of_client_grads_is_global_grad(setup):
    params, batch = setup
    _, _, grads = D.per_client_value_and_grad(loss_fn, params, batch, 4)
    g_global = jax.grad(lambda p: loss_fn(p, batch)[0])(params)
    np.testing.assert_allclose(grads["w"].mean(0), g_global["w"], rtol=1e-5)


def test_carrier_equivalence(setup):
    params, batch = setup
    dp = 4
    _, _, grads = D.per_client_value_and_grad(loss_fn, params, batch, dp)
    method = ef.EF21SGDM(compressor=C.TopK(ratio=0.3), eta=0.2)
    outs = {}
    for carrier in ("dense", "sparse"):
        efc = D.EFConfig(method=method, carrier=carrier)
        st = D.init_ef_state(efc, params, dp, init_grads=grads)
        g_est, st2 = D.ef_round(efc, grads, st, None)
        outs[carrier] = (g_est, st2)
    for key in ("w", "b"):
        np.testing.assert_allclose(outs["dense"][0][key],
                                   outs["sparse"][0][key], rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(outs["dense"][1]["clients"]["g"][key]),
            np.asarray(outs["sparse"][1]["clients"]["g"][key]), rtol=1e-5)


def test_uncompressed_round_equals_mean_grad(setup):
    params, batch = setup
    _, _, grads = D.per_client_value_and_grad(loss_fn, params, batch, 4)
    efc = D.EFConfig(method=ef.SGD())
    st = D.init_ef_state(efc, params, 4)
    g_est, _ = D.ef_round(efc, grads, st, None)
    np.testing.assert_allclose(g_est["w"], grads["w"].mean(0), rtol=1e-6)


@pytest.mark.parametrize("method_name,comp", [
    ("ef21_sgdm", C.TopK(ratio=0.3)),
    ("ef21_sgd2m", C.BlockTopK(block=8, k_per_block=3)),
    ("ef14_sgd", C.TopK(ratio=0.3)),
    ("sgdm", C.Identity()),
])
def test_train_step_converges(setup, method_name, comp):
    params, batch = setup
    dp = 4
    kwargs = {"compressor": comp}
    if method_name in ("ef21_sgdm", "ef21_sgd2m", "sgdm"):
        kwargs["eta"] = 0.3
    method = ef.make(method_name, **kwargs)
    efc = D.EFConfig(method=method)
    opt = opt_lib.sgd(0.2)
    step = jax.jit(D.make_train_step(loss_fn, efc, opt, dp))
    _, _, g0 = D.per_client_value_and_grad(loss_fn, params, batch, dp)
    p, os_, es = params, opt.init(params), D.init_ef_state(
        efc, params, dp, init_grads=g0)
    rng = jax.random.PRNGKey(1)
    losses = []
    for t in range(150):
        p, os_, es, m = step(p, os_, es, batch, jax.random.fold_in(rng, t), t)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.1 * losses[0], (method_name, losses[0], losses[-1])


def test_ef_state_b_init(setup):
    params, batch = setup
    _, _, g0 = D.per_client_value_and_grad(loss_fn, params, batch, 4)
    efc = D.EFConfig(method=ef.EF21SGDM(compressor=C.Identity()))
    st = D.init_ef_state(efc, params, 4, init_grads=g0)
    np.testing.assert_allclose(st["clients"]["v"]["w"], g0["w"])
    np.testing.assert_allclose(st["server"]["w"], g0["w"].mean(0), rtol=1e-6)
