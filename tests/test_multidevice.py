"""Multi-device semantics tests, run in a subprocess so the 8-device placeholder
flag never leaks into the main test session (spec: smoke tests see 1 device)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import compressors as C, distributed as D, ef
    from repro.launch import mesh as mesh_lib

    mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
    dp = 4
    params = {"w": jnp.zeros((8, 4))}
    rng = jax.random.PRNGKey(0)
    grads = jax.random.normal(rng, (dp, 8, 4))
    grads_t = {"w": grads}

    method = ef.EF21SGDM(compressor=C.BlockTopK(block=4, k_per_block=2), eta=0.3)
    # params replicated over 'model' so the per-shard compression domain equals
    # the per-client domain (model-sharded leaves use per-shard Block-TopK,
    # a *different but equally contractive* partition — not bit-identical)
    gspecs = {"w": P("data", None, None)}
    sspecs = {"clients": {k: {"w": P("data", None, None)} for k in ("v", "g")},
              "server": {"w": P(None, None)}}

    for carrier in ("dense", "sparse", "fused", "quant8", "quant4",
                    "fused_quant8", "fused_quant4"):
        efc = D.EFConfig(method=method, carrier=carrier, data_axes=("data",))
        st = D.init_ef_state(efc, params, dp, init_grads=grads_t)
        g_ref, st_ref = D.ef_round(efc, grads_t, st, None)
        with mesh_lib.mesh_context(mesh):
            g_sm, st_sm = jax.jit(lambda g, s: D.ef_round_sharded(
                efc, g, s, None, mesh, gspecs, sspecs))(grads_t, st)
        np.testing.assert_allclose(np.asarray(g_sm["w"]),
                                   np.asarray(g_ref["w"]), rtol=1e-5,
                                   atol=1e-7)
        np.testing.assert_allclose(
            np.asarray(st_sm["clients"]["g"]["w"]),
            np.asarray(st_ref["clients"]["g"]["w"]), rtol=1e-5, atol=1e-7)
        print(f"carrier={carrier} OK")

    # dense-quant payload (non-TopK compressor): the shard_map aggregate must
    # dequantize BEFORE the psum, and the Pallas encode_local must match the
    # vmap path's jnp oracle
    m_ht = ef.EF21SGDM(compressor=C.HardThreshold(lam=0.05), eta=0.3)
    for carrier in ("quant8", "quant4"):
        efc = D.EFConfig(method=m_ht, carrier=carrier, data_axes=("data",))
        st = D.init_ef_state(efc, params, dp, init_grads=grads_t)
        g_ref, _ = D.ef_round(efc, grads_t, st, None)
        with mesh_lib.mesh_context(mesh):
            g_sm, _ = jax.jit(lambda g, s: D.ef_round_sharded(
                efc, g, s, None, mesh, gspecs, sspecs))(grads_t, st)
        np.testing.assert_allclose(np.asarray(g_sm["w"]),
                                   np.asarray(g_ref["w"]), rtol=1e-5,
                                   atol=1e-7)
        print(f"dense-quant {carrier} OK")

    # wire_is_msg=False on the sharded dense plan: the server must receive the
    # method's MESSAGE (γ·c for Abs), not the raw compressed tensor c
    m_abs = ef.EF21SGDMAbs(compressor=C.HardThreshold(lam=1e-3), eta=0.3,
                           gamma=0.1)
    efc = D.EFConfig(method=m_abs, carrier="dense", data_axes=("data",))
    st = D.init_ef_state(efc, params, dp, init_grads=grads_t)
    g_ref, _ = D.ef_round(efc, grads_t, st, None)
    with mesh_lib.mesh_context(mesh):
        g_sm, _ = jax.jit(lambda g, s: D.ef_round_sharded(
            efc, g, s, None, mesh, gspecs, sspecs))(grads_t, st)
    np.testing.assert_allclose(np.asarray(g_sm["w"]), np.asarray(g_ref["w"]),
                               rtol=1e-5)
    print("abs-method message aggregation OK")

    # downlink broadcast on the shard_map path: every device encodes the
    # replicated server innovation (that IS the broadcast — the encoded wire
    # is what travels) and decodes per-client. Proven against the vmap
    # oracle over a sampled (method x uplink x downlink) grid, including
    # both server modes and the fused uplink.
    down_btk = C.BlockTopK(block=4, k_per_block=2)
    grid = [
        ("ef21_sgdm", "dense",  "quant4", down_btk),
        ("ef21_sgdm", "sparse", "quant8", down_btk),
        ("ef21_sgdm", "quant4", "sparse", down_btk),
        ("ef21_sgd",  "fused",  "quant4", down_btk),
        ("ef14_sgd",  "dense",  "sparse", down_btk),
        ("ef21_sgdm", "dense",  "dense",  C.HardThreshold(lam=0.05)),
    ]
    for m_name, up, down, dcomp in grid:
        kwargs = {"compressor": C.BlockTopK(block=4, k_per_block=2)}
        if m_name == "ef21_sgdm":
            kwargs["eta"] = 0.3
        m = ef.make(m_name, **kwargs)
        efc = D.EFConfig(method=m, carrier=up, data_axes=("data",),
                         down_carrier=down, down_compressor=dcomp)
        st = D.init_ef_state(efc, params, dp, init_grads=grads_t)
        assert "h" in st
        g_ref, st_ref = D.ef_round(efc, grads_t, st, None)
        sspecs_d = {"clients": {k: {"w": P("data", None, None)}
                                for k in st["clients"]},
                    "server": {"w": P(None, None)},
                    "h": {"w": P(None, None)}}
        with mesh_lib.mesh_context(mesh):
            g_sm, st_sm = jax.jit(lambda g, s: D.ef_round_sharded(
                efc, g, s, None, mesh, gspecs, sspecs_d))(grads_t, st)
        np.testing.assert_allclose(np.asarray(g_sm["w"]),
                                   np.asarray(g_ref["w"]), rtol=1e-5,
                                   atol=1e-7)
        np.testing.assert_allclose(np.asarray(st_sm["h"]["w"]),
                                   np.asarray(st_ref["h"]["w"]), rtol=1e-5,
                                   atol=1e-7)
        # the estimate every device steps with IS its broadcast memory
        np.testing.assert_allclose(np.asarray(g_sm["w"]),
                                   np.asarray(st_sm["h"]["w"]), rtol=0,
                                   atol=0)
        print(f"downlink {m_name}/{up}->{down} OK")
    print("MULTIDEVICE_OK")
""")


def test_shardmap_ef_round_matches_vmap_path():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "MULTIDEVICE_OK" in out.stdout, out.stdout + out.stderr
