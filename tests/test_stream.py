"""Wire-transport tests (core/stream.py): record round-trip through the npz
log, idempotent-vs-conflicting republish, gap/partial-step/out-of-order/
foreign-spec refusal — the integrity rules that keep a replica from ever
serving silently-drifted weights. Session-level streaming (publisher verify,
bit-identity, resync) lives in test_fleet.py."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stream as stream_lib
from repro.core.stream import (StreamGapError, StreamIntegrityError,
                               StreamOrderError, StreamSpecMismatch,
                               WireLog, WireRecord)
from repro.optim import optimizer as opt_lib

HASH = "deadbeef"


def _rec(step=1, group="*", gi=0, n=1, kind="dense", payload=None,
         spec_hash=HASH):
    if payload is None:
        rng = np.random.RandomState(step * 7 + gi)
        payload = (rng.randn(6).astype(np.float32),
                   (rng.randint(-8, 8, 12).astype(np.int8),
                    rng.randn(3).astype(np.float32)))
    return WireRecord(step=step, spec_hash=spec_hash, group=group,
                      group_index=gi, n_records=n, kind=kind,
                      payload=payload)


# ---------------------------------------------------------------------------
# log round-trip + republish semantics
# ---------------------------------------------------------------------------

def test_record_roundtrip_preserves_bits_and_structure(tmp_path):
    """Bare arrays and tuple-of-component payloads (quant wires carry
    (q, scales[, idx])) come back bit-identical with dtypes intact."""
    log = WireLog(str(tmp_path))
    rec = _rec(kind="delta")
    assert log.append(rec) is True
    got = log.read(1, 0)
    assert stream_lib.records_equal(rec, got)
    assert isinstance(got.payload[0], np.ndarray)
    assert isinstance(got.payload[1], tuple)
    assert got.payload[1][0].dtype == np.int8
    assert stream_lib.record_nbytes(got) == stream_lib.record_nbytes(rec)


def test_roundtrip_extension_dtype_bf16(tmp_path):
    """bfloat16 payloads (ef-state-dtype runs) survive the f32 npz detour
    losslessly — the checkpoint.py extension-dtype idiom."""
    log = WireLog(str(tmp_path))
    arr = jnp.asarray(np.random.RandomState(0).randn(16),
                      dtype=jnp.bfloat16)
    rec = _rec(payload=(np.asarray(arr),))
    log.append(rec)
    got = log.read(1, 0)
    assert got.payload[0].dtype == arr.dtype
    assert np.array_equal(np.asarray(got.payload[0]).view(np.uint16),
                          np.asarray(arr).view(np.uint16))


def test_append_is_idempotent_but_refuses_conflicts(tmp_path):
    """Kill-and-resume republish: a bit-identical re-append is a no-op; a
    record with the same (step, group) but different bits would fork the
    stream and must raise."""
    log = WireLog(str(tmp_path))
    rec = _rec()
    assert log.append(rec) is True
    assert log.append(rec) is False          # republish: no-op
    evil = _rec(payload=(np.zeros(6, np.float32),
                         (np.zeros(12, np.int8), np.zeros(3, np.float32))))
    with pytest.raises(StreamIntegrityError):
        log.append(evil)
    # the original bits survived the refused overwrite
    assert stream_lib.records_equal(log.read(1, 0), rec)


def test_missing_record_raises_gap(tmp_path):
    log = WireLog(str(tmp_path))
    log.append(_rec(step=1))
    with pytest.raises(StreamGapError):
        log.read(2, 0)
    with pytest.raises(StreamGapError):
        log.read_step(2)


def test_partial_step_refused_and_hidden_from_last_step(tmp_path):
    """A writer killed between the group files of one step leaves a partial
    record set: read_step must refuse it and last_step must not surface it —
    a half-published step applied would drift every subscriber."""
    log = WireLog(str(tmp_path))
    for gi in range(2):
        log.append(_rec(step=1, gi=gi, n=2, group=f"g{gi}"))
    log.append(_rec(step=2, gi=0, n=2, group="g0"))   # g1 never landed
    assert len(log.read_step(1)) == 2
    with pytest.raises(StreamIntegrityError):
        log.read_step(2)
    assert log.last_step() == 1


def test_tmp_partials_are_never_listed(tmp_path):
    """The atomic-write idiom: *.tmp.npz litter from a killed writer is
    invisible to the listing."""
    log = WireLog(str(tmp_path))
    log.append(_rec(step=1))
    os.makedirs(log.records_dir, exist_ok=True)
    with open(os.path.join(log.records_dir, "xyz.tmp.npz"), "wb") as f:
        f.write(b"garbage")
    assert log.steps() == [1]
    assert log.last_step() == 1


def test_unknown_schema_refused(tmp_path):
    log = WireLog(str(tmp_path))
    log.append(_rec(step=1))
    path = log.record_path(1, 0)
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    meta = b'{"stream": "wire/v999"}'
    flat["__meta__"] = np.frombuffer(meta, dtype=np.uint8)
    np.savez(path, **flat)
    with pytest.raises(StreamIntegrityError):
        log.read(1, 0)


def test_bootstrap_listing_and_upto(tmp_path):
    log = WireLog(str(tmp_path))
    os.makedirs(log.bootstrap_dir, exist_ok=True)
    for s in (0, 4, 8):
        with open(log.bootstrap_path(s), "wb") as f:
            f.write(b"x")
    assert log.bootstrap_steps() == [0, 4, 8]
    assert log.latest_bootstrap() == log.bootstrap_path(8)
    assert log.latest_bootstrap(upto=5) == log.bootstrap_path(4)
    assert log.latest_bootstrap(upto=-1) is None


# ---------------------------------------------------------------------------
# subscriber state machine (dense transport — no carrier needed)
# ---------------------------------------------------------------------------

def _dense_world():
    params = {"w": jnp.arange(4, dtype=jnp.float32),
              "b": jnp.ones(2, dtype=jnp.float32)}
    legs = stream_lib.resolve_legs(params)          # one dense leg, no h
    opt = opt_lib.make("sgd", lr=0.5)
    return params, legs, opt


def _dense_rec(step, params, scale=1.0):
    leaves = [np.asarray(x, np.float32) * scale
              for x in jax.tree_util.tree_leaves(params)]
    return WireRecord(step=step, spec_hash=HASH, group="*", group_index=0,
                      n_records=1, kind="dense", payload=tuple(leaves))


def test_subscriber_applies_dense_record_through_optimizer():
    """A dense record IS g_est: applying it must equal one
    optimizer.update + apply_updates at the pre-increment step."""
    params, legs, opt = _dense_world()
    sub = stream_lib.Subscriber(WireLog("/nonexistent"), HASH, legs,
                                params, opt.init(params), None, 0, opt)
    rec = _dense_rec(1, params)
    sub.apply([rec])
    assert sub.step == 1
    g_est = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params),
        [jnp.asarray(x) for x in rec.payload])
    updates, _ = opt.update(g_est, opt.init(params), params, 0)
    want = opt_lib.apply_updates(params, updates)
    for a, b in zip(jax.tree_util.tree_leaves(sub.params),
                    jax.tree_util.tree_leaves(want)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_subscriber_refuses_out_of_order():
    params, legs, opt = _dense_world()
    sub = stream_lib.Subscriber(WireLog("/nonexistent"), HASH, legs,
                                params, opt.init(params), None, 0, opt)
    with pytest.raises(StreamOrderError):
        sub.apply([_dense_rec(3, params)])       # skipping 1..2 would drift
    sub.apply([_dense_rec(1, params)])
    with pytest.raises(StreamOrderError):
        sub.apply([_dense_rec(1, params)])       # replay of an applied step
    assert sub.step == 1


def test_subscriber_refuses_foreign_spec_hash():
    params, legs, opt = _dense_world()
    sub = stream_lib.Subscriber(WireLog("/nonexistent"), HASH, legs,
                                params, opt.init(params), None, 0, opt)
    rec = _dense_rec(1, params)
    foreign = WireRecord(**{**rec.__dict__, "spec_hash": "cafebabe"})
    with pytest.raises(StreamSpecMismatch):
        sub.apply([foreign])


def test_subscriber_refuses_wrong_kind_and_group_set():
    params, legs, opt = _dense_world()
    sub = stream_lib.Subscriber(WireLog("/nonexistent"), HASH, legs,
                                params, opt.init(params), None, 0, opt)
    rec = _dense_rec(1, params)
    with pytest.raises(StreamIntegrityError):
        sub.apply([WireRecord(**{**rec.__dict__, "kind": "delta"})])
    with pytest.raises(StreamIntegrityError):
        sub.apply([WireRecord(**{**rec.__dict__, "group_index": 7})])


def test_subscriber_sync_walks_the_log_and_stops_at_gap(tmp_path):
    params, legs, opt = _dense_world()
    log = WireLog(str(tmp_path))
    for s in (1, 2, 4):                          # 3 is the gap
        log.append(_dense_rec(s, params, scale=0.1 * s))
    sub = stream_lib.Subscriber(log, HASH, legs, params,
                                opt.init(params), None, 0, opt)
    assert sub.sync(upto=2) == 2
    assert sub.step == 2
    with pytest.raises(StreamGapError):
        sub.sync()                               # needs 3, only 4 exists
    assert sub.step == 2                         # still consistent, not drifted
