# NOTE: no XLA_FLAGS here — smoke tests and benches must see exactly ONE device
# (the 512-device placeholder mesh belongs to launch/dryrun.py only).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
