# NOTE: no XLA_FLAGS here — smoke tests and benches must see exactly ONE device
# (the 512-device placeholder mesh belongs to launch/dryrun.py only).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running convergence/e2e tests — excluded from the PR "
        "gate (`pytest -m tier1`), run in full on main")
    config.addinivalue_line(
        "markers",
        "tier1: fast PR-gating tier, auto-applied to every test not marked "
        "slow (never set it by hand)")


def pytest_collection_modifyitems(config, items):
    # tier1 := not slow, maintained automatically so new tests default into
    # the PR gate and only deliberate `slow` marks opt out
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(scope="session")
def step_cache():
    """Session-scoped memo for jitted train steps. Tests that sweep carriers
    re-trace the same production step dozens of times; compiling once per
    configuration cuts minutes off the suite. Entries are jitted callables —
    pure, so sharing across tests is safe PROVIDED the key includes
    everything the cached step closes over: the loss function, the optimizer
    config, the method, the carrier, and dp (see tests/test_carriers.py
    ``_trajectory`` for the canonical keying)."""
    return {}
