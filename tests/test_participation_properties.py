"""Seeded-sampling property tests for core/participation.py.

Deterministic grid always runs; hypothesis fuzzers widen the same
properties when the library is installed (mirrors
test_carrier_properties.py). Everything here is jax-free-adjacent: the
numpy mirror cohort_mask_np is the oracle, and the jax cohort_mask must
agree with it bit-for-bit so spec previews, tests, and the traced train
step can never disagree about who was sampled.
"""
import json

import numpy as np
import pytest

from repro.core import participation as part_lib
from repro.launch import session as session_lib
from repro.launch import spec as spec_lib

try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    settings.register_profile("participation", max_examples=10, deadline=None)
    settings.load_profile("participation")
except ImportError:
    HAVE_HYPOTHESIS = False

fuzz = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis not installed; the deterministic grid ran")


def _mask_seq(part, n, rounds):
    return np.stack([part_lib.cohort_mask_np(part, n, t)
                     for t in range(rounds)])


# ---------------------------------------------------------------------------
# deterministic grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,fraction", [
    (4, 0.25), (4, 0.5), (8, 0.5), (8, 0.125), (16, 0.75), (5, 0.4),
])
def test_empirical_frequency_matches_fraction(n, fraction):
    """Over many rounds every client is sampled ≈ m/n of the time — the
    without-replacement permutation sampler is unbiased per client."""
    part = part_lib.Participation(mode="sampled", fraction=fraction, seed=11)
    rounds = 2000
    masks = _mask_seq(part, n, rounds)
    m = part.cohort_size(n)
    assert all(row.sum() == m for row in masks)      # exact cohort size
    freq = masks.mean(axis=0)
    np.testing.assert_allclose(freq, m / n, atol=0.05)


def test_same_seed_same_cohort_sequence():
    a = part_lib.Participation(mode="sampled", fraction=0.5, seed=42)
    b = part_lib.Participation(mode="sampled", fraction=0.5, seed=42)
    assert np.array_equal(_mask_seq(a, 8, 50), _mask_seq(b, 8, 50))


def test_disjoint_seeds_decorrelate():
    """Different seeds give genuinely different cohort sequences (not a
    shifted copy): the per-round masks disagree somewhere, and the match
    rate across rounds is far from 1."""
    a = _mask_seq(part_lib.Participation("sampled", 0.5, seed=1), 8, 200)
    b = _mask_seq(part_lib.Participation("sampled", 0.5, seed=2), 8, 200)
    same_rows = np.mean([np.array_equal(x, y) for x, y in zip(a, b)])
    assert same_rows < 0.5


def test_jax_mask_matches_numpy_mirror():
    """cohort_mask (jax, traced into the train step) and cohort_mask_np
    (numpy, used by previews/tests) are the same function."""
    for n in (2, 4, 7, 16):
        for frac in (0.25, 0.5, 1.0):
            part = part_lib.Participation("sampled", frac, seed=5)
            for t in range(8):
                got = np.asarray(part_lib.cohort_mask(part, n, t))
                want = part_lib.cohort_mask_np(part, n, t)
                assert np.array_equal(got, want), (n, frac, t)


def test_fraction_one_mask_is_all_ones():
    part = part_lib.Participation("sampled", 1.0, seed=9)
    for t in range(5):
        assert part_lib.cohort_mask_np(part, 6, t).all()


def test_cohort_masks_roundtrip_through_spec_json():
    """RunSpec → JSON → RunSpec → Participation reproduces the exact
    per-round cohort masks: participation is fully pinned by the spec."""
    spec = spec_lib.RunSpec(
        arch="smollm-360m", smoke=True, clients=8, global_batch=16,
        seq_len=32,
        participation={"mode": "sampled", "fraction": 0.375, "seed": 13})
    back = spec_lib.RunSpec.from_json(spec.to_json())
    assert back.participation == spec.participation
    p0 = session_lib.make_participation(spec)
    p1 = session_lib.make_participation(back)
    assert p0 == p1
    assert np.array_equal(_mask_seq(p0, spec.clients, 40),
                          _mask_seq(p1, back.clients, 40))
    pv = spec_lib.participation_preview(back)
    assert pv["cohort"] == p1.cohort_size(spec.clients)


@pytest.mark.parametrize("n", [1, 2, 3, 4, 8, 17, 64])
@pytest.mark.parametrize("fraction", [0.1, 0.25, 0.5, 0.9, 1.0])
def test_cohort_size_matches_spec_preview(n, fraction):
    part = part_lib.Participation("sampled", fraction, seed=0)
    spec = spec_lib.RunSpec(
        arch="smollm-360m", smoke=True, clients=n,
        global_batch=max(2 * n, 4), seq_len=32,
        participation={"mode": "sampled", "fraction": fraction})
    assert part.cohort_size(n) == spec_lib.participation_preview(spec)["cohort"]
    assert 1 <= part.cohort_size(n) <= n


def test_validation_errors():
    with pytest.raises(ValueError):
        part_lib.Participation(mode="bogus")
    with pytest.raises(ValueError):
        part_lib.Participation(mode="sampled", fraction=0.0)
    with pytest.raises(ValueError):
        part_lib.Participation(mode="sampled", fraction=1.5)
    with pytest.raises(ValueError):
        part_lib.ArrivalModel(kind="bogus")
    with pytest.raises(ValueError):
        part_lib.ArrivalModel(kind="dropout", drop_prob=1.0)
    with pytest.raises(ValueError):
        part_lib.ArrivalModel(kind="heavy_tail", alpha=1.0)


def test_flag_grammar_roundtrip():
    for flag in ("sampled", "sampled:0.25", "sampled:0.25:7", "async:0.5:3"):
        d = spec_lib.parse_participation_flag(flag)
        assert spec_lib.format_participation_flag(d) == flag
    with pytest.raises(ValueError):
        spec_lib.parse_participation_flag("sampled:0.25:7:9")
    with pytest.raises(ValueError):
        spec_lib.parse_participation_flag("")
    # the JSON escape hatch covers dicts the colon grammar can't print
    d = spec_lib.parse_participation_flag('{"mode": "sampled", "seed": 3}')
    assert d == {"mode": "sampled", "seed": 3}
    flag = spec_lib.format_participation_flag(d)
    assert json.loads(flag) == d


# ---------------------------------------------------------------------------
# hypothesis fuzzers — same properties, wider input space
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @fuzz
    @given(n=st.integers(1, 64),
           fraction=st.floats(0.01, 1.0, allow_nan=False),
           seed=st.integers(0, 2**31 - 1),
           step=st.integers(0, 10_000))
    def test_fuzz_mask_invariants(n, fraction, seed, step):
        part = part_lib.Participation("sampled", fraction, seed)
        mask = part_lib.cohort_mask_np(part, n, step)
        assert mask.shape == (n,)
        assert set(np.unique(mask)) <= {0.0, 1.0}
        assert mask.sum() == part.cohort_size(n)
        assert 1 <= part.cohort_size(n) <= n
        # replay determinism at arbitrary (seed, step)
        assert np.array_equal(mask, part_lib.cohort_mask_np(part, n, step))

    @fuzz
    @given(n=st.integers(2, 32),
           fraction=st.floats(0.1, 1.0, allow_nan=False),
           seed=st.integers(0, 2**31 - 1))
    def test_fuzz_jax_numpy_agree(n, fraction, seed):
        part = part_lib.Participation("sampled", fraction, seed)
        for t in (0, 1, 17):
            assert np.array_equal(
                np.asarray(part_lib.cohort_mask(part, n, t)),
                part_lib.cohort_mask_np(part, n, t))

    @fuzz
    @given(fraction=st.floats(0.1, 1.0, allow_nan=False),
           seed=st.integers(0, 2**16))
    def test_fuzz_spec_json_roundtrip(fraction, seed):
        d = {"mode": "sampled", "fraction": fraction, "seed": seed}
        spec = spec_lib.RunSpec(arch="smollm-360m", smoke=True, clients=4,
                                global_batch=8, seq_len=32, participation=d)
        back = spec_lib.RunSpec.from_json(spec.to_json())
        assert back.participation == d
        assert np.array_equal(
            _mask_seq(session_lib.make_participation(spec), 4, 10),
            _mask_seq(session_lib.make_participation(back), 4, 10))
