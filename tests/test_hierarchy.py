"""Two-tier hierarchical EF aggregation (DESIGN.md §13).

The load-bearing anchor is FLAT EQUIVALENCE: a pods=P topology with a
trivial cross hop (dense carrier, identity compressor) must be
BIT-IDENTICAL to the flat run on every runtime — the hierarchy is pure
bookkeeping until a non-trivial cross carrier is configured. On top of
that: the per-pod EF memory exists exactly when the topology is
hierarchical, a real cross hop changes the trajectory, the sharded
runtime matches the vmap oracle, kill-and-resume restores the pod
memories bit-exactly, and the jax-free spec preview / launch-layer
builders mirror core/hierarchy.py semantics.

The sharded checks run in a subprocess (forced 8 host devices) so the
XLA flag never leaks into the main test session — the same pattern as
tests/test_multidevice.py.
"""
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# pure topology knob (no jax compute)
# ---------------------------------------------------------------------------

def test_hops_knob_effective_and_trivial_cross():
    from repro.core import compressors as comp_lib
    from repro.core import hierarchy as hier_lib

    # pods <= 1 normalizes to None: callers gate ALL machinery on it
    assert hier_lib.effective(None) is None
    assert hier_lib.effective(hier_lib.Hops(pods=1)) is None
    assert hier_lib.effective(hier_lib.Hops(
        pods=1, cross_carrier="quant4")) is None
    h2 = hier_lib.Hops(pods=2)
    assert hier_lib.effective(h2) is h2

    # trivial cross = dense carrier AND identity compressor — either a
    # non-dense carrier or a real compressor makes the hop lossy
    assert hier_lib.Hops(pods=2).trivial_cross
    assert not hier_lib.Hops(pods=2, cross_carrier="quant4").trivial_cross
    assert not hier_lib.Hops(
        pods=2, cross_compressor=comp_lib.TopK(ratio=0.5)).trivial_cross

    # frozen → hashable (lives inside jit-static EFConfig/SimConfig)
    assert hash(h2) == hash(hier_lib.Hops(pods=2))

    hier_lib.check_pods(hier_lib.Hops(pods=2), 8)
    with pytest.raises(ValueError, match="must divide"):
        hier_lib.check_pods(hier_lib.Hops(pods=3), 8)


def test_mesh_portability_shrink_and_pod_major_client_axes():
    """The production multi_pod shape fits any device count pod-major —
    (2,16,16) on 8 devices keeps both pods — and ``client_axes`` is
    ('pod', 'data') regardless of the mesh's own axis order (both runtimes
    must agree on who is in which pod)."""
    from repro.launch import mesh as mesh_lib

    assert mesh_lib._shrink_shape((2, 16, 16), 512) == (2, 16, 16)
    assert mesh_lib._shrink_shape((2, 16, 16), 8) == (2, 4, 1)
    assert mesh_lib._shrink_shape((2, 16, 16), 6) == (2, 3, 1)
    assert mesh_lib._shrink_shape((2, 16, 16), 1) == (1, 1, 1)
    assert mesh_lib._shrink_shape((16, 16), 8) == (8, 1)

    # pod-major independent of axis order; pod-less meshes are untouched
    assert mesh_lib.client_axes(
        SimpleNamespace(axis_names=("pod", "data", "model"))) \
        == ("pod", "data")
    assert mesh_lib.client_axes(
        SimpleNamespace(axis_names=("data", "pod", "model"))) \
        == ("pod", "data")
    assert mesh_lib.client_axes(
        SimpleNamespace(axis_names=("data", "model"))) == ("data",)
    assert mesh_lib.data_axes(
        SimpleNamespace(axis_names=("data", "pod"))) == ("pod", "data")


# ---------------------------------------------------------------------------
# vmap runtime: flat equivalence grid + real cross hop
# ---------------------------------------------------------------------------

def _vmap_fixture():
    """Params, a fresh-grads-per-round generator, and a method. The grads
    MUST change between rounds: with ``b_init_scale`` the round-0 EF
    innovation of a constant-grads stream is identically zero and every
    topology trivially agrees — fresh draws keep the innovations live."""
    import jax
    import jax.numpy as jnp
    from repro.core import compressors as C, ef

    params = {"w": jnp.zeros((8, 4))}
    grads_at = lambda i: {"w": jax.random.normal(  # noqa: E731
        jax.random.PRNGKey(100 + i), (8, 8, 4))}
    method = ef.EF21SGDM(compressor=C.BlockTopK(block=4, k_per_block=2),
                         eta=0.3)
    return params, grads_at, method


def test_vmap_trivial_cross_is_bit_identical_to_flat():
    """pods=2 with a trivial cross must emit the flat results EXACTLY
    (np.array_equal, not allclose) for every uplink carrier, and the pod
    memories must satisfy the transparent-aggregator invariant t == b."""
    import jax
    from repro.core import distributed as D
    from repro.core import hierarchy as hier_lib

    params, grads_at, method = _vmap_fixture()
    for carrier in ("dense", "sparse", "quant8", "quant4"):
        flat = D.EFConfig(method=method, carrier=carrier)
        hier = D.EFConfig(method=method, carrier=carrier,
                          hops=hier_lib.Hops(pods=2))
        st_f = D.init_ef_state(flat, params, 8, init_grads=grads_at(0))
        st_h = D.init_ef_state(hier, params, 8, init_grads=grads_at(0))
        assert "pods" not in st_f and "pods" in st_h
        assert st_h["pods"]["t"]["w"].shape == (2, 8, 4)
        for i in range(1, 4):
            g_f, st_f = D.ef_round(flat, grads_at(i), st_f, None)
            g_h, st_h = D.ef_round(hier, grads_at(i), st_h, None)
        assert float(np.abs(np.asarray(g_f["w"])).max()) > 0
        assert np.array_equal(np.asarray(g_f["w"]), np.asarray(g_h["w"])), \
            f"carrier={carrier}: trivial-cross pods=2 drifted from flat"
        for k in st_f:
            for lf, lh in zip(jax.tree_util.tree_leaves(st_f[k]),
                              jax.tree_util.tree_leaves(st_h[k])):
                assert np.array_equal(np.asarray(lf), np.asarray(lh)), \
                    f"carrier={carrier}: state[{k!r}] drifted"
        np.testing.assert_array_equal(np.asarray(st_h["pods"]["t"]["w"]),
                                      np.asarray(st_h["pods"]["b"]["w"]))


def test_vmap_nontrivial_cross_changes_trajectory_and_keeps_pod_memory():
    """A quant4 cross hop must actually change the server estimate, and the
    pod broadcast state b must track the cross-hop decode (b != t once the
    hop is lossy); pods=4 exercises non-binary pod counts."""
    import jax
    from repro.core import compressors as C
    from repro.core import distributed as D
    from repro.core import hierarchy as hier_lib

    params, grads_at, method = _vmap_fixture()
    rng = jax.random.PRNGKey(7)
    flat = D.EFConfig(method=method, carrier="dense")
    for pods in (2, 4):
        hops = hier_lib.Hops(pods=pods, cross_carrier="quant4",
                             cross_compressor=C.BlockTopK(block=4,
                                                          k_per_block=2))
        efc = D.EFConfig(method=method, carrier="dense", hops=hops)
        st_f = D.init_ef_state(flat, params, 8, init_grads=grads_at(0))
        st = D.init_ef_state(efc, params, 8, init_grads=grads_at(0))
        assert st["pods"]["t"]["w"].shape == (pods, 8, 4)
        g_f, _ = D.ef_round(flat, grads_at(1), st_f, rng)
        g_h, st1 = D.ef_round(efc, grads_at(1), st, rng)
        assert not np.array_equal(np.asarray(g_f["w"]), np.asarray(g_h["w"]))
        assert not np.array_equal(np.asarray(st1["pods"]["t"]["w"]),
                                  np.asarray(st1["pods"]["b"]["w"])), \
            "a lossy cross hop cannot keep b == t"
        # second round must consume the pod memory without shape drift
        _, st2 = D.ef_round(efc, grads_at(2), st1, rng)
        assert st2["pods"]["b"]["w"].shape == (pods, 8, 4)


def test_vmap_absolute_mode_flat_equivalence():
    """The pod algebra has a distinct absolute-mode branch (t' = u_p,
    g = mean_p(b')) — pin its flat equivalence separately from delta."""
    import jax.numpy as jnp
    from repro.core import compressors as C, ef
    from repro.core import distributed as D
    from repro.core import hierarchy as hier_lib

    params, grads_at, _ = _vmap_fixture()
    method = ef.make("ef14_sgd",
                     compressor=C.BlockTopK(block=4, k_per_block=2))
    assert method.mode == "absolute"
    flat = D.EFConfig(method=method, carrier="dense")
    hier = D.EFConfig(method=method, carrier="dense",
                      hops=hier_lib.Hops(pods=2))
    st_f = D.init_ef_state(flat, params, 8, init_grads=grads_at(0))
    st_h = D.init_ef_state(hier, params, 8, init_grads=grads_at(0))
    for i in range(1, 3):
        g_f, st_f = D.ef_round(flat, grads_at(i), st_f, None)
        g_h, st_h = D.ef_round(hier, grads_at(i), st_h, None)
    assert np.array_equal(np.asarray(g_f["w"]), np.asarray(g_h["w"]))


# ---------------------------------------------------------------------------
# simulator: anchors + per-hop wire accounting
# ---------------------------------------------------------------------------

def test_simulator_flat_equivalence_and_cross_accounting():
    import jax
    from repro.core import compressors as comp_lib
    from repro.core import ef as ef_lib
    from repro.core import hierarchy as hier_lib
    from repro.core import problems, simulate

    prob = problems.QuadraticT1()
    method = ef_lib.make("ef21_sgdm", compressor=comp_lib.TopK(ratio=0.25),
                         eta=0.3)
    rng = jax.random.PRNGKey(0)
    base = dict(n=8, gamma=1e-3, steps=8, carrier="dense")
    flat = simulate.run(prob, method, simulate.SimConfig(**base), rng)
    triv = simulate.run(prob, method, simulate.SimConfig(
        **base, hops=hier_lib.Hops(pods=2)), rng)
    hops = hier_lib.Hops(pods=2, cross_carrier="quant4",
                         cross_compressor=comp_lib.TopK(ratio=0.25))
    q4 = simulate.run(prob, method, simulate.SimConfig(**base, hops=hops),
                      rng)

    np.testing.assert_array_equal(np.asarray(flat["grad_norm_sq"]),
                                  np.asarray(triv["grad_norm_sq"]))
    assert not np.array_equal(np.asarray(flat["grad_norm_sq"]),
                              np.asarray(q4["grad_norm_sq"]))

    # flat topology: the one client→server hop IS the cross-pod wire
    assert float(flat["wire_words_intra_per_round"]) == 0.0
    assert float(flat["wire_words_cross_per_round"]) \
        == float(flat["wire_words_up_per_round"])
    # hierarchical: n messages ride intra links, pods innovations cross
    assert float(q4["wire_words_intra_per_round"]) \
        == float(q4["wire_words_up_per_round"])
    expect = hier_lib.wire_words_cross(hops, None, method, prob.init_x())
    assert abs(float(q4["wire_words_cross_per_round"]) - float(expect)) \
        < 1e-6
    assert abs(float(q4["wire_words_total_per_round"])
               - (float(q4["wire_words_intra_per_round"])
                  + float(q4["wire_words_cross_per_round"])
                  + float(q4["wire_words_down_per_round"]))) < 1e-6
    # the cross hop is ONE message per pod — strictly cheaper than n
    # messages whenever pods < n
    assert float(q4["wire_words_cross_per_round"]) \
        < float(flat["wire_words_cross_per_round"])


def test_wire_words_cross_accepts_dim_or_tree():
    """benchmarks (roofline, hierarchy_bench) feed a raw int d; the
    simulator feeds the param tree — both must agree."""
    import jax.numpy as jnp
    from repro.core import compressors as comp_lib
    from repro.core import hierarchy as hier_lib

    hops = hier_lib.Hops(pods=2, cross_carrier="quant4",
                         cross_compressor=comp_lib.BlockTopK(block=64,
                                                             ratio=0.25))
    tree = {"a": jnp.zeros((16, 8)), "b": jnp.zeros((100,))}
    d = 16 * 8 + 100
    assert hier_lib.wire_words_cross(hops, None, None, d) \
        == hier_lib.wire_words_cross(hops, None, None, tree)
    # dense trivial cross ships the full target: d words per pod
    assert hier_lib.wire_words_cross(hier_lib.Hops(pods=2), None, None, d) \
        == 2.0 * d


# ---------------------------------------------------------------------------
# spec / launch layer mirrors core semantics
# ---------------------------------------------------------------------------

def test_spec_hops_grammar_roundtrip_and_preview_sync():
    from repro.core import carriers as carrier_lib
    from repro.core import hierarchy as hier_lib
    from repro.launch import spec as spec_lib

    h = spec_lib.parse_hops_flag("pods=2,cross=quant4:0.05")
    assert h == {"pods": 2, "cross_carrier": "quant4", "cross_ratio": 0.05}
    assert spec_lib.parse_hops_flag(spec_lib.format_hops_flag(h)) == h
    assert spec_lib.parse_hops_flag("pods=4") == {"pods": 4}
    with pytest.raises(ValueError, match="--hops"):
        spec_lib.parse_hops_flag("pods=2,foo=3")

    # every spec-accepted cross carrier must exist in the core registry,
    # and HOP_KEYS is exactly the Hops surface the launch layer maps
    assert spec_lib.HOP_KEYS == {"pods", "cross_carrier", "cross_ratio"}
    for name in spec_lib.CROSS_CARRIERS:
        assert carrier_lib.make(name).name  # fail fast on unknown names

    s = spec_lib.RunSpec(arch="smollm-360m", smoke=True, clients=8,
                         global_batch=8, seq_len=64, hops=h)
    hp = spec_lib.hops_preview(s)
    assert hp["pods"] == 2 and hp["hierarchical"]
    assert hp["clients_per_pod"] == 4
    # the jax-free trivial_cross predicate must mirror Hops.trivial_cross
    # (launch cross compressors are None exactly when the carrier is dense)
    assert hp["trivial_cross"] \
        == hier_lib.Hops(pods=2, cross_carrier="quant4").trivial_cross
    assert not hp["trivial_cross"]
    s_triv = spec_lib.RunSpec(arch="smollm-360m", smoke=True, clients=8,
                              global_batch=8, seq_len=64, hops={"pods": 2})
    assert spec_lib.hops_preview(s_triv)["trivial_cross"] \
        == hier_lib.Hops(pods=2).trivial_cross

    # invalid hop dicts never construct a RunSpec
    for bad in ({"pods": 0}, {"pods": 2, "cross_carrier": "nope"},
                {"pods": 2, "cross_ratio": 0.0}, {"podz": 2}):
        with pytest.raises(ValueError):
            spec_lib.RunSpec(arch="smollm-360m", smoke=True, clients=8,
                             global_batch=8, seq_len=64, hops=bad)


def test_make_hops_builds_core_topology_from_spec():
    from repro.launch import spec as spec_lib
    from repro.launch.session import make_hops

    mk = lambda **kw: spec_lib.RunSpec(  # noqa: E731
        arch="smollm-360m", smoke=True, clients=8, global_batch=8,
        seq_len=64, **kw)
    assert make_hops(mk()) is None
    assert make_hops(mk(hops={"pods": 1})) is None

    triv = make_hops(mk(hops={"pods": 2}))
    assert triv.pods == 2 and triv.trivial_cross
    assert triv.cross_compressor is None  # dense cross ships the target

    h = make_hops(mk(hops={"pods": 2, "cross_carrier": "quant4",
                           "cross_ratio": 0.05}))
    assert h.cross_carrier == "quant4" and not h.trivial_cross
    # the uplink compressor class re-budgeted to cross_ratio
    # (make_down_compressor rule applied to the pod→server hop)
    assert type(h.cross_compressor).__name__ == "BlockTopK"
    assert abs(h.cross_compressor.ratio - 0.05) < 1e-12
    # cross_ratio defaults to the spec's uplink ratio
    d = make_hops(mk(hops={"pods": 2, "cross_carrier": "quant4"},
                     ratio=0.02))
    assert abs(d.cross_compressor.ratio - 0.02) < 1e-12


def test_build_rejects_incompatible_hop_configs():
    from repro.core import hierarchy as hier_lib
    from repro.core import participation as part_lib
    from repro.launch import build
    from repro.launch import mesh as mesh_lib
    from repro.launch import shardings as sh

    mesh = mesh_lib.make_smoke_mesh()
    plan = sh.ShardPlan()
    hops = hier_lib.Hops(pods=2)
    # sanity: the valid construction goes through and carries the hops
    efc = build.default_ef_config(mesh, plan, hops=hops)
    assert efc.hops is hops
    with pytest.raises(ValueError, match="stacks two pod"):
        build.default_ef_config(
            mesh, sh.ShardPlan(client_granularity="pod"), hops=hops)
    with pytest.raises(ValueError, match="no stable pod membership"):
        build.default_ef_config(
            mesh, plan, hops=hops,
            participation=part_lib.Participation(mode="sampled",
                                                 fraction=0.5))
    with pytest.raises(ValueError, match="wire IS the global aggregation"):
        build.default_ef_config(mesh, plan, hops=hops,
                                carrier="fused_quant8")


# ---------------------------------------------------------------------------
# Session end to end (vmap path): flat equivalence + kill-and-resume
# ---------------------------------------------------------------------------

def test_session_flat_equivalence_and_kill_and_resume():
    """The production Session with --hops: trivial cross == flat bit-exact
    over real train steps, a quant4 cross diverges, and save→restore→step
    matches the uninterrupted run bit-for-bit INCLUDING the pod memories."""
    import tempfile

    import jax
    from repro.launch import spec as spec_lib
    from repro.launch.session import Session

    mk = lambda **kw: spec_lib.RunSpec(  # noqa: E731
        arch="smollm-360m", smoke=True, clients=8, global_batch=8,
        seq_len=64, **kw)
    s_q4 = mk(hops={"pods": 2, "cross_carrier": "quant4",
                    "cross_ratio": 0.05})

    def run(s, n=2):
        sess = Session(s)
        for _ in range(n):
            sess.step_once()
        return sess

    a, b, c = run(mk()), run(mk(hops={"pods": 2})), run(s_q4)
    pa = jax.tree_util.tree_leaves(a.params)
    pb = jax.tree_util.tree_leaves(b.params)
    pc = jax.tree_util.tree_leaves(c.params)
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(pa, pb)), "trivial-cross Session != flat"
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(pa, pc)), "quant4 cross left params flat"
    assert "pods" not in a.ef_state
    assert "pods" in b.ef_state and "pods" in c.ef_state
    assert jax.tree_util.tree_leaves(
        c.ef_state["pods"]["t"])[0].shape[0] == 2

    with tempfile.TemporaryDirectory() as d:
        ckpt = os.path.join(d, "ck.npz")
        c.save(ckpt)
        resumed = Session(s_q4)
        resumed.restore_from(ckpt)
        for x, y in zip(jax.tree_util.tree_leaves(c.ef_state),
                        jax.tree_util.tree_leaves(resumed.ef_state)):
            assert np.array_equal(np.asarray(x), np.asarray(y))
        m1, m2 = c.step_once(), resumed.step_once()
        assert np.array_equal(np.asarray(m1["loss"]),
                              np.asarray(m2["loss"]))
        for x, y in zip(jax.tree_util.tree_leaves(c.params),
                        jax.tree_util.tree_leaves(resumed.params)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                "resumed step diverged — pod memory not restored"


# ---------------------------------------------------------------------------
# sharded runtime (subprocess: 8 forced host devices)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import compressors as C, distributed as D, ef
    from repro.core import hierarchy as hier_lib
    from repro.launch import mesh as mesh_lib

    # --- direct runtime oracle: ef_round_sharded on a (pod,data,model)
    # mesh vs the vmap ef_round, pod-major client blocks on both
    mesh = mesh_lib.make_mesh((2, 2, 2), ("pod", "data", "model"))
    assert mesh_lib.client_axes(mesh) == ("pod", "data")
    dp = 4
    params = {"w": jnp.zeros((8, 4))}
    # init and round grads must DIFFER: with b_init_scale a constant
    # stream has zero innovation and every topology trivially agrees
    grads_0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (dp, 8, 4))}
    grads_t = {"w": jax.random.normal(jax.random.PRNGKey(1), (dp, 8, 4))}
    method = ef.EF21SGDM(compressor=C.BlockTopK(block=4, k_per_block=2),
                         eta=0.3)
    gspecs = {"w": P(("pod", "data"), None, None)}
    cl = {"w": P(("pod", "data"), None, None)}
    rep = {"w": P(None, None)}
    pod = {"w": P("pod", None, None)}

    def sharded(efc, st):
        sspecs = {"clients": {k: cl for k in st["clients"]},
                  "server": rep}
        if "pods" in st:
            sspecs["pods"] = {"t": pod, "b": pod}
        with mesh_lib.mesh_context(mesh):
            return jax.jit(lambda g, s: D.ef_round_sharded(
                efc, g, s, None, mesh, gspecs, sspecs))(grads_t, st)

    for carrier in ("dense", "sparse", "quant8"):
        flat = D.EFConfig(method=method, carrier=carrier,
                          data_axes=("pod", "data"))
        triv = D.EFConfig(method=method, carrier=carrier,
                          data_axes=("pod", "data"),
                          hops=hier_lib.Hops(pods=2))
        st_f = D.init_ef_state(flat, params, dp, init_grads=grads_0)
        st_t = D.init_ef_state(triv, params, dp, init_grads=grads_0)
        g_f, _ = sharded(flat, st_f)
        g_t, st_t2 = sharded(triv, st_t)
        assert np.array_equal(np.asarray(g_f["w"]), np.asarray(g_t["w"])), \\
            f"carrier={carrier}: sharded trivial-cross != sharded flat"
        print(f"sharded trivial {carrier} OK")

    hops = hier_lib.Hops(pods=2, cross_carrier="quant4",
                         cross_compressor=C.BlockTopK(block=4,
                                                      k_per_block=2))
    efc = D.EFConfig(method=method, carrier="dense",
                     data_axes=("pod", "data"), hops=hops)
    st = D.init_ef_state(efc, params, dp, init_grads=grads_0)
    g_ref, st_ref = D.ef_round(efc, grads_t, st, None)
    assert float(jnp.abs(g_ref["w"]).max()) > 0
    g_sm, st_sm = sharded(efc, st)
    np.testing.assert_allclose(np.asarray(g_sm["w"]),
                               np.asarray(g_ref["w"]), rtol=1e-5, atol=1e-7)
    for k in ("t", "b"):
        np.testing.assert_allclose(np.asarray(st_sm["pods"][k]["w"]),
                                   np.asarray(st_ref["pods"][k]["w"]),
                                   rtol=1e-5, atol=1e-7)
    print("sharded quant4 cross matches vmap oracle OK")

    # --- production launch path: the multi_pod mesh shrinks pod-major
    # onto 8 devices and the Session keeps flat equivalence end to end
    from repro.launch import spec as spec_lib
    from repro.launch.session import Session

    m = mesh_lib.make_production_mesh(multi_pod=True)
    assert dict(m.shape) == {"pod": 2, "data": 4, "model": 1}, dict(m.shape)
    assert dict(mesh_lib.make_production_mesh().shape) \\
        == {"data": 8, "model": 1}

    mk = lambda **kw: spec_lib.RunSpec(
        arch="smollm-360m", smoke=True, mesh="multi_pod", global_batch=32,
        seq_len=64, **kw)

    def run(s, n=2):
        sess = Session(s)
        for _ in range(n):
            sess.step_once()
        return sess

    a = run(mk())
    b = run(mk(hops={"pods": 2}))
    c = run(mk(hops={"pods": 2, "cross_carrier": "quant4",
                     "cross_ratio": 0.05}))
    pa, pb, pc = (jax.tree_util.tree_leaves(x.params) for x in (a, b, c))
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(pa, pb)), "sharded Session trivial != flat"
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(pa, pc)), "sharded Session q4 == flat?!"
    leaf = jax.tree_util.tree_leaves(c.ef_state["pods"]["t"])[0]
    assert leaf.shape[0] == 2
    assert leaf.sharding.spec[0] == "pod", leaf.sharding.spec
    print("HIERARCHY_SHARDED_OK")
""")


def test_sharded_hierarchy_matches_oracle_and_session_runs_multi_pod():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "HIERARCHY_SHARDED_OK" in out.stdout, out.stdout + out.stderr
