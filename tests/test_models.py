"""Per-architecture smoke tests (deliverable f) + model-level correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core import distributed as D
from repro.core import compressors as C, ef
from repro.models import model as M
from repro.optim import optimizer as opt_lib


def make_batch(cfg, rng, B=2, S=128):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.frontend is not None:
        batch["prefix_embeds"] = 0.01 * jax.random.normal(
            rng, (B, max(cfg.frontend_tokens, 8), cfg.d_model)
        ).astype(jnp.bfloat16)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced variant of each assigned architecture: one forward + one EF21-SGDM
    train step on CPU; asserts output shapes and finiteness (no NaNs)."""
    cfg = cb.get_smoke(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    rng = jax.random.PRNGKey(0)
    params = M.init_params(cfg, rng)
    batch = make_batch(cfg, rng)

    loss, aux = jax.jit(lambda p, b: M.train_loss(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    # one full distributed-emulated train step (2 clients)
    efc = D.EFConfig(method=ef.EF21SGDM(
        compressor=C.BlockTopK(block=64, k_per_block=8), eta=0.2))
    opt = opt_lib.sgd(1e-2)
    step = D.make_train_step(lambda p, b: M.train_loss(cfg, p, b), efc, opt, 2)
    es = D.init_ef_state(efc, params, 2)
    p2, _, _, m = jax.jit(step)(params, opt.init(params), es, batch,
                                jax.random.fold_in(rng, 1), 0)
    assert np.isfinite(float(m["loss"]))
    for leaf in jax.tree_util.tree_leaves(p2):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all(), arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_arch_prefill_decode(arch):
    cfg = cb.get_smoke(arch)
    rng = jax.random.PRNGKey(0)
    params = M.init_params(cfg, rng)
    B, S = 2, 64
    batch = make_batch(cfg, rng, B, S)
    batch.pop("labels")
    npre = batch["prefix_embeds"].shape[1] if cfg.frontend else 0
    cache = M.init_cache(cfg, B, S + npre + 8)
    logits, cache = jax.jit(
        lambda p, b, c: M.prefill(cfg, p, b, c))(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
    logits2, cache = jax.jit(
        lambda p, c, t, q: M.decode_step(cfg, p, c, t, q))(
        params, cache, tok, jnp.asarray(S + npre, jnp.int32))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm_360m", "falcon_mamba_7b",
                                  "zamba2_1p2b", "gemma2_9b",
                                  "h2o_danube3_4b", "olmoe_1b_7b"])
def test_prefill_decode_matches_full_forward(arch):
    """Decoding token t+1 after prefilling t tokens must equal the full forward
    at position t (cache correctness across all cache types). MoE runs dropless
    (large capacity factor) — with drops, prefill/forward token counts differ
    and exact-match is ill-defined."""
    cfg = dataclasses.replace(cb.get_smoke(arch), dtype="float32",
                              param_dtype="float32", moe_capacity_factor=8.0)
    rng = jax.random.PRNGKey(3)
    params = M.init_params(cfg, rng)
    B, S = 1, 32
    tokens = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)

    # ground truth: prefill over all S+1 tokens — last-token logits
    cache_full = M.init_cache(cfg, B, S + 1, dtype=jnp.float32)
    lg_full, _ = M.prefill(cfg, params, {"tokens": tokens}, cache_full)

    # prefill S tokens, decode token S
    cache = M.init_cache(cfg, B, S + 1, dtype=jnp.float32)
    _, cache = M.prefill(cfg, params, {"tokens": tokens[:, :S]}, cache)
    lg_dec, _ = M.decode_step(cfg, params, cache, tokens[:, S:S + 1],
                              jnp.asarray(S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=2e-2, atol=2e-2)


def test_prefill_prompt_lens_ignores_right_padding():
    """Serving pads short prompts on the right with token id 0, which is a
    LEGAL vocab token: without true lengths, prefill reads the logits
    computed on padding. With ``batch["prompt_lens"]`` each row's logits
    come from its last REAL token — a prompt ENDING in a genuine 0 must
    yield exactly the logits of the unpadded prompt (causal attention makes
    the gathered position blind to the padding after it)."""
    cfg = dataclasses.replace(cb.get_smoke("smollm_360m"), dtype="float32",
                              param_dtype="float32")
    rng = jax.random.PRNGKey(7)
    params = M.init_params(cfg, rng)
    S, L = 8, 5
    row = jax.random.randint(rng, (1, L), 1, cfg.vocab_size)
    row = row.at[0, L - 1].set(0)              # real token 0, not padding
    padded = jnp.zeros((1, S), row.dtype).at[:, :L].set(row)

    cache = M.init_cache(cfg, 1, S, dtype=jnp.float32)
    lg_len, _ = M.prefill(cfg, params,
                          {"tokens": padded,
                           "prompt_lens": jnp.asarray([L], jnp.int32)}, cache)
    cache = M.init_cache(cfg, 1, L, dtype=jnp.float32)
    lg_exact, _ = M.prefill(cfg, params, {"tokens": row}, cache)
    np.testing.assert_allclose(np.asarray(lg_len), np.asarray(lg_exact),
                               rtol=1e-5, atol=1e-5)
    # and the old behavior (read the padded tail) is genuinely different —
    # the bug this pins was a REAL conflation, not a no-op
    cache = M.init_cache(cfg, 1, S, dtype=jnp.float32)
    lg_pad, _ = M.prefill(cfg, params, {"tokens": padded}, cache)
    assert np.abs(np.asarray(lg_pad) - np.asarray(lg_exact)).max() > 1e-3


def test_sliding_window_attention_is_banded():
    """A token beyond the window must not influence attention output."""
    from repro.models import layers as L
    rng = jax.random.PRNGKey(0)
    B, S, H, hd, W = 1, 64, 2, 16, 16
    q, k, v = [jax.random.normal(jax.random.fold_in(rng, i), (B, S, H, hd))
               for i in range(3)]
    out = L.chunked_attention(q, k, v, chunk=16, window=W)
    # perturb k/v at position 0 — outputs at positions ≥ W must be unchanged
    k2 = k.at[:, 0].add(100.0)
    v2 = v.at[:, 0].add(100.0)
    out2 = L.chunked_attention(q, k2, v2, chunk=16, window=W)
    np.testing.assert_allclose(np.asarray(out[:, W:]), np.asarray(out2[:, W:]),
                               atol=1e-5)
    assert np.abs(np.asarray(out[:, :W]) - np.asarray(out2[:, :W])).max() > 1e-3


def test_chunked_attention_matches_reference():
    from repro.models import layers as L
    from repro.kernels import ref
    rng = jax.random.PRNGKey(1)
    B, S, H, hd = 2, 128, 4, 32
    q, k, v = [jax.random.normal(jax.random.fold_in(rng, i), (B, S, H, hd))
               for i in range(3)]
    out = L.chunked_attention(q, k, v, chunk=32)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_gqa_grouping():
    """GQA: each query-head group must attend with its own kv head."""
    from repro.models import layers as L
    rng = jax.random.PRNGKey(2)
    B, S, KV, G, hd = 1, 32, 2, 2, 16
    H = KV * G
    q = jax.random.normal(rng, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KV, hd))
    out = L.chunked_attention(q, k, v, chunk=32)
    # reference: expand kv heads
    k_full = jnp.repeat(k, G, axis=2)
    v_full = jnp.repeat(v, G, axis=2)
    from repro.kernels import ref
    expect = ref.flash_attention_ref(q, k_full, v_full)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_moe_routing_capacity_and_balance():
    from repro.models import moe as moe_lib
    rng = jax.random.PRNGKey(0)
    d, ff, E, k = 32, 64, 4, 2
    p = moe_lib.moe_init(rng, d, ff, E, jnp.float32)
    x = jax.random.normal(rng, (2, 16, d))
    out, aux = moe_lib.moe_apply(p, x, k=k, cf=2.0, eps=1e-6)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux["dropped_frac"]) <= 0.5
    assert float(aux["load_balance"]) >= 0.99  # ≥ 1 by Cauchy-Schwarz-ish


@pytest.mark.slow
def test_mamba1_chunked_equals_sequential():
    """Chunked selective scan == step-by-step recurrence."""
    from repro.models import ssm
    cfg = cb.get_smoke("falcon_mamba_7b")
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    rng = jax.random.PRNGKey(0)
    p = ssm.mamba1_init(rng, cfg.d_model, cfg.d_inner, cfg.ssm_state,
                        cfg.dt_rank, cfg.ssm_conv, jnp.float32)
    B, S = 1, 32
    x = 0.1 * jax.random.normal(rng, (B, S, cfg.d_model))
    y_chunk, _ = ssm.mamba1_apply(p, x, cfg)
    # sequential: decode step by step
    h = jnp.zeros((B, cfg.d_inner, cfg.ssm_state))
    conv = jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner))
    ys = []
    for t in range(S):
        y, (h, conv) = ssm.mamba1_apply(p, x[:, t:t + 1], cfg,
                                        ssm_state=h, conv_state=conv)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-4)


@pytest.mark.slow
def test_mamba2_chunked_equals_sequential():
    from repro.models import ssm
    cfg = cb.get_smoke("zamba2_1p2b")
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    rng = jax.random.PRNGKey(0)
    p = ssm.mamba2_init(rng, cfg.d_model, cfg.d_inner, cfg.ssm_state,
                        cfg.ssm_head_dim, cfg.ssm_conv, jnp.float32)
    B, S = 1, 32
    x = 0.1 * jax.random.normal(rng, (B, S, cfg.d_model))
    y_chunk, _ = ssm.mamba2_apply(p, x, cfg)
    nh = cfg.d_inner // cfg.ssm_head_dim
    h = jnp.zeros((B, nh, cfg.ssm_head_dim, cfg.ssm_state))
    conv = jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state))
    ys = []
    for t in range(S):
        y, (h, conv) = ssm.mamba2_apply(p, x[:, t:t + 1], cfg,
                                        ssm_state=h, conv_state=conv)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-4)


def test_logit_softcap_bounds():
    from repro.models import layers as L
    x = jnp.asarray([-1e6, -3.0, 0.0, 3.0, 1e6])
    y = np.asarray(L.softcap(x, 30.0))
    assert (np.abs(y) <= 30.0 + 1e-5).all()
    assert L.softcap(x, None) is x


def test_param_counts_sane():
    """Analytic counts track actual init sizes within 2%."""
    for arch in cb.ARCH_IDS:
        cfg = cb.get_smoke(arch)
        params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
        actual = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.02, \
            (arch, int(actual), int(analytic))


@pytest.mark.slow
def test_tp_head_padding_function_preserving():
    """MHA-expand (tp_pad_heads): manually padding an unpadded layer's weights
    must reproduce its output exactly (zero-wo padded q heads, replicated kv)."""
    from repro.models import layers as L
    rng = jax.random.PRNGKey(0)
    d, H, KV, hd, He = 64, 6, 2, 16, 8
    p = L.attn_init(rng, d, H, KV, hd, jnp.float32)
    G = H // KV
    idx = np.minimum(np.arange(He) // G, KV - 1)
    mask = (np.arange(He) < H)
    pp = {
        "wq": jnp.concatenate([p["wq"], jnp.full((d, He - H, hd), 0.37)], 1),
        "wk": jnp.asarray(np.where(mask[None, :, None],
                                   np.asarray(p["wk"])[:, idx], 0)),
        "wv": jnp.asarray(np.where(mask[None, :, None],
                                   np.asarray(p["wv"])[:, idx], 0)),
        "wo": jnp.concatenate([p["wo"], jnp.zeros((He - H, hd, d))], 0),
        "norm": p["norm"],
    }
    x = jax.random.normal(rng, (2, 32, d))
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    y0, _ = L.attn_apply(p, x, pos, rope_theta=1e4, eps=1e-6, chunk=16)
    y1, _ = L.attn_apply(pp, x, pos, rope_theta=1e4, eps=1e-6, chunk=16)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


@pytest.mark.slow
def test_tp_head_padding_init_shapes():
    cfg = dataclasses.replace(cb.get_smoke("musicgen_medium"), tp_pad_heads=4)
    assert cfg.eff_heads == (4, 4)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    assert p["layers"]["attn"]["wq"].shape[2] == 4
    assert p["layers"]["attn"]["wk"].shape[2] == 4
    # padded wo rows are zero
    assert float(jnp.abs(p["layers"]["attn"]["wo"][:, 3]).max()) == 0.0
