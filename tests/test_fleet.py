"""Serving-fleet tests (launch/fleet.py + the session publish hook): the
ISSUE 8 anchor invariant — after each applied wire record a replica's served
params are BIT-IDENTICAL to the trainer's post-step model — over
(dense, quant8, quant4) downlink × (uniform, mixed-schedule), mid-stream
join via checkpoint+replay, trainer kill-and-resume republish, gap →
resync-not-drift, and the decode-budget scheduler's admission rules."""
import collections
import os
import shutil

import jax
import numpy as np
import pytest

from repro.core import stream as stream_lib
from repro.launch import fleet as fleet_lib
from repro.launch.fleet import DecodeBudgetScheduler, Request
from repro.launch.session import Session
from repro.launch.spec import RunSpec

TINY = dict(arch="smollm-360m", smoke=True, clients=2, global_batch=4,
            seq_len=32)
QUANT4 = dict(compressor="block_topk", ratio=0.1,
              downlink_carrier="quant4", downlink_ratio=0.05)
MIXED_GROUPS = [
    {"pattern": "norm|bias", "carrier": "dense"},
    {"pattern": "embed", "carrier": "quant4", "ratio": 0.05},
    {"pattern": "*", "carrier": "sparse", "ratio": 0.02,
     "downlink_carrier": "quant4", "downlink_ratio": 0.05},
]


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _publish_run(stream_dir, steps, snapshots=True, **spec_kw):
    """Train a publishing session, returning (session, per-step param
    snapshots {step: tree})."""
    sess = Session(RunSpec(**TINY, **spec_kw))
    sess.publish_to(str(stream_dir), bootstrap_every=2)
    snaps = {}
    for _ in range(steps):
        sess.step_once()
        if snapshots:
            snaps[sess.step] = jax.device_get(sess.params)
    return sess, snaps


@pytest.fixture(scope="module")
def quant4_stream(tmp_path_factory):
    """One quant4 stream shared by the read-only fleet tests: 5 published
    steps, bootstraps at 0/2/4, a snapshot of the trainer's params at every
    step."""
    root = tmp_path_factory.mktemp("wire_q4")
    sess, snaps = _publish_run(root, steps=5, **QUANT4)
    return {"dir": str(root), "snaps": snaps, "spec": sess.spec}


# ---------------------------------------------------------------------------
# the anchor invariant: bit-identity after every applied record
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_kw", [
    pytest.param({}, id="uniform-dense"),
    pytest.param(dict(compressor="block_topk", ratio=0.1,
                      downlink_carrier="quant8", downlink_ratio=0.05),
                 id="uniform-quant8"),
    pytest.param(dict(groups=MIXED_GROUPS), id="mixed-schedule"),
])
def test_replica_bit_identical_after_every_record(tmp_path, spec_kw):
    """Replay from the step-0 bootstrap, comparing the replica against the
    trainer's snapshot after EVERY applied step — dense push, quant8
    downlink, and the per-group mixed schedule all land exactly."""
    _, snaps = _publish_run(tmp_path, steps=3, **spec_kw)
    rep = fleet_lib.ServeReplica(str(tmp_path), bootstrap_step=0)
    assert rep.step == 0
    for step in (1, 2, 3):
        assert rep.sync(upto=step) == 1
        assert rep.step == step
        assert _leaves_equal(rep.params, snaps[step]), \
            f"replica drifted from trainer at step {step}"


def test_replica_bit_identical_quant4_every_step(quant4_stream):
    rep = fleet_lib.ServeReplica(quant4_stream["dir"], bootstrap_step=0)
    for step in range(1, 6):
        rep.sync(upto=step)
        assert _leaves_equal(rep.params, quant4_stream["snaps"][step])


def test_mid_stream_join_uses_newest_bootstrap(quant4_stream):
    """A replica joining late must NOT replay from step 0: it joins from the
    newest bootstrap (step 4 of 5) and lands bit-identical to the head."""
    rep = fleet_lib.ServeReplica(quant4_stream["dir"])
    assert rep.step == 4                       # joined mid-stream
    rep.sync()
    assert rep.step == 5
    assert _leaves_equal(rep.params, quant4_stream["snaps"][5])


def test_lagged_replica_joins_behind_and_stays_behind(quant4_stream):
    rep = fleet_lib.ServeReplica(quant4_stream["dir"], lag=3)
    rep.sync()
    assert rep.step == 2                       # head 5 − lag 3
    assert _leaves_equal(rep.params, quant4_stream["snaps"][2])


def test_trainer_kill_and_resume_republish_is_idempotent(tmp_path):
    """Kill the trainer after publishing step 3, resume from its step-2
    checkpoint: the resumed run REPUBLISHES step 3 (verified bit-identical →
    no-op, a diverged record would raise) and extends the stream; a replica
    replaying the whole log lands on the resumed trainer's head."""
    stream = tmp_path / "wire"
    ckpt = tmp_path / "ckpt"
    sess = Session(RunSpec(**TINY, **QUANT4, ckpt_dir=str(ckpt)))
    sess.publish_to(str(stream), bootstrap_every=2)
    sess.train(2)                              # checkpoints at step 2
    sess.step_once()                           # publishes step 3, no ckpt
    del sess                                   # "kill" after step 3
    resumed = Session.resume(str(ckpt))
    assert resumed.step == 2
    resumed.publish_to(str(stream))
    for _ in range(3):                         # steps 3 (republish), 4, 5
        resumed.step_once()
    log = stream_lib.WireLog(str(stream))
    assert log.last_step() == 5
    rep = fleet_lib.ServeReplica(str(stream), bootstrap_step=0)
    rep.sync()
    assert rep.step == 5
    assert _leaves_equal(rep.params, resumed.params)


# ---------------------------------------------------------------------------
# gaps and foreign streams: resync-not-drift
# ---------------------------------------------------------------------------

def _mutable_copy(stream, tmp_path):
    dst = tmp_path / "wire_copy"
    shutil.copytree(stream["dir"], dst)
    return str(dst)


def test_gap_triggers_resync_via_later_bootstrap(quant4_stream, tmp_path):
    """Delete the step-3 record set: a replica replaying from step 0 hits the
    gap and must RESYNC from the step-4 bootstrap (checkpoint + replay),
    landing bit-identical at the head — never skipping the missing step."""
    d = _mutable_copy(quant4_stream, tmp_path)
    log = stream_lib.WireLog(d)
    os.remove(log.record_path(3, 0))
    rep = fleet_lib.ServeReplica(d, bootstrap_step=0)
    advanced = rep.sync()
    assert rep.step == 5
    assert advanced == 5                       # 2 replayed + resync to 4 + 1
    assert _leaves_equal(rep.params, quant4_stream["snaps"][5])


def test_unbridgeable_gap_raises_and_keeps_consistent_params(quant4_stream,
                                                             tmp_path):
    """A gap with NO bootstrap past it must raise StreamGapError, leaving the
    replica on its last consistent (stale, never drifted) model."""
    d = _mutable_copy(quant4_stream, tmp_path)
    log = stream_lib.WireLog(d)
    os.remove(log.record_path(3, 0))
    for b in (2, 4):                           # only the step-0 anchor left
        os.remove(log.bootstrap_path(b))
    rep = fleet_lib.ServeReplica(d, bootstrap_step=0)
    with pytest.raises(stream_lib.StreamGapError):
        rep.sync()
    assert rep.step == 2                       # applied 1..2, refused to skip 3
    assert _leaves_equal(rep.params, quant4_stream["snaps"][2])


def test_foreign_record_refused_loudly(quant4_stream, tmp_path):
    """A record written under a different RunSpec hash must raise
    StreamSpecMismatch — mirrors the checkpoint foreign-spec guard."""
    d = _mutable_copy(quant4_stream, tmp_path)
    log = stream_lib.WireLog(d)
    rec5 = log.read(5, 0)
    forged = stream_lib.WireRecord(**{
        **rec5.__dict__, "step": 6, "spec_hash": "0" * 16})
    log.append(forged)
    rep = fleet_lib.ServeReplica(d)            # joins at bootstrap 4
    with pytest.raises(stream_lib.StreamSpecMismatch):
        rep.sync()


def test_empty_stream_refuses_replica(tmp_path):
    with pytest.raises(stream_lib.StreamError):
        fleet_lib.ServeReplica(str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# decode-budget scheduler
# ---------------------------------------------------------------------------

def _queue(*max_new):
    return collections.deque(
        Request(rid=i, tokens=np.zeros(4, np.int64), max_new_tokens=m)
        for i, m in enumerate(max_new))


def test_scheduler_respects_budget_and_batch_cap():
    sched = DecodeBudgetScheduler(decode_budget=16, max_batch=8)
    q = _queue(4, 4, 4, 4, 4)
    batch, d = sched.admit(q)
    assert [r.rid for r in batch] == [0, 1, 2, 3]   # FIFO prefix
    assert d == 4 and len(batch) * d <= 16
    assert [r.rid for r in q] == [4]

    sched = DecodeBudgetScheduler(decode_budget=64, max_batch=2)
    batch, d = sched.admit(_queue(4, 4, 4))
    assert len(batch) == 2                          # max_batch binds first


def test_scheduler_buckets_decode_to_pow2():
    sched = DecodeBudgetScheduler(decode_budget=64, max_batch=4)
    batch, d = sched.admit(_queue(5, 3))
    assert d == 8                                   # bucket of max(5, 3)
    assert len(batch) == 2


def test_scheduler_admits_oversized_request_alone_capped():
    sched = DecodeBudgetScheduler(decode_budget=8, max_batch=4)
    q = _queue(100, 2)
    batch, d = sched.admit(q)
    assert [r.rid for r in batch] == [0]
    assert d == 8                                   # capped at the budget
    batch, d = sched.admit(q)
    assert [r.rid for r in batch] == [1] and d == 2


def test_synthetic_requests_deterministic():
    a = fleet_lib.synthetic_requests(5, rate=10.0, seed=3)
    b = fleet_lib.synthetic_requests(5, rate=10.0, seed=3)
    assert all(np.array_equal(x.tokens, y.tokens) and
               x.arrival_s == y.arrival_s for x, y in zip(a, b))
    assert all(a[i].arrival_s < a[i + 1].arrival_s for i in range(4))


# ---------------------------------------------------------------------------
# the fleet serves at lags
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_serves_two_lagged_replicas(quant4_stream):
    """Two replicas on ONE wire at lags (0, 2): every request completes, each
    replica serves exactly its lag target's params, and the summary carries
    the latency/staleness schema serve_bench records."""
    fleet = fleet_lib.Fleet(quant4_stream["dir"], n_replicas=2, lags=(0, 2),
                            decode_budget=8, max_batch=2, prompt_len=8)
    fleet.sync()
    assert [r.step for r in fleet.replicas] == [5, 3]
    for rep in fleet.replicas:
        assert _leaves_equal(rep.params, quant4_stream["snaps"][rep.step])
    reqs = fleet_lib.synthetic_requests(4, rate=50.0, prompt_len=8,
                                        max_new_tokens=4)
    out = fleet.run(reqs, sync_every=1)
    assert len(out["requests"]) == 4
    assert out["batches"] >= 2
    assert {r.replica for r in out["requests"]} == {"r0", "r1"}
    assert all(r.tokens_out is not None and r.latency_s >= 0
               for r in out["requests"])
    assert all(r.tokens_generated == r.max_new_tokens
               for r in out["requests"])       # nothing was budget-capped
    assert out["short_requests"] == 0
    assert out["staleness_max"] <= 2
    assert out["p50_ms"] <= out["p99_ms"]


def test_fleet_rejects_mismatched_lags(quant4_stream):
    with pytest.raises(ValueError):
        fleet_lib.Fleet(quant4_stream["dir"], n_replicas=2, lags=(0,))


# ---------------------------------------------------------------------------
# sync cadence + shortfall accounting (stubbed replicas — no compiles)
# ---------------------------------------------------------------------------

class _FakeReplica:
    """Stand-in for ServeReplica with the exact surface Fleet.run drives.
    All fakes share one ``head`` dict emulating the trainer: it advances one
    step per completed round-robin ROUND (every fake's serve in a round
    bumps ``served``; a full round bumps ``v``), so staleness dynamics are
    real without a single jitted serve."""

    def __init__(self, name, lag, head, n_replicas):
        self.name, self.lag, self.head = name, int(lag), head
        self._n = n_replicas
        self.step = max(head["v"] - self.lag, 0)
        self.sync_calls = 0

    def sync(self, upto=None):
        self.sync_calls += 1
        target = max(self.head["v"] - self.lag, 0)
        advanced = max(target - self.step, 0)
        self.step = max(self.step, target)
        return advanced

    def staleness(self):
        return max(self.head["v"] - self.step, 0)

    def serve_batch(self, batch, prompt_len, decode_steps,
                    sync_during_decode=False):
        self.head["served"] += 1
        if self.head["served"] % self._n == 0:
            self.head["v"] += 1                # one trainer step per round
        return {"tokens": np.zeros((len(batch), decode_steps + 1), np.int64),
                "mid_applied": 0}


def _fake_fleet(n_replicas, lags, head0=0, decode_budget=8, max_batch=1):
    fl = fleet_lib.Fleet.__new__(fleet_lib.Fleet)
    head = {"v": head0, "served": 0}
    fl.replicas = [_FakeReplica(f"r{i}", lags[i], head, n_replicas)
                   for i in range(n_replicas)]
    fl.scheduler = DecodeBudgetScheduler(decode_budget=decode_budget,
                                         max_batch=max_batch)
    fl.prompt_len = 8
    return fl


def test_every_replica_syncs_regression():
    """THE cadence regression: with n_replicas == sync_every the old global
    ``batches % sync_every`` check advanced in lockstep with the round-robin
    index, so r1 was NEVER synced (sync_calls == 0, staleness unbounded).
    The per-replica cadence must sync every replica."""
    fl = _fake_fleet(2, [0, 0])
    out = fl.run(fleet_lib.synthetic_requests(8, max_new_tokens=4),
                 sync_every=2)
    assert out["batches"] == 8
    for rep in fl.replicas:                    # old code: r1 had 0 syncs
        assert rep.sync_calls >= 2, (rep.name, rep.sync_calls)
    assert out["staleness_max"] <= 0 + 2       # lag + sync_every


@pytest.mark.parametrize("n_replicas", [1, 2, 3])
@pytest.mark.parametrize("sync_every", [1, 2, 3])
def test_staleness_bounded_for_every_replica(n_replicas, sync_every):
    """The grid: for every (n_replicas, sync_every) and per-replica lags,
    EVERY request's recorded staleness stays ≤ that replica's lag +
    sync_every while the trainer head keeps moving."""
    lags = list(range(n_replicas))
    fl = _fake_fleet(n_replicas, lags, head0=4)
    out = fl.run(fleet_lib.synthetic_requests(6 * n_replicas,
                                              max_new_tokens=4),
                 sync_every=sync_every)
    assert len(out["requests"]) == 6 * n_replicas
    by_name = {rep.name: rep for rep in fl.replicas}
    for r in out["requests"]:
        rep = by_name[r.replica]
        assert r.staleness <= rep.lag + sync_every, \
            (r.replica, r.staleness, rep.lag, sync_every)
    for rep in fl.replicas:
        assert rep.sync_calls >= 1, rep.name


def test_capped_request_surfaces_shortfall():
    """An oversized lone request is admitted with decode capped at the
    budget; it must complete SHORT and say so — ``tokens_generated`` on the
    request, ``short_requests``/``tokens_short`` in the summary — instead of
    silently returning fewer tokens than asked."""
    sched = DecodeBudgetScheduler(decode_budget=8, max_batch=4)
    q = _queue(100, 2)
    batch, d = sched.admit(q)                  # rid 0 alone, capped at 8
    row = np.arange(d + 1)                     # prefill token + d decodes
    fleet_lib.finalize_request(batch[0], row)
    assert batch[0].tokens_generated == 9
    assert np.array_equal(batch[0].tokens_out, row)

    batch2, d2 = sched.admit(q)                # rid 1 fits its budget
    fleet_lib.finalize_request(batch2[0], np.arange(d2 + 1))
    assert batch2[0].tokens_generated == 2     # == max_new_tokens, not short

    summary = fleet_lib._summary([batch[0], batch2[0]], batches=2)
    assert summary["short_requests"] == 1
    assert summary["tokens_short"] == 100 - 9


def test_run_summary_reports_capped_shortfall():
    fl = _fake_fleet(1, [0], decode_budget=8, max_batch=4)
    reqs = [Request(rid=0, tokens=np.zeros(4, np.int64), max_new_tokens=100),
            Request(rid=1, tokens=np.zeros(4, np.int64), max_new_tokens=4)]
    out = fl.run(reqs)
    assert out["short_requests"] == 1
    assert out["tokens_short"] == 100 - 9
    by_rid = {r.rid: r for r in out["requests"]}
    assert by_rid[0].tokens_generated == 9
    assert by_rid[1].tokens_generated == 4
