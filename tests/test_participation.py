"""Partial participation anchor grid (core/participation.py, DESIGN.md §11).

The load-bearing acceptance criterion of the participation PR: a SAMPLED
cohort at fraction=1.0 is BIT-identical to today's full-participation
synchronous path — params, the complete ef_state (gᵢ, momentum, and the
downlink memory h), and per-direction wire accounting — on all three
runtimes (the production vmap train step / ef_round, the shard_map
ef_round_sharded, and the vmap simulator), across a
(method × carrier × downlink) sample including per-group schedules. Plus:
fractional cohorts actually freeze non-sampled clients' whole EF state, the
construction errors hold, and kill-and-resume replays the identical cohort
sequence mid-stream (the seeded mask is pure in (seed, step)).
"""
import dataclasses
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.core import compressors as C
from repro.core import distributed as D
from repro.core import ef, problems, simulate
from repro.core import participation as part_lib
from repro.core import schedule as S
from repro.launch import build as build_lib
from repro.launch import mesh as mesh_lib
from repro.launch import session as session_lib
from repro.launch.session import Session
from repro.launch.spec import RunSpec

BTK = C.BlockTopK(block=8, k_per_block=3)
DOWN_BTK = C.BlockTopK(block=8, k_per_block=2)
TINY = dict(arch="smollm-360m", smoke=True, clients=2, global_batch=4,
            seq_len=32)
FULL_1 = part_lib.Participation(mode="sampled", fraction=1.0)
HALF = part_lib.Participation(mode="sampled", fraction=0.5, seed=3)


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


@pytest.fixture
def lin_setup():
    rng = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    x = jax.random.normal(rng, (16, 8))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (8, 4))
    return params, {"x": x, "y": x @ w}


def _run_train(setup, efc, steps=6, dp=4):
    from repro.optim import optimizer as opt_lib
    params, batch = setup
    opt = opt_lib.sgd(0.2)
    step = jax.jit(D.make_train_step(_loss_fn, efc, opt, dp))
    _, _, g0 = D.per_client_value_and_grad(_loss_fn, params, batch, dp)
    p, os_, es = params, opt.init(params), D.init_ef_state(
        efc, params, dp, init_grads=g0)
    rng = jax.random.PRNGKey(1)
    for t in range(steps):
        p, os_, es, _ = step(p, os_, es, batch, jax.random.fold_in(rng, t), t)
    return p, es


def _grid_cells():
    for m_name in ("ef21_sgdm", "ef21_sgd", "ef14_sgd"):
        for carrier in ("dense", "sparse", "quant4", "fused"):
            if carrier == "fused" and m_name == "ef14_sgd":
                continue                      # fused covers EF21-SGD(M) only
            for down in ("dense", "quant4"):
                yield m_name, carrier, down


def _make_method(m_name):
    kwargs = {"compressor": BTK}
    if m_name == "ef21_sgdm":
        kwargs["eta"] = 0.3
    return ef.make(m_name, **kwargs)


# ---------------------------------------------------------------------------
# anchor runtime 1: the production vmap train step (ef_round)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m_name,carrier,down", list(_grid_cells()))
def test_sampled_fraction_one_bit_matches_full_ef_round(lin_setup, m_name,
                                                        carrier, down):
    """mode=full and mode=sampled fraction=1.0 are BIT-identical — params
    and the full ef_state (clients, server, downlink h) after a multi-step
    production train run — for every (method × carrier × downlink) cell."""
    method = _make_method(m_name)
    down_comp = DOWN_BTK if down != "dense" else None
    full = D.EFConfig(method=method, carrier=carrier, down_carrier=down,
                      down_compressor=down_comp)
    sampled = dataclasses.replace(full, participation=FULL_1)
    p0, es0 = _run_train(lin_setup, full)
    p1, es1 = _run_train(lin_setup, sampled)
    assert sorted(es0) == sorted(es1)          # same state tree (incl. h)
    assert _leaves_equal(p0, p1)
    assert _leaves_equal(es0, es1)


def test_sampled_fraction_one_bit_matches_full_under_schedule(lin_setup):
    """The anchor composes with per-group schedules (PR 5): a mixed
    schedule's masked path at fraction=1.0 is bit-identical too."""
    method = ef.make("ef21_sgdm", compressor=BTK, eta=0.3)
    sched = S.CompressionSchedule((
        S.Group(pattern="b", carrier="dense"),
        S.Group(pattern="*", compressor=BTK, carrier="sparse",
                down_carrier="quant4", down_compressor=DOWN_BTK),
    ))
    full = D.EFConfig(method=method, schedule=sched)
    sampled = dataclasses.replace(full, participation=FULL_1)
    p0, es0 = _run_train(lin_setup, full)
    p1, es1 = _run_train(lin_setup, sampled)
    assert _leaves_equal(p0, p1) and _leaves_equal(es0, es1)


def test_sampled_cohort_freezes_non_sampled_state_ef_round(lin_setup):
    """The Bells & Whistles frozen-client invariant on the production step:
    a fraction=0.5 round leaves every non-sampled client's ENTIRE state
    tree (gᵢ AND momentum) bit-untouched, while sampled clients move."""
    method = ef.make("ef21_sgdm", compressor=BTK, eta=0.3)
    efc = D.EFConfig(method=method, carrier="sparse", participation=HALF)
    params, batch = lin_setup
    dp = 4
    _, _, g0 = D.per_client_value_and_grad(_loss_fn, params, batch, dp)
    # feed grads ≠ gᵢ so sampled clients have a nonzero delta to compress
    grads = jax.tree_util.tree_map(lambda g: 2.0 * g + 1.0, g0)
    es = D.init_ef_state(efc, params, dp, init_grads=g0)
    for t in range(3):
        mask = part_lib.cohort_mask_np(HALF, dp, t)
        assert mask.sum() == HALF.cohort_size(dp)
        _, es_new = D.ef_round(efc, grads, es, None, step=jnp.int32(t))
        moved = 0
        for k in es["clients"]:
            for new_l, old_l in zip(
                    jax.tree_util.tree_leaves(es_new["clients"][k]),
                    jax.tree_util.tree_leaves(es["clients"][k])):
                for i in range(dp):
                    same = np.array_equal(np.asarray(new_l)[i],
                                          np.asarray(old_l)[i])
                    if mask[i] == 0.0:
                        assert same, f"non-sampled client {i} state moved"
                    elif not same:
                        moved += 1
        assert moved > 0, "sampled clients never moved"
        es = es_new


def test_sampled_requires_step_and_async_refuses_sync_runtimes(lin_setup):
    method = ef.make("ef21_sgdm", compressor=BTK, eta=0.3)
    params, batch = lin_setup
    _, _, grads = D.per_client_value_and_grad(_loss_fn, params, batch, 4)
    efc = D.EFConfig(method=method, carrier="sparse", participation=HALF)
    es = D.init_ef_state(efc, params, 4, init_grads=grads)
    with pytest.raises(ValueError, match="pass step="):
        D.ef_round(efc, grads, es, None)
    efc_async = D.EFConfig(
        method=method, carrier="sparse",
        participation=part_lib.Participation(mode="async"))
    with pytest.raises(ValueError, match="run_async"):
        D.ef_round(efc_async, grads, es, None, step=jnp.int32(0))
    with pytest.raises(ValueError, match="run_async"):
        simulate.run(problems.QuadraticT1(), method,
                     simulate.SimConfig(
                         n=4, steps=2,
                         participation=part_lib.Participation(mode="async")),
                     jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# anchor runtime 2: the vmap simulator (wire accounting included)
# ---------------------------------------------------------------------------

def test_sampled_fraction_one_bit_matches_full_simulator():
    """Same anchor on the simulator: whole trajectory AND every wire
    accounting key (per-direction words, coords) bit-equal at fraction=1.0;
    a fractional cohort reports fraction·n uplink wires honestly while the
    downlink broadcast still reaches all n links."""
    prob = problems.MLPClassification(n=4, m_per_client=64)
    btk = C.BlockTopK(block=64, k_per_block=8)
    method = ef.EF21SGDM(compressor=btk, eta=0.2)
    down = C.BlockTopK(block=64, k_per_block=4)
    for carrier in ("dense", "sparse", "quant4"):
        base = simulate.SimConfig(n=4, steps=5, gamma=0.05, carrier=carrier,
                                  down_carrier="quant4",
                                  down_compressor=down)
        full = simulate.run_numpy(prob, method, base, seed=0)
        frac1 = simulate.run_numpy(
            prob, method,
            dataclasses.replace(base, participation=FULL_1), seed=0)
        assert sorted(full) == sorted(frac1)
        for k in full:
            assert _leaves_equal(full[k], frac1[k]), (carrier, k)
        half = simulate.run_numpy(
            prob, method,
            dataclasses.replace(base, participation=HALF), seed=0)
        # uplink scales to the cohort (m = 2 of n = 4); downlink stays × n
        assert half["wire_words_up_per_round"] \
            == full["wire_words_up_per_round"] / 2
        assert half["coords_per_round"] == full["coords_per_round"] / 2
        assert half["wire_words_down_per_round"] \
            == full["wire_words_down_per_round"]


def test_sampled_simulator_group_accounting_scales_per_group():
    prob = problems.MLPClassification(n=4, m_per_client=64)
    method = ef.EF21SGDM(compressor=C.BlockTopK(block=64, k_per_block=8),
                         eta=0.2)
    sched = S.CompressionSchedule((
        S.Group(pattern="b", carrier="dense"),
        S.Group(pattern="*", compressor=C.BlockTopK(block=64, k_per_block=8),
                carrier="sparse"),
    ))
    base = simulate.SimConfig(n=4, steps=3, gamma=0.05, schedule=sched)
    full = simulate.run_numpy(prob, method, base, seed=0)
    half = simulate.run_numpy(
        prob, method, dataclasses.replace(base, participation=HALF), seed=0)
    assert tuple(half["wire_words_up_per_group"]) == tuple(
        w / 2 for w in full["wire_words_up_per_group"])
    assert tuple(half["wire_words_down_per_group"]) == tuple(
        full["wire_words_down_per_group"])


# ---------------------------------------------------------------------------
# anchor runtime 3: ef_round_sharded (shard_map, 8 forced host devices)
# ---------------------------------------------------------------------------

def _sharded_setup(efc):
    mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
    rng = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    grads = {"w": jax.random.normal(rng, (4, 4, 8)),
             "b": jax.random.normal(jax.random.fold_in(rng, 1), (4, 8))}
    st = D.init_ef_state(efc, params, 4, init_grads=grads)
    gspecs = {"w": P("data", None, None), "b": P("data", None)}
    cl = {"w": P("data", None, None), "b": P("data", None)}
    sv = {"w": P(None, None), "b": P(None)}
    sspecs = {"clients": {k: cl for k in st["clients"]}, "server": sv}
    if "h" in st:
        sspecs["h"] = sv
    return mesh, grads, st, gspecs, sspecs


@pytest.mark.parametrize("carrier", ["dense", "sparse", "quant4", "fused"])
def test_sampled_fraction_one_bit_matches_full_sharded(carrier):
    method = ef.make("ef21_sgdm", compressor=BTK, eta=0.3)
    full = D.EFConfig(method=method, carrier=carrier, data_axes=("data",),
                      down_carrier="quant4", down_compressor=DOWN_BTK)
    sampled = dataclasses.replace(full, participation=FULL_1)
    mesh, grads, st, gspecs, sspecs = _sharded_setup(full)
    with mesh_lib.mesh_context(mesh):
        g0, s0 = jax.jit(lambda g, s: D.ef_round_sharded(
            full, g, s, None, mesh, gspecs, sspecs))(grads, st)
        g1, s1 = jax.jit(lambda g, s, t: D.ef_round_sharded(
            sampled, g, s, None, mesh, gspecs, sspecs, step=t))(
            grads, st, jnp.int32(0))
    assert _leaves_equal(g0, g1) and _leaves_equal(s0, s1)


def test_sharded_sampled_cohort_matches_vmap_sampled():
    """The masked shard_map path computes the SAME sampled round as the
    masked vmap path (same (seed, step) → same cohort on both runtimes)."""
    method = ef.make("ef21_sgdm", compressor=BTK, eta=0.3)
    efc = D.EFConfig(method=method, carrier="sparse", data_axes=("data",),
                     participation=HALF)
    mesh, grads, st, gspecs, sspecs = _sharded_setup(efc)
    with mesh_lib.mesh_context(mesh):
        g_sh, s_sh = jax.jit(lambda g, s, t: D.ef_round_sharded(
            efc, g, s, None, mesh, gspecs, sspecs, step=t))(
            grads, st, jnp.int32(1))
    g_vm, s_vm = D.ef_round(efc, grads, st, None, step=jnp.int32(1))
    for a, b in zip(jax.tree_util.tree_leaves((g_vm, s_vm)),
                    jax.tree_util.tree_leaves((g_sh, s_sh))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# construction errors: the fused wire has no per-client wire to mask
# ---------------------------------------------------------------------------

def test_sampled_fused_quant_is_a_construction_error():
    with pytest.raises(ValueError, match="no per-client wire"):
        RunSpec(**TINY, carrier="fused_quant8",
                compressor_kw={"block": 8, "k_per_block": 3},
                participation={"mode": "sampled", "fraction": 0.5})
    # the authoritative build-layer check catches hand-built configs too
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    plan = None
    from repro.launch import shardings as sh
    plan = sh.ShardPlan()
    with pytest.raises(ValueError, match="no per-client wire"):
        build_lib.default_ef_config(
            mesh, plan, carrier="fused_quant8",
            method=ef.make("ef21_sgdm", compressor=BTK, eta=0.3),
            participation=HALF)
    with pytest.raises(ValueError, match="run_async"):
        build_lib.default_ef_config(
            mesh, plan, carrier="dense",
            method=ef.make("ef21_sgdm", compressor=BTK, eta=0.3),
            participation=part_lib.Participation(mode="async"))


# ---------------------------------------------------------------------------
# kill-and-resume with a sampled cohort mid-stream
# ---------------------------------------------------------------------------

def test_kill_and_resume_sampled_cohort_bit_identical(tmp_path):
    """The cohort mask is pure in (seed, step), so a resumed run replays
    the EXACT cohort sequence: kill mid-stream, resume, and the trajectory
    (params + full ef_state) equals the uninterrupted sampled run."""
    base = RunSpec(**TINY, participation={"mode": "sampled",
                                          "fraction": 0.5, "seed": 7})
    unint = Session(base)
    unint.train(4, log_every=1)

    interrupted = Session(dataclasses.replace(base, ckpt_dir=str(tmp_path)))
    interrupted.train(2, log_every=1)
    del interrupted

    resumed = Session.resume(str(tmp_path))
    assert resumed.step == 2
    assert resumed.spec.participation == base.participation
    resumed.train(4, log_every=1)
    assert _leaves_equal(unint.params, resumed.params)
    assert _leaves_equal(unint.ef_state, resumed.ef_state)


def test_session_full_vs_sampled_fraction_one_end_to_end():
    """The whole launch stack (spec → session → build → step) preserves the
    fraction=1.0 anchor: identical params and ef_state after training."""
    full = Session(RunSpec(**TINY, carrier="sparse", compressor="topk"))
    full.train(3, log_every=1)
    sampled = Session(RunSpec(**TINY, carrier="sparse", compressor="topk",
                              participation={"mode": "sampled",
                                             "fraction": 1.0}))
    sampled.train(3, log_every=1)
    assert _leaves_equal(full.params, sampled.params)
    assert _leaves_equal(full.ef_state, sampled.ef_state)
