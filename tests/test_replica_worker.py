"""Replica worker-process tests (launch/replica_worker.py + ProcessFleet):
the §12 anchor invariant ACROSS A PROCESS BOUNDARY — a worker process joins
the wire via checkpoint + replay and its served params digest-match the
trainer's snapshot at every synced step, survives kill-and-restart
bit-identically, applies fresh records BETWEEN decode steps (continuous
sync), and a ProcessFleet completes every request even when a worker is
killed mid-run (the in-flight batch is requeued, never dropped)."""
import threading

import jax
import numpy as np
import pytest

from repro.launch import fleet as fleet_lib
from repro.launch import replica_worker as worker_lib
from repro.launch.session import Session
from repro.launch.spec import RunSpec

TINY = dict(arch="smollm-360m", smoke=True, clients=2, global_batch=4,
            seq_len=32)
QUANT4 = dict(compressor="block_topk", ratio=0.1,
              downlink_carrier="quant4", downlink_ratio=0.05)


@pytest.fixture(scope="module")
def wire(tmp_path_factory):
    """A quant4 stream with 3 published steps; the trainer session stays
    alive so tests can extend the stream mid-decode."""
    root = tmp_path_factory.mktemp("wire_rw")
    sess = Session(RunSpec(**TINY, **QUANT4))
    sess.publish_to(str(root), bootstrap_every=2)
    snaps = {}
    for _ in range(3):
        sess.step_once()
        snaps[sess.step] = jax.device_get(sess.params)
    return {"dir": str(root), "sess": sess, "snaps": snaps}


@pytest.fixture(scope="module")
def worker(wire):
    w = worker_lib.WorkerHandle(wire["dir"], name="w0", lag=0,
                                bootstrap_step=0, prompt_len=8)
    w.wait_ready()
    yield w
    w.stop()


# ---------------------------------------------------------------------------
# digest — the cross-process identity check
# ---------------------------------------------------------------------------

def test_params_digest_is_bitwise():
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.ones(4, np.int32)}
    same = {"a": tree["a"].copy(), "b": tree["b"].copy()}
    assert worker_lib.params_digest(tree) == worker_lib.params_digest(same)
    flipped = {"a": tree["a"].copy(), "b": tree["b"].copy()}
    flipped["a"][1, 2] = np.nextafter(flipped["a"][1, 2],
                                      np.float32(np.inf))  # exactly one ulp
    assert worker_lib.params_digest(tree) != worker_lib.params_digest(flipped)
    recast = {"a": tree["a"].astype(np.float64), "b": tree["b"]}
    assert worker_lib.params_digest(tree) != worker_lib.params_digest(recast)


# ---------------------------------------------------------------------------
# one worker process: sync, digest, heartbeat, continuous sync
# ---------------------------------------------------------------------------

def test_worker_syncs_bit_identical_to_trainer(wire, worker):
    """The tier-1 anchor: sync the worker to the head and compare its params
    digest against the trainer's in-memory snapshot — equal digests ⟺
    bit-identical trees, proven across the process boundary."""
    head = max(wire["snaps"])
    r = worker.call({"cmd": "sync", "upto": head})
    assert r["step"] == head
    d = worker.call({"cmd": "digest"})
    assert d["digest"] == worker_lib.params_digest(wire["snaps"][head])


def test_worker_heartbeats_and_reports_step(worker):
    worker.call({"cmd": "sync"})               # ensure at least one hb cycle
    deadline = threading.Event()
    deadline.wait(0.6)                         # > 2 heartbeat intervals
    assert worker.hb_age() < 5.0
    assert worker.step is not None


def test_worker_rejects_unknown_command(worker):
    with pytest.raises(RuntimeError, match="unknown cmd"):
        worker.call({"cmd": "frobnicate"})


@pytest.mark.slow
def test_worker_continuous_sync_during_decode(wire, worker):
    """Publish fresh steps AFTER the worker synced, then serve with
    ``sync_during_decode``: the decode hook must apply them mid-batch
    (``mid_applied`` > 0) and the worker finishes ON the new head — a long
    decode never pins the batch to the params it started with."""
    worker.call({"cmd": "sync"})
    sess = wire["sess"]
    for _ in range(2):
        sess.step_once()
        wire["snaps"][sess.step] = jax.device_get(sess.params)
    head = sess.step
    r = worker.call({"cmd": "serve", "requests": [
        {"rid": 0, "tokens": list(range(8)), "max_new_tokens": 4},
        {"rid": 1, "tokens": [0, 7, 0], "max_new_tokens": 4}],
        "decode_steps": 4, "prompt_len": 8, "sync_during_decode": True})
    assert r["step"] == head
    assert r["mid_applied"] >= 1
    assert r["tokens_generated"] == [4, 4]
    assert all(len(t) == 4 for t in r["tokens"])
    d = worker.call({"cmd": "digest"})
    assert d["digest"] == worker_lib.params_digest(wire["snaps"][head])


@pytest.mark.slow
def test_worker_kill_and_restart_bit_identity(wire, worker):
    """Kill -9 the worker and restart it: the fresh process rejoins via
    checkpoint + replay and must return the SAME digest — the anchor
    invariant survives a crash."""
    worker.call({"cmd": "sync"})
    before = worker.call({"cmd": "digest"})["digest"]
    head = max(wire["snaps"])
    assert before == worker_lib.params_digest(wire["snaps"][head])
    worker.kill()
    assert not worker.alive()
    worker.restart()
    worker.call({"cmd": "sync"})
    after = worker.call({"cmd": "digest"})["digest"]
    assert after == before
    assert worker.restarts == 1


# ---------------------------------------------------------------------------
# the multi-process fleet
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_process_fleet_serves_and_survives_kill(wire):
    """Two worker processes on one stream: every request completes across
    both workers; then a worker is killed mid-run — its in-flight batch is
    requeued at the front, the worker restarts, and every request STILL
    completes, with the restart surfaced in the summary."""
    with fleet_lib.ProcessFleet(wire["dir"], n_workers=2, lags=(0, 2),
                                decode_budget=8, max_batch=2,
                                prompt_len=8) as fl:
        fl.sync()
        steps = [w.call({"cmd": "sync"})["step"] for w in fl.workers]
        assert steps[0] - steps[1] == 2        # lags honored
        reqs = fleet_lib.synthetic_requests(6, rate=50.0, prompt_len=8,
                                            max_new_tokens=4)
        out = fl.run(reqs)
        assert sorted(r.rid for r in out["requests"]) == list(range(6))
        assert {r.replica for r in out["requests"]} == {"w0", "w1"}
        assert out["restarts"] == 0
        assert out["short_requests"] == 0
        assert all(r.tokens_generated == 4 for r in out["requests"])
        assert out["p50_ms"] <= out["p99_ms"]

        killer = threading.Timer(0.2, fl.workers[1].kill)
        killer.start()
        reqs = fleet_lib.synthetic_requests(6, rate=20.0, prompt_len=8,
                                            max_new_tokens=4, seed=1)
        out = fl.run(reqs)
        killer.cancel()
        assert sorted(r.rid for r in out["requests"]) == list(range(6))
        assert out["restarts"] >= 1
