"""Fused uplink mega-kernel (kernels/fused_round.py) vs the composed oracle
``block_quantize_ref ∘ block_topk_ref ∘ ef21_sgdm_update_ref`` (kernels/ref.py
::ef21_sgdm_topk_quant_ref), plus the one-launch downlink ``dequant_add`` vs
the two-step decode — mirroring the differential structure of test_kernels.py.

Tolerance convention (same as the quantize tests): mantissas bit-exact,
float32 chains to float-compilation tolerance (the kernel and the oracle are
two XLA compilations of the same arithmetic — FMA fusion may differ by 1 ulp).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fused_round as fr
from repro.kernels import ops, ref


def _assert_fused_matches_oracle(grad, v, g, *, eta, block, k, bits,
                                 out=None):
    vn, gn, q, s = out if out is not None else ops.ef21_sgdm_topk_quant(
        grad, v, g, eta=eta, block=block, k=k, bits=bits)
    vr, gr, qr, sr = ref.ef21_sgdm_topk_quant_ref(
        grad, v, g, eta=eta, block=block, k=k, bits=bits)
    np.testing.assert_allclose(np.asarray(vn), np.asarray(vr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(gr),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("d,block,k", [
    (50, 16, 3), (257, 128, 9), (1000, 256, 17), (4096, 1024, 16),
    (1, 256, 1), (129, 64, 5),
])
def test_fused_uplink_matches_oracle_odd_shapes(bits, d, block, k):
    """One launch == the composed three-kernel chain on non-block-multiple
    and tiny shapes, both mantissa layouts."""
    rng = np.random.RandomState(d + bits)
    grad, v, g = [jnp.asarray(rng.randn(d).astype(np.float32))
                  for _ in range(3)]
    _assert_fused_matches_oracle(grad, v, g, eta=0.17, block=block, k=k,
                                 bits=bits)


@pytest.mark.parametrize("bits", [8, 4])
def test_fused_uplink_zero_blocks(bits):
    """A block with zero residual (v' == g) must ship scale 0, decode to
    exact zeros, and leave g' unchanged there — no 0/0 anywhere."""
    d, block, k, eta = 256, 64, 7, 0.5
    rng = np.random.RandomState(bits)
    grad, v, g = [jnp.asarray(rng.randn(d).astype(np.float32))
                  for _ in range(3)]
    # force v' == g on block 0 EXACTLY: with η=0.5 and v = grad = g there,
    # v' = 0.5g + 0.5g = g bit-for-bit (0.5·g is exact, equal-magnitude add
    # is exact) — any other η leaves cancellation noise in v'−g whose tiny
    # survivors the two compilations may select differently
    g0 = np.asarray(g).copy()
    grad = grad.at[:block].set(g0[:block])
    v = v.at[:block].set(g0[:block])
    vn, gn, q, s = ops.ef21_sgdm_topk_quant(grad, v, g, eta=eta, block=block,
                                            k=k, bits=bits)
    assert float(s[0]) == 0.0
    np.testing.assert_array_equal(np.asarray(gn)[:block], g0[:block])
    _assert_fused_matches_oracle(grad, v, g, eta=eta, block=block, k=k,
                                 bits=bits, out=(vn, gn, q, s))


@pytest.mark.parametrize("bits", [8, 4])
def test_fused_uplink_bf16(bits):
    """bf16 state runs the same f32 arithmetic as the oracle; like the
    quantize bf16 test, the 1-ulp scale difference between compilations may
    flip a mantissa one step, so decodes must agree to one grid step and g'
    to one step after the bf16 round."""
    d, block, k, eta = 512, 128, 9, 0.25
    rng = np.random.RandomState(bits + 7)
    grad, v, g = [jnp.asarray(rng.randn(d), jnp.bfloat16) for _ in range(3)]
    vn, gn, q, s = ops.ef21_sgdm_topk_quant(grad, v, g, eta=eta, block=block,
                                            k=k, bits=bits)
    vr, gr, qr, sr = ref.ef21_sgdm_topk_quant_ref(
        grad, v, g, eta=eta, block=block, k=k, bits=bits)
    assert vn.dtype == grad.dtype and gn.dtype == g.dtype
    # kernel accumulates v' in f32 then rounds once; the oracle's weak-typed
    # bf16 arithmetic rounds per op — they may differ by one bf16 ulp
    np.testing.assert_allclose(np.asarray(vn, np.float32),
                               np.asarray(vr, np.float32),
                               rtol=1e-2, atol=1e-2)
    step = np.repeat(np.asarray(sr, np.float32), block)
    dec = np.asarray(ref.block_dequantize_ref(q, s, bits=bits,
                                              cols=block)).reshape(-1)
    decr = np.asarray(ref.block_dequantize_ref(qr, sr, bits=bits,
                                               cols=block)).reshape(-1)
    assert (np.abs(dec - decr) <= step * (1 + 1e-6)).all()
    gdiff = np.abs(np.asarray(gn, np.float32) - np.asarray(gr, np.float32))
    assert (gdiff <= step[:d] + 1e-2).all()


def test_fused_uplink_interpret_flag_direct():
    """The kernels/fused_round.py entry point honors interpret=True
    explicitly (the path every off-TPU caller takes)."""
    rng = np.random.RandomState(3)
    grad, v, g = [jnp.asarray(rng.randn(300).astype(np.float32))
                  for _ in range(3)]
    out = fr.ef21_sgdm_topk_quant(grad, v, g, eta=0.1, block=128, k=5,
                                  bits=8, interpret=True)
    _assert_fused_matches_oracle(grad, v, g, eta=0.1, block=128, k=5, bits=8,
                                 out=out)


def test_fused_uplink_ef_invariant():
    """g' − g must equal dequantize(wire) exactly — what the client
    remembers is what the server reads (the EF21 contract, in-kernel)."""
    rng = np.random.RandomState(11)
    d, block, k, bits = 777, 256, 13, 8
    grad, v, g = [jnp.asarray(rng.randn(d).astype(np.float32))
                  for _ in range(3)]
    _, gn, q, s = ops.ef21_sgdm_topk_quant(grad, v, g, eta=0.4, block=block,
                                           k=k, bits=bits)
    dec = np.asarray(ref.block_dequantize_ref(q, s, bits=bits,
                                              cols=block)).reshape(-1)[:d]
    np.testing.assert_allclose(np.asarray(gn) - np.asarray(g), dec,
                               atol=1e-6)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("alpha", [1.0, 0.5])
def test_dequant_add_matches_two_step(bits, alpha):
    """One-launch downlink base + α·decode == the two-step decode-then-add
    chain (same f32 arithmetic, float-compilation tolerance)."""
    rng = np.random.RandomState(bits)
    d, block = 1000, 128
    x = jnp.asarray(rng.randn(d).astype(np.float32))
    base = jnp.asarray(rng.randn(d).astype(np.float32))
    q, s = ops.block_quantize(x, block=block, bits=bits)
    out = ops.dequant_add(q, s, base, d=d, block=block, bits=bits,
                          alpha=alpha)
    two = base + alpha * ops.block_dequantize(q, s, d=d, block=block,
                                              bits=bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(two), rtol=1e-6,
                               atol=1e-7)


def test_fused_carrier_round_matches_quant8_round():
    """The fused_quant8 one-launch round is bit-compatible with the unfused
    quant8 round through the production vmap runtime: zeros quantize to
    exact 0 and the per-block absmax equals the selected absmax, so the
    dense fused payload decodes to exactly the sparse quant8 decode."""
    from repro.core import compressors as C
    from repro.core import distributed as dist
    from repro.core import ef as ef_lib
    import jax

    rng = jax.random.PRNGKey(0)
    dp, d = 4, 700
    comp = C.BlockTopK(block=128, k_per_block=16)
    grads = jnp.asarray(
        np.random.RandomState(5).randn(dp, d).astype(np.float32))
    params = {"w": jnp.zeros(d)}
    results = {}
    for carrier in ("quant8", "fused_quant8"):
        method = ef_lib.make("ef21_sgdm", compressor=comp, eta=0.3)
        efc = dist.EFConfig(method=method, carrier=carrier)
        st = dist.init_ef_state(efc, params, dp,
                                init_grads={"w": grads})
        msg, st2 = dist.ef_round(efc, {"w": grads}, st, rng, eta=0.3)
        results[carrier] = (msg, st2)
    msg_q, st_q = results["quant8"]
    msg_f, st_f = results["fused_quant8"]
    np.testing.assert_allclose(np.asarray(msg_q["w"]),
                               np.asarray(msg_f["w"]), rtol=1e-6, atol=1e-7)
    for key in st_q["clients"]:
        np.testing.assert_allclose(
            np.asarray(st_q["clients"][key]["w"]),
            np.asarray(st_f["clients"][key]["w"]), rtol=1e-6, atol=1e-7)
