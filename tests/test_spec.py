"""Spec-layer tests (launch/spec.py): the jax-free mirrors stay in sync with
the jax-importing registries, JSON round-trips are identity across the
supported grid, validation fails at construction, and the golden fixtures
under results/specs/ fail loudly on any schema drift."""
import dataclasses
import glob
import os
import subprocess
import sys

import pytest

from repro.configs import base as cb
from repro.launch import spec as spec_lib
from repro.launch.spec import RunSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# mirror ↔ registry sync (the price of a jax-free spec layer)
# ---------------------------------------------------------------------------

def test_name_universes_match_registries():
    from repro.core import carriers as carrier_lib
    from repro.core import compressors as comp_lib
    from repro.core import ef as ef_lib
    from repro.optim import optimizer as opt_lib
    from repro.core import participation as part_lib
    assert spec_lib.METHODS == set(ef_lib.REGISTRY)
    assert spec_lib.COMPRESSORS == set(comp_lib.REGISTRY)
    assert spec_lib.CARRIERS == set(carrier_lib.REGISTRY)
    assert spec_lib.OPTIMIZERS == set(opt_lib.REGISTRY)
    assert spec_lib.PART_MODES == part_lib.PART_MODES


def test_mesh_geometry_matches_mesh_module():
    from repro.launch import mesh as mesh_lib
    assert spec_lib.MESH_GEOM["pod"] == {"data": mesh_lib.PROD_DATA,
                                         "model": mesh_lib.PROD_MODEL}
    assert spec_lib.MESH_GEOM["multi_pod"] == {
        "pod": mesh_lib.PROD_PODS, "data": mesh_lib.PROD_DATA,
        "model": mesh_lib.PROD_MODEL}


def test_attribute_mirrors_match_method_and_compressor_classes():
    import dataclasses as dc

    from repro.core import compressors as comp_lib
    from repro.core import ef as ef_lib
    for name, cls in ef_lib.REGISTRY.items():
        assert (name in spec_lib.WIRE_IS_NOT_MSG) == (not cls().wire_is_msg), name
        has_eta = "eta" in {f.name for f in dc.fields(cls)}
        assert (name in spec_lib.ETA_METHODS) == has_eta, name
    for name, cls in comp_lib.REGISTRY.items():
        assert (name in spec_lib.NEEDS_RNG) == cls().needs_rng, name


def test_spec_eta_drives_every_eta_bearing_method():
    """A spec that records η must never run a class default instead — incl.
    the abs/ideal variants whose defaults differ from the spec default."""
    from repro.launch import session as session_lib
    for m in sorted(spec_lib.ETA_METHODS):
        method = session_lib.make_method(
            RunSpec(method=m, compressor="identity", eta=0.33))
        assert method.eta == 0.33, m
    # method_kw still overrides
    method = session_lib.make_method(RunSpec(
        method="ef21_sgdm", eta=0.33, method_kw={"eta": 0.5}))
    assert method.eta == 0.5


def test_downlink_plan_preview_matches_real_carriers_over_grid():
    """The jax-free downlink_plan_preview must agree with
    Carrier.plan_down_with_reason for every (compressor × carrier) cell —
    same plan, degradation reasons non-empty in the same cells (the fused
    cell is reason-ful in BOTH: the spec turns it into a construction
    error)."""
    from repro.core import carriers as carrier_lib
    from repro.launch import session as session_lib
    for c in sorted(spec_lib.COMPRESSORS):
        comp = session_lib.make_compressor(RunSpec(compressor=c))
        for ca in sorted(spec_lib.CARRIERS):
            real = carrier_lib.make(ca).plan_down_with_reason(comp)
            mirror = spec_lib.downlink_plan_preview(c, ca)
            assert mirror[0] == real[0], (c, ca, mirror, real)
            assert bool(mirror[1]) == bool(real[1]), (c, ca)
    assert spec_lib.DOWN_CARRIERS == spec_lib.CARRIERS - {"fused"}


def test_downlink_spec_construction_and_factory():
    from repro.core import compressors as comp_lib
    from repro.launch import session as session_lib
    with pytest.raises(ValueError, match="invalid RunSpec"):
        RunSpec(downlink_carrier="fused")
    with pytest.raises(ValueError, match="downlink_ratio"):
        RunSpec(downlink_carrier="quant4", downlink_ratio=0.0)
    # 'dense' downlink = NO downlink machinery: factory returns None
    assert session_lib.make_down_compressor(RunSpec()) is None
    # otherwise the uplink class re-budgeted to downlink_ratio — geometry
    # kw carries over, absolute-budget kw must not shadow the ratio
    spec = RunSpec(downlink_carrier="quant4", downlink_ratio=0.02,
                   compressor_kw={"block": 64, "k_per_block": 9})
    down = session_lib.make_down_compressor(spec)
    assert isinstance(down, comp_lib.BlockTopK)
    assert down.block == 64 and down.ratio == 0.02
    assert down.k_per_block is None


def test_plan_preview_matches_real_carriers_over_grid():
    """The jax-free plan_preview must agree with Carrier.plan_with_reason for
    every (method × compressor × carrier) cell: same plan, and degradation
    reasons are non-empty in exactly the same cells."""
    from repro.core import carriers as carrier_lib
    from repro.launch import session as session_lib
    for m in sorted(spec_lib.METHODS):
        for c in sorted(spec_lib.COMPRESSORS):
            spec = RunSpec(method=m, compressor=c, carrier="dense")
            method = session_lib.make_method(spec)
            for ca in sorted(spec_lib.CARRIERS):
                real = carrier_lib.make(ca).plan_with_reason(method, spec.eta)
                mirror = spec_lib.plan_preview(m, c, ca)
                assert mirror[0] == real[0], (m, c, ca, mirror, real)
                assert bool(mirror[1]) == bool(real[1]), (m, c, ca)


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

def _supported_grid():
    for m in sorted(spec_lib.METHODS):
        for c in sorted(spec_lib.COMPRESSORS):
            for ca in sorted(spec_lib.CARRIERS):
                if ca == "fused" and spec_lib.plan_preview(m, c, ca)[0] != "fused":
                    continue        # fused misconfig is a construction error
                if ca in ("fused_quant8", "fused_quant4") \
                        and spec_lib.plan_preview(m, c, ca)[0] != "fused_wire":
                    continue        # degraded fused_quant, same hard error
                yield m, c, ca


def test_json_roundtrip_identity_across_grid():
    n = 0
    for m, c, ca in _supported_grid():
        spec = RunSpec(method=m, compressor=c, carrier=ca)
        assert RunSpec.from_json(spec.to_json()) == spec
        n += 1
    assert n > 100      # the grid is real, not vacuously skipped


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_json_roundtrip_every_config_zoo_arch(arch):
    spec = RunSpec(arch=arch, smoke=True, carrier="sparse",
                   compressor="topk", compressor_kw={"k": 7},
                   method_kw={}, ef_state_dtype="bfloat16",
                   mesh="multi_pod", client_granularity="pod",
                   shape="train_4k")
    back = RunSpec.from_json(spec.to_json())
    assert back == spec
    assert back.spec_hash() == spec.spec_hash()


def test_flag_spec_flag_stability():
    cases = [
        RunSpec(),
        RunSpec(arch="gemma2-9b", smoke=True, carrier="quant4",
                compressor="block_topk", ratio=0.01, eta=0.3, lr=0.1,
                clients=4, global_batch=8, seq_len=64, seed=3,
                ckpt_dir="/tmp/x", ckpt_every=50),
        RunSpec(method="ef21_sgdm_abs", compressor="hard_threshold",
                compressor_kw={"lam": 0.05}, method_kw={"gamma": 0.01}),
        RunSpec(shape="prefill_32k", mesh="pod", state_sharding="zero",
                ef_state_dtype="bfloat16", tp_pad_heads=4,
                moe_impl="dense", optimizer="adamw"),
        RunSpec(carrier="sparse", downlink_carrier="quant4",
                downlink_ratio=0.02),
        # --schedule grammar round-trip (compact form)
        RunSpec(groups=spec_lib.parse_schedule_flag(
            "norm|bias=dense,embed=quant4:0.05,*=sparse:0.02")),
        # --schedule JSON round-trip (per-group knobs the grammar can't say)
        RunSpec(groups=[
            {"pattern": "norm|bias", "carrier": "dense"},
            {"pattern": "*", "carrier": "quant4", "ratio": 0.02,
             "downlink_carrier": "quant4", "downlink_ratio": 0.05,
             "ef_state_dtype": "bfloat16"}]),
        # --participation grammar round-trip (mode[:fraction[:seed]])
        RunSpec(participation={"mode": "sampled", "fraction": 0.25,
                               "seed": 7}),
        RunSpec(participation={"mode": "sampled", "fraction": 0.5}),
        # --participation JSON fallback (non-prefix keyset)
        RunSpec(participation={"mode": "sampled", "seed": 3}),
    ]
    for spec in cases:
        assert RunSpec.from_flags(spec.to_flags()) == spec, spec.to_flags()


def test_schedule_flag_grammar_roundtrip():
    """grammar → groups → grammar is identity for grammar-expressible
    schedules, and the parser rejects malformed entries."""
    s = "embed=dense,norm|bias=dense,attn=quant4:0.05@topk,*=sparse:0.02"
    groups = spec_lib.parse_schedule_flag(s)
    assert groups[2] == {"pattern": "attn", "carrier": "quant4",
                         "ratio": 0.05, "compressor": "topk"}
    assert spec_lib.format_schedule_flag(groups) == s
    # JSON fallback for non-grammar keys
    rich = [{"pattern": "*", "carrier": "quant4",
             "downlink_carrier": "quant4"}]
    out = spec_lib.format_schedule_flag(rich)
    assert spec_lib.parse_schedule_flag(out) == rich
    for bad in ("embed", "=dense", "embed=", ""):
        with pytest.raises(ValueError):
            spec_lib.parse_schedule_flag(bad)


def test_groups_validation_fails_at_construction():
    ok = [{"pattern": "norm", "carrier": "dense"}, {"pattern": "*"}]
    RunSpec(groups=ok)
    cases = [
        ([{"pattern": "norm"}], "catch-all"),          # no '*' last
        ([{"pattern": "*"}, {"pattern": "norm"}], "LAST"),
        ([{"pattern": "a"}, {"pattern": "a"}, {"pattern": "*"}],
         "duplicate"),
        ([{"pattern": "a=b"}, {"pattern": "*"}], "reserved"),
        ([{"pattern": "norm|"}, {"pattern": "*"}], "empty"),
        ([{"pattern": "embed|*"}, {"pattern": "*"}], "standalone"),
        ([{"pattern": "*", "carrier": "laser"}], "unknown carrier"),
        ([{"pattern": "*", "compressor": "gzip"}], "unknown compressor"),
        ([{"pattern": "*", "ratio": 0.0}], "ratio"),
        ([{"pattern": "*", "ef_state_dtype": "fp8"}], "ef_state_dtype"),
        ([{"pattern": "*", "downlink_carrier": "fused"}], "downlink"),
        ([{"pattern": "*", "bogus_key": 1}], "unknown keys"),
        # per-group fused misconfig is a construction error
        ([{"pattern": "*", "carrier": "fused", "compressor": "topk"}],
         "UNFUSED"),
    ]
    for groups, match in cases:
        with pytest.raises(ValueError, match=match):
            RunSpec(groups=groups)
    # a valid fused group constructs
    RunSpec(groups=[{"pattern": "*", "carrier": "fused",
                     "compressor": "block_topk"}])


def test_regen_goldens_reproduces_checked_in_fixtures(tmp_path):
    """`python -m repro.launch.spec --regen-goldens` must reproduce the
    checked-in results/specs/*.json byte-for-byte — goldens are generated
    mechanically from spec.GOLDEN_SPECS, never hand-edited."""
    golden_dir = os.path.join(os.path.dirname(__file__), "..", "results",
                              "specs")
    spec_lib.regen_goldens(str(tmp_path))
    disk = sorted(os.path.basename(p) for p in glob.glob(
        os.path.join(golden_dir, "*.json")))
    regen = sorted(os.path.basename(p) for p in glob.glob(
        str(tmp_path / "*.json")))
    assert disk == regen, "GOLDEN_SPECS and results/specs/ disagree on names"
    for name in disk:
        with open(os.path.join(golden_dir, name)) as f:
            want = f.read()
        with open(tmp_path / name) as f:
            got = f.read()
        assert got == want, f"{name} drifted from its GOLDEN_SPECS recipe"


def test_spec_hash_ignores_checkpoint_policy_only():
    a = RunSpec()
    assert dataclasses.replace(a, ckpt_dir="/x", ckpt_every=9).spec_hash() \
        == a.spec_hash()
    assert dataclasses.replace(a, eta=0.4).spec_hash() != a.spec_hash()


def test_spec_hash_survives_additive_schema_evolution():
    """The hash is over the SPARSE form (fields ≠ default), so a spec dict
    written BEFORE a new defaulted field existed hashes identically to one
    written after — additive evolution never invalidates checkpoints."""
    now = RunSpec(arch="gemma2-9b", eta=0.3, ckpt_dir="/x")
    old_dict = {k: v for k, v in now.to_dict().items()
                if k != "heterogeneity"}        # pretend the field is new
    assert RunSpec.from_dict(old_dict).spec_hash() == now.spec_hash()


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

def test_fused_misconfig_fails_at_construction():
    with pytest.raises(ValueError, match="UNFUSED dense plan"):
        RunSpec(carrier="fused", method="ef14_sgd")
    with pytest.raises(ValueError, match="UNFUSED dense plan"):
        RunSpec(carrier="fused", compressor="topk")
    # and the valid fused cell constructs
    assert RunSpec(carrier="fused", method="ef21_sgdm",
                   compressor="block_topk").plan() == ("fused", "")


def test_unknown_names_fail_at_construction():
    for kw in [{"carrier": "laser"}, {"method": "adam"},
               {"compressor": "gzip"}, {"optimizer": "lion"},
               {"arch": "gpt5"}, {"mesh": "torus"}, {"shape": "train_8k"},
               {"heterogeneity": 5.0}, {"eta": 0.0}, {"ratio": 1.5}]:
        with pytest.raises(ValueError, match="invalid RunSpec"):
            RunSpec(**kw)


def test_non_divisible_batch_fails_at_construction():
    with pytest.raises(ValueError, match="not divisible"):
        RunSpec(global_batch=10, clients=8)
    with pytest.raises(ValueError, match="not divisible"):
        # train_4k ships batch 256; 7 does not divide it on the smoke mesh
        RunSpec(shape="train_4k", clients=7)
    with pytest.raises(ValueError, match="not divisible"):
        # the INTERACTIVE train geometry is validated even when a named
        # shape is also set (Session.train would crash mid-step otherwise)
        RunSpec(shape="train_4k", clients=4, global_batch=6)
    RunSpec(global_batch=16, clients=8)     # divisible constructs fine


def test_from_json_rejects_unknown_keys_and_bad_version():
    good = RunSpec().to_dict()
    with pytest.raises(ValueError, match="unknown RunSpec keys"):
        RunSpec.from_dict({**good, "carier": "dense"})
    with pytest.raises(ValueError, match="version"):
        RunSpec.from_dict({**good, "version": 99})
    with pytest.raises(ValueError, match="version"):
        RunSpec.from_dict({k: v for k, v in good.items() if k != "version"})
    # the v2 schema bump (downlink fields change what a spec EXECUTES):
    # pre-downlink v1 specs are rejected loudly, never silently upgraded
    assert spec_lib.SCHEMA_VERSION == 5
    v1 = {k: v for k, v in good.items()
          if k not in ("downlink_carrier", "downlink_ratio", "groups",
                       "participation", "hops")}
    with pytest.raises(ValueError, match="version"):
        RunSpec.from_dict({**v1, "version": 1})


def test_old_specs_auto_upgrade_and_roundtrip():
    """v3 is purely additive over v2 (``groups`` defaults to the uniform
    one-group schedule), v4 over v3 (``participation`` defaults to mode
    'full'), and v5 over v4 (``hops`` defaults to the flat topology) —
    exactly what every older spec always meant — so old dicts upgrade
    mechanically (chaining through the intermediate versions), round-trip
    at the current schema, and hash identically: every old checkpoint
    stays resumable."""
    now = RunSpec(arch="gemma2-9b", carrier="quant4", eta=0.3)
    v4 = {k: v for k, v in now.to_dict().items() if k != "hops"}
    v4["version"] = 4
    up4 = RunSpec.from_dict(v4)
    assert up4 == now and up4.version == 5 and up4.hops == {}
    assert up4.spec_hash() == now.spec_hash()
    v3 = {k: v for k, v in now.to_dict().items()
          if k not in ("participation", "hops")}
    v3["version"] = 3
    up = RunSpec.from_dict(v3)
    assert up == now and up.version == 5 and up.participation == {}
    assert RunSpec.from_json(up.to_json()) == up
    assert up.spec_hash() == now.spec_hash()
    # v2 chains v2 → v3 → v4 → v5
    v2 = {k: v for k, v in now.to_dict().items()
          if k not in ("groups", "participation", "hops")}
    v2["version"] = 2
    up2 = RunSpec.from_dict(v2)
    assert up2 == now and up2.version == 5 and up2.groups == []
    assert up2.spec_hash() == now.spec_hash()
    # an old dict that somehow carries the newer field is NOT silently
    # upgraded (it was written by something claiming an impossible schema)
    with pytest.raises(ValueError, match="version"):
        RunSpec.from_dict({**now.to_dict(), "version": 2})
    with pytest.raises(ValueError, match="version"):
        RunSpec.from_dict(
            {**v3, "version": 3,
             "participation": {"mode": "sampled", "fraction": 0.5}})
    with pytest.raises(ValueError, match="version"):
        RunSpec.from_dict({**v4, "version": 4, "hops": {"pods": 2}})


# ---------------------------------------------------------------------------
# golden fixtures: schema drift must be loud
# ---------------------------------------------------------------------------

def test_golden_spec_fixtures_roundtrip_bytewise():
    """Every results/specs/*.json must parse as a valid RunSpec AND
    re-serialize to exactly the bytes on disk. Adding/renaming/removing a
    RunSpec field changes the canonical JSON and fails here — regenerate the
    fixtures deliberately (python -m repro.launch.spec --out ...) and bump
    SCHEMA_VERSION when the change is not purely additive."""
    fixtures = sorted(glob.glob(os.path.join(
        os.path.dirname(__file__), "..", "results", "specs", "*.json")))
    assert fixtures, "golden spec fixtures missing (results/specs/*.json)"
    for path in fixtures:
        with open(path) as f:
            text = f.read()
        spec = RunSpec.from_json(text)
        assert spec.to_json(indent=1) + "\n" == text, \
            f"schema drift against golden fixture {os.path.basename(path)}"


# ---------------------------------------------------------------------------
# the jax-free guarantee + CLI
# ---------------------------------------------------------------------------

def test_spec_module_importable_without_jax():
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    code = ("import sys; import repro.launch.spec as S; "
            "s = S.RunSpec(arch='gemma2-9b'); s.to_json(); "
            "assert 'jax' not in sys.modules, 'spec import dragged in jax'")
    subprocess.run([sys.executable, "-c", code], check=True, env=env)


def test_no_smoke_negation_overrides_spec_file(tmp_path):
    """Truthy bools in a --spec file must be revocable from the CLI."""
    import argparse
    path = tmp_path / "cell.json"
    path.write_text(RunSpec(smoke=True, arch="gemma2-9b").to_json())
    ap = argparse.ArgumentParser()
    spec_lib.add_flags(ap)
    spec = RunSpec.from_args(ap.parse_args(["--spec", str(path),
                                            "--no-smoke"]))
    assert spec.smoke is False and spec.arch == "gemma2-9b"
    # without the negation, the file's value wins
    spec = RunSpec.from_args(ap.parse_args(["--spec", str(path)]))
    assert spec.smoke is True


def test_explicit_fields_detects_flags_equal_to_defaults():
    import argparse
    ap = argparse.ArgumentParser()
    spec_lib.add_flags(ap)
    # --lr 0.5 equals the default VALUE but was explicitly passed: it must
    # count, so a --resume enforces it against the checkpoint spec
    args = ap.parse_args(["--lr", "0.5", "--ckpt-dir", "/tmp/x"])
    assert spec_lib.explicit_fields(args, ignore=("ckpt_dir",)) == ["lr"]
    assert spec_lib.explicit_fields(ap.parse_args(["--ckpt-dir", "/tmp/x"]),
                                    ignore=("ckpt_dir",)) == []


def test_spec_cli_print_emits_valid_json():
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.spec", "--print",
         "--arch", "olmoe-1b-7b", "--carrier", "quant8", "--eta", "0.25"],
        check=True, env=env, capture_output=True, text=True).stdout
    spec = RunSpec.from_json(out)
    assert spec.arch == "olmoe-1b-7b" and spec.carrier == "quant8"
    assert spec.eta == 0.25
