"""Straggler scenarios for the event-driven async EF simulator
(core/participation.run_async, DESIGN.md §11).

Three arrival models — uniform (well-behaved), heavy_tail (Pareto
stragglers), dropout (clients that vanish and resample) — exercised for
the properties the async design claims: wall-clock wins over the
synchronous barrier under heavy tails, no deadlock under dropout, honest
staleness accounting with a hard cap, and replay determinism. Marked
slow: these run the numpy event loop for dozens of model updates.
"""
import numpy as np
import pytest

from repro.core import compressors as C
from repro.core import ef, problems
from repro.core import participation as part_lib

pytestmark = pytest.mark.slow

BTK = C.BlockTopK(block=16, k_per_block=4)


def _method():
    return ef.EF21SGDM(compressor=BTK, eta=0.2)


def _prob(n):
    return problems.MLPClassification(n=n, m_per_client=32)


# ---------------------------------------------------------------------------
# uniform arrivals: the well-behaved baseline and its accounting invariants
# ---------------------------------------------------------------------------

def test_uniform_arrivals_accounting_invariants():
    n, rounds = 4, 5
    out = part_lib.run_async(
        _prob(n), _method(), n=n, gamma=0.05, rounds=rounds,
        arrival=part_lib.ArrivalModel(kind="uniform"), seed=0)
    # a round = n accepted uploads; uniform never drops or discards
    assert out["rounds"] == rounds
    assert out["arrivals_applied"] == n * rounds
    assert out["arrivals_dropped"] == 0
    assert out["arrivals_discarded"] == 0
    assert out["wall_clock"] > 0.0
    # every applied arrival lands in exactly one staleness bucket
    assert out["stale_age_hist"].sum() == out["arrivals_applied"]
    assert len(out["grad_norm_sq_per_round"]) == rounds
    assert np.isfinite(out["loss"])
    assert np.isfinite(out["grad_norm_sq"])


def test_async_replay_is_deterministic():
    kw = dict(n=4, gamma=0.05, rounds=3,
              arrival=part_lib.ArrivalModel(kind="uniform"), seed=7)
    a = part_lib.run_async(_prob(4), _method(), **kw)
    b = part_lib.run_async(_prob(4), _method(), **kw)
    assert a["wall_clock"] == b["wall_clock"]
    import jax
    for la, lb in zip(jax.tree_util.tree_leaves(a["x_final"]),
                      jax.tree_util.tree_leaves(b["x_final"])):
        assert np.array_equal(np.asarray(la), np.asarray(lb))
    assert np.array_equal(a["stale_age_hist"], b["stale_age_hist"])


def test_async_actually_optimizes():
    out = part_lib.run_async(
        _prob(4), _method(), n=4, gamma=0.02, rounds=30,
        arrival=part_lib.ArrivalModel(kind="uniform"), seed=0)
    gpr = np.asarray(out["grad_norm_sq_per_round"])
    # the tail of the trajectory sits well below the head
    assert gpr[-5:].mean() < gpr[:5].mean()


# ---------------------------------------------------------------------------
# heavy-tail stragglers: async beats the synchronous barrier on wall-clock
# ---------------------------------------------------------------------------

def test_heavy_tail_async_beats_sync_barrier_wallclock():
    """Under Pareto(alpha=1.3) compute times with n=16 clients the sync
    barrier pays E[max of 16 draws] per round while async pays ~mean per
    accepted upload — async finishes the same number of rounds several
    times faster. Verified across seeds (margins 2.9×–5.9× empirically)."""
    n, rounds = 16, 4
    arrival = part_lib.ArrivalModel(kind="heavy_tail", alpha=1.3)
    for seed in range(3):
        out = part_lib.run_async(_prob(n), _method(), n=n, gamma=0.05,
                                 rounds=rounds, arrival=arrival, seed=seed)
        assert out["rounds"] == rounds
        assert out["wall_clock"] < out["sync_wall_clock"], (
            f"seed={seed}: async {out['wall_clock']:.2f} did not beat "
            f"sync barrier {out['sync_wall_clock']:.2f}")


def test_heavy_tail_produces_staleness():
    """Stragglers make stale wires: the age histogram has mass above 0
    and max_staleness reflects the oldest applied wire."""
    out = part_lib.run_async(
        _prob(16), _method(), n=16, gamma=0.05, rounds=4,
        arrival=part_lib.ArrivalModel(kind="heavy_tail", alpha=1.3), seed=0)
    hist = out["stale_age_hist"]
    assert hist.sum() == out["arrivals_applied"]
    assert len(hist) == out["max_staleness"] + 1
    assert hist[1:].sum() > 0, "heavy tails never produced a stale wire"
    assert out["mean_staleness"] > 0.0
    assert out["max_staleness"] >= out["mean_staleness"]


def test_staleness_cap_bounds_applied_ages():
    """With staleness_cap=k no applied wire is older than k rounds of
    server progress; over-age arrivals are counted discarded, and the
    emitted histogram is bounded by the cap."""
    cap = 8
    arrival = part_lib.ArrivalModel(kind="heavy_tail", alpha=1.3)
    capped = part_lib.run_async(_prob(16), _method(), n=16, gamma=0.05,
                                rounds=3, arrival=arrival,
                                staleness_cap=cap, seed=0)
    free = part_lib.run_async(_prob(16), _method(), n=16, gamma=0.05,
                              rounds=3, arrival=arrival, seed=0)
    assert capped["max_staleness"] <= cap
    assert len(capped["stale_age_hist"]) <= cap + 1
    assert capped["arrivals_discarded"] > 0
    assert free["arrivals_discarded"] == 0
    assert free["max_staleness"] > cap  # the cap actually bit something
    assert capped["rounds"] == free["rounds"] == 3


# ---------------------------------------------------------------------------
# dropout: vanishing clients resample — progress continues, no deadlock
# ---------------------------------------------------------------------------

def test_dropout_never_deadlocks_and_counts_drops():
    n, rounds = 8, 3
    out = part_lib.run_async(
        _prob(n), _method(), n=n, gamma=0.05, rounds=rounds,
        arrival=part_lib.ArrivalModel(kind="dropout", drop_prob=0.5), seed=1)
    assert out["rounds"] == rounds           # completed despite 50% drops
    assert out["arrivals_applied"] == n * rounds
    assert out["arrivals_dropped"] > 0
    assert np.isfinite(out["loss"])
    # a dropped upload costs wall-clock but no server progress
    assert out["wall_clock"] > 0.0


def test_dropout_heavier_drops_cost_more_wallclock():
    kw = dict(n=8, gamma=0.05, rounds=3, seed=2)
    light = part_lib.run_async(
        _prob(8), _method(),
        arrival=part_lib.ArrivalModel(kind="dropout", drop_prob=0.1), **kw)
    heavy = part_lib.run_async(
        _prob(8), _method(),
        arrival=part_lib.ArrivalModel(kind="dropout", drop_prob=0.7), **kw)
    assert heavy["arrivals_dropped"] > light["arrivals_dropped"]
    assert heavy["wall_clock"] > light["wall_clock"]


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_absolute_mode_method_is_rejected():
    m = ef.make("ef14_sgd", compressor=BTK)
    with pytest.raises(ValueError, match="absolute"):
        part_lib.run_async(_prob(4), m, n=4, gamma=0.05, rounds=2)


def test_sync_barrier_wallclock_scales_with_rounds():
    arrival = part_lib.ArrivalModel(kind="uniform")
    short = part_lib.sync_barrier_wallclock(arrival, n=4, rounds=2, seed=0)
    long = part_lib.sync_barrier_wallclock(arrival, n=4, rounds=8, seed=0)
    assert 0 < short < long
