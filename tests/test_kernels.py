"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402

settings.register_profile("kern", max_examples=12, deadline=None)
settings.load_profile("kern")


@pytest.mark.parametrize("B,S,H,hd,bq,bk", [
    (1, 128, 1, 64, 64, 64),
    (2, 256, 4, 64, 128, 64),
    (1, 512, 2, 128, 128, 128),
    (2, 128, 3, 32, 128, 128),     # block == S edge
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(B, S, H, hd, bq, bk, dtype):
    rng = np.random.RandomState(B * S + H)
    q, k, v = [jnp.asarray(rng.randn(B, S, H, hd), dtype) for _ in range(3)]
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_non_causal():
    rng = np.random.RandomState(0)
    q, k, v = [jnp.asarray(rng.randn(1, 128, 2, 64), jnp.float32)
               for _ in range(3)]
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=1e-4)


@given(st.integers(10, 5000), st.sampled_from([256, 512, 1024]),
       st.integers(1, 32), st.integers(0, 1000))
def test_block_topk_kernel_property(d, block, k, seed):
    k = min(k, block)
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(d).astype(np.float32))
    out = ops.block_topk(x, block=block, k=k)
    expect = ref.block_topk_ref(x, block, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_topk_dtypes(dtype):
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(4096), dtype)
    out = ops.block_topk(x, block=512, k=8)
    nz = int((np.asarray(out, np.float32) != 0).sum())
    assert nz == 8 * 8
    # kept values must be the originals
    mask = np.asarray(out, np.float32) != 0
    np.testing.assert_array_equal(np.asarray(out)[mask], np.asarray(x)[mask])


@given(st.integers(100, 4000), st.floats(0.01, 1.0), st.integers(0, 500))
def test_ef_update_kernel_property(d, eta, seed):
    rng = np.random.RandomState(seed)
    grad, v, g = [jnp.asarray(rng.randn(d).astype(np.float32))
                  for _ in range(3)]
    vn, gn, c = ops.ef21_sgdm_update(grad, v, g, eta=eta, block=512, k=16)
    vr, gr, cr = ref.ef21_sgdm_update_ref(grad, v, g, eta=eta, block=512, k=16)
    np.testing.assert_allclose(np.asarray(vn), np.asarray(vr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(gr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=1e-6)


def test_ef_update_kernel_matches_method():
    """The fused kernel computes exactly EF21SGDM.update with BlockTopK."""
    from repro.core import compressors as C, ef
    rng = np.random.RandomState(7)
    d, block, k, eta = 2048, 512, 16, 0.2
    grad = jnp.asarray(rng.randn(d).astype(np.float32))
    v0 = jnp.asarray(rng.randn(d).astype(np.float32))
    g0 = jnp.asarray(rng.randn(d).astype(np.float32))
    m = ef.EF21SGDM(compressor=C.BlockTopK(block=block, k_per_block=k), eta=eta)
    msg, st = m.update({"x": grad}, {"v": {"x": v0}, "g": {"x": g0}})
    vn, gn, c = ops.ef21_sgdm_update(grad, v0, g0, eta=eta, block=block, k=k)
    np.testing.assert_allclose(np.asarray(c), np.asarray(msg["x"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(vn), np.asarray(st["v"]["x"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(st["g"]["x"]),
                               atol=1e-5)


def test_bisection_threshold_exactness():
    """Bisection recovers the k-th largest magnitude to float precision."""
    from repro.kernels.topk_compress import _bisect_threshold
    rng = np.random.RandomState(0)
    ab = jnp.abs(jnp.asarray(rng.randn(4, 1024).astype(np.float32)))
    for k in (1, 16, 300, 1024):
        t = np.asarray(_bisect_threshold(ab, k))
        kth = np.sort(np.asarray(ab), axis=1)[:, -k]
        cnt = (np.asarray(ab) >= t[:, None]).sum(1)
        assert (cnt >= k).all()
        np.testing.assert_allclose(t, kth, rtol=2e-4)   # 26 bisection iters
