"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over shapes/dtypes.

The parametrized differential tests always run; the hypothesis fuzzers engage
wherever hypothesis is installed (CI via requirements-dev.txt)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    settings.register_profile("kern", max_examples=12, deadline=None)
    settings.load_profile("kern")
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref


@pytest.mark.slow
@pytest.mark.parametrize("B,S,H,hd,bq,bk", [
    (1, 128, 1, 64, 64, 64),
    (2, 256, 4, 64, 128, 64),
    (1, 512, 2, 128, 128, 128),
    (2, 128, 3, 32, 128, 128),     # block == S edge
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(B, S, H, hd, bq, bk, dtype):
    rng = np.random.RandomState(B * S + H)
    q, k, v = [jnp.asarray(rng.randn(B, S, H, hd), dtype) for _ in range(3)]
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_non_causal():
    rng = np.random.RandomState(0)
    q, k, v = [jnp.asarray(rng.randn(1, 128, 2, 64), jnp.float32)
               for _ in range(3)]
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("d,block,k,seed", [
    (10, 256, 1, 0), (1000, 256, 17, 3), (4096, 512, 32, 7),
    (2500, 1024, 9, 11),
])
def test_block_topk_kernel_matches_ref(d, block, k, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(d).astype(np.float32))
    out = ops.block_topk(x, block=block, k=k)
    expect = ref.block_topk_ref(x, block, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-7)


if HAVE_HYPOTHESIS:
    @given(st.integers(10, 5000), st.sampled_from([256, 512, 1024]),
           st.integers(1, 32), st.integers(0, 1000))
    def test_block_topk_kernel_property(d, block, k, seed):
        k = min(k, block)
        rng = np.random.RandomState(seed)
        x = jnp.asarray(rng.randn(d).astype(np.float32))
        out = ops.block_topk(x, block=block, k=k)
        expect = ref.block_topk_ref(x, block, k)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=1e-7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_topk_dtypes(dtype):
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(4096), dtype)
    out = ops.block_topk(x, block=512, k=8)
    nz = int((np.asarray(out, np.float32) != 0).sum())
    assert nz == 8 * 8
    # kept values must be the originals
    mask = np.asarray(out, np.float32) != 0
    np.testing.assert_array_equal(np.asarray(out)[mask], np.asarray(x)[mask])


@pytest.mark.parametrize("d,eta,seed", [
    (100, 0.1, 0), (1000, 0.5, 3), (4000, 1.0, 7), (777, 0.01, 11),
])
def test_ef_update_kernel_matches_ref(d, eta, seed):
    rng = np.random.RandomState(seed)
    grad, v, g = [jnp.asarray(rng.randn(d).astype(np.float32))
                  for _ in range(3)]
    vn, gn, c = ops.ef21_sgdm_update(grad, v, g, eta=eta, block=512, k=16)
    vr, gr, cr = ref.ef21_sgdm_update_ref(grad, v, g, eta=eta, block=512, k=16)
    np.testing.assert_allclose(np.asarray(vn), np.asarray(vr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(gr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=1e-6)


if HAVE_HYPOTHESIS:
    @given(st.integers(100, 4000), st.floats(0.01, 1.0), st.integers(0, 500))
    def test_ef_update_kernel_property(d, eta, seed):
        rng = np.random.RandomState(seed)
        grad, v, g = [jnp.asarray(rng.randn(d).astype(np.float32))
                      for _ in range(3)]
        vn, gn, c = ops.ef21_sgdm_update(grad, v, g, eta=eta, block=512, k=16)
        vr, gr, cr = ref.ef21_sgdm_update_ref(grad, v, g, eta=eta, block=512,
                                              k=16)
        np.testing.assert_allclose(np.asarray(vn), np.asarray(vr), atol=1e-6)
        np.testing.assert_allclose(np.asarray(gn), np.asarray(gr), atol=1e-6)
        np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=1e-6)


def test_ef_update_kernel_matches_method():
    """The fused kernel computes exactly EF21SGDM.update with BlockTopK."""
    from repro.core import compressors as C, ef
    rng = np.random.RandomState(7)
    d, block, k, eta = 2048, 512, 16, 0.2
    grad = jnp.asarray(rng.randn(d).astype(np.float32))
    v0 = jnp.asarray(rng.randn(d).astype(np.float32))
    g0 = jnp.asarray(rng.randn(d).astype(np.float32))
    m = ef.EF21SGDM(compressor=C.BlockTopK(block=block, k_per_block=k), eta=eta)
    msg, st = m.update({"x": grad}, {"v": {"x": v0}, "g": {"x": g0}})
    vn, gn, c = ops.ef21_sgdm_update(grad, v0, g0, eta=eta, block=block, k=k)
    np.testing.assert_allclose(np.asarray(c), np.asarray(msg["x"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(vn), np.asarray(st["v"]["x"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(st["g"]["x"]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# quantize/dequantize (kernels/quantize.py) vs oracles (kernels/ref.py)
# ---------------------------------------------------------------------------

def _quant_ref(x, block, bits):
    """Oracle pipeline on the kernel's blocked layout."""
    d = x.size
    nb = -(-d // block)
    xb = jnp.pad(x.reshape(-1).astype(jnp.float32),
                 (0, nb * block - d)).reshape(nb, block)
    return ref.block_quantize_ref(xb, bits)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("d,block", [
    (50, 16), (257, 128), (1000, 256), (4096, 1024), (1, 256), (129, 64),
])
def test_quantize_kernel_matches_ref_odd_shapes(bits, d, block):
    """Pallas codec == jnp oracle on non-block-multiple and tiny shapes:
    mantissas bit-exact, scales/decodes to float-compilation tolerance."""
    rng = np.random.RandomState(d + bits)
    x = jnp.asarray(rng.randn(d).astype(np.float32))
    q, s = ops.block_quantize(x, block=block, bits=bits)
    qr, sr = _quant_ref(x, block, bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    y = ops.block_dequantize(q, s, d=d, block=block, bits=bits)
    yr = ref.block_dequantize_ref(qr, sr, bits=bits,
                                  cols=block).reshape(-1)[:d]
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6)


@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_kernel_zero_blocks(bits):
    """An all-zero block must get scale 0 and decode to exact zeros (no 0/0)."""
    x = jnp.concatenate([jnp.zeros(64), jnp.ones(64)])
    q, s = ops.block_quantize(x, block=64, bits=bits)
    assert float(s[0]) == 0.0 and float(s[1]) > 0.0
    y = np.asarray(ops.block_dequantize(q, s, d=128, block=64, bits=bits))
    assert (y[:64] == 0.0).all()
    np.testing.assert_allclose(y[64:], 1.0, rtol=2e-1 if bits == 4 else 2e-2)


@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_kernel_nonfinite_guard(bits):
    """inf/nan inputs quantize to exactly 0 with a finite scale (EF re-sends
    the lost mass as ordinary residual) — kernel and oracle agree."""
    x = jnp.asarray([1.0, jnp.inf, -jnp.inf, jnp.nan, -2.0, 0.5, 0.0, 3.0],
                    jnp.float32)
    q, s = ops.block_quantize(x, block=4, bits=bits)
    qr, sr = _quant_ref(x, 4, bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    y = np.asarray(ops.block_dequantize(q, s, d=8, block=4, bits=bits))
    assert np.isfinite(y).all()
    assert y[1] == 0.0 and y[2] == 0.0 and y[3] == 0.0


@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_kernel_bf16(bits):
    """bf16 wires quantize through the same f32 arithmetic as the oracle.
    bf16's coarse grid puts inputs exactly on round-to-nearest boundaries,
    where the 1-ULP scale difference between the two compilations may flip a
    mantissa by one step — so kernel and oracle decodes must agree to one
    grid step, and BOTH must satisfy the round-trip bound vs the input."""
    rng = np.random.RandomState(bits)
    x = jnp.asarray(rng.randn(512), jnp.bfloat16)
    q, s = ops.block_quantize(x, block=128, bits=bits)
    qr, sr = _quant_ref(x, 128, bits)
    y = np.asarray(ops.block_dequantize(q, s, d=512, block=128, bits=bits))
    yr = np.asarray(ref.block_dequantize_ref(qr, sr, bits=bits,
                                             cols=128)).reshape(-1)
    step = np.repeat(np.asarray(s), 128)
    assert (np.abs(y - yr) <= step * (1 + 1e-6)).all()
    xf = np.asarray(x, np.float32)
    bound = np.abs(xf.reshape(4, 128)).max(1) / 2 ** (bits - 1)
    for dec in (y, yr):
        assert (np.abs(dec - xf).reshape(4, 128)
                <= bound[:, None] + 1e-6).all()


def test_bisection_threshold_exactness():
    """Bisection recovers the k-th largest magnitude to float precision."""
    from repro.kernels.topk_compress import _bisect_threshold
    rng = np.random.RandomState(0)
    ab = jnp.abs(jnp.asarray(rng.randn(4, 1024).astype(np.float32)))
    for k in (1, 16, 300, 1024):
        t = np.asarray(_bisect_threshold(ab, k))
        kth = np.sort(np.asarray(ab), axis=1)[:, -k]
        cnt = (np.asarray(ab) >= t[:, None]).sum(1)
        assert (cnt >= k).all()
        np.testing.assert_allclose(t, kth, rtol=2e-4)   # 26 bisection iters
