"""Property-based carrier equivalence harness (core/carriers.py).

Carrier correctness was previously asserted on a handful of hand-picked
shapes; this harness states the invariants as properties and sweeps
(method × compressor × carrier × shape), including non-block-multiple sizes
and scalar leaves:

  (a) ``local_c`` IS the decode of the wire, bit-exactly — the EF invariant
      (client state and server aggregate must agree on what was shipped);
  (b) ``aggregate`` equals the mean of the per-client wire decodes;
  (c) quantize round-trip error ≤ absmax/2^(bits−1) per block;
  (d) the composed compressor decode∘Q∘C still satisfies Definition 1 with
      the predicted constant (``QuantCarrier.composed_err_factor``);
  (e) one EF round keeps server and clients consistent: the server increment
      equals the mean client g-increment for every delta-mode method/carrier.

Each property is a plain checker driven two ways: a deterministic
parametrized grid that ALWAYS runs (the container has no hypothesis), and a
hypothesis fuzzer over the same space that engages wherever hypothesis is
installed (CI, dev machines with requirements-dev.txt).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    settings.register_profile("carrier", max_examples=10, deadline=None)
    settings.load_profile("carrier")
except ImportError:                                   # deterministic grid only
    HAVE_HYPOTHESIS = False

from repro.core import carriers as carrier_lib
from repro.core import compressors as C
from repro.core import distributed as D
from repro.core import ef
from repro.kernels import ref as kref

fuzz = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis fuzzing needs hypothesis "
    "(pip install -r requirements-dev.txt); the deterministic grid ran")

CARRIER_NAMES = sorted(carrier_lib.REGISTRY)

# deterministic compressors that every non-dense wire can ship; the block
# sizes are small and non-pretty on purpose (the dims below are NOT multiples)
COMPRESSORS = {
    "topk": lambda: C.TopK(ratio=0.3),
    "block_topk": lambda: C.BlockTopK(block=12, k_per_block=5),
    "identity": lambda: C.Identity(),
}

# one representative per shape class — scalar leaf, exact single block,
# non-block-multiple, multi-block (also crossing the quant qblock boundary);
# the hypothesis fuzzers sweep the full 1..300 range in CI
DIMS = [1, 12, 50, 257]
DELTA_METHODS = ["ef21_sgd", "ef21_sgdm"]


def _vec(d, seed, rows=None):
    rng = np.random.RandomState(seed)
    shape = (d,) if rows is None else (rows, d)
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


def _require_wire(carrier_name, comp, unsupported=pytest.skip):
    """Reject combos whose carrier has no wire for this compressor (the plan
    degrades to dense and encode is never reached in production — that
    degradation is itself asserted in tests/test_carriers.py). The grid
    drivers skip; the fuzzers discard the example (hypothesis.assume)."""
    car = carrier_lib.make(carrier_name)
    plan, reason = car.plan_with_reason(ef.EF21SGD(compressor=comp))
    if plan == "dense" and car.name not in ("dense", "fused"):
        unsupported(f"{carrier_name} has no wire for this combo: {reason}")
    return car


def _assume_supported(msg):
    hypothesis.assume(False)


# ---------------------------------------------------------------------------
# (a) local_c == decode(wire), bit-exact
# ---------------------------------------------------------------------------

def check_local_c_is_wire_decode(carrier_name, comp_name, d, seed,
                                 unsupported=pytest.skip):
    comp = COMPRESSORS[comp_name]()
    car = _require_wire(carrier_name, comp, unsupported)
    x = _vec(d, seed)

    @jax.jit                       # one compile per case, not one per op
    def case(x):
        wire = car.encode(comp, x)
        return car.local_c(comp, x, wire), car.decode(comp, wire, d=d,
                                                      dtype=x.dtype)

    c, dec = case(x)
    np.testing.assert_array_equal(np.asarray(c),
                                  np.asarray(dec).reshape(c.shape))


@pytest.mark.parametrize("carrier_name", CARRIER_NAMES)
@pytest.mark.parametrize("comp_name", sorted(COMPRESSORS))
@pytest.mark.parametrize("d", DIMS)
def test_local_c_is_wire_decode_bit_exact(carrier_name, comp_name, d):
    """(a) what the client keeps equals the decode of what it shipped —
    bit-exactly, for every carrier (a drifted reimplementation of local_c
    would silently break error feedback on ties/quantization)."""
    check_local_c_is_wire_decode(carrier_name, comp_name, d, seed=d)


if HAVE_HYPOTHESIS:
    @fuzz
    @given(st.sampled_from(CARRIER_NAMES),
           st.sampled_from(sorted(COMPRESSORS)),
           st.integers(1, 300), st.integers(0, 10_000))
    def test_local_c_is_wire_decode_fuzz(carrier_name, comp_name, d, seed):
        check_local_c_is_wire_decode(carrier_name, comp_name, d, seed,
                                     unsupported=_assume_supported)


# ---------------------------------------------------------------------------
# (b) aggregate == mean of per-client decodes
# ---------------------------------------------------------------------------

def check_aggregate_is_mean_of_decodes(carrier_name, comp_name, d, n, seed,
                                       unsupported=pytest.skip):
    comp = COMPRESSORS[comp_name]()
    car = _require_wire(carrier_name, comp, unsupported)
    xs = _vec(d, seed, rows=n)

    @jax.jit
    def case(xs):
        wire = jax.vmap(lambda v: car.encode(comp, v))(xs)
        agg = car.aggregate(comp, wire, d=d, dtype=xs.dtype, dp=n)
        decs = jax.vmap(lambda i: car.decode(
            comp, jax.tree_util.tree_map(lambda a: a[i], wire),
            d=d, dtype=xs.dtype))(jnp.arange(n))
        return agg, decs

    agg, decs = case(xs)
    np.testing.assert_allclose(np.asarray(agg),
                               np.asarray(decs).mean(0),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("carrier_name", CARRIER_NAMES)
@pytest.mark.parametrize("comp_name", sorted(COMPRESSORS))
@pytest.mark.parametrize("d,n", [(1, 2), (12, 3), (50, 4)])
def test_aggregate_is_mean_of_decodes(carrier_name, comp_name, d, n):
    """(b) the server-side aggregate is exactly the mean of the per-client
    wire decodes (scatter-add collisions must SUM, quantized wires must
    dequantize before averaging)."""
    check_aggregate_is_mean_of_decodes(carrier_name, comp_name, d, n,
                                       seed=d * 7 + n)


if HAVE_HYPOTHESIS:
    @fuzz
    @given(st.sampled_from(CARRIER_NAMES),
           st.sampled_from(sorted(COMPRESSORS)),
           st.integers(1, 300), st.integers(1, 5), st.integers(0, 10_000))
    def test_aggregate_is_mean_of_decodes_fuzz(carrier_name, comp_name, d, n,
                                               seed):
        check_aggregate_is_mean_of_decodes(carrier_name, comp_name, d, n,
                                           seed,
                                           unsupported=_assume_supported)


# ---------------------------------------------------------------------------
# (c) quantize round-trip error bound
# ---------------------------------------------------------------------------

def check_quantize_roundtrip_bound(bits, rows, cols, seed):
    x = _vec(cols, seed, rows=rows)
    q, s = kref.block_quantize_ref(x, bits)
    y = kref.block_dequantize_ref(q, s, bits=bits, cols=cols)
    err = np.abs(np.asarray(y) - np.asarray(x))
    bound = np.abs(np.asarray(x)).max(axis=1) / 2 ** (bits - 1)
    assert (err <= bound[:, None] + 1e-7).all()


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("rows,cols", [(1, 1), (2, 7), (5, 16), (3, 33)])
def test_quantize_roundtrip_error_bound(bits, rows, cols):
    """(c) per-block round-trip error ≤ absmax/2^(bits−1): the grid step is
    absmax/qmax and round-to-nearest loses at most half a step, so the bound
    holds with a factor-2 margin."""
    check_quantize_roundtrip_bound(bits, rows, cols, seed=rows * 31 + cols)


if HAVE_HYPOTHESIS:
    @fuzz
    @given(st.sampled_from([8, 4]), st.integers(1, 12), st.integers(1, 40),
           st.integers(0, 10_000))
    def test_quantize_roundtrip_error_bound_fuzz(bits, rows, cols, seed):
        check_quantize_roundtrip_bound(bits, rows, cols, seed)


# ---------------------------------------------------------------------------
# (d) composed compressor still satisfies Definition 1 with the predicted α
# ---------------------------------------------------------------------------

def check_composed_definition1(carrier_name, comp_name, d, seed,
                               unsupported=pytest.skip):
    comp = COMPRESSORS[comp_name]()
    car = _require_wire(carrier_name, comp, unsupported)
    x = _vec(d, seed)
    cx = np.asarray(jax.jit(
        lambda x: car.decode(comp, car.encode(comp, x), d=d,
                             dtype=x.dtype))(x))
    err = float(np.sum((cx - np.asarray(x)) ** 2))
    nx = float(np.sum(np.asarray(x) ** 2))
    factor = car.composed_err_factor(comp, d)
    assert err <= factor * nx + 1e-6
    assert car.composed_alpha(comp, d) == pytest.approx(
        max(0.0, 1.0 - factor))


@pytest.mark.parametrize("carrier_name", ["quant8", "quant4"])
@pytest.mark.parametrize("comp_name", sorted(COMPRESSORS))
@pytest.mark.parametrize("d", DIMS)
def test_composed_compressor_satisfies_definition1(carrier_name, comp_name,
                                                   d):
    """(d) decode∘quantize∘C is still a Definition-1 compressor with the
    predicted constant: ‖QC(x) − x‖² ≤ (√(1−α) + √ε)²·‖x‖²."""
    check_composed_definition1(carrier_name, comp_name, d, seed=d * 13)


if HAVE_HYPOTHESIS:
    @fuzz
    @given(st.sampled_from(["quant8", "quant4"]),
           st.sampled_from(sorted(COMPRESSORS)),
           st.integers(1, 300), st.integers(0, 10_000))
    def test_composed_definition1_fuzz(carrier_name, comp_name, d, seed):
        check_composed_definition1(carrier_name, comp_name, d, seed,
                                   unsupported=_assume_supported)


# ---------------------------------------------------------------------------
# (e) one EF round: server increment == mean client increment
# ---------------------------------------------------------------------------

def check_ef_round_consistency(carrier_name, method_name, d, seed):
    comp = C.BlockTopK(block=12, k_per_block=5)
    kwargs = {"compressor": comp}
    if method_name == "ef21_sgdm":
        kwargs["eta"] = 0.4
    method = ef.make(method_name, **kwargs)
    dp = 3
    grads = {"w": _vec(d, seed, rows=dp)}
    efc = D.EFConfig(method=method, carrier=carrier_name)
    state = D.init_ef_state(efc, {"w": jnp.zeros((d,), jnp.float32)}, dp,
                            init_grads=grads)
    g0_client = np.asarray(state["clients"]["g"]["w"])
    g0_server = np.asarray(state["server"]["w"])
    g_new, state_new = jax.jit(
        lambda g, s: D.ef_round(efc, g, s, None))(grads, state)
    d_server = np.asarray(g_new["w"]) - g0_server
    d_clients = (np.asarray(state_new["clients"]["g"]["w"])
                 - g0_client).mean(0)
    np.testing.assert_allclose(d_server, d_clients, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("carrier_name", CARRIER_NAMES)
@pytest.mark.parametrize("method_name", DELTA_METHODS)
@pytest.mark.parametrize("d", [12, 50])
def test_ef_round_server_matches_mean_client_increment(carrier_name,
                                                       method_name, d):
    """(e) transport neutrality of one full EF round: for delta-mode methods
    the server increment is the mean of the client gᵢ increments, whatever
    wire carried them — if a carrier dropped or double-counted mass, the two
    sides would disagree and EF would never re-send the difference."""
    check_ef_round_consistency(carrier_name, method_name, d, seed=d * 3)


if HAVE_HYPOTHESIS:
    @fuzz
    @given(st.sampled_from(CARRIER_NAMES), st.sampled_from(DELTA_METHODS),
           st.integers(2, 150), st.integers(0, 10_000))
    def test_ef_round_consistency_fuzz(carrier_name, method_name, d, seed):
        check_ef_round_consistency(carrier_name, method_name, d, seed)
