"""CI/tooling guards: the tier1/slow marker scheme stays airtight and the CI
job keeps its gates.

The PR gate is ``pytest -m tier1`` — it only works if EVERY test carries
exactly one of the two tier markers. tests/conftest.py auto-applies tier1 to
everything not marked slow, so the scheme is enforced mechanically; the audit
below re-collects the suite in a subprocess and fails if any test escapes it
(e.g. a new tests/ subtree outside the conftest, or the auto-marker hook
being edited away) — an unmarked slow test sneaking into PR CI is exactly
the regression this guards."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def test_every_test_carries_exactly_one_tier_marker():
    """Selecting the violators — tests with neither marker, or with both —
    must collect NOTHING (pytest exit code 5 = no tests selected)."""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "tests", "--collect-only", "-q",
         "-p", "no:cacheprovider",
         "-m", "(not tier1 and not slow) or (tier1 and slow)"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 5, (
        "tests escaped the tier1/slow marker scheme (the PR gate would "
        "mis-tier them):\n" + out.stdout + out.stderr)
    assert "deselected" in out.stdout


def test_ruff_config_checked_in_and_ci_runs_it():
    """The lint gate is real: ruff.toml exists with the correctness ruleset,
    and ci.yml runs `ruff check` over src and tests."""
    path = os.path.join(ROOT, "ruff.toml")
    assert os.path.exists(path), "ruff.toml missing — the lint gate needs "\
        "its config checked in"
    with open(path) as f:
        cfg = f.read()
    for rule in ("F401", "F82"):
        assert rule in cfg, f"ruff config dropped the {rule} rule"
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert "ruff check" in ci, "ci.yml no longer runs the ruff lint step"
    for tree in ("src", "tests"):
        assert tree in ci.split("ruff check", 1)[1].splitlines()[0], tree


def test_ruff_clean_when_available():
    """`ruff check` passes over the whole repo — enforced here whenever the
    container ships ruff (CI installs it; the baked image may not)."""
    import shutil
    ruff = shutil.which("ruff")
    if ruff is None:
        pytest.skip("ruff not installed in this environment")
    out = subprocess.run(
        [ruff, "check", "src", "tests", "benchmarks", "examples"],
        cwd=ROOT, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr


def test_checked_in_bench_ledgers_validate():
    """The perf ledgers at the repo root (DESIGN.md §10) are schema-valid,
    and the acceptance bars ride the shared gate
    (``benchmarks.common.check_no_regression``): the newest full-geometry
    fused-round run must hold the ≥2× fused-vs-unfused claim."""
    import json
    sys.path.insert(0, ROOT)
    from benchmarks.common import check_no_regression, validate_bench
    for name in ("BENCH_kernels.json", "BENCH_fused_round.json",
                  "BENCH_roofline.json", "BENCH_serving.json",
                  "BENCH_hierarchy.json"):
        path = os.path.join(ROOT, name)
        assert os.path.exists(path), f"{name} missing from the repo root"
        with open(path) as f:
            payload = json.load(f)
        errs = validate_bench(payload)
        assert not errs, f"{name} malformed: {errs}"
    assert check_no_regression("fused_round", "fused_round_vs_unfused_step",
                               2.0, full_geometry_only=True) >= 2.0


def test_ci_runs_bench_smoke_and_ledger_validation():
    """ci.yml keeps the bench-smoke step: tiny kernel_bench +
    fused_round_bench + roofline runs and the bench/v1 schema gate over
    all three checked-in ledgers."""
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert "kernel_bench --tiny" in ci, "CI dropped the tiny kernel bench"
    assert "fused_round_bench --tiny" in ci, (
        "CI dropped the tiny fused-round bench")
    assert "roofline --tiny" in ci, "CI dropped the tiny roofline bench"
    assert "serve_bench --tiny" in ci, "CI dropped the tiny serving bench"
    assert "hierarchy_bench --tiny" in ci, (
        "CI dropped the tiny hierarchy bench")
    assert "benchmarks.common --validate" in ci, (
        "CI no longer validates the BENCH ledgers")
    for name in ("BENCH_kernels.json", "BENCH_fused_round.json",
                 "BENCH_roofline.json", "BENCH_serving.json",
                 "BENCH_hierarchy.json"):
        assert name in ci, f"CI ledger gate no longer covers {name}"
    # every checked-in ledger must exist at the repo root so the CI
    # append+validate path starts from the committed state
    for name in ("BENCH_kernels.json", "BENCH_fused_round.json",
                 "BENCH_roofline.json", "BENCH_serving.json",
                 "BENCH_hierarchy.json"):
        assert os.path.exists(os.path.join(ROOT, name)), (
            f"{name} is not checked in at the repo root")


def test_ci_runs_streaming_smoke_and_serving_ledger_claim():
    """ci.yml keeps the trainer→replica streaming e2e cell (train
    --publish-stream feeding serve --serve-stream), and the checked-in
    serving ledger records the acceptance claim: wire bytes per sync ≥ 20×
    under a dense f32 push at quant4 (ISSUE 8)."""
    import json
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert "--publish-stream" in ci, (
        "CI dropped the trainer-side streaming smoke (train "
        "--publish-stream)")
    assert "--serve-stream" in ci, (
        "CI dropped the replica-side streaming smoke (serve --serve-stream)")
    sys.path.insert(0, ROOT)
    from benchmarks.common import check_no_regression
    assert check_no_regression("serving", "wire_bytes_vs_dense_f32",
                               20.0, full_geometry_only=True) >= 20.0


def test_ci_runs_multiprocess_smoke_and_ledger_records_it():
    """ci.yml keeps the TWO-PROCESS streaming smoke (serve --processes: a
    worker process per replica tailing the wire over launch/transport.py),
    and the checked-in serving ledger carries a full-geometry
    ``serving_multiproc`` section with the QPS/p50/p99/staleness the
    multi-process fleet actually measured (ISSUE 9)."""
    import json
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert "--processes" in ci, (
        "CI dropped the multi-process streaming smoke (serve --processes)")
    with open(os.path.join(ROOT, "BENCH_serving.json")) as f:
        serving = json.load(f)
    mp = [r["serving_multiproc"] for r in serving["runs"]
          if "serving_multiproc" in r and not r["geometry"].get("tiny")]
    assert mp, ("no full-geometry multi-process serving run recorded in "
                "BENCH_serving.json")
    for section in mp:
        for key, stats in section.items():
            for field in ("qps", "p50_ms", "p99_ms", "staleness_max",
                          "workers", "restarts"):
                assert field in stats, (key, field)


def test_ci_runs_hierarchy_smoke_and_ledger_records_claim():
    """ci.yml keeps the two-tier hierarchical cells — the forced-8-device
    multi_pod ``--hops`` train smoke and the 2-process jax.distributed
    fabric smoke (launch/multiproc.py) — and the checked-in hierarchy
    ledger holds the acceptance claim: ≥ 8× cross-pod wire reduction for
    the quant4 cross hop vs the flat quant8 wire at the gemma2-9b pod
    geometry, anchored by a bit-exact flat-equivalence simulator run
    (DESIGN.md §13)."""
    import json
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert "--hops" in ci, (
        "CI dropped the hierarchical --hops train smoke")
    assert "xla_force_host_platform_device_count=8" in ci, (
        "CI's --hops smoke no longer forces the 8-device multi_pod mesh")
    assert "repro.launch.multiproc" in ci, (
        "CI dropped the 2-process jax.distributed fabric smoke")
    sys.path.insert(0, ROOT)
    from benchmarks.common import check_no_regression
    assert check_no_regression("hierarchy", "cross_pod_wire_vs_flat_quant8",
                               8.0) >= 8.0
    with open(os.path.join(ROOT, "BENCH_hierarchy.json")) as f:
        ledger = json.load(f)
    anchored = [r["anchors"] for r in ledger["runs"] if "anchors" in r]
    assert anchored, "no simulator anchors recorded in BENCH_hierarchy.json"
    for a in anchored:
        assert a["flat_equivalence_bitexact"], (
            "a recorded run lost the trivial-cross flat-equivalence anchor")
        assert a["sim_accounting_consistent"], (
            "simulator cross-wire accounting drifted from the formula")


def test_serving_ledger_records_remote_transport_cell():
    """The checked-in serving ledger carries a full-geometry
    ``serving_remote`` section: the SAME load with the fleet tailing the
    stream over tcp:// (launch/transport.py TailServer RPC) — the socket
    transport's QPS/p50/p99 measured next to the in-process numbers."""
    import json
    with open(os.path.join(ROOT, "BENCH_serving.json")) as f:
        serving = json.load(f)
    remote = [r["serving_remote"] for r in serving["runs"]
              if "serving_remote" in r and not r["geometry"].get("tiny")]
    assert remote, ("no full-geometry remote-transport serving run "
                    "recorded in BENCH_serving.json")
    for section in remote:
        for key, stats in section.items():
            assert stats.get("transport") == "tcp", (key, stats)
            for field in ("qps", "p50_ms", "p99_ms", "staleness_max"):
                assert field in stats, (key, field)


def test_ci_workflow_keeps_tier_gate_and_timing_report():
    """The CI yaml must keep (a) the tier-1 PR gate and (b) the
    --durations=15 timing report that makes slow-test creep visible in every
    run's log."""
    path = os.path.join(ROOT, ".github", "workflows", "ci.yml")
    with open(path) as f:
        text = f.read()
    assert "-m tier1" in text, "PR gate no longer runs the tier1 marker"
    pytest_lines = [ln for ln in text.splitlines() if "-m pytest" in ln]
    assert pytest_lines, "no pytest invocations in ci.yml?"
    for ln in pytest_lines:
        assert "--durations=15" in ln, f"timing report missing from: {ln}"
