"""Transport-tail tests (launch/transport.py): the FileTail poller must be an
exact stand-in for reading the WireLog directly, the SocketTail RPC backend
must mirror records/bootstraps byte-for-byte through the same local decode
path, and a ServeReplica joining over ``tcp://`` must land bit-identical to
one on the shared filesystem — the transport moves bytes, never meaning."""
import os

import jax
import numpy as np
import pytest

from repro.core import stream as stream_lib
from repro.launch import fleet as fleet_lib
from repro.launch import transport as transport_lib
from repro.launch.session import Session
from repro.launch.spec import RunSpec

TINY = dict(arch="smollm-360m", smoke=True, clients=2, global_batch=4,
            seq_len=32)
QUANT4 = dict(compressor="block_topk", ratio=0.1,
              downlink_carrier="quant4", downlink_ratio=0.05)


@pytest.fixture(scope="module")
def wire(tmp_path_factory):
    """One quant4 stream shared by the transport tests: 4 published steps,
    bootstraps at 0/2/4, the trainer session kept alive so tests can extend
    the stream, plus per-step param snapshots."""
    root = tmp_path_factory.mktemp("wire_tp")
    sess = Session(RunSpec(**TINY, **QUANT4))
    sess.publish_to(str(root), bootstrap_every=2)
    snaps = {}
    for _ in range(4):
        sess.step_once()
        snaps[sess.step] = jax.device_get(sess.params)
    return {"dir": str(root), "sess": sess, "snaps": snaps}


@pytest.fixture(scope="module")
def server(wire):
    srv = transport_lib.TailServer(wire["dir"]).start()
    yield srv
    srv.stop()


def _records_equal(a, b):
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        da, db = ra.__dict__, rb.__dict__
        if da.keys() != db.keys():
            return False
        for k in da:
            la = jax.tree_util.tree_leaves(da[k])
            lb = jax.tree_util.tree_leaves(db[k])
            if len(la) != len(lb) or not all(
                    np.array_equal(np.asarray(x), np.asarray(y))
                    for x, y in zip(la, lb)):
                return False
    return True


# ---------------------------------------------------------------------------
# file backend
# ---------------------------------------------------------------------------

def test_file_tail_matches_wirelog(wire):
    log = stream_lib.WireLog(wire["dir"])
    tail = transport_lib.make_tail(wire["dir"])
    assert isinstance(tail, transport_lib.FileTail)
    assert tail.last_step() == log.last_step()
    assert tail.bootstrap_steps() == log.bootstrap_steps()
    assert tail.bootstrap_path(0) == log.bootstrap_path(0)
    assert tail.latest_bootstrap(upto=3) == log.bootstrap_path(2)
    for step in (1, tail.last_step()):
        assert _records_equal(tail.read_step(step), log.read_step(step))


def test_file_tail_head_cache_tracks_new_records(wire):
    """The cached head must advance when the trainer publishes — the cache
    key is the newest step's record listing, so an unchanged directory is
    one listdir and a grown one re-verifies."""
    tail = transport_lib.FileTail(wire["dir"])
    before = tail.last_step()
    assert tail.last_step() == before          # cache hit, same answer
    sess = wire["sess"]
    sess.step_once()
    wire["snaps"][sess.step] = jax.device_get(sess.params)
    assert tail.last_step() == before + 1      # cache invalidated by growth


def test_file_tail_empty_dir_is_none(tmp_path):
    tail = transport_lib.FileTail(str(tmp_path))
    assert tail.last_step() is None
    assert tail.latest_bootstrap() is None
    with pytest.raises(stream_lib.StreamError):
        tail.read_step(0)


# ---------------------------------------------------------------------------
# socket RPC backend
# ---------------------------------------------------------------------------

def test_socket_tail_parity_with_file(wire, server, tmp_path):
    log = stream_lib.WireLog(wire["dir"])
    tail = transport_lib.make_tail(server.address,
                                   cache_dir=str(tmp_path / "mirror"))
    assert isinstance(tail, transport_lib.SocketTail)
    assert tail.last_step() == log.last_step()
    assert tail.bootstrap_steps() == log.bootstrap_steps()
    for step in (1, 2):
        assert _records_equal(tail.read_step(step), log.read_step(step))
    # the mirrored bootstrap is byte-identical to the server's file
    bp = tail.bootstrap_path(2)
    assert os.path.exists(bp) and bp != log.bootstrap_path(2)
    with open(bp, "rb") as fa, open(log.bootstrap_path(2), "rb") as fb:
        assert fa.read() == fb.read()
    tail.close()


def test_socket_tail_missing_step_raises_gap(server, tmp_path):
    tail = transport_lib.make_tail(server.address,
                                   cache_dir=str(tmp_path / "mirror"))
    with pytest.raises(stream_lib.StreamGapError):
        tail.read_step(999)
    tail.close()


def test_socket_tail_reconnects_after_drop(wire, server, tmp_path):
    """A dropped connection between polls must be survived transparently —
    the client reconnects once and repeats the call."""
    tail = transport_lib.make_tail(server.address,
                                   cache_dir=str(tmp_path / "mirror"))
    head = tail.last_step()
    tail.close_socket()                        # simulate a dropped transport
    assert tail.last_step() == head
    tail.close()


def test_make_tail_passthrough_and_dispatch(wire):
    ft = transport_lib.FileTail(wire["dir"])
    assert transport_lib.make_tail(ft) is ft
    assert isinstance(transport_lib.make_tail(wire["dir"]),
                      transport_lib.FileTail)


# ---------------------------------------------------------------------------
# a replica over tcp:// is the same replica
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_replica_over_tcp_bit_identical(wire, server, tmp_path):
    """ServeReplica(tcp://…) must land on exactly the trainer's params —
    checkpoint + replay through the mirrored files is the SAME decode path
    as the shared-filesystem tail, so identity survives the transport."""
    tail = transport_lib.make_tail(server.address,
                                   cache_dir=str(tmp_path / "mirror"))
    rep = fleet_lib.ServeReplica(tail, bootstrap_step=0, name="tcp0")
    rep.sync()
    head = stream_lib.WireLog(wire["dir"]).last_step()
    assert rep.step == head
    la = jax.tree_util.tree_leaves(rep.params)
    lb = jax.tree_util.tree_leaves(wire["snaps"][head])
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))
