"""Per-parameter-group compression schedules (core/schedule.py, DESIGN.md §9).

The load-bearing guarantees:
  * a UNIFORM one-group schedule is bit-identical (params, full ef_state
    incl. the downlink memory h, trajectory) to the legacy single-compressor
    path — the regression anchor, pinned over a (method × carrier × downlink)
    grid on the production train step and on the vmap simulator;
  * a MIXED schedule trains end-to-end through Session (uplink + quant4
    downlink) with per-group wire accounting that matches hand-computed
    group totals;
  * spec v2 → v3 auto-upgrade round-trips (tests/test_spec.py) and
    kill-and-resume covers per-group EF state bit-exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import carriers as carrier_lib
from repro.core import compressors as C
from repro.core import distributed as D
from repro.core import ef, problems, simulate
from repro.core import schedule as S
from repro.launch import build as build_lib
from repro.launch import session as session_lib
from repro.launch import spec as spec_lib
from repro.launch.session import Session
from repro.launch.spec import RunSpec

BTK = C.BlockTopK(block=8, k_per_block=3)
DOWN_BTK = C.BlockTopK(block=8, k_per_block=2)
TINY = dict(arch="smollm-360m", smoke=True, clients=2, global_batch=4,
            seq_len=32)
MIXED_GROUPS = [
    {"pattern": "norm|bias", "carrier": "dense"},
    {"pattern": "embed", "carrier": "quant4", "ratio": 0.05},
    {"pattern": "*", "carrier": "sparse", "ratio": 0.02,
     "downlink_carrier": "quant4", "downlink_ratio": 0.05},
]


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# schedule construction / resolution semantics
# ---------------------------------------------------------------------------

def test_schedule_validates_at_construction():
    ok = S.CompressionSchedule((S.Group(pattern="norm"),
                                S.Group(pattern="*")))
    assert not ok.has_downlink
    cases = [
        ((), "at least one group"),
        ((S.Group(pattern="norm"),), "catch-all"),
        ((S.Group(pattern="*"), S.Group(pattern="norm")), "LAST"),
        ((S.Group(pattern="a"), S.Group(pattern="a"),
          S.Group(pattern="*")), "duplicate"),
        ((S.Group(pattern="a=b"), S.Group(pattern="*")), "reserved"),
        # an empty '|' token is a substring of EVERY path — 'norm|' would
        # silently swallow the whole model into one group
        ((S.Group(pattern="norm|"), S.Group(pattern="*")), "empty"),
        # a '*' token inside a composite pattern shadows every later group
        ((S.Group(pattern="embed|*"), S.Group(pattern="*")), "standalone"),
        ((S.Group(pattern="*", carrier="laser"),), "unknown carrier"),
        ((S.Group(pattern="*", down_carrier="fused"),), "downlink"),
        ((S.Group(pattern="*", state_dtype="fp8"),), "state_dtype"),
    ]
    for groups, match in cases:
        with pytest.raises(ValueError, match=match):
            S.CompressionSchedule(groups)


def test_spec_mirrors_match_schedule_module():
    """The jax-free spec-layer mirrors of the schedule surface must equal
    the real module's constants (same contract as every other mirror in
    launch/spec.py), and the group-entry key set must cover exactly what
    session.make_schedule consumes."""
    assert spec_lib.GROUP_STATE_DTYPES == S.GROUP_STATE_DTYPES
    assert spec_lib.PATTERN_RESERVED == S.PATTERN_RESERVED
    for pat in ("norm", "norm|bias", "*", "norm|", "|", "embed|*", "a||b"):
        assert spec_lib.pattern_token_errors(pat) \
            == S.pattern_token_errors(pat), pat
    resolved = spec_lib.resolved_groups(RunSpec())[0]
    assert set(resolved) == set(spec_lib.GROUP_KEYS)


def test_pattern_matching_is_case_insensitive():
    """Leaf paths are lower-cased; patterns must match regardless of the
    case they were written in (a pattern in the tree's literal mixed case
    must not silently resolve to zero leaves)."""
    tree = {"Embed": jnp.zeros((4,)), "w": jnp.zeros((4,))}
    sched = S.CompressionSchedule((S.Group(pattern="Embed"),
                                   S.Group(pattern="*")))
    assert sched.resolve(tree) == (0, 1)


def test_first_match_wins_every_leaf_lands_in_exactly_one_group():
    tree = {"embed": jnp.zeros((4, 8)),
            "layers": {"attn": {"wq": jnp.zeros((8, 8)),
                                "norm": jnp.zeros((8,))},
                       "mlp": {"w_up": jnp.zeros((8, 16)),
                               "norm": jnp.zeros((8,))}},
            "final_norm": jnp.zeros((8,))}
    sched = S.CompressionSchedule((
        S.Group(pattern="norm|bias"),          # wins over 'attn' for
        S.Group(pattern="attn"),               # layers/attn/norm
        S.Group(pattern="*"),
    ))
    paths = S.leaf_paths(tree)
    gids = sched.resolve(tree)
    by_path = dict(zip(paths, gids))
    assert by_path["embed"] == 2
    assert by_path["layers/attn/wq"] == 1
    assert by_path["layers/attn/norm"] == 0     # first match wins
    assert by_path["layers/mlp/norm"] == 0
    assert by_path["final_norm"] == 0
    assert by_path["layers/mlp/w_up"] == 2
    # totality: every leaf got exactly one group index
    assert len(gids) == len(paths)


def test_uniform_schedule_and_alpha_min():
    sched = S.CompressionSchedule((
        S.Group(pattern="b", compressor=C.Identity()),
        S.Group(pattern="*", compressor=C.TopK(ratio=0.25)),
    ))
    tree = {"w": jnp.zeros((16,)), "b": jnp.zeros((4,))}
    # α of the composed compressor = min over groups (identity α=1)
    assert S.alpha_min(sched, tree) == pytest.approx(0.25)
    uni = S.CompressionSchedule.uniform(BTK, carrier="sparse",
                                        down_carrier="quant4",
                                        down_compressor=DOWN_BTK)
    assert len(uni.groups) == 1 and uni.has_downlink


# ---------------------------------------------------------------------------
# THE regression anchor: uniform one-group schedule ≡ legacy path, bit-exact
# ---------------------------------------------------------------------------

def _loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


@pytest.fixture
def lin_setup():
    rng = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    x = jax.random.normal(rng, (16, 8))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (8, 4))
    return params, {"x": x, "y": x @ w}


def _run_train(setup, efc, steps=6, dp=4):
    from repro.optim import optimizer as opt_lib
    params, batch = setup
    opt = opt_lib.sgd(0.2)
    step = jax.jit(D.make_train_step(_loss_fn, efc, opt, dp))
    _, _, g0 = D.per_client_value_and_grad(_loss_fn, params, batch, dp)
    p, os_, es = params, opt.init(params), D.init_ef_state(
        efc, params, dp, init_grads=g0)
    rng = jax.random.PRNGKey(1)
    for t in range(steps):
        p, os_, es, _ = step(p, os_, es, batch, jax.random.fold_in(rng, t), t)
    return p, es


def _grid_cells():
    for m_name in ("ef21_sgdm", "ef21_sgd", "ef14_sgd"):
        for carrier in ("dense", "sparse", "quant4", "fused"):
            if carrier == "fused" and m_name == "ef14_sgd":
                continue                      # fused covers EF21-SGD(M) only
            for down in ("dense", "quant4"):
                yield m_name, carrier, down


@pytest.mark.parametrize("m_name,carrier,down", list(_grid_cells()))
def test_uniform_schedule_bit_matches_legacy_path(lin_setup, m_name, carrier,
                                                  down):
    """The schedule grid equivalence harness: for every
    (method × carrier × downlink) cell, the grouped engine under a uniform
    one-group schedule reproduces the pre-refactor single-compressor path
    BIT-exactly — params and the full ef_state (clients, server, and the
    downlink memory h) after a multi-step production train run."""
    kwargs = {"compressor": BTK}
    if m_name == "ef21_sgdm":
        kwargs["eta"] = 0.3
    method = ef.make(m_name, **kwargs)
    down_comp = DOWN_BTK if down != "dense" else None
    legacy = D.EFConfig(method=method, carrier=carrier, down_carrier=down,
                        down_compressor=down_comp)
    uniform = D.EFConfig(method=method, schedule=S.CompressionSchedule.uniform(
        BTK, carrier=carrier, down_carrier=down, down_compressor=down_comp))
    p0, es0 = _run_train(lin_setup, legacy)
    p1, es1 = _run_train(lin_setup, uniform)
    assert sorted(es0) == sorted(es1)          # same state tree (incl. h)
    assert _leaves_equal(p0, p1)
    assert _leaves_equal(es0, es1)


def test_uniform_schedule_bit_matches_legacy_simulator():
    """Same anchor on the third runtime (the vmap simulator), whole
    trajectory, including the per-round wire accounting keys."""
    prob = problems.MLPClassification(n=4, m_per_client=64)
    btk = C.BlockTopK(block=64, k_per_block=8)
    method = ef.EF21SGDM(compressor=btk, eta=0.2)
    down = C.BlockTopK(block=64, k_per_block=4)
    for carrier in ("dense", "sparse", "quant4"):
        legacy = simulate.SimConfig(n=4, batch_size=4, gamma=0.05, steps=12,
                                    carrier=carrier, down_carrier="quant4",
                                    down_compressor=down)
        uniform = dataclasses.replace(
            legacy, carrier="dense", down_carrier="dense",
            down_compressor=None,
            schedule=S.CompressionSchedule.uniform(
                btk, carrier=carrier, down_carrier="quant4",
                down_compressor=down))
        o0 = simulate.run_numpy(prob, method, legacy, seed=0)
        o1 = simulate.run_numpy(prob, method, uniform, seed=0)
        assert np.array_equal(o0["grad_norm_sq"], o1["grad_norm_sq"]), carrier
        assert np.array_equal(o0["loss"], o1["loss"]), carrier
        assert _leaves_equal(o0["x_final"], o1["x_final"])


# ---------------------------------------------------------------------------
# mixed schedules: execution + hand-computed per-group accounting
# ---------------------------------------------------------------------------

def test_mixed_wire_accounting_matches_hand_computed_totals():
    """wire_words_tree sums each group's wire over that group's leaves; the
    expected numbers are computed BY HAND from the carrier formulas."""
    tree = {"embed": jnp.zeros((8, 16)),          # 128 → quant4 group
            "w": jnp.zeros((64,)),                # 64  → sparse catch-all
            "norm": jnp.zeros((4,))}              # 4   → dense group
    emb_comp = C.BlockTopK(block=32, k_per_block=4)
    w_comp = C.BlockTopK(block=16, k_per_block=2)
    down4 = C.BlockTopK(block=16, k_per_block=1)
    sched = S.CompressionSchedule((
        S.Group(pattern="norm", compressor=C.Identity(), carrier="dense"),
        S.Group(pattern="embed", compressor=emb_comp, carrier="quant4"),
        S.Group(pattern="*", compressor=w_comp, carrier="sparse",
                down_carrier="quant4", down_compressor=down4),
    ))
    method = ef.EF21SGDM(compressor=BTK, eta=0.2)
    per, total = S.wire_words_tree(sched, method, tree, "up")
    # dense norm: d = 4 words
    assert per[0] == 4.0
    # quant4 sparse payload, embed: nb=4 blocks × (1 scale + kb·(4/32 bits
    # + 0.5 int16 idx)) = 4 · (1 + 4·0.625) = 14
    assert per[1] == pytest.approx(4 * (1 + 4 * (4 / 32 + 0.5)))
    # sparse (values + int32 idx): 2·nb·kb = 2·4·2 = 16
    assert per[2] == pytest.approx(2 * 4 * 2)
    assert total == pytest.approx(per[0] + per[1] + per[2])
    dper, dtotal = S.wire_words_tree(sched, method, tree, "down")
    # groups without a downlink honestly ship dense: 4 + 128 words
    assert dper[0] == 4.0 and dper[1] == 128.0
    # quant4 downlink on w: nb=4 × (1 + 1·(0.125 + 0.5)) = 6.5
    assert dper[2] == pytest.approx(4 * (1 + 1 * (4 / 32 + 0.5)))
    assert dtotal == pytest.approx(dper[0] + dper[1] + dper[2])
    # the Method-level pytree form keeps the flat-d UNITS: no carrier →
    # idealized coords (paper x-axis) on the uplink, broadcast words down
    assert method.coords_per_message_tree(tree, schedule=sched) == \
        S.coords_tree(sched, method, tree)
    assert method.coords_per_message_tree(
        tree, schedule=sched, direction="down") == dtotal
    # schedule + carrier args would be silently contradictory — hard error
    with pytest.raises(ValueError, match="names its own carrier"):
        method.coords_per_message_tree(tree, schedule=sched, carrier="dense")


def test_mixed_schedule_simulator_reports_per_group_words():
    prob = problems.MLPClassification(n=4, m_per_client=64)
    btk = C.BlockTopK(block=64, k_per_block=8)
    method = ef.EF21SGDM(compressor=btk, eta=0.2)
    sched = S.CompressionSchedule((
        S.Group(pattern="b", compressor=C.Identity(), carrier="dense"),
        S.Group(pattern="*", compressor=btk, carrier="quant4",
                down_carrier="quant4",
                down_compressor=C.BlockTopK(block=64, k_per_block=4)),
    ))
    cfg = simulate.SimConfig(n=4, batch_size=4, gamma=0.05, steps=8,
                             schedule=sched)
    out = simulate.run_numpy(prob, method, cfg, seed=0)
    up = out["wire_words_up_per_group"]
    dn = out["wire_words_down_per_group"]
    x0 = prob.init_x()
    eper, etot = S.wire_words_tree(sched, method, x0, "up")
    assert np.allclose(np.asarray(up), np.asarray(eper) * cfg.n)
    assert out["wire_words_up_per_round"] == pytest.approx(etot * cfg.n)
    dper, dtot = S.wire_words_tree(sched, method, x0, "down")
    assert np.allclose(np.asarray(dn), np.asarray(dper) * cfg.n)
    assert out["wire_words_total_per_round"] == pytest.approx(
        (etot + dtot) * cfg.n)
    # convergence is not wrecked by the mixed wire (loose sanity bound)
    assert np.isfinite(out["grad_norm_sq"]).all()


@pytest.mark.slow
def test_mixed_schedule_trains_end_to_end_through_session():
    """Acceptance: a mixed 3-group schedule (dense norms/biases + quant4
    embeds + sparse catch-all) trains through Session on both the uplink and
    a quant4 downlink, with the resolved table and accounting consistent."""
    spec = RunSpec(**TINY, groups=MIXED_GROUPS)
    sess = Session(spec)
    table = sess.schedule_table()
    assert table is not None and "quant4" in table and "sparse" in table
    hist = sess.train(3, log_every=1)
    assert hist and all(np.isfinite(r["loss"]) for r in hist)
    # the downlink memory h exists (the catch-all group has a downlink)
    assert "h" in sess.ef_state
    # per-group accounting over the REAL param tree matches the table's sums
    sched = session_lib.make_schedule(spec)
    shapes = jax.eval_shape(lambda: sess.params)
    per, total = S.wire_words_tree(sched, sess.method, shapes, "up")
    assert len(per) == 3 and total == pytest.approx(sum(per))
    # dense group ships exactly its param count; mixed groups undercut dense
    gids = sched.resolve(shapes)
    leaves = jax.tree_util.tree_leaves(shapes)
    d_dense = sum(int(x.size) for x, g in zip(leaves, gids) if g == 0)
    d_rest = sum(int(x.size) for x, g in zip(leaves, gids) if g != 0)
    assert per[0] == pytest.approx(d_dense)
    assert per[1] + per[2] < d_rest


def test_kill_and_resume_mixed_schedule_bit_identical(tmp_path):
    """Acceptance: kill-and-resume covers per-group EF state bit-exactly —
    a mixed schedule's ef_state (incl. h) survives a restart and the resumed
    trajectory equals the uninterrupted one."""
    base = RunSpec(**TINY, groups=MIXED_GROUPS)
    unint = Session(base)
    unint.train(4, log_every=1)

    interrupted = Session(dataclasses.replace(base, ckpt_dir=str(tmp_path)))
    interrupted.train(2, log_every=1)
    del interrupted

    resumed = Session.resume(str(tmp_path))
    assert resumed.step == 2
    assert resumed.spec.groups == base.groups
    resumed.train(4, log_every=1)
    assert _leaves_equal(unint.params, resumed.params)
    assert _leaves_equal(unint.ef_state, resumed.ef_state)


# ---------------------------------------------------------------------------
# launch-surface wiring
# ---------------------------------------------------------------------------

def test_schedule_preview_matches_real_carriers_per_group():
    """The jax-free spec.schedule_preview mirror must agree with the real
    carrier objects for every group of a schedule-bearing spec."""
    specs = [
        RunSpec(**TINY, groups=MIXED_GROUPS),
        RunSpec(groups=[{"pattern": "a", "carrier": "quant8"},
                        {"pattern": "*", "carrier": "fused",
                         "compressor": "block_topk"}]),
        RunSpec(compressor="randk",
                groups=[{"pattern": "*", "carrier": "sparse"}]),
    ]
    for spec in specs:
        sched = session_lib.make_schedule(spec)
        method = session_lib.make_method(spec)
        rows = spec_lib.schedule_preview(spec)
        assert len(rows) == len(sched.groups)
        for row, grp in zip(rows, sched.groups):
            m_g = S.group_method(method, grp)
            real = carrier_lib.make(grp.carrier).plan_with_reason(
                m_g, spec.eta)
            assert row["plan"] == real[0], (spec.groups, row)
            assert bool(row["plan_reason"]) == bool(real[1])
            dreal = carrier_lib.make(grp.down_carrier).plan_down_with_reason(
                grp.down_comp())
            if grp.has_downlink:
                assert row["downlink_plan"] == dreal[0]


def test_ef_config_builds_schedule_and_state_pspecs():
    spec = RunSpec(**TINY, groups=MIXED_GROUPS)
    sess = Session(spec)
    efc = session_lib.ef_config(spec, sess.mesh, sess.plan)
    assert efc.schedule is not None and len(efc.schedule.groups) == 3
    assert efc.has_downlink                  # via the catch-all group
    from repro.launch import shardings as sh
    specs = sh.ef_state_pspecs(sess.cfg, sess.mesh, sess.plan, efc.method,
                               downlink=efc.has_downlink,
                               schedule=efc.schedule)
    assert set(specs) == {"clients", "server", "h"}
    assert set(specs["clients"]) == {"v", "g"}


def test_group_state_dtype_overrides_per_group():
    """Per-group EF-state dtypes: one group bf16, one full precision, both
    visible in the initialized client state."""
    spec = RunSpec(**TINY, groups=[
        {"pattern": "embed", "ef_state_dtype": "bfloat16",
         "carrier": "sparse"},
        {"pattern": "*", "carrier": "dense"}])
    sess = Session(spec)
    es = sess.ef_state
    assert es["clients"]["g"]["embed"].dtype == jnp.bfloat16
    assert es["clients"]["g"]["final_norm"].dtype == jnp.float32


def test_build_warns_once_per_distinct_group_reason():
    """Plan-degradation warnings are deduplicated: re-constructing the SAME
    config (a Session builds its EFConfig more than once) warns a single
    time under the stable PlanDegradationWarning category, while a different
    config degrading — even for the same textual reason — still warns."""
    import warnings as W
    from repro.launch import mesh as mesh_lib
    from repro.launch import shardings as sh
    mesh = mesh_lib.make_smoke_mesh()
    plan = sh.ShardPlan()
    build_lib.reset_plan_warnings()
    sched = S.CompressionSchedule((
        S.Group(pattern="*", compressor=C.RandK(), carrier="sparse"),))
    method = ef.EF21SGDM(compressor=C.RandK(), eta=0.1)
    with W.catch_warnings(record=True) as rec:
        W.simplefilter("always")
        build_lib.default_ef_config(mesh, plan, method=method,
                                    schedule=sched)
        build_lib.default_ef_config(mesh, plan, method=method,
                                    schedule=sched)
    hits = [w for w in rec
            if issubclass(w.category, build_lib.PlanDegradationWarning)]
    assert len(hits) == 1, [str(w.message) for w in rec]
    # a DIFFERENT (group, reason) still warns
    with W.catch_warnings(record=True) as rec2:
        W.simplefilter("always")
        build_lib.default_ef_config(
            mesh, plan, method=ef.EF21SGDM(compressor=C.RandK(), eta=0.1),
            carrier="quant8")
    hits2 = [w for w in rec2
             if issubclass(w.category, build_lib.PlanDegradationWarning)]
    assert len(hits2) == 1
    # a DIFFERENT config (here: another η ⇒ another method) degrading with
    # the SAME (group, reason) text is a new experiment — it warns again
    with W.catch_warnings(record=True) as rec3:
        W.simplefilter("always")
        build_lib.default_ef_config(
            mesh, plan, method=ef.EF21SGDM(compressor=C.RandK(), eta=0.2),
            schedule=sched)
    hits3 = [w for w in rec3
             if issubclass(w.category, build_lib.PlanDegradationWarning)]
    assert len(hits3) == 1
    build_lib.reset_plan_warnings()


def test_fused_group_misconfig_is_hard_error_in_build():
    from repro.launch import mesh as mesh_lib
    from repro.launch import shardings as sh
    sched = S.CompressionSchedule((
        S.Group(pattern="*", compressor=C.TopK(), carrier="fused"),))
    with pytest.raises(ValueError, match="UNFUSED"):
        build_lib.default_ef_config(
            mesh_lib.make_smoke_mesh(), sh.ShardPlan(),
            method=ef.EF21SGDM(compressor=C.TopK(), eta=0.1),
            schedule=sched)
