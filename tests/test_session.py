"""Session-layer tests (launch/session.py): full-state checkpointing makes a
kill-and-resume run BIT-IDENTICAL to an uninterrupted one (the EF21 invariant
that server and clients agree on g survives restarts), the spec-hash guard
refuses foreign checkpoints, latest() orders numerically, and serve/lower run
through the build/shardings path on the session mesh."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt_lib
from repro.launch import session as session_lib
from repro.launch.session import Session
from repro.launch.spec import RunSpec

TINY = dict(arch="smollm-360m", smoke=True, clients=2, global_batch=4,
            seq_len=32)


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def test_kill_and_resume_is_bit_identical(tmp_path):
    """save→restore→step equals the uninterrupted run exactly: params,
    opt_state, ef_state (gᵢ, vᵢ — the old --resume dropped these, violating
    Algorithm 1's server/client agreement on g), and the logged loss
    trajectory."""
    base = RunSpec(**TINY)
    unint = Session(base)
    unint.train(6, log_every=1)

    interrupted = Session(dataclasses.replace(base, ckpt_dir=str(tmp_path)))
    interrupted.train(3, log_every=1)
    del interrupted                         # "kill" the process

    resumed = Session.resume(str(tmp_path))
    assert resumed.step == 3
    assert resumed.spec.spec_hash() == base.spec_hash()  # no flags re-passed
    resumed.train(6, log_every=1)

    assert _leaves_equal(unint.params, resumed.params)
    assert _leaves_equal(unint.opt_state, resumed.opt_state)
    assert _leaves_equal(unint.ef_state, resumed.ef_state)
    tail = [(r["step"], r["loss"], r["g_norm"]) for r in unint.history[3:]]
    got = [(r["step"], r["loss"], r["g_norm"]) for r in resumed.history]
    assert tail == got


def test_kill_and_resume_bidirectional_covers_server_memory(tmp_path):
    """The downlink server memory h (DESIGN.md §8) is part of ef_state and
    must survive a kill-and-resume bit-exactly — restoring everything BUT h
    would re-initialize the broadcast memory to g while the restored params
    are mid-trajectory, silently desynchronizing server and clients."""
    base = RunSpec(**TINY, downlink_carrier="quant4", downlink_ratio=0.1)
    unint = Session(base)
    unint.train(4, log_every=1)
    assert "h" in unint.ef_state

    interrupted = Session(dataclasses.replace(base, ckpt_dir=str(tmp_path)))
    interrupted.train(2, log_every=1)
    del interrupted

    resumed = Session.resume(str(tmp_path))
    assert resumed.step == 2
    assert resumed.spec.downlink_carrier == "quant4"
    resumed.train(4, log_every=1)
    assert _leaves_equal(unint.params, resumed.params)
    assert _leaves_equal(unint.ef_state["h"], resumed.ef_state["h"])
    assert _leaves_equal(unint.ef_state, resumed.ef_state)


def test_resume_refuses_foreign_spec_unless_overridden(tmp_path):
    spec = RunSpec(**TINY, ckpt_dir=str(tmp_path))
    sess = Session(spec)
    sess.train(2, log_every=1)

    other = dataclasses.replace(spec, lr=0.01)
    with pytest.raises(ValueError, match="different RunSpec"):
        Session.resume(str(tmp_path), spec=other)
    forced = Session.resume(str(tmp_path), spec=other,
                            allow_spec_mismatch=True)
    assert forced.step == 2 and forced.spec.lr == 0.01


def test_resume_layers_overrides_onto_embedded_spec(tmp_path):
    """'--resume --eta X' means 'the same run, new eta' — overrides layer
    onto the checkpoint's embedded spec, never onto defaults."""
    spec = RunSpec(**TINY, ckpt_dir=str(tmp_path))
    Session(spec).train(1, log_every=1)

    with pytest.raises(ValueError, match="different RunSpec"):
        Session.resume(str(tmp_path), overrides={"lr": 0.01})
    sess = Session.resume(str(tmp_path), overrides={"lr": 0.01},
                          allow_spec_mismatch=True)
    # the embedded geometry survives; only the override changed
    assert sess.spec.seq_len == 32 and sess.spec.clients == 2
    assert sess.spec.smoke and sess.spec.lr == 0.01
    # checkpoint-POLICY overrides need no mismatch approval (hash-excluded)
    sess = Session.resume(str(tmp_path), overrides={"ckpt_every": 5})
    assert sess.spec.ckpt_every == 5 and sess.spec.seq_len == 32


def test_checkpoint_latest_orders_numerically(tmp_path):
    tree = {"x": np.zeros((2,), np.float32)}
    for step in (2, 10):                   # lexicographic max() picks step_2
        ckpt_lib.save(str(tmp_path / f"step_{step}.npz"), tree, step=step)
    # a killed save leaves a mkstemp partial; it must never be selected
    (tmp_path / "tmpzz99999999.tmp.npz").write_bytes(b"partial")
    path = ckpt_lib.latest(str(tmp_path))
    assert path.endswith("step_10.npz")
    assert ckpt_lib.parse_step("step_00000010.npz") == 10
    assert ckpt_lib.parse_step("final.npz") is None


def test_save_records_spec_hash_in_meta(tmp_path):
    spec = RunSpec(**TINY, ckpt_dir=str(tmp_path))
    sess = Session(spec)
    sess.train(1, log_every=1)
    meta = ckpt_lib.read_meta(ckpt_lib.latest(str(tmp_path)))
    assert meta["spec_hash"] == spec.spec_hash()
    assert meta["step"] == sess.step        # the data cursor
    assert RunSpec.from_dict(meta["spec"]) == spec


def test_periodic_save_does_not_double_write_final_step(tmp_path):
    spec = RunSpec(**TINY, ckpt_dir=str(tmp_path), ckpt_every=2)
    sess = Session(spec)
    sess.train(4, log_every=1)              # ckpt_every divides the end step
    import os
    files = sorted(os.listdir(tmp_path))
    assert files == ["step_00000002.npz", "step_00000004.npz"]
    # ...and a later resume restores from the template path without paying a
    # fresh init (behavioral check: state round-trips exactly)
    resumed = Session.resume(str(tmp_path))
    assert resumed.step == 4
    assert _leaves_equal(resumed.params, sess.params)
    assert _leaves_equal(resumed.ef_state, sess.ef_state)


def test_failed_restore_leaves_session_usable(tmp_path):
    """A restore that dies mid-way (shape mismatch under forced resume) must
    not leave abstract ShapeDtypeStruct templates behind — the session still
    trains from a fresh init afterwards."""
    other = Session(RunSpec(arch="h2o-danube-3-4b", smoke=True, clients=2,
                            global_batch=4, seq_len=32,
                            ckpt_dir=str(tmp_path)))
    other.train(1, log_every=1)

    sess = Session(RunSpec(**TINY))
    with pytest.raises((ValueError, KeyError)):
        sess.restore_from(ckpt_lib.latest(str(tmp_path)),
                          allow_spec_mismatch=True)
    sess.train(1, log_every=1)              # fresh init, not template leaves
    assert np.isfinite(sess.history[-1]["loss"])


def test_evaluate_and_method_accessors():
    sess = Session(RunSpec(**TINY))
    loss = sess.evaluate(batches=1)
    assert np.isfinite(loss) and loss > 0
    assert sess.method.name == "ef21_sgdm"
    assert sess.n_clients == 2


def test_serve_runs_through_build_shardings():
    sess = Session(RunSpec(**TINY))
    out = sess.serve(batch=2, prompt_len=16, decode_steps=2)
    assert out["tokens"].shape == (2, 3)    # first token + 2 decode steps
    assert out["cache_bytes"] > 0


def test_serve_params_track_same_step_state_changes(tmp_path):
    """Regression for the step-keyed serve cache: ``_serve_params`` used to
    key on the step counter, so restoring state or injecting a subscriber
    tree WITHOUT moving the step served stale params. The cache now keys on
    ``_params_version`` — the single source of truth every mutation path
    (step_once, restore_from, set_serve_params) bumps."""
    import jax.numpy as jnp

    sess = Session(RunSpec(**TINY, ckpt_dir=str(tmp_path)))
    sess.train(2)                                # checkpoints at step 2
    path = ckpt_lib.latest(str(tmp_path))
    sess.serve(batch=1, prompt_len=8, decode_steps=1)
    trained = jax.device_get(sess._serve_params[1])

    # inject a different tree at the SAME step (the wire-subscriber path):
    # the step counter does not move, the served params must
    zeros = jax.tree_util.tree_map(jnp.zeros_like, sess.params)
    sess.set_serve_params(zeros)
    sess.serve(batch=1, prompt_len=8, decode_steps=1)
    assert all(not np.any(np.asarray(leaf)) for leaf in
               jax.tree_util.tree_leaves(sess._serve_params[1]))

    # restore at the SAME step: the injected tree is superseded and serve
    # returns to the checkpoint's params without the step counter moving
    sess.restore_from(path)
    assert sess.step == 2
    sess.serve(batch=1, prompt_len=8, decode_steps=1)
    assert _leaves_equal(sess._serve_params[1], trained)


def test_lower_produces_dryrun_artifact_on_smoke_mesh():
    sess = Session(RunSpec(**TINY, carrier="sparse"))
    with sess.mesh_context():
        lowered = sess.lower()              # custom train shape, 1-device mesh
        hlo = lowered.as_text()
    assert "while" in hlo or "fusion" in hlo or len(hlo) > 1000


def test_make_method_rejects_unknown_kwargs():
    with pytest.raises(ValueError, match="method_kw"):
        session_lib.make_method(RunSpec(method_kw={"bogus_knob": 1}))
    with pytest.raises(ValueError, match="compressor_kw"):
        session_lib.make_compressor(RunSpec(compressor_kw={"nope": 2}))
