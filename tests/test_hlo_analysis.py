"""The while-aware HLO analyzer vs XLA's own cost analysis (loop-free) and vs
known trip counts (loops)."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_loop_free_matches_xla():
    a, b = jnp.ones((256, 512)), jnp.ones((512, 128))
    c = _compiled(lambda a, b: a @ b, a, b)
    r = H.analyze(c.as_text())
    xla = H.cost_analysis_dict(c)["flops"]
    assert r["dot_flops"] == xla == 2 * 256 * 512 * 128


def test_scan_body_multiplied():
    def f(x):
        def body(c, _):
            return c @ c, None
        return jax.lax.scan(body, x, None, length=10)[0]
    c = _compiled(f, jnp.ones((128, 128)))
    r = H.analyze(c.as_text())
    assert r["dot_flops"] == 10 * 2 * 128 ** 3
    # and confirm XLA itself undercounts (the reason this module exists)
    assert H.cost_analysis_dict(c)["flops"] < r["dot_flops"]


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=4)[0]
    c = _compiled(f, jnp.ones((64, 64)))
    r = H.analyze(c.as_text())
    assert r["dot_flops"] == 12 * 2 * 64 ** 3


def test_batched_dot_flops():
    a = jnp.ones((8, 64, 32))
    b = jnp.ones((8, 32, 16))
    c = _compiled(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    r = H.analyze(c.as_text())
    assert r["dot_flops"] == 2 * 8 * 64 * 32 * 16


def test_shape_bytes():
    assert H._shape_bytes("f32[2,3]") == 24
    assert H._shape_bytes("bf16[10]") == 20
    assert H._shape_bytes("(s32[], f32[4])") == 20
    assert H._shape_bytes("pred[8]") == 8
