"""Bidirectional compression: the downlink broadcast leg (DESIGN.md §8).

Round-trip state-sync harness: after every round the server's broadcast
memory h and every client's reconstruction of it must be BIT-identical for
all (method × uplink carrier × downlink carrier) combinations — the model
everyone steps with derives from h, so h-sync IS model-sync. The harness
also anchors the regression surface (downlink='dense' must be bit-identical
to the unidirectional runtime, including the ef_state tree structure),
proves the vmap runtime against the simulator's scan loop on a deterministic
problem, and mirrors ``test_ef_recovers_quantization_error`` for the
broadcast leg (EF21-SGDM over a quant4 downlink reaches the dense-downlink
floor; the naive no-memory broadcast stalls).

Each invariant is a plain checker driven by a deterministic grid that ALWAYS
runs; a hypothesis fuzzer sweeps random shapes wherever hypothesis is
installed (the container has none — same pattern as
tests/test_carrier_properties.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    settings.register_profile("bidir", max_examples=10, deadline=None)
    settings.load_profile("bidir")
except ImportError:                                   # deterministic grid only
    HAVE_HYPOTHESIS = False

from repro.core import carriers as carrier_lib
from repro.core import compressors as C
from repro.core import distributed as D
from repro.core import ef, problems, simulate

fuzz = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis fuzzing needs hypothesis "
    "(pip install -r requirements-dev.txt); the deterministic grid ran")

BTK = C.BlockTopK(block=8, k_per_block=3)
DOWN_BTK = C.BlockTopK(block=8, k_per_block=2)

# sampled (method × uplink × downlink × downlink compressor) grid — every
# downlink carrier crossed with both server modes ('delta' and 'absolute')
# and with dense/sparse/quant uplinks
GRID = [
    ("ef21_sgdm", "dense", "sparse", DOWN_BTK),
    ("ef21_sgdm", "dense", "quant8", DOWN_BTK),
    ("ef21_sgdm", "sparse", "quant4", DOWN_BTK),
    ("ef21_sgdm", "quant8", "sparse", DOWN_BTK),
    ("ef21_sgdm", "quant4", "quant4", C.Identity()),   # dense-payload quant
    ("ef21_sgd", "dense", "quant4", DOWN_BTK),
    ("ef21_sgd", "fused", "quant8", DOWN_BTK),
    ("ef14_sgd", "dense", "sparse", DOWN_BTK),         # 'absolute' server mode
    ("ef14_sgd", "sparse", "quant8", DOWN_BTK),
    ("sgdm", "dense", "quant4", DOWN_BTK),             # 'absolute', momentum
    # dense WIRE with a compressed payload: the naive-looking config that
    # still runs the full EF21 server-memory leg
    ("ef21_sgdm", "dense", "dense", C.HardThreshold(lam=0.05)),
]


def _method(name):
    kw = {"compressor": BTK}
    if name in ("ef21_sgdm", "sgdm"):
        kw["eta"] = 0.3
    return ef.make(name, **kw)


def _setup(dp=4, seed=0):
    rng = jax.random.PRNGKey(seed)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jax.random.normal(rng, (dp, 8, 4)),
             "b": jax.random.normal(jax.random.fold_in(rng, 1), (dp, 4))}
    return params, grads


def _client_reconstruction(down_carrier, down_comp, g_new, h_old):
    """What ONE client reconstructs from the broadcast wire, recomputed
    independently of the runtime: decode the encoded wire leaf by leaf and
    integrate into its copy of h. Bit-exact agreement with the server's
    ``ef_state['h']`` is the state-sync invariant."""
    car = carrier_lib.make(down_carrier)
    plan = car.plan_down(down_comp)
    out = {}
    for k in g_new:
        delta = (g_new[k].astype(jnp.float32)
                 - h_old[k].astype(jnp.float32)).reshape(-1)
        delta = delta.astype(g_new[k].dtype)
        if plan == "wire":
            wire = car.encode(down_comp, delta)          # the broadcast bits
            dec = car.decode(down_comp, wire, d=delta.size, dtype=delta.dtype)
        else:
            dec = down_comp(delta).astype(delta.dtype)
        out[k] = (h_old[k].reshape(-1) + dec).reshape(h_old[k].shape)
    return out


# ---------------------------------------------------------------------------
# round-trip state-sync invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m_name,up,down,down_comp", GRID,
                         ids=[f"{m}-{u}-{d}" for m, u, d, _ in GRID])
def test_state_sync_bit_identical_every_round(m_name, up, down, down_comp):
    """After EVERY round, the server's broadcast memory h, the g_est the
    model steps with, and each client's independent reconstruction from the
    wire are all bit-identical — across the full method × carrier grid."""
    params, grads = _setup()
    method = _method(m_name)
    efc = D.EFConfig(method=method, carrier=up, down_carrier=down,
                     down_compressor=down_comp)
    st = D.init_ef_state(efc, params, 4, init_grads=grads)
    assert "h" in st
    # h⁰ = g⁰: the init handshake ships dense state once
    for k in st["server"]:
        assert np.array_equal(np.asarray(st["h"][k]),
                              np.asarray(st["server"][k]))
    rng = jax.random.PRNGKey(7)
    for t in range(3):
        g_prev_h = st["h"]
        g_est, st = D.ef_round(efc, grads, st,
                               jax.random.fold_in(rng, t))
        # the estimate everyone steps with IS the broadcast memory
        for k in st["h"]:
            assert np.array_equal(np.asarray(g_est[k]),
                                  np.asarray(st["h"][k])), (t, k)
        # a client's independent decode of the wire lands on the same h —
        # and because the reconstruction is a deterministic function of the
        # broadcast bits alone (nothing client-specific enters), one client
        # standing in for all n IS the invariant, not a shortcut
        recon = _client_reconstruction(down, down_comp, st["server"],
                                       g_prev_h)
        for k in st["h"]:
            assert np.array_equal(np.asarray(recon[k]),
                                  np.asarray(st["h"][k])), (t, k)


@pytest.mark.parametrize("m_name", ["ef21_sgdm", "ef14_sgd"])
def test_downlink_dense_is_bit_identical_to_main(m_name):
    """Regression anchor: downlink='dense' (no downlink compressor) must be
    byte-for-byte the pre-downlink runtime — same ef_state tree structure (no
    'h' sibling) and a bit-identical multi-step production trajectory."""
    from repro.optim import optimizer as opt_lib

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    rng = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    x = jax.random.normal(rng, (16, 8))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (8, 4))
    batch = {"x": x, "y": x @ w}
    dp = 4
    method = _method(m_name)

    trajs = {}
    for tag, efc in [
            ("default", D.EFConfig(method=method, carrier="sparse")),
            ("explicit", D.EFConfig(method=method, carrier="sparse",
                                    down_carrier="dense",
                                    down_compressor=None))]:
        assert not efc.has_downlink
        opt = opt_lib.sgd(0.2)
        step = jax.jit(D.make_train_step(loss_fn, efc, opt, dp))
        _, _, g0 = D.per_client_value_and_grad(loss_fn, params, batch, dp)
        st = D.init_ef_state(efc, params, dp, init_grads=g0)
        assert "h" not in st
        p, os_ = params, opt.init(params)
        servers = []
        for t in range(10):
            p, os_, st, _ = step(p, os_, st, batch,
                                 jax.random.fold_in(rng, t), t)
            servers.append(np.asarray(st["server"]["w"]))
        trajs[tag] = np.stack(servers)
    assert np.array_equal(trajs["default"], trajs["explicit"])


def test_downlink_dense_identity_wire_tracks_server():
    """A bidirectional round that compresses nothing (dense wire, Identity
    compressor) reconstructs the unidirectional estimate up to float
    cancellation — h ← h + (g − h) is an ulp off g, never more — while the
    server/client h agreement stays bit-exact (the invariant above)."""
    params, grads = _setup()
    method = _method("ef21_sgdm")
    base = D.EFConfig(method=method, carrier="dense")
    bidir = D.EFConfig(method=method, carrier="dense",
                       down_carrier="dense", down_compressor=C.Identity())
    assert bidir.has_downlink
    st_b = D.init_ef_state(base, params, 4, init_grads=grads)
    st_d = D.init_ef_state(bidir, params, 4, init_grads=grads)
    for t in range(3):
        g_b, st_b = D.ef_round(base, grads, st_b, None)
        g_d, st_d = D.ef_round(bidir, grads, st_d, None)
        for k in g_b:
            np.testing.assert_allclose(
                np.asarray(g_b[k]), np.asarray(g_d[k]), rtol=1e-6,
                atol=1e-6, err_msg=k)
            np.testing.assert_allclose(
                np.asarray(st_d["h"][k]), np.asarray(st_d["server"][k]),
                rtol=1e-6, atol=1e-6, err_msg=k)


# ---------------------------------------------------------------------------
# runtime agreement: the simulator's scan loop vs the vmap runtime
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _DetQuadratic:
    """Deterministic problem (stoch_grad ignores rng): makes the simulator
    and a hand-rolled ef_round loop comparable step by step."""
    d: int = 6

    def init_x(self):
        return jnp.arange(1.0, self.d + 1.0, dtype=jnp.float32)

    def full_grad(self, x):
        return x                                        # f(x) = ‖x‖²/2

    def stoch_grad(self, x, client, rng, batch):
        shift = (jnp.arange(self.d) == (client % self.d))
        return x + 0.2 * (client + 1.0) * shift.astype(jnp.float32)

    def loss(self, x):
        return 0.5 * jnp.sum(x * x)


@pytest.mark.parametrize("up,down,down_comp", [
    ("dense", "dense", None),
    ("dense", "quant4", C.BlockTopK(block=2, k_per_block=1)),
    ("sparse", "sparse", C.BlockTopK(block=2, k_per_block=1)),
    ("quant8", "quant8", C.Identity()),
], ids=["no-downlink", "dense-q4", "sparse-sparse", "q8-q8"])
def test_simulate_matches_ef_round_loop(up, down, down_comp):
    """core/simulate.py and core/distributed.py must run the SAME round —
    including the downlink ordering (x steps with h, server integrates the
    broadcast AFTER the uplink aggregate): the simulator's whole trajectory
    equals a hand-rolled loop over ``ef_round`` on a deterministic problem."""
    prob = _DetQuadratic()
    n, gamma, steps = 3, 1e-2, 12
    method = ef.EF21SGDM(compressor=C.BlockTopK(block=2, k_per_block=1),
                         eta=0.2)
    cfg = simulate.SimConfig(n=n, batch_size=1, gamma=gamma, steps=steps,
                             down_carrier=down, down_compressor=down_comp)
    out = simulate.run_numpy(prob, method, cfg, seed=0)

    clients = jnp.arange(n)
    x = prob.init_x()
    g0 = jax.vmap(lambda c: prob.stoch_grad(x, c, None, 1))(clients)
    efc = D.EFConfig(method=method, carrier=up, down_carrier=down,
                     down_compressor=down_comp)
    st = D.init_ef_state(efc, x, n, init_grads=g0)
    g_use = st["h"] if efc.has_downlink else st["server"]
    gns = []
    for _ in range(steps):
        x = x - gamma * g_use
        grads = jax.vmap(lambda c: prob.stoch_grad(x, c, None, 1))(clients)
        g_use, st = D.ef_round(efc, grads, st, None)
        gns.append(float(jnp.sum(jnp.square(prob.full_grad(x)))))
    np.testing.assert_allclose(out["grad_norm_sq"], np.asarray(gns),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(out["x_final"]), np.asarray(x),
                               rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# wire accounting: the up/down split
# ---------------------------------------------------------------------------

def test_simulator_reports_downlink_wire_split():
    prob = problems.QuadraticT1()
    method = ef.EF21SGDM(compressor=C.TopK(k=1), eta=0.5)
    # d = 2, n = 2. Downlink TopK(k=1) over quant8: 1 scale + 1 quantized
    # value (1/4 word) + 1 int16 block-local index (1/2 word) = 1.75 words
    cfg = simulate.SimConfig(n=2, steps=3, down_carrier="quant8",
                             down_compressor=C.TopK(k=1))
    out = simulate.run_numpy(prob, method, cfg, seed=0)
    assert out["wire_words_per_round"] == out["wire_words_up_per_round"] == 4.0
    assert out["wire_words_down_per_round"] == 1.75 * 2
    assert out["wire_words_total_per_round"] == 4.0 + 3.5
    # without a downlink carrier the broadcast is honest dense-f32: d words
    out_d = simulate.run_numpy(
        prob, method, simulate.SimConfig(n=2, steps=3), seed=0)
    assert out_d["wire_words_down_per_round"] == 2.0 * 2
    assert out_d["wire_words_total_per_round"] == 4.0 + 4.0


def test_downlink_words_and_direction_accounting():
    d = 4096
    btk = C.BlockTopK(block=1024, k_per_block=16)
    for name in ("sparse", "quant8", "quant4"):
        car = carrier_lib.make(name)
        assert carrier_lib.downlink_words(car, btk, d) == \
            car.wire_words(btk, d)
    # degraded plans ship the dense broadcast: d words
    assert carrier_lib.downlink_words(
        carrier_lib.make("sparse"), C.Identity(), d) == d
    assert carrier_lib.downlink_words(
        carrier_lib.make("quant8"), C.RandK(), d) == d
    assert carrier_lib.downlink_words(
        carrier_lib.make("dense"), btk, d) == d
    # coords_per_message grows a direction: 'down' counts ONE broadcast of
    # the (possibly different) downlink compressor, even for Neolithic's
    # R-round uplink
    m = ef.EF21SGDM(compressor=btk)
    assert m.coords_per_message(d, carrier="quant4", direction="down") == \
        carrier_lib.make("quant4").wire_words(btk, d)
    small = C.BlockTopK(block=1024, k_per_block=4)
    assert m.coords_per_message(d, carrier="sparse", direction="down",
                                compressor=small) == \
        carrier_lib.make("sparse").wire_words(small, d)
    neo = ef.Neolithic(compressor=btk, rounds=4)
    assert neo.coords_per_message(d, carrier="sparse", direction="down") == \
        carrier_lib.make("sparse").wire_words(btk, d)          # NOT 4×


def test_downlink_plan_reasons():
    for name in ("quant8", "quant4"):
        car = carrier_lib.make(name)
        assert car.plan_down(BTK) == "wire"
        assert car.plan_down(C.Identity()) == "wire"     # dense payload
        plan, reason = car.plan_down_with_reason(C.RandK())
        assert plan == "dense" and "randomness" in reason
    plan, reason = carrier_lib.make("sparse").plan_down_with_reason(
        C.Identity())
    assert plan == "dense" and reason
    plan, reason = carrier_lib.make("fused").plan_down_with_reason(BTK)
    assert plan == "dense" and "UPLINK" in reason
    assert carrier_lib.make("dense").plan_down_with_reason(BTK) == \
        ("dense", "")


# ---------------------------------------------------------------------------
# property checkers (deterministic grid always; hypothesis fuzz when present)
# ---------------------------------------------------------------------------

def _check_downlink_roundtrip(d, down, down_comp, seed):
    """(a) the decode every client integrates equals the server's own
    integration bit-exactly; (b) downlink_round is deterministic (the same
    wire decodes identically however often a client replays it)."""
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(d).astype(np.float32))
    h = jnp.asarray(rng.randn(d).astype(np.float32))
    car = carrier_lib.make(down)
    dec1 = carrier_lib.downlink_round(car, down_comp, g - h)
    dec2 = carrier_lib.downlink_round(car, down_comp, g - h)
    assert np.array_equal(np.asarray(dec1), np.asarray(dec2))
    _, h_new = ef.downlink_sync(car, down_comp, g, h)
    assert np.array_equal(np.asarray(h + dec1), np.asarray(h_new))
    # Identity over the dense wire reconstructs g up to float cancellation
    # (h + (g − h) is an ulp off g when magnitudes differ — what stays
    # BIT-exact is the server/client agreement above, never the target)
    _, h_exact = ef.downlink_sync(carrier_lib.make("dense"), C.Identity(),
                                  g, h)
    np.testing.assert_allclose(np.asarray(h_exact), np.asarray(g),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("d", [1, 12, 50, 257])
@pytest.mark.parametrize("down,down_comp", [
    ("sparse", C.BlockTopK(block=12, k_per_block=5)),
    ("quant8", C.BlockTopK(block=12, k_per_block=5)),
    ("quant4", C.Identity()),
    ("dense", C.TopK(ratio=0.3)),
])
def test_downlink_roundtrip_grid(d, down, down_comp):
    _check_downlink_roundtrip(d, down, down_comp, seed=d)


if HAVE_HYPOTHESIS:
    @fuzz
    @given(d=st.integers(min_value=1, max_value=300),
           down=st.sampled_from(["sparse", "quant8", "quant4", "dense"]),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_downlink_roundtrip_fuzz(d, down, seed):
        _check_downlink_roundtrip(
            d, down, C.BlockTopK(block=12, k_per_block=5), seed)


# ---------------------------------------------------------------------------
# paper claims on the broadcast leg (slow tier — mirrors
# test_ef_recovers_quantization_error for the downlink)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_downlink_ef_recovers_compression_error():
    """EF21-SGDM with a quant4 DOWNLINK reaches the dense-downlink loss
    floor on the quadratic problem: the server memory h absorbs the
    broadcast compression error and re-sends it (the same contraction that
    makes uplink EF21 work). The naive broadcast WITHOUT server memory
    (ship the quant4 wire of g itself every round) stalls orders of
    magnitude higher — nothing re-sends the truncated mass."""
    prob = problems.RandomQuadratics(n=8, d=40, lam=0.05, sigma=1e-3, seed=0)
    sgdm = ef.EF21SGDM(compressor=C.BlockTopK(block=8, k_per_block=2),
                       eta=0.1)
    down = C.BlockTopK(block=8, k_per_block=1)
    kw = dict(n=8, batch_size=1, gamma=5e-2, steps=2500)

    def end(**cfg_kw):
        cfg = simulate.SimConfig(**kw, **cfg_kw)
        out = simulate.run_numpy(prob, sgdm, cfg, seed=0)
        return out["grad_norm_sq"][-300:].mean()

    end_dense = end()
    end_q4 = end(down_carrier="quant4", down_compressor=down)
    end_naive = end(down_carrier="quant4", down_compressor=down,
                    down_memory=False)
    # the bidirectional run sits on the same σ² noise floor as dense-down...
    assert end_q4 < 2 * end_dense, (end_q4, end_dense)
    # ...while the memory-less broadcast stalls far above it (measured
    # ~100×; 30× keeps seed headroom)
    assert end_naive > 30 * end_q4, (end_naive, end_q4)
