"""Unit tests: every EF method's update rule against hand-computed algebra."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as C
from repro.core import ef


def tree(x):
    return {"a": jnp.asarray(x, jnp.float32)}


IDC = C.Identity()


def test_ef21_sgd_update_rule():
    m = ef.EF21SGD(compressor=IDC)
    st = m.init(tree([0.0, 0.0]), init_grads=tree([1.0, 2.0]))
    msg, st2 = m.update(tree([3.0, 4.0]), st)
    # c = C(grad − g) = grad − g; g' = g + c = grad
    np.testing.assert_allclose(msg["a"], [2.0, 2.0])
    np.testing.assert_allclose(st2["g"]["a"], [3.0, 4.0])


def test_ef21_sgdm_update_rule():
    m = ef.EF21SGDM(compressor=IDC, eta=0.25)
    st = m.init(tree([0, 0]), init_grads=tree([4.0, 8.0]))
    msg, st2 = m.update(tree([0.0, 0.0]), st)
    # v' = 0.75·v = [3, 6]; c = v' − g = [−1, −2]; g' = v'
    np.testing.assert_allclose(st2["v"]["a"], [3.0, 6.0])
    np.testing.assert_allclose(msg["a"], [-1.0, -2.0])
    np.testing.assert_allclose(st2["g"]["a"], [3.0, 6.0])


def test_ef21_sgd2m_update_rule():
    m = ef.EF21SGD2M(compressor=IDC, eta=0.5)
    st = m.init(tree([0, 0]), init_grads=tree([2.0, 2.0]))
    msg, st2 = m.update(tree([4.0, 0.0]), st)
    # v' = .5·2+.5·4 = 3 | .5·2 = 1 ; u' = .5·2+.5·v' = 2.5 | 1.5
    np.testing.assert_allclose(st2["v"]["a"], [3.0, 1.0])
    np.testing.assert_allclose(st2["u"]["a"], [2.5, 1.5])
    np.testing.assert_allclose(st2["g"]["a"], [2.5, 1.5])


def test_ef14_update_rule():
    m = ef.EF14SGD(compressor=C.TopK(k=1))
    st = m.init(tree([0.0, 0.0]))
    msg, st2 = m.update(tree([1.0, 3.0]), st)
    # p = e + grad = [1,3]; C keeps |3|; e' = p − c = [1, 0]
    np.testing.assert_allclose(msg["a"], [0.0, 3.0])
    np.testing.assert_allclose(st2["e"]["a"], [1.0, 0.0])


def test_sgdm_equals_ef21_sgdm_identity():
    """Algorithm 1 with C = identity degenerates to plain SGDM (App. J)."""
    g0 = tree([1.0, -2.0])
    grads = [tree([0.5, 0.5]), tree([-1.0, 2.0]), tree([0.3, 0.3])]
    m1 = ef.SGDM(eta=0.3)
    m2 = ef.EF21SGDM(compressor=IDC, eta=0.3)
    s1, s2 = m1.init(g0, init_grads=g0), m2.init(g0, init_grads=g0)
    srv1 = ef.server_init(m1, g0, g0)
    srv2 = ef.server_init(m2, g0, g0)
    for g in grads:
        msg1, s1 = m1.update(g, s1)
        msg2, s2 = m2.update(g, s2)
        srv1 = ef.server_step(m1, srv1, msg1)
        srv2 = ef.server_step(m2, srv2, msg2)
        np.testing.assert_allclose(srv1["a"], srv2["a"], rtol=1e-6)


def test_ef21_sgdm_eta1_equals_ef21_sgd():
    g0 = tree([1.0, -2.0])
    grads = [tree([0.5, 1.5]), tree([-1.0, 2.0])]
    m1 = ef.EF21SGD(compressor=C.TopK(k=1))
    m2 = ef.EF21SGDM(compressor=C.TopK(k=1), eta=1.0)
    s1, s2 = m1.init(g0, init_grads=g0), m2.init(g0, init_grads=g0)
    for g in grads:
        msg1, s1 = m1.update(g, s1)
        msg2, s2 = m2.update(g, s2)
        np.testing.assert_allclose(msg1["a"], msg2["a"], rtol=1e-6)


def test_storm_estimator_unbiased_recursion():
    m = ef.EF21STORM(compressor=IDC, eta=0.5)
    st = m.init(tree([0.0]), init_grads=tree([1.0]))
    msg, st2 = m.update((tree([2.0]), tree([0.5])), st)
    # w' = g_new + (1−η)(w − g_prev) = 2 + 0.5·(1 − 0.5) = 2.25
    np.testing.assert_allclose(st2["w"]["a"], [2.25])


def test_abs_variant_gamma_scaling():
    m = ef.EF21SGDMAbs(compressor=C.HardThreshold(lam=0.5), eta=1.0, gamma=0.1)
    st = m.init(tree([0.0]))
    msg, st2 = m.update(tree([0.04]), st)
    # innov/γ = 0.4 < λ → compressed to 0 → c = 0
    np.testing.assert_allclose(msg["a"], [0.0])
    msg, _ = m.update(tree([0.06]), st)
    # innov/γ = 0.6 ≥ λ → kept → c = γ·0.6 = 0.06
    np.testing.assert_allclose(msg["a"], [0.06], rtol=1e-6)


def test_neolithic_rounds_reduce_residual():
    m = ef.Neolithic(compressor=C.TopK(k=1), rounds=4)
    g = tree([4.0, 3.0, 2.0, 1.0])
    msg, _ = m.update(g, {})
    np.testing.assert_allclose(msg["a"], [4.0, 3.0, 2.0, 1.0])
    assert m.coords_per_message(4) == 4.0


def test_server_modes():
    delta = ef.EF21SGDM(compressor=IDC)
    absm = ef.SGD()
    g = tree([1.0])
    assert ef.server_step(delta, tree([2.0]), g)["a"][0] == 3.0
    assert ef.server_step(absm, tree([2.0]), g)["a"][0] == 1.0


def test_state_dtype_cast():
    m = ef.EF21SGDM(compressor=IDC, eta=0.5, state_dtype=jnp.bfloat16)
    st = m.init(tree([1.0, 2.0]))
    assert st["v"]["a"].dtype == jnp.bfloat16
    _, st2 = m.update(tree([1.0, 1.0]), st)
    assert st2["g"]["a"].dtype == jnp.bfloat16


def test_registry_complete():
    for name in ["ef21_sgd", "ef21_sgdm", "ef21_sgd2m", "ef21_sgdm_abs",
                 "ef21_storm", "ef14_sgd", "sgdm", "sgd", "neolithic"]:
        assert name in ef.REGISTRY
    with pytest.raises(ValueError):
        ef.make("nope")
