"""Quickstart: EF21-SGDM (Algorithm 1) end-to-end through the RunSpec/Session
API (launch/spec.py, launch/session.py — DESIGN.md §7).

Each experiment is ONE declarative, JSON-serializable RunSpec; Session owns
the rest (mesh, EFConfig, pipeline, jitted step). Trains a reduced SmolLM on
the synthetic pipeline with 4 emulated clients and Top-16-per-block
compression, then compares against uncompressed SGDM at equal steps and
prints the transmitted-coordinate savings.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.session import Session
from repro.launch.spec import RunSpec

STEPS = 120
base = dict(arch="smollm-360m", smoke=True, clients=4, global_batch=8,
            seq_len=128, eta=0.2, lr=0.5)

for name, spec in [
    ("EF21-SGDM + BlockTopK(1.6%)",
     RunSpec(**base, method="ef21_sgdm", compressor="block_topk",
             compressor_kw={"block": 1024, "k_per_block": 16})),
    ("SGDM (uncompressed)",
     RunSpec(**base, method="sgdm", compressor="identity")),
]:
    print(f"== {name}")
    sess = Session(spec)
    sess.train(STEPS, log_every=40, verbose=True)   # prints loss live
    d = sess.cfg.param_count()
    coords = sess.method.coords_per_message(d)
    print(f"{name}: final loss {sess.history[-1]['loss']:.4f}, "
          f"{coords:.3g}/{d:.3g} coords per client per round "
          f"({100 * coords / d:.1f}% of uncompressed)")
    print(f"  spec: {spec.to_json()}\n")
