"""Quickstart: EF21-SGDM (Algorithm 1) end-to-end in ~40 lines.

Trains a reduced SmolLM on the synthetic pipeline with 4 emulated clients and
Top-1%-per-block compression, then compares against uncompressed SGDM at equal
steps and prints the transmitted-coordinate savings.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.core import compressors as C, distributed as D, ef
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import model as M
from repro.optim import optimizer as opt_lib

ARCH, CLIENTS, BATCH, SEQ, STEPS = "smollm-360m", 4, 8, 128, 120

cfg = cb.get_smoke(ARCH)
rng = jax.random.PRNGKey(0)
pipe = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=SEQ,
                                  global_batch=BATCH, dp_groups=CLIENTS))


def loss_fn(p, b):
    return M.train_loss(cfg, p, b)


d = cfg.param_count()
for name, method in [
    ("EF21-SGDM + BlockTopK(1.6%)",
     ef.EF21SGDM(compressor=C.BlockTopK(block=1024, k_per_block=16), eta=0.2)),
    ("SGDM (uncompressed)", ef.SGDM(eta=0.2)),
]:
    params = M.init_params(cfg, rng)
    efc = D.EFConfig(method=method)
    opt = opt_lib.sgd(0.5)
    step = jax.jit(D.make_train_step(loss_fn, efc, opt, CLIENTS))
    _, _, g0 = D.per_client_value_and_grad(loss_fn, params, pipe.batch(0),
                                           CLIENTS)
    p, os_, es = params, opt.init(params), D.init_ef_state(
        efc, params, CLIENTS, init_grads=g0)
    for t in range(STEPS):
        p, os_, es, m = step(p, os_, es, pipe.batch(t),
                             jax.random.fold_in(rng, t), t)
        if t % 40 == 0 or t == STEPS - 1:
            print(f"  [{name}] step {t:4d} loss {float(m['loss']):.4f}")
    coords = method.coords_per_message(d)
    print(f"{name}: final loss {float(m['loss']):.4f}, "
          f"{coords:.3g}/{d:.3g} coords per client per round "
          f"({100 * coords / d:.1f}% of uncompressed)\n")
