"""Serving demo: batched prefill + decode across three architecture families
(dense SWA, Mamba1, hybrid), showing the cache machinery end-to-end.

    PYTHONPATH=src python examples/distributed_serve.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.models import model as M

for arch in ["h2o-danube-3-4b", "falcon-mamba-7b", "zamba2-1.2b"]:
    cfg = cb.get_smoke(arch)
    rng = jax.random.PRNGKey(0)
    params = M.init_params(cfg, rng)
    B, S, DEC = 2, 64, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, B, S + DEC)

    prefill = jax.jit(lambda p, b, c: M.prefill(cfg, p, b, c))
    decode = jax.jit(lambda p, c, t, q: M.decode_step(cfg, p, c, t, q))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": tokens}, cache)
    tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
    gen = [tok]
    for i in range(DEC):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(S + i, jnp.int32))
        tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
        gen.append(tok)
    jax.block_until_ready(tok)
    out = jnp.concatenate(gen, axis=1)
    cache_mb = sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(cache)) / 2 ** 20
    print(f"{arch:18s} family={cfg.family:7s} prefill+{DEC}tok: "
          f"{time.time() - t0:5.1f}s  cache={cache_mb:6.1f} MiB  "
          f"sample={jax.device_get(out)[0, :8].tolist()}")
