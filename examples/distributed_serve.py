"""Serving demo: batched prefill + decode across three architecture families
(dense SWA, Mamba1, hybrid) via the RunSpec/Session API — each arch is one
spec, and ``Session.serve`` routes through the production
``build_prefill``/``build_decode`` shardings (launch/build.py).

    PYTHONPATH=src python examples/distributed_serve.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.session import Session
from repro.launch.spec import RunSpec

for arch in ["h2o-danube-3-4b", "falcon-mamba-7b", "zamba2-1.2b"]:
    sess = Session(RunSpec(arch=arch, smoke=True))
    t0 = time.time()
    out = sess.serve(batch=2, prompt_len=64, decode_steps=16)
    print(f"{arch:18s} family={sess.cfg.family:7s} prefill+16tok: "
          f"{time.time() - t0:5.1f}s  cache={out['cache_bytes']/2**20:6.1f} "
          f"MiB  sample={out['tokens'][0, :8].tolist()}")
