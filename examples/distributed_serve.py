"""Streaming-serve demo: one trainer publishing its downlink wire, two
serving replicas subscribing at different lags (launch/fleet.py).

The trainer runs EF21-SGDM with a quant4 downlink carrier and publishes every
wire record to a stream dir; each replica joins from the stream's bootstrap
checkpoint, replays the records through the exact train-step tail, and serves
requests on params that are BIT-IDENTICAL to the trainer's post-step model at
its lag — dense f32 weights never travel (DESIGN.md §12).

    PYTHONPATH=src python examples/distributed_serve.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro.launch import fleet as fleet_lib
from repro.launch.session import Session
from repro.launch.spec import RunSpec

stream_dir = os.path.join(tempfile.mkdtemp(prefix="repro_wire_"), "wire")

# --- the trainer: EF21-SGDM, quant4 downlink, publishing to the stream -----
spec = RunSpec(arch="smollm-360m", smoke=True, clients=2, global_batch=4,
               seq_len=32, compressor="block_topk", ratio=0.1,
               downlink_carrier="quant4", downlink_ratio=0.05)
trainer = Session(spec)
trainer.publish_to(stream_dir, bootstrap_every=4)
trainer.train(6)
print(f"trainer @ step {trainer.step}, stream at {stream_dir}")

# --- the fleet: two replicas on ONE wire, one fresh and one 2 steps behind -
fleet = fleet_lib.Fleet(stream_dir, n_replicas=2, lags=(0, 2),
                        decode_budget=16, max_batch=2, prompt_len=16)
fleet.sync()
for rep in fleet.replicas:
    match = all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree_util.tree_leaves(trainer._tr["params"]),
                                jax.tree_util.tree_leaves(rep.params)))
    print(f"{rep.name}: lag={rep.lag} step={rep.step} "
          f"bit-identical-to-head={match}")

# --- drive a small request load through the fleet --------------------------
reqs = fleet_lib.synthetic_requests(8, rate=20.0, prompt_len=16,
                                    max_new_tokens=8,
                                    vocab_size=trainer.cfg.vocab_size)
out = fleet.run(reqs, sync_every=1)
print(f"{len(out['requests'])} requests in {out['batches']} batches: "
      f"qps={out['qps']:.2f} p50={out['p50_ms']:.0f}ms "
      f"p99={out['p99_ms']:.0f}ms staleness mean={out['staleness_mean']:.1f}")
for req in out["requests"][:3]:
    print(f"  req {req.rid} via {req.replica} (staleness {req.staleness}): "
          f"{req.tokens_out.tolist()}")
