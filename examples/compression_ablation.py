"""Ablation: every EF method × several compressors on one problem (the paper's
method zoo side by side), reporting final ‖∇f‖² and transmitted coordinates.

Each grid cell is named by a declarative RunSpec (launch/spec.py) — the same
serializable surface the production drivers use — and the Method object is
derived from it via ``session.make_method``, so the simulator sweep and the
production train path can never disagree about what a cell means. Swap
``simulate.run_numpy`` for ``Session(spec).train`` to run any cell at model
scale.

    PYTHONPATH=src python examples/compression_ablation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import problems, simulate
from repro.launch import session as session_lib
from repro.launch.spec import RunSpec

prob = problems.LogisticRegression(n=8, m_per_client=128, l=32, c=5, seed=0)
d = prob.dim
STEPS = 1200

COMPRESSORS = [
    ("top10", "topk", {"k": 10}),
    ("block_topk", "block_topk", {"block": 64, "k_per_block": 4}),
    ("randk10", "randk", {"k": 10}),
    ("natural", "natural", {}),
    ("rank1", "rank1", {"rows": 15}),
]

grid = [RunSpec(method=mname, compressor=cname, compressor_kw=ckw, eta=0.1)
        for _, cname, ckw in COMPRESSORS
        for mname in ["ef21_sgd", "ef21_sgdm", "ef21_sgd2m", "ef14_sgd"]]
# absolute compressor variant (Algorithm 4)
grid.append(RunSpec(method="ef21_sgdm_abs", compressor="hard_threshold",
                    compressor_kw={"lam": 0.05}, method_kw={"gamma": 0.05},
                    eta=0.1))
# bidirectional cell (DESIGN.md §8): same uplink as the block_topk row, but
# the server broadcast rides a quant4 wire instead of dense f32 — compare
# its total (up + down) wire words against the unidirectional rows
grid.append(RunSpec(method="ef21_sgdm", compressor="block_topk",
                    compressor_kw={"block": 64, "k_per_block": 4}, eta=0.1,
                    downlink_carrier="quant4", downlink_ratio=0.05))

rows = []
for spec in grid:
    m = session_lib.make_method(spec)
    cfg = simulate.SimConfig(n=8, batch_size=4, gamma=0.05, steps=STEPS,
                             b_init=4, down_carrier=spec.downlink_carrier,
                             down_compressor=session_lib.make_down_compressor(
                                 spec))
    out = simulate.run_numpy(prob, m, cfg, seed=0)
    gn = float(np.asarray(out["grad_norm_sq"][-100:]).mean())
    label = spec.compressor + (f"+{spec.downlink_carrier}↓"
                               if spec.downlink_carrier != "dense" else "")
    rows.append((spec.method, label, gn, m.coords_per_message(d),
                 out["wire_words_total_per_round"]))

print(f"{'method':15s} {'compressor':12s} {'end ‖∇f‖²':>12s} "
      f"{'coords/round':>13s} {'wire up+down':>13s}")
for mname, cname, gn, coords, wire in sorted(rows, key=lambda r: r[2]):
    print(f"{mname:15s} {cname:12s} {gn:12.3e} {coords:13.0f} {wire:13.0f}")

# ---------------------------------------------------------------------------
# mixed per-parameter-group schedule (DESIGN.md §9) on a multi-leaf problem:
# dense biases (+ the model's "norm"-like tiny tensors), quant4 on the input
# layer (the embedding analogue), sparse on the remaining matrices —
# per-group and total wire words against the uniform sparse baseline
# ---------------------------------------------------------------------------
from repro.core import compressors as C  # noqa: E402
from repro.core import ef as ef_lib  # noqa: E402
from repro.core import schedule as sched_lib  # noqa: E402

mlp = problems.MLPClassification(n=8, m_per_client=128, seed=0)
btk = C.BlockTopK(block=64, k_per_block=4)
method = ef_lib.EF21SGDM(compressor=btk, eta=0.1)
mixed = sched_lib.CompressionSchedule((
    sched_lib.Group(pattern="b", compressor=C.Identity(), carrier="dense"),
    sched_lib.Group(pattern="w1", compressor=btk, carrier="quant4"),
    sched_lib.Group(pattern="*", compressor=C.BlockTopK(block=64,
                                                        k_per_block=2),
                    carrier="sparse"),
))
uniform = sched_lib.CompressionSchedule.uniform(btk, carrier="sparse")
print("\nmixed schedule (dense b* | quant4 w1 | sparse *) vs uniform sparse:")
for label, sched in (("uniform", uniform), ("mixed", mixed)):
    cfg = simulate.SimConfig(n=8, batch_size=4, gamma=0.05, steps=400,
                             b_init=4, schedule=sched)
    out = simulate.run_numpy(mlp, method, cfg, seed=0)
    gn = float(np.asarray(out["grad_norm_sq"][-50:]).mean())
    per = ", ".join(f"{g.pattern}={w:.0f}" for g, w in zip(
        sched.groups, np.asarray(out["wire_words_up_per_group"])))
    print(f"  {label:8s} end ‖∇f‖² {gn:9.3e}  wire/round up "
          f"{out['wire_words_up_per_round']:6.0f} [{per}] "
          f"total {out['wire_words_total_per_round']:.0f}")
print(sched_lib.plan_table(mixed, method, mlp.init_x()))
