"""Ablation: every EF method × several compressors on one problem (the paper's
method zoo side by side), reporting final ‖∇f‖² and transmitted coordinates.

    PYTHONPATH=src python examples/compression_ablation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import compressors as C, ef, problems, simulate

prob = problems.LogisticRegression(n=8, m_per_client=128, l=32, c=5, seed=0)
d = prob.dim
STEPS = 1200

rows = []
for cname, comp in [
    ("top10", C.TopK(k=10)),
    ("block_topk", C.BlockTopK(block=64, k_per_block=4)),
    ("randk10", C.RandK(k=10)),
    ("natural", C.NaturalCompression()),
    ("rank1", C.Rank1(rows=15)),
]:
    for mname in ["ef21_sgd", "ef21_sgdm", "ef21_sgd2m", "ef14_sgd"]:
        kw = {"compressor": comp}
        if "sgdm" in mname or "2m" in mname:
            kw["eta"] = 0.1
        m = ef.make(mname, **kw)
        cfg = simulate.SimConfig(n=8, batch_size=4, gamma=0.05, steps=STEPS,
                                 b_init=4)
        out = simulate.run_numpy(prob, m, cfg, seed=0)
        gn = float(np.asarray(out["grad_norm_sq"][-100:]).mean())
        rows.append((mname, cname, gn, m.coords_per_message(d)))

# absolute compressor variant (Algorithm 4)
m = ef.EF21SGDMAbs(compressor=C.HardThreshold(lam=0.05), eta=0.1, gamma=0.05)
out = simulate.run_numpy(prob, m, simulate.SimConfig(
    n=8, batch_size=4, gamma=0.05, steps=STEPS, b_init=4), seed=0)
rows.append(("ef21_sgdm_abs", "hard_thresh",
             float(np.asarray(out["grad_norm_sq"][-100:]).mean()), d))

print(f"{'method':15s} {'compressor':12s} {'end ‖∇f‖²':>12s} {'coords/round':>13s}")
for mname, cname, gn, coords in sorted(rows, key=lambda r: r[2]):
    print(f"{mname:15s} {cname:12s} {gn:12.3e} {coords:13.0f}")
