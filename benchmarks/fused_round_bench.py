"""The acceptance bench for the one-launch fused EF round (ISSUE 6): the
fused uplink round vs today's unfused multi-launch step at smollm-360m
geometry, recorded in the checked-in ledger BENCH_fused_round.json.

What is timed (both sides jit-COMPILED — never the Pallas interpreter):

* ``unfused_step`` — the pre-fusion hot path as four separately dispatched
  launches, each fenced by ``block_until_ready`` so every stage round-trips
  memory exactly as the separate-kernel chain does on device:
  (1) EF21-SGDM update v' = (1−η)v + η·grad and residual v'−g,
  (2) BlockTopK select (``core/compressors.py::BlockTopK.__call__`` math:
      per-block lax.top_k threshold mask),
  (3) block-quantize the selection (``kernels/ref.py::block_quantize_ref``),
  (4) dequantize + integrate g' = g + decode(wire)  (the EF invariant).

* ``fused_round`` — the same four stages as ONE jit (one launch), running
  the mega-kernel's own selection algorithm: per-block threshold bisection
  on the float bit pattern (``kernels/topk_compress.py`` semantics —
  compare-and-count passes instead of a serial sort/heap), exactly as
  ``kernels/fused_round.py::ef21_sgdm_topk_quant`` selects on TPU.

The two paths are asserted BIT-IDENTICAL on every output (v', g', q,
scales) before a single timing run — the speedup is never bought with a
different answer."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_run, csv_row, measure_ns, save_bench
from repro.kernels import ref

ETA, BLOCK, K, BITS = 0.1, 1024, 16, 8


def _kth_bisect(ab, k: int):
    """Exact per-row kth largest of non-negative ``ab`` via bisection on the
    float32 bit pattern (monotone for non-negative floats): 32 vectorized
    compare-and-count passes, no sort — the fused kernel's selection rule."""
    lo = jnp.zeros((ab.shape[0],), jnp.int32)
    hi = jnp.full((ab.shape[0],), jnp.int32(0x7F800000))  # +inf bit pattern
    abi = ab.astype(jnp.float32).view(jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2
        ge = jnp.sum(abi >= mid[:, None], axis=1) >= k
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

    lo, _ = jax.lax.fori_loop(0, 32, body, (lo, hi))
    return lo.view(jnp.float32)


def _build(nb: int, d: int):
    """(unfused_step, fused_round) callables over flat (d,) leaves padded to
    ``nb`` launch blocks; both return (v', g', q, scales)."""

    def update(grad, v, g):
        v2 = (1.0 - ETA) * v + ETA * grad
        return v2, v2 - g

    def select_topk(delta):
        db = delta.reshape(nb, BLOCK)
        ab = jnp.abs(db)
        thr = jax.lax.top_k(ab, K)[0][:, -1:]
        return jnp.where(ab >= thr, db, 0.0)

    def integrate(g, q, scales):
        c_hat = ref.block_dequantize_ref(q, scales, bits=BITS, cols=BLOCK)
        return g + c_hat.reshape(-1)[:d]

    f_update = jax.jit(update)
    f_select = jax.jit(select_topk)
    f_quant = jax.jit(lambda c: ref.block_quantize_ref(c, BITS))
    f_integrate = jax.jit(integrate)

    def unfused_step(grad, v, g):
        v2, delta = f_update(grad, v, g)
        jax.block_until_ready(delta)          # launch 1 lands in memory
        c = f_select(delta)
        jax.block_until_ready(c)              # launch 2
        q, scales = f_quant(c)
        jax.block_until_ready(scales)         # launch 3
        g2 = f_integrate(g, q, scales)
        jax.block_until_ready(g2)             # launch 4
        return v2, g2, q, scales

    @jax.jit
    def fused_round(grad, v, g):
        v2, delta = update(grad, v, g)
        db = delta.reshape(nb, BLOCK)
        ab = jnp.abs(db)
        thr = _kth_bisect(ab, K)
        c = jnp.where(ab >= thr[:, None], db, 0.0)
        q, scales = ref.block_quantize_ref(c, BITS)
        return v2, integrate(g, q, scales), q, scales

    return unfused_step, fused_round


def _param_count(arch: str) -> int:
    from repro.configs import base as cb
    from repro.models import model as model_lib

    cfg = cb.get(arch)
    shapes = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    return sum(int(np.prod(leaf.shape))
               for leaf in jax.tree_util.tree_leaves(shapes))


def run(tiny: bool = False) -> dict:
    arch = "smollm-360m"
    params = 1 << 16 if tiny else _param_count(arch)
    nb = -(-params // BLOCK)
    d = nb * BLOCK
    rng = np.random.RandomState(0)
    grad, v, g = [jnp.asarray(rng.randn(d).astype(np.float32))
                  for _ in range(3)]
    unfused_step, fused_round = _build(nb, d)

    # correctness gate first: the fused launch must reproduce the unfused
    # chain bit-for-bit before its time is worth recording
    u = unfused_step(grad, v, g)
    f = jax.block_until_ready(fused_round(grad, v, g))
    for name, a, b in zip(("v_new", "g_new", "q", "scales"), u, f):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"fused {name} != unfused")

    iters, warmup = (5, 2) if tiny else (3, 1)
    metrics = {
        "unfused_step": measure_ns(unfused_step, grad, v, g,
                                   iters=iters, warmup=warmup),
        "fused_round": measure_ns(fused_round, grad, v, g,
                                  iters=iters, warmup=warmup),
    }
    speedup = (metrics["unfused_step"]["median_ns"]
               / max(metrics["fused_round"]["median_ns"], 1))
    ledger = save_bench("fused_round", bench_run(
        geometry={"arch": arch, "params": params, "d": d, "nb": nb,
                  "block": BLOCK, "k_per_block": K, "bits": BITS,
                  "eta": ETA, "tiny": tiny},
        metrics=metrics,
        speedup_vs_ref={"fused_round_vs_unfused_step": speedup}))
    csv_row("fused_round_bench",
            metrics["fused_round"]["median_ns"] / 1e3,
            f"unfused_us={metrics['unfused_step']['median_ns'] / 1e3:.0f};"
            f"speedup_x={speedup:.2f};params={params};tiny={tiny}")
    return {"speedup": speedup, "ledger": ledger, "metrics": metrics}


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke geometry (64K params) instead of the full "
                        "smollm-360m parameter count")
    out = run(tiny=p.parse_args().tiny)
    print(f"fused_round speedup vs unfused step: {out['speedup']:.2f}x "
          f"(ledger: {out['ledger']})")
