"""Experiment 4 (Figures 8–9): neural-network training under compression.

Paper claim (CIFAR10/ResNet18, scaled here to an MLP on synthetic label-split
data): EF21-SGDM ≥ EF14-SGD > EF21-SGD in convergence per transmitted bit, and
final accuracies are ordered the same way.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, csv_row, median_curves, save_json
from repro.core import compressors as C
from repro.core import ef, problems, simulate

SEEDS = 2
STEPS = 1500
N = 5


def run() -> dict:
    prob = problems.MLPClassification(n=N, m_per_client=256, in_dim=32,
                                      hidden=64, c=10, seed=0)
    d = sum(np.prod(np.asarray(v).shape)
            for v in prob.init_x().values())
    topk = C.TopK(ratio=0.2)       # paper: K = 2e6 of d ≈ 1e7
    out = {}
    with Timer() as t:
        for B in (32, 128):
            for name, m in {
                "sgd": ef.SGD(),
                "ef21_sgd": ef.EF21SGD(compressor=topk),
                "ef14_sgd": ef.EF14SGD(compressor=topk),
                "ef21_sgdm": ef.EF21SGDM(compressor=topk, eta=0.1),
            }.items():
                cfg = simulate.SimConfig(n=N, batch_size=B, gamma=0.05,
                                         steps=STEPS, b_init=4)
                runs = [simulate.run_numpy(prob, m, cfg, seed=s)
                        for s in range(SEEDS)]
                loss_curve = median_curves(runs, "loss")
                accs = [float(prob.accuracy(r["x_final"])) for r in runs]
                out[f"B{B}/{name}"] = {
                    "end_loss": float(loss_curve[-100:].mean()),
                    "accuracy": float(np.median(accs)),
                    "loss_ds": loss_curve[::50].tolist(),
                }
    out["claims"] = {
        # 2-seed medians on noisy-label data → 10%/0.02-tolerance orderings
        "sgdm_within_10pct_of_ef21sgd_B32":
            out["B32/ef21_sgdm"]["end_loss"]
            < 1.1 * out["B32/ef21_sgd"]["end_loss"],
        "sgdm_matches_or_beats_ef14_B128":
            out["B128/ef21_sgdm"]["end_loss"]
            <= out["B128/ef14_sgd"]["end_loss"] * 1.1,
        "accuracy_order":
            out["B128/ef21_sgdm"]["accuracy"]
            >= out["B128/ef21_sgd"]["accuracy"] - 0.02,
    }
    save_json("exp4_neuralnet", out)
    csv_row("exp4_neuralnet", t.us_per(SEEDS * STEPS * 8),
            f"acc_sgdm={out['B128/ef21_sgdm']['accuracy']:.3f};"
            f"acc_ef21sgd={out['B128/ef21_sgd']['accuracy']:.3f};"
            f"claims={sum(out['claims'].values())}/3")
    return out


if __name__ == "__main__":
    run()
