"""Figure 1 (+ Figure 4): divergence of EF21-SGD on f(x)=½‖x‖² with Top1, B=1.

Paper claims validated here:
  (a) EF21-SGD drifts AWAY from the optimum (‖∇f‖² grows orders of magnitude
      above its start) — Fig 1a;
  (b) increasing n does not rescue it — Fig 1b;
  (c) EF21-SGDM is stable near the optimum on the same instance — Fig 1a;
  (d) the same happens with the App-J time-varying schedule — Fig 4.
"""
from __future__ import annotations


from benchmarks.common import Timer, csv_row, median_curves, save_json
from repro.core import compressors as C
from repro.core import ef, problems, simulate

SEEDS = 5
STEPS = 10_000


def run() -> dict:
    prob = problems.QuadraticT1()
    top1 = C.TopK(k=1)
    out = {}

    def runs(method, n, tv=False, gamma=1e-3):
        cfg = simulate.SimConfig(n=n, batch_size=1, gamma=gamma, steps=STEPS,
                                 time_varying=tv)
        return [simulate.run_numpy(prob, method, cfg, seed=s)
                for s in range(SEEDS)]

    with Timer() as t:
        for name, m in [("ef21_sgd", ef.EF21SGD(compressor=top1)),
                        ("ef21_sgdm", ef.EF21SGDM(compressor=top1, eta=1e-3)),
                        ("sgd", ef.SGD())]:
            curve = median_curves(runs(m, n=1))
            out[f"fig1a/{name}"] = {
                "start": float(curve[0]), "end": float(curve[-500:].mean()),
                "max": float(curve.max()),
                "curve_ds": curve[::100].tolist(),
            }
        # Fig 1b: n-sweep for EF21-SGD
        for n in (1, 4, 16):
            curve = median_curves(runs(ef.EF21SGD(compressor=top1), n=n))
            out[f"fig1b/ef21_sgd_n{n}"] = {"start": float(curve[0]),
                                           "end": float(curve[-500:].mean())}
        # Theorem 1 exact object: EF21-SGD-ideal floor at x⁰=(0,−1) (Part II),
        # independent of n:  E‖∇f‖² ≥ min(σ², ‖∇f(x⁰)‖²)/60 = 1/60
        prob_thm = problems.QuadraticT1(x0=(0.0, -1.0))
        floor = 1.0 / 60.0
        for n in (1, 4):
            m = ef.EF21SGDMIdeal(compressor=top1, eta=1.0)
            cfg = simulate.SimConfig(n=n, batch_size=1, gamma=0.5, steps=STEPS)
            curve = median_curves([simulate.run_numpy(prob_thm, m, cfg, seed=s)
                                   for s in range(SEEDS)])
            out[f"thm1/ideal_n{n}"] = {"end": float(curve[-500:].mean()),
                                       "floor": floor}
        # Fig 4: time-varying parameters
        for name, m in [("ef21_sgd", ef.EF21SGD(compressor=top1)),
                        ("ef21_sgdm", ef.EF21SGDM(compressor=top1, eta=0.1))]:
            curve = median_curves(runs(m, n=1, tv=True, gamma=0.1))
            out[f"fig4/{name}"] = {"end": float(curve[-500:].mean())}

    sgd_end = out["fig1a/ef21_sgd"]["end"]
    sgdm_end = out["fig1a/ef21_sgdm"]["end"]
    out["claims"] = {
        "ef21_sgd_diverges": sgd_end > 10 * out["fig1a/ef21_sgd"]["start"],
        "sgdm_stable": sgdm_end < sgd_end / 3,
        # "no improvement with n" = convergence is NOT restored at any n
        # (the error still ends ≥2× above its start for every n)
        "no_n_restores_convergence": all(
            out[f"fig1b/ef21_sgd_n{n}"]["end"]
            > 2 * out[f"fig1b/ef21_sgd_n{n}"]["start"] for n in (1, 4, 16)),
        "thm1_floor_holds_all_n": all(
            out[f"thm1/ideal_n{n}"]["end"] >= out[f"thm1/ideal_n{n}"]["floor"]
            for n in (1, 4)),
        "tv_same_story": out["fig4/ef21_sgd"]["end"]
        > 3 * out["fig4/ef21_sgdm"]["end"],
    }
    save_json("fig1_divergence", out)
    csv_row("fig1_divergence", t.us_per(SEEDS * STEPS * 8),
            f"ef21_sgd_end={sgd_end:.2e};sgdm_end={sgdm_end:.2e};"
            f"claims={sum(out['claims'].values())}/{len(out['claims'])}")
    return out


if __name__ == "__main__":
    run()
