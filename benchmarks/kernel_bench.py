"""Kernel micro-bench: wall time of the Pallas kernels (interpret mode on CPU —
these numbers validate correctness-path overhead, NOT TPU performance; the
roofline derivation for real TPU lives in benchmarks/roofline.py) and of the
pure-JAX equivalents the models use on CPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, save_json
from repro.kernels import ops, ref


def _bench(fn, *args, iters=3):
    fn(*args)  # compile/interpret warmup
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return 1e6 * (time.time() - t0) / iters


def _train_step_compare(out: dict) -> None:
    """Full train-step wall time, fused vs unfused carrier (core/carriers.py):
    the SAME production path the train driver runs — one RunSpec per carrier,
    stepped through ``Session.step_once`` (launch/session.py) — dispatched
    through DenseCarrier (unfused pre→C→post chain) vs FusedPallasCarrier
    (one interpreted Pallas pass per leaf on CPU — compiled Mosaic on TPU)."""
    from benchmarks.common import bench_session

    for carrier in ("dense", "fused"):
        sess = bench_session(
            carrier=carrier, method="ef21_sgdm", compressor="block_topk",
            compressor_kw={"block": 1024, "k_per_block": 16}, eta=0.1)
        # time ONLY the jitted step on a fixed batch/state — host-side batch
        # synthesis must not dilute the fused-vs-dense device delta
        step, batch = sess.step_fn, sess.batch_for(0)
        state = (sess.params, sess.opt_state, sess.ef_state)
        key = jax.random.PRNGKey(0)

        def one(t):
            return step(*state, batch, jax.random.fold_in(key, t), t)[3]

        out[f"train_step_{carrier}_us"] = _bench(one, 0, iters=3)


def _quantize_bench(out: dict, x) -> None:
    """Wire-codec wall time: Pallas block-quantize/dequantize (interpret on
    CPU) vs the jit'd jnp oracle the vmap runtimes execute."""
    d = x.size
    nb = d // 1024
    for bits in (8, 4):
        out[f"quantize{bits}_pallas_interp_us"] = _bench(
            lambda t, b=bits: ops.block_quantize(t, block=1024, bits=b),
            x, iters=2)
        out[f"quantize{bits}_ref_us"] = _bench(
            jax.jit(lambda t, b=bits: ref.block_quantize_ref(
                t.reshape(nb, 1024), b)), x)
        q, s = ops.block_quantize(x, block=1024, bits=bits)
        out[f"dequantize{bits}_pallas_interp_us"] = _bench(
            lambda a, b, bb=bits: ops.block_dequantize(
                a, b, d=d, block=1024, bits=bb), q, s, iters=2)
        out[f"dequantize{bits}_ref_us"] = _bench(
            jax.jit(lambda a, b, bb=bits: ref.block_dequantize_ref(
                a, b, bits=bb, cols=1024)), q, s)


def _wire_savings(out: dict) -> None:
    """Honest per-client wire words of one d-dim EF message per carrier at
    equal K (core/carriers.py::Carrier.wire_words): the x-axis the paper's
    per-bit plots use, and the collective-bytes lever --carrier buys."""
    from repro.core import carriers as carrier_lib
    from repro.core import compressors as C

    d = 1 << 20
    btk = C.BlockTopK(block=1024, k_per_block=16)
    for name in ("dense", "sparse", "quant8", "quant4"):
        out[f"wire_words_{name}"] = carrier_lib.make(name).wire_words(btk, d)
    out["wire_savings_quant8_vs_sparse"] = (
        out["wire_words_sparse"] / out["wire_words_quant8"])
    out["wire_savings_quant4_vs_sparse"] = (
        out["wire_words_sparse"] / out["wire_words_quant4"])
    # downlink split (DESIGN.md §8): the server broadcast per round, per
    # carrier — 'dense' is the implicit f32 broadcast every unidirectional
    # runtime ships, the lever --downlink-carrier attacks (acceptance: the
    # quant4 broadcast undercuts dense by well over 7×)
    for name in ("dense", "sparse", "quant8", "quant4"):
        out[f"downlink_words_{name}"] = carrier_lib.downlink_words(
            carrier_lib.make(name), btk, d)
    for name in ("sparse", "quant8", "quant4"):
        out[f"downlink_savings_{name}_vs_dense"] = (
            out["downlink_words_dense"] / out[f"downlink_words_{name}"])


def _schedule_wire(out: dict) -> None:
    """Mixed-schedule wire accounting (DESIGN.md §9): dense norms/biases +
    quant4 embeds + sparse attention/MLP over the real (smoke) smollm param
    tree, per group and in total, against the uniform BlockTopK baseline —
    the scenario lever per-group schedules buy over any single-knob config."""
    import jax

    from repro.configs import base as cb
    from repro.core import compressors as C
    from repro.core import ef as ef_lib
    from repro.core import schedule as sched_lib
    from repro.models import model as model_lib

    cfg = cb.get_smoke("smollm-360m")
    shapes = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    btk = C.BlockTopK(ratio=0.05)
    method = ef_lib.EF21SGDM(compressor=btk, eta=0.1)
    mixed = sched_lib.CompressionSchedule((
        sched_lib.Group(pattern="norm|bias", compressor=C.Identity(),
                        carrier="dense"),
        sched_lib.Group(pattern="embed", compressor=btk, carrier="quant4"),
        sched_lib.Group(pattern="*", compressor=C.BlockTopK(ratio=0.02),
                        carrier="sparse"),
    ))
    uniform = sched_lib.CompressionSchedule.uniform(btk, carrier="sparse")
    per, total = sched_lib.wire_words_tree(mixed, method, shapes, "up")
    for grp, words in zip(mixed.groups, per):
        out[f"sched_wire_up_{grp.pattern.replace('|', '_')}"] = words
    out["sched_wire_up_mixed_total"] = total
    _, out["sched_wire_up_uniform_total"] = sched_lib.wire_words_tree(
        uniform, method, shapes, "up")
    out["sched_mixed_vs_uniform"] = (
        out["sched_wire_up_uniform_total"] / max(total, 1e-9))


def run() -> dict:
    rng = np.random.RandomState(0)
    out = {}

    B, S, H, hd = 1, 512, 4, 64
    q, k, v = [jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
               for _ in range(3)]
    out["flash_pallas_interp_us"] = _bench(
        lambda a, b, c: ops.flash_attention(a, b, c, block_q=128, block_k=128),
        q, k, v, iters=2)
    out["flash_ref_us"] = _bench(
        jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c)), q, k, v)

    x = jnp.asarray(rng.randn(1 << 20).astype(np.float32))
    out["block_topk_pallas_interp_us"] = _bench(
        lambda t: ops.block_topk(t, block=1024, k=16), x, iters=2)
    out["block_topk_ref_us"] = _bench(
        jax.jit(lambda t: ref.block_topk_ref(t, 1024, 16)), x)

    g, vv, gg = [jnp.asarray(rng.randn(1 << 20).astype(np.float32))
                 for _ in range(3)]
    out["ef_update_fused_interp_us"] = _bench(
        lambda a, b, c: ops.ef21_sgdm_update(a, b, c, eta=0.1), g, vv, gg,
        iters=2)
    out["ef_update_ref_us"] = _bench(
        jax.jit(lambda a, b, c: ref.ef21_sgdm_update_ref(
            a, b, c, eta=0.1, block=1024, k=16)), g, vv, gg)

    _quantize_bench(out, x)
    _wire_savings(out)
    _schedule_wire(out)
    _train_step_compare(out)

    save_json("kernel_bench", out)
    csv_row("kernel_bench", out["flash_pallas_interp_us"],
            f"topk_ref_us={out['block_topk_ref_us']:.0f};"
            f"ef_ref_us={out['ef_update_ref_us']:.0f};"
            f"step_dense_us={out['train_step_dense_us']:.0f};"
            f"step_fused_us={out['train_step_fused_us']:.0f};"
            f"wire_q8_x={out['wire_savings_quant8_vs_sparse']:.1f};"
            f"wire_q4_x={out['wire_savings_quant4_vs_sparse']:.1f};"
            f"down_q4_x={out['downlink_savings_quant4_vs_dense']:.1f}")
    return out


if __name__ == "__main__":
    run()
