"""Kernel micro-bench: wall time of the Pallas kernels (interpret mode on CPU —
these numbers validate correctness-path overhead, NOT TPU performance; the
roofline derivation for real TPU lives in benchmarks/roofline.py) and of the
pure-JAX equivalents the models use on CPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, csv_row, save_json
from repro.kernels import ops, ref


def _bench(fn, *args, iters=3):
    fn(*args)  # compile/interpret warmup
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return 1e6 * (time.time() - t0) / iters


def run() -> dict:
    rng = np.random.RandomState(0)
    out = {}

    B, S, H, hd = 1, 512, 4, 64
    q, k, v = [jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
               for _ in range(3)]
    out["flash_pallas_interp_us"] = _bench(
        lambda a, b, c: ops.flash_attention(a, b, c, block_q=128, block_k=128),
        q, k, v, iters=2)
    out["flash_ref_us"] = _bench(
        jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c)), q, k, v)

    x = jnp.asarray(rng.randn(1 << 20).astype(np.float32))
    out["block_topk_pallas_interp_us"] = _bench(
        lambda t: ops.block_topk(t, block=1024, k=16), x, iters=2)
    out["block_topk_ref_us"] = _bench(
        jax.jit(lambda t: ref.block_topk_ref(t, 1024, 16)), x)

    g, vv, gg = [jnp.asarray(rng.randn(1 << 20).astype(np.float32))
                 for _ in range(3)]
    out["ef_update_fused_interp_us"] = _bench(
        lambda a, b, c: ops.ef21_sgdm_update(a, b, c, eta=0.1), g, vv, gg,
        iters=2)
    out["ef_update_ref_us"] = _bench(
        jax.jit(lambda a, b, c: ref.ef21_sgdm_update_ref(
            a, b, c, eta=0.1, block=1024, k=16)), g, vv, gg)

    save_json("kernel_bench", out)
    csv_row("kernel_bench", out["flash_pallas_interp_us"],
            f"topk_ref_us={out['block_topk_ref_us']:.0f};"
            f"ef_ref_us={out['ef_update_ref_us']:.0f}")
    return out


if __name__ == "__main__":
    run()
