"""Kernel micro-bench: wall time of the Pallas kernels (interpret mode on CPU —
these numbers validate correctness-path overhead, NOT TPU performance; the
roofline derivation for real TPU lives in benchmarks/roofline.py) and of the
pure-JAX equivalents the models use on CPU.

Every timed section records {p10, median, p90} ns into the checked-in perf
ledger BENCH_kernels.json at the repo root (benchmarks/common.py::save_bench);
the legacy results/kernel_bench.json keeps its flat median-us keys. ``--tiny``
shrinks every geometry for the CI bench-smoke step."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_run, csv_row, measure_ns, save_bench,
                               save_json)
from repro.kernels import ops, ref

# metric name -> measure_ns dict, accumulated by _bench for the ledger
_NS: dict = {}


def _bench(fn, *args, iters=3, key=None, warmup=2):
    """Time fn(*args): explicit warmup, then per-call block_until_ready
    timings (common.py::measure_ns). Returns median us for the flat legacy
    dict; the full {p10, median, p90} ns sample lands in the ledger under
    ``key``."""
    m = measure_ns(fn, *args, iters=iters, warmup=warmup)
    if key is not None:
        _NS[key] = m
    return m["median_ns"] / 1e3


def _train_step_compare(out: dict) -> None:
    """Full train-step wall time, fused vs unfused carrier (core/carriers.py):
    the SAME production path the train driver runs — one RunSpec per carrier,
    stepped through ``Session.step_once`` (launch/session.py) — dispatched
    through DenseCarrier (unfused pre→C→post chain) vs FusedPallasCarrier
    (one interpreted Pallas pass per leaf on CPU — compiled Mosaic on TPU)."""
    from benchmarks.common import bench_session

    for carrier in ("dense", "fused"):
        sess = bench_session(
            carrier=carrier, method="ef21_sgdm", compressor="block_topk",
            compressor_kw={"block": 1024, "k_per_block": 16}, eta=0.1)
        # time ONLY the jitted step on a fixed batch/state — host-side batch
        # synthesis must not dilute the fused-vs-dense device delta
        step, batch = sess.step_fn, sess.batch_for(0)
        state = (sess.params, sess.opt_state, sess.ef_state)
        key = jax.random.PRNGKey(0)

        def one(t):
            return step(*state, batch, jax.random.fold_in(key, t), t)[3]

        out[f"train_step_{carrier}_us"] = _bench(
            one, 0, iters=3, key=f"train_step_{carrier}")


def _quantize_bench(out: dict, x, block: int) -> None:
    """Wire-codec wall time: Pallas block-quantize/dequantize (interpret on
    CPU) vs the jit'd jnp oracle the vmap runtimes execute."""
    d = x.size
    nb = d // block
    for bits in (8, 4):
        out[f"quantize{bits}_pallas_interp_us"] = _bench(
            lambda t, b=bits: ops.block_quantize(t, block=block, bits=b),
            x, iters=2, key=f"quantize{bits}_pallas_interp")
        out[f"quantize{bits}_ref_us"] = _bench(
            jax.jit(lambda t, b=bits: ref.block_quantize_ref(
                t.reshape(nb, block), b)), x, key=f"quantize{bits}_ref")
        q, s = ops.block_quantize(x, block=block, bits=bits)
        out[f"dequantize{bits}_pallas_interp_us"] = _bench(
            lambda a, b, bb=bits: ops.block_dequantize(
                a, b, d=d, block=block, bits=bb), q, s, iters=2,
            key=f"dequantize{bits}_pallas_interp")
        out[f"dequantize{bits}_ref_us"] = _bench(
            jax.jit(lambda a, b, bb=bits: ref.block_dequantize_ref(
                a, b, bits=bb, cols=block)), q, s,
            key=f"dequantize{bits}_ref")


def _wire_savings(out: dict, d: int, block: int, k: int) -> None:
    """Honest per-client wire words of one d-dim EF message per carrier at
    equal K (core/carriers.py::Carrier.wire_words): the x-axis the paper's
    per-bit plots use, and the collective-bytes lever --carrier buys."""
    from repro.core import carriers as carrier_lib
    from repro.core import compressors as C

    btk = C.BlockTopK(block=block, k_per_block=k)
    uplink = ("dense", "sparse", "quant8", "quant4",
              "fused_quant8", "fused_quant4")
    for name in uplink:
        out[f"wire_words_{name}"] = carrier_lib.make(name).wire_words(btk, d)
    out["wire_savings_quant8_vs_sparse"] = (
        out["wire_words_sparse"] / out["wire_words_quant8"])
    out["wire_savings_quant4_vs_sparse"] = (
        out["wire_words_sparse"] / out["wire_words_quant4"])
    # the fused carrier ships dense quantized payloads (no index words, every
    # block present) — this is the wire premium the one-launch uplink pays
    out["wire_premium_fused_quant8_vs_quant8"] = (
        out["wire_words_fused_quant8"] / out["wire_words_quant8"])
    # downlink split (DESIGN.md §8): the server broadcast per round, per
    # carrier — 'dense' is the implicit f32 broadcast every unidirectional
    # runtime ships, the lever --downlink-carrier attacks (acceptance: the
    # quant4 broadcast undercuts dense by well over 7×)
    for name in uplink:
        out[f"downlink_words_{name}"] = carrier_lib.downlink_words(
            carrier_lib.make(name), btk, d)
    for name in ("sparse", "quant8", "quant4"):
        out[f"downlink_savings_{name}_vs_dense"] = (
            out["downlink_words_dense"] / out[f"downlink_words_{name}"])


def _schedule_wire(out: dict) -> None:
    """Mixed-schedule wire accounting (DESIGN.md §9): dense norms/biases +
    quant4 embeds + sparse attention/MLP over the real (smoke) smollm param
    tree, per group and in total, against the uniform BlockTopK baseline —
    the scenario lever per-group schedules buy over any single-knob config."""
    import jax

    from repro.configs import base as cb
    from repro.core import compressors as C
    from repro.core import ef as ef_lib
    from repro.core import schedule as sched_lib
    from repro.models import model as model_lib

    cfg = cb.get_smoke("smollm-360m")
    shapes = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    btk = C.BlockTopK(ratio=0.05)
    method = ef_lib.EF21SGDM(compressor=btk, eta=0.1)
    mixed = sched_lib.CompressionSchedule((
        sched_lib.Group(pattern="norm|bias", compressor=C.Identity(),
                        carrier="dense"),
        sched_lib.Group(pattern="embed", compressor=btk, carrier="quant4"),
        sched_lib.Group(pattern="*", compressor=C.BlockTopK(ratio=0.02),
                        carrier="sparse"),
    ))
    uniform = sched_lib.CompressionSchedule.uniform(btk, carrier="sparse")
    per, total = sched_lib.wire_words_tree(mixed, method, shapes, "up")
    for grp, words in zip(mixed.groups, per):
        out[f"sched_wire_up_{grp.pattern.replace('|', '_')}"] = words
    out["sched_wire_up_mixed_total"] = total
    _, out["sched_wire_up_uniform_total"] = sched_lib.wire_words_tree(
        uniform, method, shapes, "up")
    out["sched_mixed_vs_uniform"] = (
        out["sched_wire_up_uniform_total"] / max(total, 1e-9))


def run(tiny: bool = False) -> dict:
    rng = np.random.RandomState(0)
    out = {}
    _NS.clear()

    # --tiny shrinks every geometry so the CI bench-smoke step exercises the
    # full codepath (incl. the ledger write) in seconds; the numbers it
    # records are labelled by their geometry, never compared across modes.
    S = 128 if tiny else 512
    d = 1 << 14 if tiny else 1 << 20
    block, k = (256, 8) if tiny else (1024, 16)

    B, H, hd = 1, 4, 64
    q, kk, v = [jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
                for _ in range(3)]
    out["flash_pallas_interp_us"] = _bench(
        lambda a, b, c: ops.flash_attention(a, b, c, block_q=128, block_k=128),
        q, kk, v, iters=2, key="flash_pallas_interp")
    out["flash_ref_us"] = _bench(
        jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c)), q, kk, v,
        key="flash_ref")

    x = jnp.asarray(rng.randn(d).astype(np.float32))
    out["block_topk_pallas_interp_us"] = _bench(
        lambda t: ops.block_topk(t, block=block, k=k), x, iters=2,
        key="block_topk_pallas_interp")
    out["block_topk_ref_us"] = _bench(
        jax.jit(lambda t: ref.block_topk_ref(t, block, k)), x,
        key="block_topk_ref")

    g, vv, gg = [jnp.asarray(rng.randn(d).astype(np.float32))
                 for _ in range(3)]
    out["ef_update_fused_interp_us"] = _bench(
        lambda a, b, c: ops.ef21_sgdm_update(a, b, c, eta=0.1), g, vv, gg,
        iters=2, key="ef_update_fused_interp")
    out["ef_update_ref_us"] = _bench(
        jax.jit(lambda a, b, c: ref.ef21_sgdm_update_ref(
            a, b, c, eta=0.1, block=block, k=k)), g, vv, gg,
        key="ef_update_ref")

    # the one-launch uplink mega-kernel vs its composed jnp oracle (both
    # interpret-path on CPU — differential overhead only; the honest speedup
    # claim lives in benchmarks/fused_round_bench.py on the compiled path)
    out["fused_uplink_pallas_interp_us"] = _bench(
        lambda a, b, c: ops.ef21_sgdm_topk_quant(
            a, b, c, eta=0.1, block=block, k=k, bits=8), g, vv, gg, iters=2,
        key="fused_uplink_pallas_interp")
    out["fused_uplink_ref_us"] = _bench(
        jax.jit(lambda a, b, c: ref.ef21_sgdm_topk_quant_ref(
            a, b, c, eta=0.1, block=block, k=k, bits=8)), g, vv, gg,
        key="fused_uplink_ref")

    _quantize_bench(out, x, block)
    _wire_savings(out, d, block, k)
    if not tiny:
        _schedule_wire(out)
        _train_step_compare(out)

    save_json("kernel_bench", out)
    speedups = {"ef_update_ref_vs_fused_uplink_ref": (
        _NS["ef_update_ref"]["median_ns"]
        / max(_NS["fused_uplink_ref"]["median_ns"], 1))}
    if "train_step_dense" in _NS:
        speedups["train_step_fused_vs_dense"] = (
            _NS["train_step_dense"]["median_ns"]
            / max(_NS["train_step_fused"]["median_ns"], 1))
    ledger = save_bench("kernels", bench_run(
        geometry={"d": d, "block": block, "k_per_block": k, "bits": [8, 4],
                  "flash": {"B": B, "S": S, "H": H, "hd": hd},
                  "tiny": tiny},
        metrics=_NS, speedup_vs_ref=speedups))
    out["bench_ledger"] = ledger
    step = ("" if tiny else
            f"step_dense_us={out['train_step_dense_us']:.0f};"
            f"step_fused_us={out['train_step_fused_us']:.0f};")
    csv_row("kernel_bench", out["flash_pallas_interp_us"],
            f"topk_ref_us={out['block_topk_ref_us']:.0f};"
            f"ef_ref_us={out['ef_update_ref_us']:.0f};"
            f"fused_uplink_ref_us={out['fused_uplink_ref_us']:.0f};" + step +
            f"wire_q8_x={out['wire_savings_quant8_vs_sparse']:.1f};"
            f"wire_q4_x={out['wire_savings_quant4_vs_sparse']:.1f};"
            f"down_q4_x={out['downlink_savings_quant4_vs_dense']:.1f}")
    return out


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="CI bench-smoke geometry: shrink every size so the "
                        "full codepath (incl. the BENCH ledger write) runs "
                        "in seconds")
    run(tiny=p.parse_args().tiny)
