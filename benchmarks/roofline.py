"""§Roofline: derive the three roofline terms per (arch × shape × mesh) from the
dry-run JSON produced by launch/dryrun.py.

  compute term    = dot_flops_per_device / peak_FLOP/s          [s]
  memory term     = state_stream_bytes_per_device / HBM_bw      [s]
  collective term = collective_bytes_per_device / link_bw       [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Notes on sources (all per-device, from the post-SPMD partitioned module):
  * flops: loop-corrected dot+conv FLOPs from launch/hlo_analysis.py (XLA's own
    cost_analysis counts while bodies once — recorded alongside for reference).
  * memory: argument+output bytes (params, EF/optimizer state, batch, caches
    streamed once per step) — a LOWER bound; activation traffic adds to it but
    params/state dominate for training and cache reads dominate decode.
  * collective: per-device operand bytes (all-gather counts its per-device
    input shard; reduce-scatter its full input; all-reduce its buffer — ring
    algorithms move ≈2× the buffer, so wall-clock is ≥ the term shown).

MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (prefill/decode),
per device; the ratio MODEL/HLO exposes remat & masked-attention waste.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.configs import base as cb

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

# HBM passes over the d-word EF client state per sync round (kernels/ef_update.py):
# unfused streams grad/v/g in and δ/c/v'/g' through HBM separately (~9 passes);
# the fused Pallas carrier (--carrier fused) reads grad/v/g and writes v'/g'/c
# in ONE kernel (~3 effective passes on the roofline).
EF_UNFUSED_PASSES = 9
EF_FUSED_PASSES = 3


def ef_update_memory_terms(rec: Dict) -> Optional[Dict]:
    """Analytic fused-vs-unfused memory term of the EF client update for a
    train record: seconds to stream the per-device EF state the required
    number of times. This is the term the FusedPallasCarrier attacks.

    Each device streams d/tp state words: a client's (vᵢ, gᵢ) are sharded
    over the MODEL axis only under the default 'client' state sharding
    (launch/shardings.py) — the data axes index clients, they don't divide a
    client's state. (ZeRO state sharding would further divide by the free
    data-axis product; the sweep records don't carry the plan, so this is
    the default-plan term.)"""
    from repro.launch import mesh as mesh_lib
    shape = cb.INPUT_SHAPES[rec["shape"]]
    if shape.kind != "train":
        return None
    cfg = cb.get(rec["arch"])
    d_per_dev = cfg.active_param_count() / mesh_lib.PROD_MODEL
    word = 4.0                       # f32 state; bf16 halves both terms alike
    return {
        "ef_mem_unfused_s": EF_UNFUSED_PASSES * d_per_dev * word / HBM_BW,
        "ef_mem_fused_s": EF_FUSED_PASSES * d_per_dev * word / HBM_BW,
    }


def ef_wire_terms(rec: Dict) -> Optional[Dict]:
    """Analytic per-carrier EF-sync wire terms for a train record, split by
    DIRECTION: seconds to put one client's uplink message on the links
    (``ef_wire_*_s``) and seconds for the server's downlink broadcast
    (``ef_wire_down_*_s``), for the default production compressor (BlockTopK
    block=1024, ratio=1%). ``Carrier.wire_words`` / ``downlink_words`` are
    the honest fractional counts (values + indices + scales; a 4-bit
    mantissa is 1/8 word of 4 bytes) — the uplink term is what the
    sparse/quant carriers attack, the downlink term is what
    --downlink-carrier attacks (an unidirectional round always pays the
    dense d-word broadcast down)."""
    from repro.core import carriers as carrier_lib
    from repro.core import compressors as comp_lib
    from repro.launch import mesh as mesh_lib
    shape = cb.INPUT_SHAPES[rec["shape"]]
    if shape.kind != "train":
        return None
    cfg = cb.get(rec["arch"])
    d_per_dev = cfg.active_param_count() / mesh_lib.PROD_MODEL
    btk = comp_lib.BlockTopK(block=1024, ratio=0.01)
    word = 4.0
    out = {
        f"ef_wire_{name}_s":
            carrier_lib.make(name).wire_words(btk, int(d_per_dev))
            * word / LINK_BW
        for name in ("dense", "sparse", "quant8", "quant4")
    }
    out.update({
        f"ef_wire_down_{name}_s":
            carrier_lib.downlink_words(carrier_lib.make(name), btk,
                                       int(d_per_dev)) * word / LINK_BW
        for name in ("dense", "sparse", "quant8", "quant4")
    })
    return out


def ef_hierarchy_wire_terms(rec: Dict) -> Optional[Dict]:
    """Per-HOP wire accounting of the two-tier EF topology (DESIGN.md §13)
    at the production pod geometry for a train record: under
    ``--hops pods=P`` the n = P·data client messages ride in-pod ICI
    (``wire_words_intra_per_round`` — the ×n rule) while the cross-pod hop
    ships ONE error-fed innovation per pod (``wire_words_cross_per_round``
    — the ×P rule) on the quant4 cross carrier re-budgeted to the same 1%
    innovation ratio as the uplink. The flat baseline pays its whole
    n-client quant8 wire ACROSS the pod boundary (the server lives in one
    pod), so ``cross_pod_reduction_vs_flat`` is the byte ratio the
    hierarchy buys on the slow links — the number
    benchmarks/hierarchy_bench.py measures and CI gates at ≥ 8×. Same
    accounting functions as the runtimes (``hierarchy.wire_words_cross``),
    so the roofline rows cannot drift from what the simulator reports."""
    from repro.core import carriers as carrier_lib
    from repro.core import compressors as comp_lib
    from repro.core import hierarchy as hier_lib
    from repro.launch import mesh as mesh_lib
    shape = cb.INPUT_SHAPES[rec["shape"]]
    if shape.kind != "train":
        return None
    cfg = cb.get(rec["arch"])
    d = int(cfg.active_param_count())
    word = 4.0
    pods = mesh_lib.PROD_PODS
    n = pods * mesh_lib.PROD_DATA
    up_words = carrier_lib.make("quant8").wire_words(
        comp_lib.BlockTopK(block=1024, ratio=0.01), d)
    hops = hier_lib.Hops(
        pods=pods, cross_carrier="quant4",
        cross_compressor=comp_lib.BlockTopK(block=1024, ratio=0.01))
    cross_words = hier_lib.wire_words_cross(hops, None, None, d)
    flat_cross = n * up_words
    return {
        "wire_words_intra_per_round": n * up_words,
        "wire_words_cross_per_round": cross_words,
        "ef_wire_cross_s": cross_words * word / LINK_BW,
        "cross_pod_reduction_vs_flat": flat_cross / cross_words,
    }


def model_flops_per_device(rec: Dict) -> float:
    cfg = cb.get(rec["arch"])
    shape = cb.INPUT_SHAPES[rec["shape"]]
    n_active = cfg.active_param_count()
    ndev = rec["n_devices"]
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens / ndev
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens / ndev
    return 2.0 * n_active * shape.global_batch / ndev      # decode: 1 tok/seq


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec["status"] != "OK":
        return None
    mem = rec["memory"] or {}
    state_bytes = mem.get("argument_bytes", 0) + mem.get("output_bytes", 0)
    compute_s = rec["flops"] / PEAK_FLOPS
    memory_s = state_bytes / HBM_BW
    coll_s = rec["collective_bytes"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    ratio = mf / rec["flops"] if rec["flops"] else float("nan")
    advice = {
        "compute": ("halve masked-attention waste with the blocked-causal "
                    "Pallas kernel / banded SWA; shard replicated heads"),
        "memory": ("fuse the EF client update (--carrier fused, "
                   "kernels/ef_update.py), bf16 EF state, ZeRO state "
                   "sharding (--state-sharding zero)"),
        "collective": ("switch the EF sync to the sparse (values,indices) "
                       "carrier (--carrier sparse) or the block-quantized "
                       "wire (--carrier quant8/quant4 — int8/uint4 mantissas "
                       "cut the value words another 4–8×); compress the "
                       "server broadcast too (--downlink-carrier quant4 — "
                       "the downlink otherwise ships dense f32); "
                       "pod-granularity clients put the compressed bytes on "
                       "the slow inter-pod links"),
    }[dominant]
    row = {
        "arch": rec["arch"], "shape": rec["shape"], "tag": rec.get("tag", ""),
        "multi_pod": rec["multi_pod"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops": rec["flops"],
        "useful_ratio": ratio,
        "temp_gib": mem.get("temp_bytes", 0) / 2 ** 30,
        "fits_hbm16": (mem.get("temp_bytes", 0)
                       + mem.get("argument_bytes", 0)) < 16 * 2 ** 30,
        "advice": advice,
    }
    ef_terms = ef_update_memory_terms(rec)
    if ef_terms:
        row.update(ef_terms)
    wire_terms = ef_wire_terms(rec)
    if wire_terms:
        row.update(wire_terms)
    hier_terms = ef_hierarchy_wire_terms(rec)
    if hier_terms:
        row.update(hier_terms)
    return row


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | temp GiB | fits 16G | EF upd s unfused→fused | "
           "EF wire s sparse→q8→q4 | EF downlink s dense→q4 |\n|"
           + "---|" * 12 + "\n")
    lines = []
    for r in rows:
        if "ef_mem_unfused_s" in r:
            ef = (f"{r['ef_mem_unfused_s']:.2e} → {r['ef_mem_fused_s']:.2e} "
                  f"({r['ef_mem_unfused_s'] / r['ef_mem_fused_s']:.1f}×)")
        else:
            ef = "—"
        if "ef_wire_sparse_s" in r:
            wire = (f"{r['ef_wire_sparse_s']:.2e} → "
                    f"{r['ef_wire_quant8_s']:.2e} → "
                    f"{r['ef_wire_quant4_s']:.2e} "
                    f"({r['ef_wire_sparse_s'] / r['ef_wire_quant4_s']:.1f}×)")
        else:
            wire = "—"
        if "ef_wire_down_dense_s" in r:
            down = (f"{r['ef_wire_down_dense_s']:.2e} → "
                    f"{r['ef_wire_down_quant4_s']:.2e} "
                    f"({r['ef_wire_down_dense_s'] / r['ef_wire_down_quant4_s']:.1f}×)")
        else:
            down = "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['temp_gib']:.1f} | {'✓' if r['fits_hbm16'] else '✗'} | "
            f"{ef} | {wire} | {down} |")
    return hdr + "\n".join(lines) + "\n"


def _emit_ledger(rows: List[Dict], in_path: str) -> Optional[str]:
    """Record the derived roofline terms in the checked-in BENCH_roofline.json
    ledger (benchmarks/common.py). The terms are analytic — deterministic
    given the dry-run HLO — so each metric is a single 'sample' with
    p10 = median = p90 (the schema's percentile fields still give later PRs
    one uniform shape to diff against measured benches)."""
    from benchmarks.common import bench_run, save_bench
    metrics = {}
    for r in rows:
        key = f"{r['arch']}_{r['shape']}"
        for term in ("compute_s", "memory_s", "collective_s"):
            ns = r[term] * 1e9
            metrics[f"{key}_{term[:-2]}"] = {
                "p10_ns": ns, "median_ns": ns, "p90_ns": ns, "iters": 1}
    if not metrics:
        return None
    speedups = {f"{r['arch']}_{r['shape']}_ef_mem_unfused_vs_fused":
                r["ef_mem_unfused_s"] / r["ef_mem_fused_s"]
                for r in rows if "ef_mem_unfused_s" in r}
    return save_bench("roofline", bench_run(
        geometry={"source": in_path, "analytic": True,
                  "hardware": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                               "link_bw": LINK_BW}},
        metrics=metrics, speedup_vs_ref=speedups or None))


def tiny_record() -> Dict:
    """A synthetic dry-run record for --tiny: the smallest arch on the
    train shape with analytically self-consistent terms (flops = the MODEL
    estimate so useful_ratio = 1.0, memory = the f32 param+EF state stream,
    collectives = one dense all-reduce of the grads). Exercises the full
    analyze → ledger path without needing results/dryrun_baseline_1pod.json
    — CI checks the emitted BENCH_roofline.json against the bench/v1
    schema alongside the kernel and fused-round ledgers."""
    from repro.launch import mesh as mesh_lib
    arch, shape_name = "smollm-360m", "train_4k"
    rec = {"status": "OK", "arch": arch, "shape": shape_name,
           "tag": "tiny-synthetic", "multi_pod": False,
           "n_devices": mesh_lib.PROD_MODEL, "flops": 1.0,
           "collective_bytes": 0.0, "memory": {}}
    rec["flops"] = model_flops_per_device(rec)
    d_per_dev = cb.get(arch).active_param_count() / mesh_lib.PROD_MODEL
    # params + grads + EF (vᵢ, gᵢ) + opt state streamed once, f32
    rec["memory"] = {"argument_bytes": 5 * d_per_dev * 4.0,
                     "output_bytes": 3 * d_per_dev * 4.0,
                     "temp_bytes": 2 * d_per_dev * 4.0}
    rec["collective_bytes"] = d_per_dev * 4.0
    return rec


def run_tiny() -> List[Dict]:
    rows = [analyze_record(tiny_record())]
    path = _emit_ledger(rows, "synthetic:--tiny")
    for r in rows:
        print(f"{r['arch']:18s} {r['shape']:12s} dom={r['dominant']:10s} "
              f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
              f"x={r['collective_s']:.2e} useful={r['useful_ratio']:.2f}")
    print(f"ledger: {path}")
    return rows


def run(in_path: str = "results/dryrun_baseline_1pod.json",
        out_prefix: str = "results/roofline_baseline") -> List[Dict]:
    with open(in_path) as f:
        recs = json.load(f)
    rows, skips = [], []
    for rec in recs:
        row = analyze_record(rec)
        if row:
            rows.append(row)
        elif rec["status"] == "SKIP":
            skips.append(rec)
    _emit_ledger(rows, in_path)
    with open(out_prefix + ".json", "w") as f:
        json.dump({"rows": rows, "skips": skips}, f, indent=1)
    with open(out_prefix + ".md", "w") as f:
        f.write(to_markdown(rows))
        if skips:
            f.write("\nSkipped (sub-quadratic requirement):\n")
            for s in skips:
                f.write(f"* {s['arch']} × {s['shape']}: {s['reason']}\n")
    for r in rows:
        print(f"{r['arch']:18s} {r['shape']:12s} dom={r['dominant']:10s} "
              f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
              f"x={r['collective_s']:.2e} useful={r['useful_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="in_path",
                    default="results/dryrun_baseline_1pod.json")
    ap.add_argument("--out", dest="out_prefix",
                    default="results/roofline_baseline")
    ap.add_argument("--tiny", action="store_true",
                    help="synthesize one self-consistent record and emit "
                         "the BENCH_roofline.json ledger (no dry-run JSON "
                         "needed — the CI bench-smoke path)")
    args = ap.parse_args()
    if args.tiny:
        run_tiny()
    else:
        run(args.in_path, args.out_prefix)
