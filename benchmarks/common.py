"""Shared benchmark utilities, built on the RunSpec/Session API
(launch/spec.py, launch/session.py): a benchmark names its configuration as a
declarative ``bench_spec(...)`` and drives the SAME production path the
train driver uses via ``bench_session`` — no bespoke step assembly."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

# the checked-in perf ledger (DESIGN.md §10): BENCH_<topic>.json at the repo
# root, one append-only ``runs`` list per topic so regressions are a diff,
# not an archaeology dig. CI regenerates and validates it every run.
BENCH_SCHEMA = "bench/v1"
_RUN_KEYS = {"timestamp", "device", "backend", "geometry", "metrics"}
_METRIC_KEYS = {"p10_ns", "median_ns", "p90_ns", "iters"}


def bench_spec(**overrides):
    """A RunSpec with CPU-bench-sized defaults (reduced smollm, 4 clients,
    tiny batch); override any field. Import-light — building the spec (for
    sweep emission or accounting) costs no jax import."""
    from repro.launch.spec import RunSpec
    base = dict(arch="smollm-360m", smoke=True, clients=4, global_batch=8,
                seq_len=32)
    base.update(overrides)
    return RunSpec(**base)


def bench_session(**overrides):
    """Session over ``bench_spec`` — the unit of work benchmarks time is
    ``session.step_once()`` (the jitted production train step)."""
    from repro.launch.session import Session
    return Session(bench_spec(**overrides))


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def measure_ns(fn, *args, iters: int = 5, warmup: int = 2) -> Dict:
    """Honest per-call timing: explicit warmup calls (compile + caches),
    then ``iters`` timed calls each fenced by ``jax.block_until_ready`` so
    async dispatch never hides device time. Returns the schema'd metric dict
    {p10_ns, median_ns, p90_ns, iters} (percentiles over the timed calls —
    a noisy CI neighbor shows up as p90 spread, not a corrupted median)."""
    import jax
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter_ns()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter_ns() - t0)
    return {"p10_ns": float(np.percentile(ts, 10)),
            "median_ns": float(np.percentile(ts, 50)),
            "p90_ns": float(np.percentile(ts, 90)),
            "iters": len(ts)}


def bench_run(geometry: Dict, metrics: Dict,
              speedup_vs_ref: Dict = None) -> Dict:
    """One schema'd ledger entry: where (device/backend), on what
    (geometry), the measurements (metrics — name → measure_ns dict), and
    the derived speedups (speedup_vs_ref — name → ratio)."""
    import jax
    dev = jax.devices()[0]
    run = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
           "device": getattr(dev, "device_kind", str(dev)),
           "backend": jax.default_backend(),
           "geometry": dict(geometry),
           "metrics": dict(metrics)}
    if speedup_vs_ref is not None:
        run["speedup_vs_ref"] = {k: float(v)
                                 for k, v in speedup_vs_ref.items()}
    return run


def bench_path(topic: str) -> str:
    return os.path.join(REPO_ROOT, f"BENCH_{topic}.json")


def validate_bench(payload) -> List[str]:
    """Schema check of one BENCH_<topic>.json payload; returns the list of
    violations (empty = valid). CI fails the build on any violation."""
    errs: List[str] = []
    if not isinstance(payload, dict):
        return [f"payload must be a dict, got {type(payload).__name__}"]
    if payload.get("schema") != BENCH_SCHEMA:
        errs.append(f"schema must be {BENCH_SCHEMA!r}, "
                    f"got {payload.get('schema')!r}")
    if not isinstance(payload.get("topic"), str) or not payload.get("topic"):
        errs.append("topic must be a non-empty string")
    runs = payload.get("runs")
    if not isinstance(runs, list) or not runs:
        return errs + ["runs must be a non-empty list"]
    for i, run in enumerate(runs):
        if not isinstance(run, dict):
            errs.append(f"runs[{i}] must be a dict")
            continue
        missing = sorted(_RUN_KEYS - set(run))
        if missing:
            errs.append(f"runs[{i}] missing keys {missing}")
            continue
        if not isinstance(run["geometry"], dict):
            errs.append(f"runs[{i}].geometry must be a dict")
        metrics = run["metrics"]
        if not isinstance(metrics, dict) or not metrics:
            errs.append(f"runs[{i}].metrics must be a non-empty dict")
            continue
        for name, m in metrics.items():
            if not isinstance(m, dict) or not _METRIC_KEYS <= set(m):
                errs.append(f"runs[{i}].metrics[{name!r}] missing "
                            f"{sorted(_METRIC_KEYS - set(m or {}))}")
                continue
            if not all(isinstance(m[k], (int, float)) and m[k] >= 0
                       for k in _METRIC_KEYS):
                errs.append(f"runs[{i}].metrics[{name!r}] has non-numeric "
                            "or negative fields")
            elif not m["p10_ns"] <= m["median_ns"] <= m["p90_ns"]:
                errs.append(f"runs[{i}].metrics[{name!r}] percentiles out "
                            "of order")
        sp = run.get("speedup_vs_ref")
        if sp is not None and (not isinstance(sp, dict) or not all(
                isinstance(v, (int, float)) for v in sp.values())):
            errs.append(f"runs[{i}].speedup_vs_ref must map names to "
                        "numbers")
    return errs


def save_bench(topic: str, run: Dict, path: str = None,
               keep_runs: int = 50) -> str:
    """Append one ``bench_run`` entry to the checked-in BENCH_<topic>.json
    ledger (created if absent, validated before and after — a malformed
    ledger fails loudly rather than accreting). The runs list is capped at
    ``keep_runs`` newest entries so the file stays reviewable."""
    path = path or bench_path(topic)
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
        errs = validate_bench(payload)
        if errs:
            raise ValueError(f"existing {path} is malformed:\n  - "
                             + "\n  - ".join(errs))
        if payload["topic"] != topic:
            raise ValueError(f"{path} holds topic {payload['topic']!r}, "
                             f"refusing to append topic {topic!r}")
    else:
        payload = {"schema": BENCH_SCHEMA, "topic": topic, "runs": []}
    payload["runs"] = (payload["runs"] + [run])[-keep_runs:]
    errs = validate_bench(payload)
    if errs:
        raise ValueError("refusing to write a malformed ledger:\n  - "
                         + "\n  - ".join(errs))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")
    return path


def check_no_regression(topic: str, metric: str, bar: float,
                        full_geometry_only: bool = False) -> float:
    """The acceptance gate over a checked-in ledger: the NEWEST eligible run
    in BENCH_<topic>.json must carry ``metric`` in its ``speedup_vs_ref`` at
    or above ``bar``. ``full_geometry_only`` restricts to runs whose
    geometry is not ``tiny`` — the CI bench smoke appends tiny runs in the
    workspace before pytest, and a tiny CPU geometry must never be read as
    a regression of a full-geometry claim. Returns the value. Raises
    ValueError when the ledger or metric is absent/malformed — a missing
    number must never read as a pass — and AssertionError below the bar, so
    pytest reports it as the perf regression it is."""
    path = bench_path(topic)
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ValueError(f"{path}: missing/unreadable ledger ({e})")
    errs = validate_bench(payload)
    if errs:
        raise ValueError(f"{path}: malformed ledger:\n  - "
                         + "\n  - ".join(errs))
    runs = payload["runs"]
    if full_geometry_only:
        runs = [r for r in runs if not r["geometry"].get("tiny")]
        if not runs:
            raise ValueError(f"{path}: no full-geometry run recorded")
    run = runs[-1]
    sp = run.get("speedup_vs_ref") or {}
    if metric not in sp:
        raise ValueError(
            f"{path}: newest eligible run has no speedup_vs_ref[{metric!r}] "
            f"(has {sorted(sp)})")
    val = float(sp[metric])
    if not val >= bar:
        raise AssertionError(
            f"perf regression: {topic}.{metric} = {val:.2f}x is below the "
            f"{bar:g}x bar ({path})")
    return val


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0

    def us_per(self, calls: int) -> float:
        return 1e6 * self.dt / max(calls, 1)


def median_curves(runs: List[Dict], key: str = "grad_norm_sq") -> np.ndarray:
    return np.median(np.stack([r[key] for r in runs]), axis=0)


def _validate_cli(paths: List[str]) -> int:
    """``python -m benchmarks.common --validate BENCH_x.json ...`` — the CI
    ledger gate: exit non-zero if any file is missing or malformed."""
    rc = 0
    for path in paths:
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: MISSING/UNREADABLE ({e})")
            rc = 1
            continue
        errs = validate_bench(payload)
        if errs:
            print(f"{path}: MALFORMED\n  - " + "\n  - ".join(errs))
            rc = 1
        else:
            print(f"{path}: OK ({payload['topic']}, "
                  f"{len(payload['runs'])} runs)")
    return rc


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description="perf-ledger utilities")
    ap.add_argument("--validate", nargs="+", metavar="PATH",
                    help="validate BENCH_<topic>.json files against "
                         f"the {BENCH_SCHEMA} schema")
    a = ap.parse_args()
    if a.validate:
        sys.exit(_validate_cli(a.validate))
    ap.print_help()
