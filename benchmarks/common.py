"""Shared benchmark utilities, built on the RunSpec/Session API
(launch/spec.py, launch/session.py): a benchmark names its configuration as a
declarative ``bench_spec(...)`` and drives the SAME production path the
train driver uses via ``bench_session`` — no bespoke step assembly."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def bench_spec(**overrides):
    """A RunSpec with CPU-bench-sized defaults (reduced smollm, 4 clients,
    tiny batch); override any field. Import-light — building the spec (for
    sweep emission or accounting) costs no jax import."""
    from repro.launch.spec import RunSpec
    base = dict(arch="smollm-360m", smoke=True, clients=4, global_batch=8,
                seq_len=32)
    base.update(overrides)
    return RunSpec(**base)


def bench_session(**overrides):
    """Session over ``bench_spec`` — the unit of work benchmarks time is
    ``session.step_once()`` (the jitted production train step)."""
    from repro.launch.session import Session
    return Session(bench_spec(**overrides))


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0

    def us_per(self, calls: int) -> float:
        return 1e6 * self.dt / max(calls, 1)


def median_curves(runs: List[Dict], key: str = "grad_norm_sq") -> np.ndarray:
    return np.median(np.stack([r[key] for r in runs]), axis=0)
