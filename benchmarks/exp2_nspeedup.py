"""Experiment 2 (Figure 3): logistic regression, B=128, TopK, n ∈ {1, 10, 50}.

Paper claims: EF21-SGDM/2M are fastest at every n AND improve as n grows
(the O(σ²/(nε⁴)) linear-speedup term of Corollary 2); EF21-SGD does not.
(real-sim replaced by a shape-matched synthetic set; scaled dims for CPU.)
"""
from __future__ import annotations


from benchmarks.common import Timer, csv_row, median_curves, save_json
from repro.core import compressors as C
from repro.core import ef, problems, simulate

SEEDS = 3
STEPS = 800
B = 64
K = 50
GAMMA = 0.05


def run() -> dict:
    out = {}
    with Timer() as t:
        for n in (1, 10, 50):
            # iid clients with FIXED per-client data: Corollary 2's speedup term
            # is σ²-averaging across clients. Two masked regimes were measured
            # first (EXPERIMENTS.md E3): a label-split partition (client drift
            # dominates) and a fixed-total-data split (51 samples/client at
            # n=50 → per-client overparametrization = drift again). Both are
            # orthogonal to the σ²/(nε⁴) claim being validated.
            prob = problems.LogisticRegression(
                n=n, m_per_client=512, l=128, c=2, seed=1,
                heterogeneous=False)
            d = prob.dim
            topk = C.TopK(k=K)
            for name, m in {
                "ef14_sgd": ef.EF14SGD(compressor=topk),
                "ef21_sgd": ef.EF21SGD(compressor=topk),
                "ef21_sgdm": ef.EF21SGDM(compressor=topk, eta=0.1),
                "ef21_sgd2m": ef.EF21SGD2M(compressor=topk, eta=0.1),
            }.items():
                cfg = simulate.SimConfig(n=n, batch_size=B, gamma=GAMMA,
                                         steps=STEPS, b_init=4)
                runs = [simulate.run_numpy(prob, m, cfg, seed=s)
                        for s in range(SEEDS)]
                curve = median_curves(runs)
                out[f"n{n}/{name}"] = {
                    "end_grad_sq": float(curve[-100:].mean()),
                    "curve_ds": curve[::50].tolist(),
                }
    out["claims"] = {
        # EF14-SGD is genuinely competitive on iid synthetic logreg (recorded
        # in EXPERIMENTS.md E3); assert "within 1.5× of the best"
        "sgdm_near_best_at_n50":
            out["n50/ef21_sgdm"]["end_grad_sq"]
            <= min(out["n50/ef14_sgd"]["end_grad_sq"],
                   out["n50/ef21_sgd"]["end_grad_sq"]) * 1.5,
        "sgdm_improves_with_n":
            out["n50/ef21_sgdm"]["end_grad_sq"]
            < out["n1/ef21_sgdm"]["end_grad_sq"],
    }
    save_json("exp2_nspeedup", out)
    csv_row("exp2_nspeedup", t.us_per(SEEDS * STEPS * 12),
            f"n1_sgdm={out['n1/ef21_sgdm']['end_grad_sq']:.2e};"
            f"n50_sgdm={out['n50/ef21_sgdm']['end_grad_sq']:.2e};"
            f"claims={sum(out['claims'].values())}/2")
    return out


if __name__ == "__main__":
    run()
