"""Benchmark suite entry point — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig1,exp1,...]

Each module prints a ``name,us_per_call,derived`` CSV row and writes the full
payload to results/<name>.json. The roofline module consumes the dry-run JSON
(run ``python -m repro.launch.dryrun --all --out results/dryrun_baseline_1pod.json``
first; a checked-in copy is used if present).
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ALL = ["fig1", "exp1", "exp2", "exp3", "exp4", "complexity", "kernels",
       "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else ALL

    print("name,us_per_call,derived")
    failures = []
    for name in chosen:
        try:
            if name == "fig1":
                from benchmarks import fig1_divergence as m
            elif name == "exp1":
                from benchmarks import exp1_batchsize as m
            elif name == "exp2":
                from benchmarks import exp2_nspeedup as m
            elif name == "exp3":
                from benchmarks import exp3_quadratic as m
            elif name == "exp4":
                from benchmarks import exp4_neuralnet as m
            elif name == "complexity":
                from benchmarks import complexity_check as m
            elif name == "kernels":
                from benchmarks import kernel_bench as m
            elif name == "roofline":
                from benchmarks import roofline as m
                if os.path.exists("results/dryrun_baseline_1pod.json"):
                    m.run()
                else:
                    print("roofline,0,SKIP(no dry-run json; run "
                          "repro.launch.dryrun --all first)")
                continue
            else:
                print(f"{name},0,UNKNOWN")
                continue
            m.run()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"{name},0,FAILED({type(e).__name__})")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
