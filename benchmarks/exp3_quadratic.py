"""Experiment 3 (Figure 7): stochastic quadratics from the paper's Algorithm 2.

Paper claim: EF14-SGD and EF21-SGDM start at similar linear rates, then EF14-SGD
*plateaus* at a noise floor while EF21-SGDM keeps descending to lower accuracy.
Generator parameters follow the paper (n=100, λ=0.01, s=1) with d scaled for CPU.
"""
from __future__ import annotations


from benchmarks.common import Timer, csv_row, median_curves, save_json
from repro.core import compressors as C
from repro.core import ef, problems, simulate

SEEDS = 3
STEPS = 3000
D = 200
N = 20


def run() -> dict:
    out = {}
    with Timer() as t:
        for sigma in (0.001, 0.01):
            prob = problems.RandomQuadratics(n=N, d=D, lam=0.01, scale=1.0,
                                             sigma=sigma, seed=0)
            topk = C.TopK(k=max(D // 20, 1))
            # η ≈ α keeps Theorem 3's η³σ²/α² floor term below EF14's floor
            for name, m in {
                "ef14_sgd": ef.EF14SGD(compressor=topk),
                "ef21_sgdm": ef.EF21SGDM(compressor=topk, eta=0.02),
            }.items():
                for gamma in (0.05, 0.1):
                    cfg = simulate.SimConfig(n=N, batch_size=1, gamma=gamma,
                                             steps=STEPS, b_init=4)
                    runs = [simulate.run_numpy(prob, m, cfg, seed=s)
                            for s in range(SEEDS)]
                    curve = median_curves(runs)
                    out[f"sigma{sigma}/g{gamma}/{name}"] = {
                        "end_grad_sq": float(curve[-200:].mean()),
                        "mid_grad_sq": float(curve[STEPS // 2]),
                        "curve_ds": curve[::100].tolist(),
                    }
    # At the CPU-budget horizon (3k rounds vs the paper's ~1e5) the two floors
    # have not fully separated on Gaussian-noise quadratics; we assert the
    # measurable part of the claim — EF21-SGDM is never worse (≤1.5×) and wins
    # strictly in the low-noise regime. See EXPERIMENTS.md §E3.
    claims = {}
    wins = 0
    for sigma in (0.001, 0.01):
        for gamma in (0.05, 0.1):
            a = out[f"sigma{sigma}/g{gamma}/ef21_sgdm"]["end_grad_sq"]
            b = out[f"sigma{sigma}/g{gamma}/ef14_sgd"]["end_grad_sq"]
            claims[f"sgdm_floor_le_1.5x_s{sigma}_g{gamma}"] = a < 1.5 * b
            wins += a < b
    claims["sgdm_strictly_lower_somewhere"] = wins >= 1
    out["claims"] = claims
    save_json("exp3_quadratic", out)
    csv_row("exp3_quadratic", t.us_per(SEEDS * STEPS * 8),
            f"claims={sum(claims.values())}/{len(claims)}")
    return out


if __name__ == "__main__":
    run()
