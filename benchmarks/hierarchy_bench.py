"""Cross-pod wire savings of the two-tier EF topology (DESIGN.md §13),
recorded in the checked-in ledger BENCH_hierarchy.json.

The claim being measured: on a (pod, data, model) mesh the flat topology
ships EVERY client's uplink message across the slow inter-pod links (the
server lives in one pod — n messages cross DCI per round), while the
two-tier topology keeps client messages on in-pod ICI and ships ONE
error-fed innovation per pod on its own cross carrier. At the production
geometry (gemma2-9b, pods=2, n=32 clients) with the flat baseline on the
quant8 wire and the cross hop on quant4 re-budgeted to the same 1%
innovation ratio, the cross-pod bytes drop ≥ 8× — the acceptance bar CI
gates via ``benchmarks.common.check_no_regression`` — and the golden spec's
laxer 5% cross budget is recorded alongside so the ratio/byte trade is a
row, not a footnote.

The word counts come from the SAME accounting the runtimes report
(``core/hierarchy.wire_words_cross`` / ``Carrier.wire_words`` — values +
indices + scales, fractional words for sub-word mantissas), so the ledger
and the simulator's ``wire_words_{intra,cross}_per_round`` can never drift
apart silently.

Two measured anchors keep the analytic rows honest:

* flat-equivalence — the pods=2 trivial-cross simulator trajectory is
  BIT-IDENTICAL to the flat run (the hierarchy is pure bookkeeping until a
  non-trivial cross carrier is configured);
* a quant4-cross simulator run whose reported cross words match the same
  ``wire_words_cross`` formula used for the gemma2-9b rows.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_run, csv_row, save_bench

WORD = 4.0
LINK_BW = 50e9          # inter-pod DCI, matching benchmarks/roofline.py

ARCH = "gemma2-9b"
FLAT_CARRIER = "quant8"
CROSS_CARRIER = "quant4"
UP_RATIO = 0.01         # production uplink innovation budget
CROSS_RATIOS = (0.01, 0.05)   # uplink-matched headline + the golden's 5%
BAR = 8.0


def _ns(words: float) -> dict:
    """Analytic link-seconds for one round's words as a schema'd metric
    (deterministic → p10 = median = p90, iters = 1)."""
    ns = words * WORD / LINK_BW * 1e9
    return {"p10_ns": ns, "median_ns": ns, "p90_ns": ns, "iters": 1}


def analytic_rows() -> dict:
    """The gemma2-9b multi_pod wire accounting, per round."""
    from repro.configs import base as cb
    from repro.core import carriers as carrier_lib
    from repro.core import compressors as comp_lib
    from repro.core import hierarchy as hier_lib
    from repro.launch import mesh as mesh_lib

    d = cb.get(ARCH).active_param_count()
    pods = mesh_lib.PROD_PODS
    n = pods * mesh_lib.PROD_DATA
    up_words = carrier_lib.make(FLAT_CARRIER).wire_words(
        comp_lib.BlockTopK(block=1024, ratio=UP_RATIO), int(d))
    flat_cross = n * up_words           # every client message crosses DCI
    rows = {"d": int(d), "n": n, "pods": pods,
            "flat_cross_words": flat_cross,
            "intra_words": n * up_words}
    for r in CROSS_RATIOS:
        hops = hier_lib.Hops(
            pods=pods, cross_carrier=CROSS_CARRIER,
            cross_compressor=comp_lib.BlockTopK(block=1024, ratio=r))
        cross = hier_lib.wire_words_cross(hops, None, None, int(d))
        rows[f"hier_cross_words_r{r:g}"] = cross
        rows[f"reduction_r{r:g}"] = flat_cross / cross
    return rows


def sim_anchors(tiny: bool = False) -> dict:
    """The measured flat-equivalence + accounting anchors on the toy
    simulator (QuadraticT1, n=8 clients, pods=2)."""
    import jax
    from repro.core import compressors as comp_lib
    from repro.core import hierarchy as hier_lib
    from repro.core import problems, simulate
    from repro.core import ef as ef_lib

    steps = 10 if tiny else 40
    prob = problems.QuadraticT1()
    method = ef_lib.make("ef21_sgdm",
                         compressor=comp_lib.TopK(ratio=0.25), eta=0.3)
    rng = jax.random.PRNGKey(0)
    base = dict(n=8, gamma=1e-3, steps=steps, carrier="dense")
    flat = simulate.run(prob, method, simulate.SimConfig(**base), rng)
    triv = simulate.run(prob, method, simulate.SimConfig(
        **base, hops=hier_lib.Hops(pods=2)), rng)
    q4 = simulate.run(prob, method, simulate.SimConfig(
        **base, hops=hier_lib.Hops(
            pods=2, cross_carrier=CROSS_CARRIER,
            cross_compressor=comp_lib.TopK(ratio=0.25))), rng)
    flat_eq = bool(np.array_equal(np.asarray(flat["grad_norm_sq"]),
                                  np.asarray(triv["grad_norm_sq"])))
    q4_differs = not np.array_equal(np.asarray(flat["grad_norm_sq"]),
                                    np.asarray(q4["grad_norm_sq"]))
    hops = hier_lib.Hops(pods=2, cross_carrier=CROSS_CARRIER,
                         cross_compressor=comp_lib.TopK(ratio=0.25))
    expect = hier_lib.wire_words_cross(hops, None, method, prob.init_x())
    reported = float(q4["wire_words_cross_per_round"])
    return {"flat_equivalence_bitexact": flat_eq,
            "quant4_cross_differs": q4_differs,
            "sim_cross_words_reported": reported,
            "sim_cross_words_formula": float(expect),
            "sim_accounting_consistent": abs(reported - float(expect)) < 1e-6,
            "sim_steps": steps}


def run(tiny: bool = False) -> dict:
    rows = analytic_rows()
    anchors = sim_anchors(tiny=tiny)
    assert anchors["flat_equivalence_bitexact"], \
        "trivial-cross pods=2 must be bit-identical to the flat simulator"
    assert anchors["quant4_cross_differs"], \
        "a quant4 cross must actually change the trajectory"
    assert anchors["sim_accounting_consistent"], \
        (f"simulator cross words {anchors['sim_cross_words_reported']} != "
         f"formula {anchors['sim_cross_words_formula']}")

    headline = rows[f"reduction_r{CROSS_RATIOS[0]:g}"]
    metrics = {
        "cross_wire_flat_quant8": _ns(rows["flat_cross_words"]),
        "intra_wire_hier_quant8": _ns(rows["intra_words"]),
    }
    for r in CROSS_RATIOS:
        metrics[f"cross_wire_hier_quant4_r{r:g}"] = _ns(
            rows[f"hier_cross_words_r{r:g}"])
        csv_row(f"hierarchy_cross_r{r:g}",
                metrics[f"cross_wire_hier_quant4_r{r:g}"]["median_ns"] / 1e3,
                f"reduction={rows[f'reduction_r{r:g}']:.1f}x")

    entry = bench_run(
        geometry={"arch": ARCH, "pods": rows["pods"], "clients": rows["n"],
                  "d": rows["d"], "flat_carrier": FLAT_CARRIER,
                  "cross_carrier": CROSS_CARRIER, "up_ratio": UP_RATIO,
                  "cross_ratios": list(CROSS_RATIOS), "tiny": tiny,
                  "analytic": True},
        metrics=metrics,
        speedup_vs_ref={
            "cross_pod_wire_vs_flat_quant8": headline,
            f"cross_pod_wire_vs_flat_quant8_r{CROSS_RATIOS[1]:g}":
                rows[f"reduction_r{CROSS_RATIOS[1]:g}"],
        })
    entry["anchors"] = anchors
    ledger = save_bench("hierarchy", entry)
    assert headline >= BAR, \
        f"cross-pod reduction {headline:.1f}x fell below the {BAR}x bar"
    return {"ledger": ledger, "reduction": headline, "rows": rows,
            "anchors": anchors}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="shrink the simulator anchors (CI smoke); the "
                         "analytic gemma2-9b rows are identical either way")
    out = run(tiny=ap.parse_args().tiny)
    print(f"cross-pod wire vs flat quant8: {out['reduction']:.1f}x "
          f"(bar {BAR}x; ledger: {out['ledger']})")
