"""Tables 1–2 sanity: measured convergence-rate exponents for EF21-SGDM.

Theorem 2/3 predict E‖∇f(x̂ᵀ)‖² = O(1/(αT)) in the deterministic case and
O(√(σ²/T)) asymptotically in the stochastic case. We measure the log-log slope
of the running-average gradient norm² vs T on the paper's quadratic and check
the exponents land in the right regime (≈ −1 deterministic, ≈ −1/2 stochastic).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, csv_row, save_json
from repro.core import compressors as C
from repro.core import ef, problems, simulate


def _avg_curve(prob, method, steps, sigma_zero=False, seeds=3, **kw):
    cfg = simulate.SimConfig(steps=steps, **kw)
    outs = [simulate.run_numpy(prob, method, cfg, seed=s) for s in range(seeds)]
    gn = np.median(np.stack([o["grad_norm_sq"] for o in outs]), 0)
    return np.cumsum(gn) / np.arange(1, steps + 1)    # E over uniform x̂ᵗ


def run() -> dict:
    out = {}
    Ts = np.array([500, 2000, 8000])
    with Timer() as t:
        # deterministic: σ = 0 → O(1/(αT))
        prob_det = problems.QuadraticT1(sigma=0.0, x0=(1.0, -1.0))
        m = ef.EF21SGDM(compressor=C.TopK(k=1), eta=1.0)
        curve = _avg_curve(prob_det, m, int(Ts[-1]), n=1, batch_size=1,
                           gamma=0.2)
        vals_det = curve[Ts - 1]
        slope_det = np.polyfit(np.log(Ts), np.log(vals_det + 1e-30), 1)[0]

        # stochastic: σ = 1, tuned η per-T like Theorem 2 (η ∝ T^{-1/2})
        prob_st = problems.QuadraticT1(sigma=1.0, x0=(0.0, -1.0))
        vals_st = []
        for T in Ts:
            eta = min(1.0, 3.0 / np.sqrt(T))
            m = ef.EF21SGDM(compressor=C.TopK(k=1), eta=float(eta))
            cfg = simulate.SimConfig(n=1, batch_size=1, gamma=0.05 * eta,
                                     steps=int(T), b_init=16)
            outs = [simulate.run_numpy(prob_st, m, cfg, seed=s)
                    for s in range(4)]
            gn = np.median(np.stack([o["grad_norm_sq"] for o in outs]), 0)
            vals_st.append(gn.mean())
        slope_st = np.polyfit(np.log(Ts), np.log(np.asarray(vals_st)), 1)[0]

    out["deterministic"] = {"Ts": Ts.tolist(), "vals": vals_det.tolist(),
                            "slope": float(slope_det), "theory": -1.0}
    out["stochastic"] = {"Ts": Ts.tolist(), "vals": list(map(float, vals_st)),
                         "slope": float(slope_st), "theory": -0.5}
    out["claims"] = {
        "det_rate_at_least_1_over_T": slope_det < -0.7,
        "stoch_rate_near_half": -1.1 < slope_st < -0.25,
    }
    save_json("complexity_check", out)
    csv_row("complexity_check", t.us_per(int(Ts.sum()) * 7),
            f"slope_det={slope_det:.2f}(-1);slope_stoch={slope_st:.2f}(-0.5);"
            f"claims={sum(out['claims'].values())}/2")
    return out


if __name__ == "__main__":
    run()
