"""Experiment 1 (Figure 2): nonconvex logistic regression, n=10, TopK, varying B.

Paper claims: EF21-SGDM / EF21-SGD2M converge fast at every B (batch-free);
EF21-SGD suffers at small B; NEOLITHIC pays R=⌈d/K⌉ extra coordinates per round.
x-axis parity with the paper: we report error at equal TRANSMITTED COORDINATES.
(MNIST is replaced by a shape-matched synthetic set, label-split across clients —
offline container; see EXPERIMENTS.md E1 for the validity argument.)
"""
from __future__ import annotations


from benchmarks.common import Timer, csv_row, median_curves, save_json
from repro.core import compressors as C
from repro.core import ef, problems, simulate

SEEDS = 3
STEPS = 1500
N = 10
K = 10


def methods(d):
    topk = C.TopK(k=K)
    return {
        "ef14_sgd": ef.EF14SGD(compressor=topk),
        "ef21_sgd": ef.EF21SGD(compressor=topk),
        "ef21_sgdm": ef.EF21SGDM(compressor=topk, eta=0.1),
        "ef21_sgd2m": ef.EF21SGD2M(compressor=topk, eta=0.1),
        "neolithic": ef.Neolithic(compressor=topk, rounds=max(d // K // 8, 1)),
    }


def run() -> dict:
    prob = problems.LogisticRegression(n=N, m_per_client=256, l=64, c=10,
                                       seed=0)
    d = prob.dim
    out = {}
    with Timer() as t:
        for B in (1, 32, 128):
            for name, m in methods(d).items():
                gamma = 0.05 if "21" in name or B > 1 else 0.02
                cfg = simulate.SimConfig(n=N, batch_size=B, gamma=gamma,
                                         steps=STEPS, b_init=min(B, 8))
                runs = [simulate.run_numpy(prob, m, cfg, seed=s)
                        for s in range(SEEDS)]
                curve = median_curves(runs)
                coords = m.coords_per_message(d) * N
                out[f"B{B}/{name}"] = {
                    "end_grad_sq": float(curve[-100:].mean()),
                    "end_loss": float(median_curves(runs, "loss")[-100:].mean()),
                    "coords_per_round": coords,
                    "total_coords": coords * STEPS,
                    "curve_ds": curve[::50].tolist(),
                }
    # claims (B1 separation weakened for synthetic data — see EXPERIMENTS.md E1:
    # the dramatic EF21-SGD divergence needs Theorem-1-style noise, reproduced
    # exactly in fig1_divergence; here we assert "never worse")
    out["claims"] = {
        "sgdm_never_worse_B1":
            out["B1/ef21_sgdm"]["end_grad_sq"]
            < 2.0 * out["B1/ef21_sgd"]["end_grad_sq"],
        "sgdm_improves_with_B":
            out["B128/ef21_sgdm"]["end_grad_sq"]
            < out["B1/ef21_sgdm"]["end_grad_sq"],
        "neolithic_pays_more_coords":
            out["B1/neolithic"]["coords_per_round"]
            > 5 * out["B1/ef21_sgdm"]["coords_per_round"],
    }
    save_json("exp1_batchsize", out)
    csv_row("exp1_batchsize", t.us_per(SEEDS * STEPS * 15),
            f"B1_sgdm={out['B1/ef21_sgdm']['end_grad_sq']:.2e};"
            f"B1_ef21sgd={out['B1/ef21_sgd']['end_grad_sq']:.2e};"
            f"claims={sum(out['claims'].values())}/3")
    return out


if __name__ == "__main__":
    run()
