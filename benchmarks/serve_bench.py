"""Load-generator benchmark for the wire-fed serving fleet (ISSUE 8): one
trainer publishes its quant4 downlink stream, a replica fleet subscribes, and
a synthetic request load drives it at configurable arrival rates. Recorded in
the checked-in ledger BENCH_serving.json.

What is measured, per arrival rate:

* request latency percentiles (p10/p50/p90 + p99) over the completed load —
  wall-clock from arrival to batch completion under the decode-budget
  scheduler, so queueing delay is in the number, not hidden;
* sustained QPS and the staleness (trainer head − replica step) each request
  was actually served at;
* the wire accounting that justifies streaming at all: broadcast words per
  sync (``core/stream.py::legs_wire_words`` — the same accounting the
  training downlink reports) vs a dense f32 weight push, as bytes and as a
  compression ratio. The acceptance bar is ≥ 20× at quant4;
* the SAME load through a ``ProcessFleet`` of replica worker PROCESSES
  (``launch/replica_worker.py``) tailing the stream over the transport
  layer with continuous sync during decode — recorded under
  ``serving_multiproc`` so the process boundary's cost sits next to the
  in-process numbers it must be compared against;
* the SAME load with the fleet tailing a REMOTE stream over ``tcp://``
  (``launch/transport.py::TailServer`` RPC — the wire a cross-machine
  replica actually rides) — recorded under ``serving_remote`` so the
  socket transport's cost is a measured row, not a claim.

Every replica in the timed fleet serves params BIT-IDENTICAL to the
trainer's post-step model at its lag (the invariant tests/test_fleet.py
pins); the latency numbers are never bought with drifted weights."""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import bench_run, bench_session, csv_row, save_bench
from repro.core import stream as stream_lib
from repro.launch import fleet as fleet_lib
from repro.launch import transport as transport_lib

# CPU-bench-sized trainer: EF21-SGDM uplink + quant4 downlink at the reduced
# smollm geometry — the same production step the train driver runs
SPEC_KW = dict(clients=2, global_batch=4, compressor="block_topk", ratio=0.1,
               downlink_carrier="quant4", downlink_ratio=0.05)


def _percentiles_ns(latencies_s) -> dict:
    lat = np.asarray(sorted(latencies_s)) * 1e9
    return {"p10_ns": float(np.percentile(lat, 10)),
            "median_ns": float(np.percentile(lat, 50)),
            "p90_ns": float(np.percentile(lat, 90)),
            "p99_ns": float(np.percentile(lat, 99)),
            "iters": int(lat.size)}


def run(tiny: bool = False) -> dict:
    steps = 3 if tiny else 6
    n_requests = 6 if tiny else 24
    rates = [20.0] if tiny else [4.0, 16.0]
    prompt_len, max_new = (8, 4) if tiny else (16, 8)
    decode_budget, max_batch = (8, 2) if tiny else (16, 4)

    stream_dir = tempfile.mkdtemp(prefix="serve_bench_wire_")
    try:
        sess = bench_session(**SPEC_KW)
        sess.publish_to(stream_dir, bootstrap_every=max(steps // 2, 1))
        t0 = time.time()
        sess.train(steps)
        train_s = time.time() - t0

        fleet = fleet_lib.Fleet(stream_dir, n_replicas=2, lags=(0, 2),
                                decode_budget=decode_budget,
                                max_batch=max_batch, prompt_len=prompt_len)
        fleet.sync()

        # wire accounting: per-sync broadcast words on THIS stream's legs vs
        # a dense f32 push of the whole model — the one-wire-two-consumers
        # claim (DESIGN.md §12) in bytes
        rep = fleet.replicas[0]
        params_like = rep._likes["params"]
        wire_words = stream_lib.legs_wire_words(rep.legs, params_like)
        d = sum(int(np.prod(leaf.shape)) for leaf in
                jax.tree_util.tree_leaves(params_like))
        wire_bytes = 4.0 * wire_words
        dense_bytes = 4.0 * d
        ratio_vs_dense = dense_bytes / max(wire_bytes, 1.0)

        metrics, serving = {}, {}
        for rate in rates:
            reqs = fleet_lib.synthetic_requests(
                n_requests, rate=rate, prompt_len=prompt_len,
                max_new_tokens=max_new,
                vocab_size=fleet.replicas[0].session.cfg.vocab_size)
            out = fleet.run(reqs, sync_every=1)
            key = f"latency_rate{rate:g}"
            metrics[key] = _percentiles_ns(
                [r.latency_s for r in out["requests"]])
            serving[key] = {
                "rate_req_s": rate, "qps": out["qps"],
                "p50_ms": out["p50_ms"], "p99_ms": out["p99_ms"],
                "batches": out["batches"],
                "staleness_mean": out["staleness_mean"],
                "staleness_max": out["staleness_max"],
            }
            csv_row(f"serve_bench_rate{rate:g}",
                    metrics[key]["median_ns"] / 1e3,
                    f"qps={out['qps']:.2f};p99_ms={out['p99_ms']:.0f};"
                    f"staleness_max={out['staleness_max']}")

        # the multi-process fleet on the SAME stream: worker processes tail
        # the wire over launch/transport.py and sync continuously during
        # decode — the transport's cost is measured against the in-process
        # numbers above, not asserted away
        mp_rate = rates[-1]
        serving_mp = {}
        with fleet_lib.ProcessFleet(
                stream_dir, n_workers=2, lags=(0, 2),
                decode_budget=decode_budget, max_batch=max_batch,
                prompt_len=prompt_len) as pfl:
            pfl.sync()
            reqs = fleet_lib.synthetic_requests(
                n_requests, rate=mp_rate, prompt_len=prompt_len,
                max_new_tokens=max_new)
            mp_out = pfl.run(reqs)
        key = f"multiproc_rate{mp_rate:g}"
        metrics[key] = _percentiles_ns(
            [r.latency_s for r in mp_out["requests"]])
        serving_mp[key] = {
            "rate_req_s": mp_rate, "qps": mp_out["qps"],
            "p50_ms": mp_out["p50_ms"], "p99_ms": mp_out["p99_ms"],
            "batches": mp_out["batches"],
            "staleness_mean": mp_out["staleness_mean"],
            "staleness_max": mp_out["staleness_max"],
            "workers": len(mp_out["workers"]),
            "restarts": mp_out["restarts"],
            "mid_applied": mp_out["mid_applied"],
        }
        csv_row(f"serve_bench_multiproc_rate{mp_rate:g}",
                metrics[key]["median_ns"] / 1e3,
                f"qps={mp_out['qps']:.2f};p99_ms={mp_out['p99_ms']:.0f};"
                f"staleness_max={mp_out['staleness_max']}")

        # the remote tail on the SAME stream: the fleet subscribes through
        # tcp:// (TailServer RPC + local mirror) instead of the filesystem —
        # identical decode path, the socket hop is the only variable
        serving_remote = {}
        srv = transport_lib.TailServer(stream_dir).start()
        try:
            rfl = fleet_lib.Fleet(srv.address, n_replicas=2, lags=(0, 2),
                                  decode_budget=decode_budget,
                                  max_batch=max_batch, prompt_len=prompt_len)
            rfl.sync()
            reqs = fleet_lib.synthetic_requests(
                n_requests, rate=mp_rate, prompt_len=prompt_len,
                max_new_tokens=max_new,
                vocab_size=rfl.replicas[0].session.cfg.vocab_size)
            r_out = rfl.run(reqs, sync_every=1)
        finally:
            srv.stop()
        key = f"remote_rate{mp_rate:g}"
        metrics[key] = _percentiles_ns(
            [r.latency_s for r in r_out["requests"]])
        serving_remote[key] = {
            "rate_req_s": mp_rate, "qps": r_out["qps"],
            "p50_ms": r_out["p50_ms"], "p99_ms": r_out["p99_ms"],
            "batches": r_out["batches"],
            "staleness_mean": r_out["staleness_mean"],
            "staleness_max": r_out["staleness_max"],
            "transport": srv.address.split("://")[0],
        }
        csv_row(f"serve_bench_remote_rate{mp_rate:g}",
                metrics[key]["median_ns"] / 1e3,
                f"qps={r_out['qps']:.2f};p99_ms={r_out['p99_ms']:.0f};"
                f"staleness_max={r_out['staleness_max']}")

        run_entry = bench_run(
            geometry={"arch": fleet.replicas[0].spec.arch, "tiny": tiny,
                      "steps": steps, "requests": n_requests,
                      "replicas": len(fleet.replicas), "lags": [0, 2],
                      "prompt_len": prompt_len, "max_new_tokens": max_new,
                      "decode_budget": decode_budget, "max_batch": max_batch,
                      "downlink_carrier": "quant4", "downlink_ratio": 0.05},
            metrics=metrics,
            speedup_vs_ref={"wire_bytes_vs_dense_f32": ratio_vs_dense})
        run_entry["serving"] = serving
        run_entry["serving_multiproc"] = serving_mp
        run_entry["serving_remote"] = serving_remote
        run_entry["wire"] = {
            "wire_bytes_per_sync": wire_bytes,
            "dense_f32_push_bytes": dense_bytes,
            "ratio_vs_dense": ratio_vs_dense,
            "train_s": train_s,
        }
        ledger = save_bench("serving", run_entry)
        return {"ledger": ledger, "ratio_vs_dense": ratio_vs_dense,
                "serving": serving, "metrics": metrics}
    finally:
        shutil.rmtree(stream_dir, ignore_errors=True)


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke load (3 trainer steps, 6 requests, one "
                        "rate) instead of the full sweep")
    out = run(tiny=p.parse_args().tiny)
    print(f"wire bytes per sync vs dense f32 push: "
          f"{out['ratio_vs_dense']:.1f}x (ledger: {out['ledger']})")
