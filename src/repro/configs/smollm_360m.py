"""smollm-360m — small llama-arch dense LM [hf:HuggingFaceTB/SmolLM-135M]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense", citation="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5, d_ff=2560,
    vocab_size=49152,
)

def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=192, num_heads=3, num_kv_heads=1,
        d_ff=512, vocab_size=256, remat=False, attn_chunk=64)
