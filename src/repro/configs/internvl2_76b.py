"""internvl2-76b — InternViT-6B vision encoder + InternLM2/Llama-70B-class LLM
[arXiv:2404.16821]. The vision tower is a STUB: input_specs provides projected
patch embeddings prepended to the text sequence (spec carve-out, DESIGN.md §5)."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm", citation="arXiv:2404.16821",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, d_ff=28672,
    vocab_size=128256, frontend="vision", frontend_tokens=256,
)

def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=256, frontend_tokens=16, remat=False,
        attn_chunk=64)
