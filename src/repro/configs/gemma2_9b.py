"""gemma2-9b — local(4k SWA)/global alternating attention + logit softcapping
[arXiv:2408.00118]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense", citation="arXiv:2408.00118",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000, local_global=True, sliding_window=4096,
    logit_softcap=50.0, final_softcap=30.0,
)

def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, sliding_window=128,
        remat=False, attn_chunk=64)
