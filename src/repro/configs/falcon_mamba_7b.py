"""falcon-mamba-7b — pure Mamba1 LM, attention-free [arXiv:2410.05355]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", citation="arXiv:2410.05355",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0, d_ff=0,
    vocab_size=65024, ssm_variant="mamba1", ssm_state=16, ssm_expand=2,
)

def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, vocab_size=256, ssm_state=8,
        remat=False, attn_chunk=64)
