"""Architecture & run configuration system.

Every assigned architecture gets one module ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (full size, exercised only via the dry-run) and ``smoke_config()``
(reduced variant for CPU tests: ≤2 layers, d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# NOTE: no top-level jax import. This module is the arch/shape *registry* and
# is consumed by launch/spec.py, which must stay importable without jax so
# sweep tooling (`python -m repro.launch.spec --print`) can emit RunSpec JSON
# from lightweight processes. jnp is imported lazily where needed.


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    citation: str

    num_layers: int = 12
    d_model: int = 1024
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: Optional[int] = None   # default d_model // num_heads
    d_ff: int = 4096
    vocab_size: int = 32000

    # attention flavour
    sliding_window: Optional[int] = None     # SWA width (h2o-danube, gemma2 local)
    local_global: bool = False               # gemma2: alternate local/global layers
    logit_softcap: Optional[float] = None    # gemma2 attn softcap
    final_softcap: Optional[float] = None    # gemma2 final-logit softcap
    rope_theta: float = 10000.0

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    moe_impl: str = "dispatch"        # 'dispatch' | 'dense' (see moe.py §Perf)

    # SSM
    ssm_variant: Optional[str] = None        # 'mamba1' | 'mamba2'
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64                   # mamba2 head size
    ssm_dt_rank: Optional[int] = None        # mamba1: default ceil(d_model/16)

    # hybrid (zamba2): one SHARED attention block applied every k SSM layers
    hybrid_attn_every: int = 0

    # modality frontend stub: None | 'audio' | 'vision'
    frontend: Optional[str] = None
    frontend_tokens: int = 0                 # vision: # patch embeddings prepended

    # TP head padding (beyond-paper §Perf): when num_heads/num_kv_heads don't
    # divide the model axis, pad q heads to a multiple of `tp_pad_heads` and
    # MHA-expand kv (replicate each kv head over its query group; padded q
    # heads get zero output rows → function preserved exactly, and attention
    # shards over TP instead of replicating). 0 = off.
    tp_pad_heads: int = 0

    # numerics / memory
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    norm_eps: float = 1e-6
    remat: bool = True                       # activation checkpoint each block
    attn_chunk: int = 512                    # chunked-attention block size

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def eff_heads(self) -> Tuple[int, int]:
        """(H_eff, KV_eff) after optional TP head padding (MHA-expand)."""
        H, KV = self.num_heads, self.num_kv_heads
        t = self.tp_pad_heads
        if not t or H == 0 or (H % t == 0 and KV % t == 0):
            return H, KV
        Hp = -(-H // t) * t
        return Hp, Hp

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank if self.ssm_dt_rank is not None \
            else -(-self.d_model // 16)

    @property
    def activation_dtype(self):
        import jax.numpy as jnp
        return jnp.dtype(self.dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic state at 500k decode: SSM/hybrid or sliding-window dense."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None or self.local_global)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6·N·D)."""
        d, L = self.d_model, self.num_layers
        hd = self.head_dim_
        n = self.vocab_size * d                     # embedding (tied output head)
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm", "hybrid"):
            attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
                + (self.num_heads * hd) * d
        if self.family in ("dense", "audio", "vlm"):
            per_layer = attn + 3 * d * self.d_ff + 2 * d
        elif self.family == "moe":
            ff = self.d_ff
            per_layer = attn + self.num_experts * 3 * d * ff + d * self.num_experts + 2 * d
        elif self.family == "ssm":
            di = self.d_inner
            per_layer = (2 * d * di + self.ssm_conv * di
                         + di * (self.dt_rank + 2 * self.ssm_state)
                         + self.dt_rank * di + di * self.ssm_state + di
                         + di * d + d)
        elif self.family == "hybrid":
            di = self.d_inner
            nh = di // self.ssm_head_dim
            per_layer = (d * (2 * di + 2 * self.ssm_state + nh) + self.ssm_conv
                         * (di + 2 * self.ssm_state) + nh + nh + di + di * d + d)
            n += attn + 3 * d * self.d_ff   # one shared attention(+mlp) block
        n += per_layer * L + d               # final norm
        if self.frontend is not None:
            n += d * d                       # frontend projector stub
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        total = self.param_count()
        moe_all = L * self.num_experts * 3 * d * self.d_ff
        moe_active = L * self.num_experts_per_tok * 3 * d * self.d_ff
        return total - moe_all + moe_active


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "falcon_mamba_7b", "musicgen_medium", "granite_34b", "zamba2_1p2b",
    "smollm_360m", "gemma2_9b", "internvl2_76b", "h2o_danube3_4b",
    "olmoe_1b_7b", "grok1_314b",
]

# public --arch ids (hyphenated) → module names
ARCH_ALIASES = {
    "falcon-mamba-7b": "falcon_mamba_7b", "musicgen-medium": "musicgen_medium",
    "granite-34b": "granite_34b", "zamba2-1.2b": "zamba2_1p2b",
    "smollm-360m": "smollm_360m", "gemma2-9b": "gemma2_9b",
    "internvl2-76b": "internvl2_76b", "h2o-danube-3-4b": "h2o_danube3_4b",
    "olmoe-1b-7b": "olmoe_1b_7b", "grok-1-314b": "grok1_314b",
}


def get(arch: str) -> ArchConfig:
    mod_name = ARCH_ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke(arch: str) -> ArchConfig:
    mod_name = ARCH_ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config()
