"""granite-34b — llama-arch code model, GQA with a single KV head (MQA)
[arXiv:2405.04324]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense", citation="arXiv:2405.04324",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1, d_ff=24576,
    vocab_size=49152,
)

def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=1,
        d_ff=512, vocab_size=256, remat=False, attn_chunk=64)
