"""zamba2-1.2b — Mamba2 backbone with a SHARED attention block applied every 6
SSM layers [arXiv:2411.15242]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid", citation="arXiv:2411.15242",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, d_ff=8192,
    vocab_size=32000, ssm_variant="mamba2", ssm_state=64, ssm_expand=2,
    ssm_head_dim=64, hybrid_attn_every=6,
    # long-context serving config gives the shared attention block a 4k window
    sliding_window=None,
)

def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=256, ssm_state=16, ssm_head_dim=32,
        hybrid_attn_every=2, remat=False, attn_chunk=64)
