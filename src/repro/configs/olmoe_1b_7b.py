"""olmoe-1b-7b — 64-expert top-8 MoE, 1B active / 7B total [arXiv:2409.02060]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe", citation="arXiv:2409.02060",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, d_ff=1024,
    vocab_size=50304, num_experts=64, num_experts_per_tok=8,
)

def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=64, vocab_size=256, num_experts=4, num_experts_per_tok=2,
        remat=False, attn_chunk=64)
