"""musicgen-medium — decoder-only transformer over EnCodec audio tokens
[arXiv:2306.05284]. The EnCodec frontend is a STUB: input_specs provides
precomputed frame-token embeddings (spec carve-out, DESIGN.md §5)."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio", citation="arXiv:2306.05284",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24, d_ff=6144,
    vocab_size=2048, frontend="audio",
)

def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=192, num_heads=3, num_kv_heads=3,
        d_ff=768, vocab_size=256, remat=False, attn_chunk=64)
