"""grok-1-314b — 8-expert top-2 MoE, 314B total [hf:xai-org/grok-1]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe", citation="hf:xai-org/grok-1",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8, d_ff=32768,
    vocab_size=131072, num_experts=8, num_experts_per_tok=2,
)

def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=256, num_experts=4, num_experts_per_tok=2,
        remat=False, attn_chunk=64)
