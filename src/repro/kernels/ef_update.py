"""Fused EF21-SGDM client update (Pallas TPU) — Algorithm 1 lines 6–8 in ONE
HBM pass:

    v' = (1−η)·v + η·grad
    c  = BlockTopK(v' − g)        (threshold bisection, see topk_compress.py)
    g' = g + c

The unfused update reads/writes each of (grad, v, g, δ, c, g') separately — ~9
HBM passes of d words; the optimizer phase of EF training is purely memory-bound,
so fusion is a direct ~3× on its memory-roofline term (§Perf). All arithmetic is
elementwise + the bisection counts; everything lives in one VMEM tile.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import _row_tiles
from repro.kernels.topk_compress import _bisect_threshold


def _ef_kernel(grad_ref, v_ref, g_ref, v_out, g_out, c_out, *,
               eta: float, k: int):
    grad = grad_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    v_new = (1.0 - eta) * v + eta * grad
    delta = v_new - g
    ab = jnp.abs(delta)
    t = _bisect_threshold(ab, k)
    c = jnp.where(ab >= t[:, None], delta, 0.0)
    v_out[...] = v_new.astype(v_out.dtype)
    g_out[...] = (g + c).astype(g_out.dtype)
    c_out[...] = c.astype(c_out.dtype)


def ef21_sgdm_update(grad: jax.Array, v: jax.Array, g: jax.Array, *,
                     eta: float, block: int = 1024, k: int = 16,
                     rows_per_tile: int = 8, interpret: bool = False
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """All inputs same shape. Returns (v', g', c)."""
    shape, d = grad.shape, grad.size
    nb = -(-d // block)
    pad = nb * block - d

    def prep(x):
        return jnp.pad(x.reshape(-1), (0, pad)).reshape(nb, block)

    rt = _row_tiles(nb, block, rows_per_tile)
    spec = pl.BlockSpec((rt, block), lambda i: (i, 0))
    v_new, g_new, c = pl.pallas_call(
        functools.partial(_ef_kernel, eta=eta, k=k),
        grid=(nb // rt,),
        in_specs=[spec, spec, spec],
        out_specs=(spec, spec, spec),
        out_shape=tuple(jax.ShapeDtypeStruct((nb, block), x.dtype)
                        for x in (v, g, g)),
        interpret=interpret,
    )(prep(grad), prep(v), prep(g))

    def unprep(x):
        return x.reshape(-1)[:d].reshape(shape)

    return unprep(v_new), unprep(g_new), unprep(c)
