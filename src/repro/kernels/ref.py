"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q,k,v: (B, S, H, hd) (same head counts — GQA expansion done by caller).
    Plain materialized-softmax attention in f32."""
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def block_topk_ref(x: jax.Array, block: int, k: int) -> jax.Array:
    """Block-TopK sparsification: within each contiguous `block`, zero everything
    but the k largest-|·| entries (ties keep the earliest index, matching the
    kernel's >threshold-and-capacity rule)."""
    d = x.size
    nb = -(-d // block)
    xb = jnp.pad(x.reshape(-1), (0, nb * block - d)).reshape(nb, block)
    ab = jnp.abs(xb)
    # exact top-k with deterministic tie-break by index (earlier wins)
    order = jnp.argsort(-ab, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    out = jnp.where(ranks < k, xb, 0.0)
    return out.reshape(-1)[:d].reshape(x.shape)


def ef21_sgdm_update_ref(grad: jax.Array, v: jax.Array, g: jax.Array, *,
                         eta: float, block: int, k: int
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused EF21-SGDM client update (Algorithm 1 lines 6–8) with Block-TopK:
       v' = (1−η)v + η·grad;  c = BlockTopK(v' − g);  g' = g + c.
    Returns (v', g', c)."""
    v_new = (1.0 - eta) * v + eta * grad
    c = block_topk_ref(v_new - g, block, k)
    return v_new, g + c, c


def ef21_sgdm_topk_quant_ref(grad: jax.Array, v: jax.Array, g: jax.Array, *,
                             eta: float, block: int, k: int, bits: int
                             ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                        jax.Array]:
    """Composed oracle for the fused uplink mega-kernel
    (kernels/fused_round.py): block_quantize_ref ∘ block_topk_ref ∘
    ef21_sgdm_update_ref, then g' integrates the DECODE of the wire (the EF
    invariant — what the client remembers must equal what the server reads).
    Returns (v', g', q, scales) on the same flat-block layout as the kernel."""
    shape, d = grad.shape, grad.size
    nb = -(-d // block)
    v_new, _, c = ef21_sgdm_update_ref(grad, v, g, eta=eta, block=block, k=k)
    cb = jnp.pad(c.reshape(-1).astype(jnp.float32),
                 (0, nb * block - d)).reshape(nb, block)
    q, scales = block_quantize_ref(cb, bits)
    c_hat = block_dequantize_ref(q, scales, bits=bits,
                                 cols=block).reshape(-1)[:d].reshape(shape)
    g_new = (g.astype(jnp.float32) + c_hat).astype(g.dtype)
    return v_new, g_new, q, scales


def block_quantize_ref(x: jax.Array, bits: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Per-row absmax quantization of a (rows, cols) array — each row is one
    quantization block. Symmetric signed grid: scale = absmax/(2^(bits−1)−1),
    q = round(x/scale) ∈ [−qmax, qmax]. bits=8 stores int8 mantissas; bits=4
    packs two uint4 mantissas (offset by +8) per uint8 byte, odd cols padded.
    Non-finite inputs are treated as 0 (the scale stays finite; they decode to
    exactly 0 — EF then re-sends that mass as ordinary residual).
    A zero row gets scale 0 and decodes to exact zeros.
    Returns (q, scales): q int8 (rows, cols) | uint8 (rows, ceil(cols/2)),
    scales f32 (rows,)."""
    x = x.astype(jnp.float32)
    x = jnp.where(jnp.isfinite(x), x, 0.0)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(x), axis=1) / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[:, None]), -qmax, qmax)
    if bits == 8:
        return q.astype(jnp.int8), scale
    if q.shape[1] % 2:
        q = jnp.pad(q, ((0, 0), (0, 1)))
    u = (q + 8.0).astype(jnp.uint8).reshape(q.shape[0], -1, 2)
    return (u[:, :, 0] << 4) | u[:, :, 1], scale


def block_dequantize_ref(q: jax.Array, scales: jax.Array, *, bits: int,
                         cols: int) -> jax.Array:
    """Inverse of :func:`block_quantize_ref`: q·scale per row, f32 (rows, cols)."""
    if bits == 8:
        vals = q.astype(jnp.float32)
    else:
        hi = (q >> 4).astype(jnp.float32) - 8.0
        lo = (q & 0xF).astype(jnp.float32) - 8.0
        vals = jnp.stack([hi, lo], axis=-1).reshape(q.shape[0], -1)[:, :cols]
    return vals * scales.astype(jnp.float32)[:, None]
