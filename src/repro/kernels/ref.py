"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q,k,v: (B, S, H, hd) (same head counts — GQA expansion done by caller).
    Plain materialized-softmax attention in f32."""
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def block_topk_ref(x: jax.Array, block: int, k: int) -> jax.Array:
    """Block-TopK sparsification: within each contiguous `block`, zero everything
    but the k largest-|·| entries (ties keep the earliest index, matching the
    kernel's >threshold-and-capacity rule)."""
    d = x.size
    nb = -(-d // block)
    xb = jnp.pad(x.reshape(-1), (0, nb * block - d)).reshape(nb, block)
    ab = jnp.abs(xb)
    # exact top-k with deterministic tie-break by index (earlier wins)
    order = jnp.argsort(-ab, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    out = jnp.where(ranks < k, xb, 0.0)
    return out.reshape(-1)[:d].reshape(x.shape)


def ef21_sgdm_update_ref(grad: jax.Array, v: jax.Array, g: jax.Array, *,
                         eta: float, block: int, k: int
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused EF21-SGDM client update (Algorithm 1 lines 6–8) with Block-TopK:
       v' = (1−η)v + η·grad;  c = BlockTopK(v' − g);  g' = g + c.
    Returns (v', g', c)."""
    v_new = (1.0 - eta) * v + eta * grad
    c = block_topk_ref(v_new - g, block, k)
    return v_new, g + c, c
