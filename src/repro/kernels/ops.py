"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` (the Pallas
interpreter runs the kernel body op-for-op — the correctness target validated
against ref.py). On TPU, ``interpret=False`` compiles to Mosaic. The model code
selects between these wrappers and the pure-JAX paths via ``use_pallas``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax

from repro.kernels import ef_update as _ef
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_round as _fr
from repro.kernels import quantize as _qz
from repro.kernels import topk_compress as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256) -> jax.Array:
    """(B,S,H,hd) attention; GQA callers expand kv heads first."""
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block", "k"))
def block_topk(x, *, block: int = 1024, k: int = 16) -> jax.Array:
    return _tk.block_topk(x, block=block, k=k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eta", "block", "k"))
def ef21_sgdm_update(grad, v, g, *, eta: float, block: int = 1024,
                     k: int = 16) -> Tuple[jax.Array, jax.Array, jax.Array]:
    return _ef.ef21_sgdm_update(grad, v, g, eta=eta, block=block, k=k,
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eta", "block", "k", "bits"))
def ef21_sgdm_topk_quant(grad, v, g, *, eta: float, block: int = 1024,
                         k: int = 16, bits: int = 8
                         ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                    jax.Array]:
    """One-launch uplink: EF update + BlockTopK + quantize → (v', g', q, s)."""
    return _fr.ef21_sgdm_topk_quant(grad, v, g, eta=eta, block=block, k=k,
                                    bits=bits, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("d", "block", "bits", "alpha"))
def dequant_add(q, scales, base, *, d: int, block: int = 256, bits: int = 8,
                alpha: float = 1.0) -> jax.Array:
    """One-launch downlink: base + alpha·dequantize(q, scales)."""
    return _fr.dequant_add(q, scales, base, d=d, block=block, bits=bits,
                           alpha=alpha, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block", "bits"))
def block_quantize(x, *, block: int = 256,
                   bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Per-block absmax wire quantization → (mantissas, scales)."""
    return _qz.block_quantize(x, block=block, bits=bits,
                              interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("d", "block", "bits"))
def block_dequantize(q, scales, *, d: int, block: int = 256,
                     bits: int = 8) -> jax.Array:
    return _qz.block_dequantize(q, scales, d=d, block=block, bits=bits,
                                interpret=_interpret())
