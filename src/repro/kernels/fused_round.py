"""One-launch fused EF round (Pallas TPU) — the whole client uplink and the
whole server downlink each collapse into a single kernel launch.

Uplink mega-kernel (``ef21_sgdm_topk_quant``), Algorithm 1 lines 6-8 plus the
wire codec in ONE HBM pass:

    v' = (1-eta)*v + eta*grad            momentum estimate
    c  = BlockTopK(v' - g)               threshold bisection, topk_compress.py
    (q, s) = quantize(c)                 per-block absmax, 8/4-bit mantissas
    g' = g + dequantize(q, s)            EF invariant: integrate the DECODE

The unfused path (ef_update.py -> topk_compress.py -> quantize.py) launches
three kernels and round-trips every intermediate (v', delta, c) through HBM —
~9 passes of d words for a phase that is purely memory-bound. Here every stage
lives in one VMEM tile: 3 reads (grad, v, g) + 2 f32 writes (v', g') + the
mantissa write at bits/32 of a word each.

Two contracts worth naming:

* **EF invariant.** ``g'`` integrates the dequantized wire, not the raw ``c``
  — what the client remembers must equal what the server decodes, otherwise
  the quantization error is never re-sent. The composed three-kernel path gets
  this for free only if the caller remembers to decode; the mega-kernel bakes
  it in.
* **Dense payload == sparse payload.** The quantization row is the selection
  block, so the masked row's absmax IS the absmax of the selected values, and
  masked-out zeros quantize to mantissa 0 exactly. Shipping the dense
  (nb, block) mantissa plane therefore decodes bit-identically to shipping the
  (vals, idx) sparse payload — no in-kernel compaction (TPU-hostile scatter)
  is needed to keep the wire faithful.

Non-finite grads are a client-side fault, not a supported input: the codec
guard keeps the wire and ``g'`` finite (non-finite entries decode to exactly
0), but the selection among a partially non-finite row is unspecified (the
bisection degenerates to keep-everything-finite).

Downlink kernel (``dequant_add``): dequantize + integrate in one launch,

    out = base + alpha * dequantize(q, s)

covering the EF21 broadcast-memory integration h' = h + decode(wire)
(alpha=1) and the fused model step x' = x - gamma*decode (alpha=-gamma).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import _row_tiles
from repro.kernels.topk_compress import _bisect_threshold


def _fused_uplink_kernel(grad_ref, v_ref, g_ref, v_out, g_out, q_out, s_out,
                         *, eta: float, k: int, bits: int):
    grad = grad_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    # lines 6-7: momentum estimate, innovation, Block-TopK selection
    v_new = (1.0 - eta) * v + eta * grad
    delta = v_new - g
    ab = jnp.abs(delta)
    t = _bisect_threshold(ab, k)
    c = jnp.where(ab >= t[:, None], delta, 0.0)
    # wire codec — same arithmetic as quantize._quant_kernel, one row per
    # selection block (the masked row's absmax is the selected values' absmax)
    c = jnp.where(jnp.isfinite(c), c, 0.0)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(c), axis=1) / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(c / safe[:, None]), -qmax, qmax)
    # line 8 under the EF invariant: g' integrates what the server decodes
    c_hat = q * scale[:, None]
    v_out[...] = v_new.astype(v_out.dtype)
    g_out[...] = (g + c_hat).astype(g_out.dtype)
    s_out[...] = scale[:, None]
    if bits == 8:
        q_out[...] = q.astype(jnp.int8)
    else:
        u = (q + 8.0).astype(jnp.uint8).reshape(q.shape[0], -1, 2)
        q_out[...] = (u[:, :, 0] << 4) | u[:, :, 1]


def ef21_sgdm_topk_quant(grad: jax.Array, v: jax.Array, g: jax.Array, *,
                         eta: float, block: int = 1024, k: int = 16,
                         bits: int = 8, rows_per_tile: int = 8,
                         interpret: bool = False
                         ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                    jax.Array]:
    """All inputs the same shape. Returns ``(v', g', q, scales)`` where
    (q, scales) is the quantized wire of c at the selection geometry — q int8
    (nb, block) for bits=8, packed uint4 pairs (nb, block//2) for bits=4 —
    and g' = g + dequantize(q, scales) (the EF invariant, enforced in-kernel).
    """
    assert bits in (8, 4), bits
    assert bits == 8 or block % 2 == 0, "uint4 packing needs an even block"
    shape, d = grad.shape, grad.size
    nb = -(-d // block)
    pad = nb * block - d

    def prep(x):
        return jnp.pad(x.reshape(-1), (0, pad)).reshape(nb, block)

    rt = _row_tiles(nb, block, rows_per_tile)
    qcols = block if bits == 8 else block // 2
    qdtype = jnp.int8 if bits == 8 else jnp.uint8
    spec = pl.BlockSpec((rt, block), lambda i: (i, 0))
    v_new, g_new, q, scales = pl.pallas_call(
        functools.partial(_fused_uplink_kernel, eta=eta, k=k, bits=bits),
        grid=(nb // rt,),
        in_specs=[spec, spec, spec],
        out_specs=(spec, spec,
                   pl.BlockSpec((rt, qcols), lambda i: (i, 0)),
                   pl.BlockSpec((rt, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((nb, block), v.dtype),
                   jax.ShapeDtypeStruct((nb, block), g.dtype),
                   jax.ShapeDtypeStruct((nb, qcols), qdtype),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)),
        interpret=interpret,
    )(prep(grad), prep(v), prep(g))

    def unprep(x):
        return x.reshape(-1)[:d].reshape(shape)

    return unprep(v_new), unprep(g_new), q, scales.reshape(-1)


def _dequant_add_kernel(q_ref, s_ref, b_ref, o_ref, *, bits: int,
                        alpha: float):
    scale = s_ref[...][:, 0]
    if bits == 8:
        vals = q_ref[...].astype(jnp.float32)
    else:
        p = q_ref[...]
        hi = (p >> 4).astype(jnp.float32) - 8.0
        lo = (p & 0xF).astype(jnp.float32) - 8.0
        vals = jnp.stack([hi, lo], axis=-1).reshape(p.shape[0], -1)
    dec = vals * scale[:, None]
    if alpha != 1.0:
        dec = alpha * dec
    base = b_ref[...].astype(jnp.float32)
    o_ref[...] = (base + dec).astype(o_ref.dtype)


def dequant_add(q: jax.Array, scales: jax.Array, base: jax.Array, *, d: int,
                block: int = 256, bits: int = 8, alpha: float = 1.0,
                rows_per_tile: int = 8, interpret: bool = False) -> jax.Array:
    """``base + alpha * dequantize(q, scales)`` in one launch.

    ``base`` holds the first ``d`` of ``q``'s nb*block decoded slots (same flat
    layout as block_dequantize); returns an array of base's shape and dtype.
    The arithmetic is the oracle's f32 chain (dequantize then add), so the
    result is bit-identical to the two-step path.
    """
    assert bits in (8, 4), bits
    shape = base.shape
    nb = q.shape[0]
    bb = jnp.pad(base.reshape(-1).astype(jnp.float32),
                 (0, nb * block - d)).reshape(nb, block)
    rt = _row_tiles(nb, block, rows_per_tile)
    out = pl.pallas_call(
        functools.partial(_dequant_add_kernel, bits=bits, alpha=alpha),
        grid=(nb // rt,),
        in_specs=[pl.BlockSpec((rt, q.shape[1]), lambda i: (i, 0)),
                  pl.BlockSpec((rt, 1), lambda i: (i, 0)),
                  pl.BlockSpec((rt, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), base.dtype),
        interpret=interpret,
    )(q, scales.reshape(-1, 1), bb)
    return out.reshape(-1)[:d].reshape(shape)
