"""Block-quantize / dequantize (Pallas TPU) — the wire codec of the quantized
carriers (core/carriers.py::QuantCarrier).

One grid step quantizes a tile of rows: each row is an independent
quantization block (per-row absmax scale + int8 or packed-uint4 mantissas).
Everything is elementwise + a per-row max, so the kernel is purely
VPU/memory-bound: on TPU it streams the f32 input once and writes mantissas at
1/4 (int8) or 1/8 (uint4) of the input bytes. Deterministic round-to-nearest —
bit-identical to the pure-jnp oracle (kernels/ref.py::block_quantize_ref),
which is what the carriers run under vmap (no vmap-of-pallas_call is ever
emitted; the unbatched shard_map encode path calls the kernel directly).

Guards (same contract as the oracle): non-finite inputs quantize to exactly 0
with a finite scale; an all-zero block gets scale 0 and decodes to exact zeros.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)
    x = jnp.where(jnp.isfinite(x), x, 0.0)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(x), axis=1) / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[:, None]), -qmax, qmax)
    s_ref[...] = scale[:, None]
    if bits == 8:
        q_ref[...] = q.astype(jnp.int8)
    else:
        u = (q + 8.0).astype(jnp.uint8).reshape(q.shape[0], -1, 2)
        q_ref[...] = (u[:, :, 0] << 4) | u[:, :, 1]


def _dequant_kernel(q_ref, s_ref, o_ref, *, bits: int):
    scale = s_ref[...][:, 0]
    if bits == 8:
        vals = q_ref[...].astype(jnp.float32)
    else:
        p = q_ref[...]
        hi = (p >> 4).astype(jnp.float32) - 8.0
        lo = (p & 0xF).astype(jnp.float32) - 8.0
        vals = jnp.stack([hi, lo], axis=-1).reshape(p.shape[0], -1)
    o_ref[...] = (vals * scale[:, None]).astype(o_ref.dtype)


# Per-operand VMEM budget for one grid step, in f32 words. A kernel holds a
# handful of (rt, block) tiles live at once (inputs + outputs + intermediates
# like |x| in the bisection), so 128Ki words ≈ 512 KB/operand keeps the worst
# case (~6 operands) comfortably inside the ~16 MB/core VMEM.
_VMEM_TILE_WORDS = 1 << 17


def _row_tiles(nb: int, block: int, rows_per_tile: int = 8) -> int:
    """Rows per grid step, picked from the array geometry.

    ``rows_per_tile`` is an upper bound, further capped so one operand tile
    (rt × block f32) stays within the per-operand VMEM budget — a lane-rounded
    single-block leaf can make ``block`` itself huge, and a fixed rt=8 would
    blow VMEM. The result must divide ``nb`` exactly (the grid is uniform);
    lane alignment is the 128-wide last axis, which the callers own — this
    helper only sizes the sublane (row) axis.
    """
    cap = max(1, _VMEM_TILE_WORDS // max(1, block))
    rt = max(1, min(rows_per_tile, nb, cap))
    while nb % rt:
        rt -= 1
    return rt


def block_quantize(x: jax.Array, *, block: int = 256, bits: int = 8,
                   rows_per_tile: int = 8, interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """x: any shape, flattened and zero-padded to whole blocks. Returns
    (q, scales): q int8 (nb, block) for bits=8, uint8 (nb, block//2) packed
    uint4 pairs for bits=4 (block must be even), scales f32 (nb,)."""
    assert bits in (8, 4), bits
    assert bits == 8 or block % 2 == 0, "uint4 packing needs an even block"
    d = x.size
    nb = -(-d // block)
    xb = jnp.pad(x.reshape(-1), (0, nb * block - d)).reshape(nb, block)
    rt = _row_tiles(nb, block, rows_per_tile)
    qcols = block if bits == 8 else block // 2
    qdtype = jnp.int8 if bits == 8 else jnp.uint8

    q, scales = pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=(nb // rt,),
        in_specs=[pl.BlockSpec((rt, block), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((rt, qcols), lambda i: (i, 0)),
                   pl.BlockSpec((rt, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((nb, qcols), qdtype),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)),
        interpret=interpret,
    )(xb)
    return q, scales.reshape(-1)


def block_dequantize(q: jax.Array, scales: jax.Array, *, d: int,
                     block: int = 256, bits: int = 8, rows_per_tile: int = 8,
                     interpret: bool = False) -> jax.Array:
    """Inverse of :func:`block_quantize`; returns the flat (d,) f32 decode."""
    assert bits in (8, 4), bits
    nb = q.shape[0]
    rt = _row_tiles(nb, block, rows_per_tile)
    out = pl.pallas_call(
        functools.partial(_dequant_kernel, bits=bits),
        grid=(nb // rt,),
        in_specs=[pl.BlockSpec((rt, q.shape[1]), lambda i: (i, 0)),
                  pl.BlockSpec((rt, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(q, scales.reshape(-1, 1))
    return out.reshape(-1)[:d]
