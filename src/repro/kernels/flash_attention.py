"""Blocked causal flash attention (Pallas TPU).

Canonical online-softmax formulation with a (B, H, nq, nk) grid; the kv-block
index is the innermost (sequential) grid dimension, accumulating into VMEM
scratch (running max m, running sum l, output accumulator acc) and finalizing on
the last kv block. Strictly-future kv blocks are skipped with @pl.when — on real
TPU this halves causal FLOPs vs. the masked pure-JAX path (models/layers.py),
which is exactly the §Perf "banded/blocked schedule" optimization.

Block sizes are MXU-aligned (multiples of 128 on the lane dim; head_dim padded by
the wrapper in ops.py when needed). VMEM working set per program:
  q(bq·hd) + k(bk·hd) + v(bk·hd) + acc(bq·hd) + scores(bq·bk) + m,l(2·bq)
e.g. bq=bk=256, hd=128, f32 ≈ 0.75 MiB ≪ 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, nk: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (qi * bq + bq - 1 >= ki * bk) if causal else True

    @pl.when(run if causal else ki >= 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False) -> jax.Array:
    """q,k,v: (B, S, H, hd) with equal head counts (wrapper expands GQA)."""
    B, S, H, hd = q.shape
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = hd ** -0.5

    # layout: (B, H, S, hd) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               causal=causal, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
