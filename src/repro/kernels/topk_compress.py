"""Block-TopK sparsification via in-kernel threshold bisection (Pallas TPU).

TPU adaptation of the paper's TopK compressor (DESIGN.md §4): global sort-based
selection is MXU/VPU-hostile, so we select *within* VMEM-tile-sized blocks using
~24 iterations of threshold bisection on |x| — each iteration is a fully
vectorized count-compare over the tile (VPU-friendly), no sort anywhere.

Exactness: bisection on float32 magnitudes converges to the k-th largest |x| to
~2⁻²⁴ relative precision; the emitted mask keeps entries with |x| ≥ threshold.
With distinct magnitudes this is exactly Block-TopK; exact ties at the threshold
are all kept (error only shrinks; the contraction bound α = k/block still holds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import _row_tiles

BISECT_ITERS = 26


def _bisect_threshold(ab: jax.Array, k: int) -> jax.Array:
    """ab: (rows, block) |values|. Returns per-row threshold t s.t.
    count(ab >= t) >= k and t is (approximately) maximal."""
    hi = jnp.max(ab, axis=1)                      # count(>=hi) >= 1
    lo = jnp.zeros_like(hi)                       # count(>=0)  = block >= k

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((ab >= mid[:, None]).astype(jnp.int32), axis=1)
        ok = cnt >= k                             # mid keeps enough → raise lo
        lo = jnp.where(ok, mid, lo)
        hi = jnp.where(ok, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, BISECT_ITERS, body, (lo, hi))
    return lo


def _topk_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)            # (rows, block)
    ab = jnp.abs(x)
    t = _bisect_threshold(ab, k)
    o_ref[...] = jnp.where(ab >= t[:, None], x, 0.0).astype(o_ref.dtype)


def block_topk(x: jax.Array, *, block: int = 1024, k: int = 16,
               rows_per_tile: int = 8, interpret: bool = False) -> jax.Array:
    """x: any shape; flattened, padded to blocks, sparsified, reshaped back."""
    shape, d = x.shape, x.size
    nb = -(-d // block)
    xb = jnp.pad(x.reshape(-1), (0, nb * block - d)).reshape(nb, block)
    rt = _row_tiles(nb, block, rows_per_tile)

    out = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(nb // rt,),
        in_specs=[pl.BlockSpec((rt, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), x.dtype),
        interpret=interpret,
    )(xb)
    return out.reshape(-1)[:d].reshape(shape)
