"""Wire carriers: the transport format of one EF synchronization round.

EF21's separation result (Richtárik et al., 2021) is that the *wire format* of
the compressed innovation is independent of the *method semantics* — Algorithm 1
only requires that every client ships C(vᵢ − gᵢ) and receives meanᵢ(cᵢ). This
module makes that separation first-class (DESIGN.md §6): a :class:`Carrier`
owns how c travels, every runtime (the vmap simulator in core/simulate.py, the
vmap runtime ``ef_round``, and the shard_map runtime ``ef_round_sharded`` in
core/distributed.py) dispatches through it, and methods never see the wire.

Three carriers:

  DenseCarrier        paper-faithful: c is shipped as a dense d-word tensor and
                      the mean lowers to an all-reduce (lax.pmean on the mesh,
                      ``.mean(0)`` over the client axis in vmap runtimes).
  SparseBlockCarrier  fixed-(values, block-local int32 indices) wire for the
                      TopK family: an all-gather of 2·nb·kb words per client
                      followed by a local scatter-add. Block-local indices mean
                      no flat index ever exceeds the block size, so leaves with
                      > 2³¹ elements (grok expert weights) are safe. Plain TopK
                      is the single-block special case (block = d, exact global
                      TopK).
  FusedPallasCarrier  dense wire + the whole EF21-SGD(M) client chain
                      (pre_compress → Block-TopK → post_compress) fused into ONE
                      HBM pass via kernels/ef_update.py (~3× on the memory-
                      roofline term of the client update). Falls back to the
                      Pallas interpreter off-TPU, and to the unfused dense plan
                      for methods/compressors the kernel does not cover.

Execution plans — a runtime asks ``carrier.plan(method, eta)`` and gets:

  'dense'  run the method's own update (pre → tree_compress → post or
           ``method.update``) and aggregate the dense message;
  'wire'   run pre_compress, then per-leaf encode → local_c → aggregate,
           then post_compress (message must equal the wire, method.wire_is_msg);
  'fused'  call ``carrier.fused_update`` which replaces the entire three-phase
           chain with the fused kernel; aggregate the dense c it returns.

Aggregation runs in one of two contexts, selected by keyword:

  aggregate(..., dp=n)       wire leaves carry a leading client axis (vmap
                             runtimes) — reduce over axis 0;
  aggregate(..., axes=(...)) wire leaves are client-local inside shard_map —
                             reduce with explicit named-axis collectives.

``wire_words`` is the honest per-client, per-message word count for benchmark
x-axes (values AND indices both count; a dense all-reduce counts d), exposed to
plots via ``Method.coords_per_message(d, carrier=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compressors as comp_lib

PyTree = Any
Wire = Any


def axis_size(axis_name) -> jax.Array:
    """Size of a shard_map/pmap axis, portable across JAX versions
    (``jax.lax.axis_size`` only exists on newer releases)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


@dataclasses.dataclass(frozen=True)
class Carrier:
    """Base carrier. Frozen dataclass → hashable, usable inside jit statics."""

    name: str = "abstract"

    # -- plan selection ------------------------------------------------------
    def plan(self, method, eta=None) -> str:
        """'dense' | 'wire' | 'fused' — how a runtime should execute one round
        of ``method``. Carriers must degrade to 'dense' (always correct) when
        they cannot ship this method's messages."""
        return "dense"

    # -- per-client wire API (flat (d,) leaves) ------------------------------
    def encode(self, comp: comp_lib.Compressor, delta: jax.Array,
               rng: Optional[jax.Array] = None) -> Wire:
        """delta: flat (d,). Returns the wire representation of C(delta)."""
        raise NotImplementedError

    def local_c(self, comp: comp_lib.Compressor, delta: jax.Array,
                wire: Wire) -> jax.Array:
        """The dense C(delta) the client keeps locally for its gᵢ update —
        never transmitted. Returns flat (d,)."""
        raise NotImplementedError

    def aggregate(self, comp: comp_lib.Compressor, wire: Wire, *, d: int,
                  dtype, dp: Optional[int] = None,
                  axes: Optional[Tuple[str, ...]] = None) -> jax.Array:
        """meanᵢ(cᵢ) from the wire. Exactly one of ``dp`` (leading-axis vmap
        layout) / ``axes`` (named shard_map axes) must be given. Returns flat
        (d,)."""
        raise NotImplementedError

    # -- accounting ----------------------------------------------------------
    def wire_words(self, comp: comp_lib.Compressor, d: int) -> float:
        """Words one client puts on the wire per message of dimension d."""
        raise NotImplementedError

    # -- fusion hook ---------------------------------------------------------
    def fused_update(self, method, grads: PyTree, state: dict, *,
                     eta=None, batched: bool = False):
        raise NotImplementedError(f"carrier {self.name!r} does not fuse")


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseCarrier(Carrier):
    """Paper-faithful wire: the dense tensor C(delta) itself; the mean is a
    d-word all-reduce over the client axes (what the paper's own simulations
    do — no wire savings; the §Perf baseline)."""

    name: str = "dense"

    def encode(self, comp, delta, rng=None):
        return comp(delta, rng)

    def local_c(self, comp, delta, wire):
        return wire

    def aggregate(self, comp, wire, *, d, dtype, dp=None, axes=None):
        if axes is not None:
            return jax.lax.pmean(wire, axes)
        return wire.mean(0)

    def wire_words(self, comp, d):
        return float(d)


# ---------------------------------------------------------------------------
# sparse block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparseBlockCarrier(Carrier):
    """Fixed-size (values, block-local indices) wire for the TopK family.

    Collective bytes drop from d to 2·nb·kb per client: the mean is an
    all-gather of the small arrays followed by a local scatter-ADD (index
    collisions across clients must SUM — ``.at[].add``). ``local_c`` is the
    exact decode of the wire, so client state and server aggregate always
    agree on what was transmitted (see local_c)."""

    name: str = "sparse"

    def plan(self, method, eta=None) -> str:
        if method.wire_is_msg and self.supports(method.compressor):
            return "wire"
        return "dense"

    def supports(self, comp) -> bool:
        # has_sparse_carrier is the compressor's opt-in; the isinstance check
        # narrows to the families whose fixed-size geometry _geom understands
        # (RandK opts in but needs rng-dependent indices — not expressible as
        # a deterministic block wire, so it degrades to dense)
        return (comp.has_sparse_carrier
                and isinstance(comp, (comp_lib.TopK, comp_lib.BlockTopK)))

    def _geom(self, comp, d: int) -> Tuple[int, int, int]:
        """(nb, block, kb). Plain TopK = one block spanning the leaf."""
        if isinstance(comp, comp_lib.BlockTopK):
            block, kb = comp.block, comp._kb()
        elif isinstance(comp, comp_lib.TopK):
            block, kb = d, comp._k(d)
        else:
            raise ValueError(
                f"sparse carrier cannot ship {type(comp).__name__}")
        nb = -(-d // block)
        return nb, block, kb

    @staticmethod
    def _blocked(x: jax.Array, nb: int, block: int) -> jax.Array:
        return jnp.pad(x, (0, nb * block - x.size)).reshape(nb, block)

    def encode(self, comp, delta, rng=None):
        nb, block, kb = self._geom(comp, delta.size)
        xb = self._blocked(delta, nb, block)
        _, idx = jax.lax.top_k(jnp.abs(xb), kb)          # (nb, kb), sorted
        vals = jnp.take_along_axis(xb, idx, axis=1)
        return vals, idx.astype(jnp.int32)               # block-LOCAL indices

    def local_c(self, comp, delta, wire):
        # exact decode of the wire (scatter of the shipped values), NOT a
        # threshold mask: the client's gᵢ update must see precisely what the
        # server aggregated, or a tie at the kb-th rank would leave mass the
        # client believes transmitted but the server never received — error
        # feedback would then never re-send it
        vals, idx = wire
        nb, block, _ = self._geom(comp, delta.size)
        rows = jnp.broadcast_to(
            jnp.arange(nb, dtype=jnp.int32)[:, None], idx.shape)
        buf = jnp.zeros((nb, block), delta.dtype).at[rows, idx].set(vals)
        return buf.reshape(-1)[: delta.size]

    def aggregate(self, comp, wire, *, d, dtype, dp=None, axes=None):
        vals, idx = wire
        nb, block, kb = self._geom(comp, d)
        if axes is not None:
            n = 1
            for a in axes:                               # explicit wire
                n = n * axis_size(a)
                vals = jax.lax.all_gather(vals, a)
                idx = jax.lax.all_gather(idx, a)
            vals = vals.reshape(-1, nb, kb)
            idx = idx.reshape(-1, nb, kb)
        else:
            n = dp                                       # (dp, nb, kb) layout
        rows = jnp.broadcast_to(
            jnp.arange(nb, dtype=jnp.int32)[None, :, None], idx.shape)
        buf = jnp.zeros((nb, block), dtype).at[rows, idx].add(vals) / n
        return buf.reshape(-1)[:d]

    def wire_words(self, comp, d):
        nb, _, kb = self._geom(comp, d)
        return 2.0 * nb * kb                             # values + int32 idx


# ---------------------------------------------------------------------------
# fused Pallas
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedPallasCarrier(DenseCarrier):
    """Dense wire + the whole EF21-SGD(M) client update in one HBM pass.

    ``fused_update`` replaces pre_compress → C(·) → post_compress for
    EF21SGDM / EF21SGD with a BlockTopK compressor by a single call into
    ``kernels/ef_update.py::ef21_sgdm_update`` per leaf (EF21SGD is the η = 1
    special case: v' = grad). The kernel needs a *static* momentum, so the
    plan degrades to 'dense' whenever η is traced (time-varying schedules).

    ``interpret=None`` auto-selects the Pallas interpreter off-TPU so the
    carrier runs (slowly but correctly) in CPU containers and under tests.
    """

    name: str = "fused"
    interpret: Optional[bool] = None

    def _interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def plan(self, method, eta=None) -> str:
        static_eta = eta is None or isinstance(eta, (int, float))
        if (method.name in ("ef21_sgdm", "ef21_sgd") and static_eta
                and isinstance(method.compressor, comp_lib.BlockTopK)):
            return "fused"
        return "dense"

    def fused_update(self, method, grads, state, *, eta=None,
                     batched: bool = False):
        """One fused HBM pass per leaf. ``grads``/``state`` leaves are either
        client-local (shard_map runtime, ``batched=False``) or carry a leading
        client axis (vmap runtimes, ``batched=True`` — clients become extra
        tile rows, so no vmap-of-pallas_call is ever emitted).
        Returns (c_tree, new_state)."""
        from repro.kernels import ef_update as ef_kernel

        comp = method.compressor
        block, kb = comp.block, comp._kb()
        if method.name == "ef21_sgd":
            eta_f = 1.0                                  # v' = grad exactly
            v_tree = state["g"]
        else:
            eta_f = float(eta) if eta is not None else float(method.eta)
            v_tree = state["v"]
        interp = self._interpret()

        g_leaves, treedef = jax.tree_util.tree_flatten(state["g"])
        v_leaves = jax.tree_util.tree_leaves(v_tree)
        grad_leaves = jax.tree_util.tree_leaves(grads)

        v_out, g_out, c_out = [], [], []
        for grad, v, g in zip(grad_leaves, v_leaves, g_leaves):
            if batched:
                # pad each client's leaf to whole blocks FIRST so client
                # boundaries and block boundaries coincide in the flat view
                dp = grad.shape[0]
                d = grad[0].size
                nb = -(-d // block)
                pad = nb * block - d

                def prep(x):
                    return jnp.pad(x.reshape(dp, d), ((0, 0), (0, pad)))

                v2, g2, c = ef_kernel.ef21_sgdm_update(
                    prep(grad), prep(v), prep(g), eta=eta_f, block=block,
                    k=kb, interpret=interp)
                unprep = lambda x: x[:, :d].reshape(grad.shape)  # noqa: E731
                v2, g2, c = unprep(v2), unprep(g2), unprep(c)
            else:
                v2, g2, c = ef_kernel.ef21_sgdm_update(
                    grad, v, g, eta=eta_f, block=block, k=kb,
                    interpret=interp)
            v_out.append(v2)
            g_out.append(g2)
            c_out.append(c)

        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)  # noqa: E731
        c_tree = unf(c_out)
        g_new = method._cast(unf(g_out))
        if method.name == "ef21_sgd":
            new_state = {"g": g_new}
        else:
            new_state = {"v": method._cast(unf(v_out)), "g": g_new}
        return c_tree, new_state


# ---------------------------------------------------------------------------
# shared per-leaf dispatch for the 'wire' plan (used by every runtime)
# ---------------------------------------------------------------------------

def wire_round_batched(carrier: Carrier, comp, deltas: PyTree, dp: int
                       ) -> Tuple[PyTree, PyTree]:
    """encode → local_c → aggregate per leaf, clients on a leading axis (vmap
    runtimes). Returns (c_tree, msg_mean_tree)."""
    dleaves, dtree = jax.tree_util.tree_flatten(deltas)
    c_leaves, agg_leaves = [], []
    for leaf in dleaves:
        d = int(leaf[0].size)
        flat = leaf.reshape(dp, d)
        wire = jax.vmap(lambda x: carrier.encode(comp, x))(flat)
        c_loc = jax.vmap(lambda x, w: carrier.local_c(comp, x, w))(flat, wire)
        agg = carrier.aggregate(comp, wire, d=d, dtype=leaf.dtype, dp=dp)
        c_leaves.append(c_loc.reshape(leaf.shape))
        agg_leaves.append(agg.reshape(leaf.shape[1:]))
    return (jax.tree_util.tree_unflatten(dtree, c_leaves),
            jax.tree_util.tree_unflatten(dtree, agg_leaves))


def wire_round_local(carrier: Carrier, comp, deltas: PyTree,
                     axes: Tuple[str, ...], rng=None) -> Tuple[PyTree, PyTree]:
    """encode → local_c → aggregate per leaf, client-local inside shard_map
    (explicit named-axis collectives). Returns (c_tree, msg_mean_tree)."""
    dleaves, dtree = jax.tree_util.tree_flatten(deltas)
    c_leaves, agg_leaves = [], []
    for leaf in dleaves:
        flat = leaf.reshape(-1)
        wire = carrier.encode(comp, flat, rng)
        c_leaves.append(carrier.local_c(comp, flat, wire).reshape(leaf.shape))
        agg_leaves.append(carrier.aggregate(
            comp, wire, d=leaf.size, dtype=leaf.dtype, axes=axes)
            .reshape(leaf.shape))
    return (jax.tree_util.tree_unflatten(dtree, c_leaves),
            jax.tree_util.tree_unflatten(dtree, agg_leaves))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

REGISTRY = {
    "dense": DenseCarrier,
    "sparse": SparseBlockCarrier,
    "fused": FusedPallasCarrier,
}


def make(name) -> Carrier:
    if isinstance(name, Carrier):
        return name
    if name not in REGISTRY:
        raise ValueError(f"unknown carrier {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]()
