"""Wire carriers: the transport format of one EF synchronization round.

EF21's separation result (Richtárik et al., 2021) is that the *wire format* of
the compressed innovation is independent of the *method semantics* — Algorithm 1
only requires that every client ships C(vᵢ − gᵢ) and receives meanᵢ(cᵢ). This
module makes that separation first-class (DESIGN.md §6): a :class:`Carrier`
owns how c travels, every runtime (the vmap simulator in core/simulate.py, the
vmap runtime ``ef_round``, and the shard_map runtime ``ef_round_sharded`` in
core/distributed.py) dispatches through it, and methods never see the wire.

The carriers:

  DenseCarrier        paper-faithful: c is shipped as a dense d-word tensor and
                      the mean lowers to an all-reduce (lax.pmean on the mesh,
                      ``.mean(0)`` over the client axis in vmap runtimes).
  SparseBlockCarrier  fixed-(values, block-local int32 indices) wire for the
                      TopK family: an all-gather of 2·nb·kb words per client
                      followed by a local scatter-add. Block-local indices mean
                      no flat index ever exceeds the block size, so leaves with
                      > 2³¹ elements (grok expert weights) are safe. Plain TopK
                      is the single-block special case (block = d, exact global
                      TopK).
  FusedPallasCarrier  dense wire + the whole EF21-SGD(M) client chain
                      (pre_compress → Block-TopK → post_compress) fused into ONE
                      HBM pass via kernels/ef_update.py (~3× on the memory-
                      roofline term of the client update). Falls back to the
                      Pallas interpreter off-TPU, and to the unfused dense plan
                      for methods/compressors the kernel does not cover.
  QuantCarrier        block-quantized wire (kernels/quantize.py +
                      kernels/ref.py oracles): per-block absmax scale + int8
                      (``quant8``) or packed-uint4 (``quant4``) mantissas, for
                      both dense payloads (quantized C(δ)) and sparse-block
                      payloads (quantized TopK values + block-local indices).
                      EF21's contraction argument absorbs the extra bounded
                      wire distortion into the residual (``local_c`` is the
                      decode of the wire, so quantization error is re-sent in
                      later rounds), cutting wire words another 4–8× on top of
                      sparsification. Aggregation always dequantizes BEFORE
                      the collective arithmetic: summing int8 mantissas across
                      blocks with different scales is not associative.
  FusedQuantCarrier   ``fused_quant8`` / ``fused_quant4``: the quantized wire
                      AND the one-launch uplink — EF21-SGD(M) update,
                      Block-TopK selection, absmax quantization, and the
                      EF-invariant g' = g + decode(wire) integration all in a
                      single mega-kernel (kernels/fused_round.py). The
                      payload is the block-dense quantized innovation at the
                      selection geometry (decodes bit-identically to the
                      sparse payload; see the class docstring for the
                      wire-words tradeoff).

Execution plans — a runtime asks ``carrier.plan(method, eta)`` and gets:

  'dense'  run the method's own update (pre → tree_compress → post or
           ``method.update``) and aggregate the dense message;
  'wire'   run pre_compress, then per-leaf encode → local_c → aggregate,
           then post_compress (message must equal the wire, method.wire_is_msg);
  'fused'  call ``carrier.fused_update`` which replaces the entire three-phase
           chain with the fused kernel; aggregate the dense c it returns;
  'fused_wire'
           call ``carrier.fused_wire_round`` — one mega-kernel launch per
           leaf produces (v', g', quantized wire) with the EF invariant
           integrated in-kernel, and the aggregated mean comes back with it
           (the aggregation needs the wire, so it cannot be split off).

``plan_with_reason`` additionally returns WHY a carrier degraded from its
native plan (empty reason = the native plan runs). Launch
surfaces print it, so a misconfigured run no longer looks identical to a
working one in logs.

Carriers are direction-aware (DESIGN.md §8): the same wire formats also ship
the DOWNLINK leg — the server's broadcast of its compressed innovation
C(g_server − h) against an EF21 server memory h. ``plan_down_with_reason``
is the downlink twin of ``plan_with_reason`` (no method enters: the broadcast
payload is always the compressed innovation, so only the compressor gates the
wire), ``downlink_round`` runs the encode → decode leg shared by every
runtime (aggregation is a no-op — one server, one message), and
``downlink_words`` is the honest broadcast word count.

Aggregation runs in one of two contexts, selected by keyword:

  aggregate(..., dp=n)       wire leaves carry a leading client axis (vmap
                             runtimes) — reduce over axis 0;
  aggregate(..., axes=(...)) wire leaves are client-local inside shard_map —
                             reduce with explicit named-axis collectives.

``wire_words`` is the honest per-client, per-message word count for benchmark
x-axes (values AND indices both count; a dense all-reduce counts d), exposed to
plots via ``Method.coords_per_message(d, carrier=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compressors as comp_lib

PyTree = Any
Wire = Any

# rng fold constant of the downlink leg — ONE value shared by every runtime
# (the broadcast must be one identical message on server and all clients, so
# its key is derived from the round rng BEFORE any per-client folding)
DOWNLINK_FOLD = 1 << 20


def axis_size(axis_name) -> jax.Array:
    """Size of a shard_map/pmap axis, portable across JAX versions
    (``jax.lax.axis_size`` only exists on newer releases)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def ring_all_gather(x: PyTree, axis_name, fn=None) -> PyTree:
    """``lax.all_gather`` rebuilt as a ring of n−1 ``ppermute`` steps.

    Bit-identical transport: the result is stacked in axis-index order, exactly
    like ``lax.all_gather``'s leading axis — only the route differs. The point
    of the ring is *comm/compute overlap*: ``fn`` (when given) maps each chunk
    as it lands, and because chunk s never depends on permute s+1, XLA is free
    to run ``fn`` on the chunk in hand while the next permute is in flight —
    double-buffered decode behind the collective. ``fn`` must be elementwise
    per chunk (applied chunk-by-chunk here vs. once on the gathered stack must
    be the same bits); identity when omitted.

    Degenerates to a no-op stack on a 1-device axis."""
    if fn is None:
        fn = lambda c: c                                 # noqa: E731
    n = int(axis_size(axis_name))
    if n == 1:
        return jax.tree_util.tree_map(lambda a: a[None], fn(x))
    perm = [(i, (i + 1) % n) for i in range(n)]
    buf, chunks = x, [fn(x)]
    for _ in range(n - 1):
        # chunk s arrives from ring-neighbor s hops back while fn(chunk s−1)
        # is still runnable — the double buffer is (buf, chunks[-1])
        buf = jax.tree_util.tree_map(
            lambda a: jax.lax.ppermute(a, axis_name, perm), buf)
        chunks.append(fn(buf))
    stacked = jax.tree_util.tree_map(lambda *cs: jnp.stack(cs), *chunks)
    # chunks[s] came from axis index (me − s) mod n; re-index to axis order
    me = jax.lax.axis_index(axis_name)
    order = jnp.mod(me - jnp.arange(n), n)
    return jax.tree_util.tree_map(lambda s: jnp.take(s, order, axis=0),
                                  stacked)


def sparse_geom(comp, d: int) -> Tuple[int, int, int]:
    """(nb, block, kb) geometry of the fixed-size TopK-family wire for a flat
    (d,) leaf. Plain TopK = one block spanning the leaf (exact global TopK);
    BlockTopK geometry is d-aware (``BlockTopK.geom``: sub-block leaves get a
    proportional budget, not the degenerate full-block K); shared by the
    sparse and quantized carriers."""
    if isinstance(comp, comp_lib.BlockTopK):
        return comp.geom(d)
    if isinstance(comp, comp_lib.TopK):
        return 1, d, comp._k(d)
    raise ValueError(
        f"no fixed-size sparse wire for {type(comp).__name__}")


def sparse_select(comp, delta: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """The TopK-family wire selection shared by every carrier that ships it:
    pad to whole blocks, per-block top-|·|-k. Returns (vals, idx), both
    (nb, kb), idx block-LOCAL and sorted by magnitude rank. One
    implementation so tie-breaking/padding can never diverge between the
    sparse and quantized wires."""
    nb, block, kb = sparse_geom(comp, delta.size)
    xb = jnp.pad(delta, (0, nb * block - delta.size)).reshape(nb, block)
    _, idx = jax.lax.top_k(jnp.abs(xb), kb)
    vals = jnp.take_along_axis(xb, idx, axis=1)
    return vals, idx


def scatter_blocks(vals: jax.Array, idx: jax.Array, *, nb: int, block: int,
                   d: int, dtype) -> jax.Array:
    """Scatter one client's (nb, kb) block-wire values back to a flat (d,)
    tensor — the shared decode of the block-sparse wires. ``set`` semantics:
    indices are unique within one wire; cross-client aggregation must
    scatter-ADD instead (see the aggregate methods)."""
    rows = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32)[:, None],
                            idx.shape)
    buf = jnp.zeros((nb, block), dtype)
    buf = buf.at[rows, idx.astype(jnp.int32)].set(vals)
    return buf.reshape(-1)[:d]


@dataclasses.dataclass(frozen=True)
class Carrier:
    """Base carrier. Frozen dataclass → hashable, usable inside jit statics."""

    name: str = "abstract"
    # comm/compute overlap (EFConfig.overlap / RunSpec --overlap): gather-wire
    # carriers transport their all-gathers as a ppermute ring and decode each
    # chunk while the next is in flight (ring_all_gather). Bit-identical to
    # the blocking path by construction; a no-op for all-reduce wires (dense
    # psum has no per-client chunks to pipeline).
    overlap: bool = False

    def _gather(self, x: PyTree, axis_name, fn=None) -> PyTree:
        """The collective behind every gather-wire aggregate: blocking
        ``lax.all_gather`` by default, the overlapped ppermute ring when
        ``overlap`` is set. ``fn`` maps chunks as they arrive (overlap path)
        or the whole stack at once (blocking path) — same bits either way."""
        if self.overlap:
            return ring_all_gather(x, axis_name, fn)
        gathered = jax.tree_util.tree_map(
            lambda a: jax.lax.all_gather(a, axis_name), x)
        if fn is None:
            return gathered
        return jax.vmap(fn)(gathered)

    # -- plan selection ------------------------------------------------------
    def plan_with_reason(self, method, eta=None) -> Tuple[str, str]:
        """(plan, reason): plan is 'dense' | 'wire' | 'fused' | 'fused_wire'.
        The reason is
        the empty string when the carrier's native plan runs, and a
        human-readable explanation whenever it degraded to 'dense' — runtimes
        stay silent, but launch surfaces print it so a degraded configuration
        is visible in logs."""
        return "dense", "abstract base carrier has no wire format"

    def plan(self, method, eta=None) -> str:
        """'dense' | 'wire' | 'fused' — how a runtime should execute one round
        of ``method``. Carriers must degrade to 'dense' (always correct) when
        they cannot ship this method's messages."""
        return self.plan_with_reason(method, eta)[0]

    # -- downlink (server → client broadcast) --------------------------------
    def plan_down_with_reason(self, comp: comp_lib.Compressor
                              ) -> Tuple[str, str]:
        """(plan, reason) for the DOWNLINK leg: the server broadcasts ONE
        message C(g_server − h) and every client decodes it — there is no
        aggregation, so the plan depends only on the compressor (no method:
        the broadcast payload is always the compressed innovation itself).
        'wire' ships the carrier's native format; 'dense' ships the dense
        C(δ) tensor (always correct). A non-empty reason explains a
        degradation, exactly like ``plan_with_reason``."""
        return "dense", "abstract base carrier has no wire format"

    def plan_down(self, comp: comp_lib.Compressor) -> str:
        return self.plan_down_with_reason(comp)[0]

    # -- per-client wire API (flat (d,) leaves) ------------------------------
    def encode(self, comp: comp_lib.Compressor, delta: jax.Array,
               rng: Optional[jax.Array] = None) -> Wire:
        """delta: flat (d,). Returns the wire representation of C(delta)."""
        raise NotImplementedError

    def encode_local(self, comp: comp_lib.Compressor, delta: jax.Array,
                     rng: Optional[jax.Array] = None) -> Wire:
        """``encode`` for the client-local (shard_map, unbatched) context —
        carriers with a Pallas fast path override this (the batched runtimes
        keep the pure-jnp ``encode`` so no vmap-of-pallas_call is emitted).
        Must be bit-compatible with ``encode``."""
        return self.encode(comp, delta, rng)

    def decode(self, comp: comp_lib.Compressor, wire: Wire, *, d: int,
               dtype) -> jax.Array:
        """The dense decode of one client's wire. ``local_c`` is DEFINED as
        this decode (not an independent recomputation of C(δ)): client state
        and the server aggregate must agree on exactly what was shipped, or
        error feedback would never re-send mass lost to ties/quantization."""
        raise NotImplementedError

    def local_c(self, comp: comp_lib.Compressor, delta: jax.Array,
                wire: Wire) -> jax.Array:
        """The dense C(delta) the client keeps locally for its gᵢ update —
        never transmitted. Returns flat (d,)."""
        return self.decode(comp, wire, d=delta.size, dtype=delta.dtype)

    def decode_add(self, comp: comp_lib.Compressor, wire: Wire,
                   base: jax.Array, *, d: int, dtype) -> jax.Array:
        """``base + decode(wire)`` as one logical launch — the downlink's
        h-integration hook (``downlink_round_integrate``). The default IS
        that expression, so overriding carriers (quantized wires run the
        fused dequantize+add Pallas kernel on TPU) stay bit-compatible
        within float-compilation tolerance. ``base``: flat (d,)."""
        return base + self.decode(comp, wire, d=d, dtype=dtype)

    def aggregate(self, comp: comp_lib.Compressor, wire: Wire, *, d: int,
                  dtype, dp: Optional[int] = None,
                  axes: Optional[Tuple[str, ...]] = None) -> jax.Array:
        """meanᵢ(cᵢ) from the wire. Exactly one of ``dp`` (leading-axis vmap
        layout) / ``axes`` (named shard_map axes) must be given. Returns flat
        (d,)."""
        raise NotImplementedError

    # -- accounting ----------------------------------------------------------
    def wire_words(self, comp: comp_lib.Compressor, d: int) -> float:
        """Words one client puts on the wire per message of dimension d."""
        raise NotImplementedError

    # -- fusion hook ---------------------------------------------------------
    def fused_update(self, method, grads: PyTree, state: dict, *,
                     eta=None, batched: bool = False):
        raise NotImplementedError(f"carrier {self.name!r} does not fuse")


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseCarrier(Carrier):
    """Paper-faithful wire: the dense tensor C(delta) itself; the mean is a
    d-word all-reduce over the client axes (what the paper's own simulations
    do — no wire savings; the §Perf baseline)."""

    name: str = "dense"

    def plan_with_reason(self, method, eta=None):
        return "dense", ""          # dense IS this carrier's native wire

    def plan_down_with_reason(self, comp):
        return "dense", ""          # ...in both directions

    def encode(self, comp, delta, rng=None):
        return comp(delta, rng)

    def decode(self, comp, wire, *, d, dtype):
        return wire

    def aggregate(self, comp, wire, *, d, dtype, dp=None, axes=None):
        if axes is not None:
            return jax.lax.pmean(wire, axes)
        return wire.mean(0)

    def wire_words(self, comp, d):
        return float(d)


# ---------------------------------------------------------------------------
# sparse block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparseBlockCarrier(Carrier):
    """Fixed-size (values, block-local indices) wire for the TopK family.

    Collective bytes drop from d to 2·nb·kb per client: the mean is an
    all-gather of the small arrays followed by a local scatter-ADD (index
    collisions across clients must SUM — ``.at[].add``). ``local_c`` is the
    exact decode of the wire, so client state and server aggregate always
    agree on what was transmitted (see local_c)."""

    name: str = "sparse"

    def plan_with_reason(self, method, eta=None):
        if not method.wire_is_msg:
            return "dense", (
                f"method {method.name!r} transmits a transform of c "
                "(wire_is_msg=False); a non-dense wire cannot ship it")
        if not self.supports(method.compressor):
            return "dense", (
                f"compressor {type(method.compressor).__name__} has no "
                "deterministic fixed-size (values, indices) wire")
        return "wire", ""

    def plan_down_with_reason(self, comp):
        # no wire_is_msg question on the downlink: the broadcast IS the
        # compressed innovation, so only the compressor gates the wire
        if not self.supports(comp):
            return "dense", (
                f"compressor {type(comp).__name__} has no deterministic "
                "fixed-size (values, indices) wire")
        return "wire", ""

    def supports(self, comp) -> bool:
        # has_sparse_carrier is the compressor's opt-in; the isinstance check
        # narrows to the families whose fixed-size geometry _geom understands
        # (RandK opts in but needs rng-dependent indices — not expressible as
        # a deterministic block wire, so it degrades to dense)
        return (comp.has_sparse_carrier
                and isinstance(comp, (comp_lib.TopK, comp_lib.BlockTopK)))

    def _geom(self, comp, d: int) -> Tuple[int, int, int]:
        return sparse_geom(comp, d)

    def encode(self, comp, delta, rng=None):
        vals, idx = sparse_select(comp, delta)           # (nb, kb), sorted
        return vals, idx.astype(jnp.int32)               # block-LOCAL indices

    def decode(self, comp, wire, *, d, dtype):
        # exact decode of the wire (scatter of the shipped values), NOT a
        # threshold mask: the client's gᵢ update must see precisely what the
        # server aggregated, or a tie at the kb-th rank would leave mass the
        # client believes transmitted but the server never received — error
        # feedback would then never re-send it
        vals, idx = wire
        nb, block, _ = self._geom(comp, d)
        return scatter_blocks(vals, idx, nb=nb, block=block, d=d,
                              dtype=dtype)

    def aggregate(self, comp, wire, *, d, dtype, dp=None, axes=None):
        vals, idx = wire
        nb, block, kb = self._geom(comp, d)
        if axes is not None:
            n = 1
            for a in axes:                               # explicit wire
                n = n * axis_size(a)
                vals, idx = self._gather((vals, idx), a)
            vals = vals.reshape(-1, nb, kb)
            idx = idx.reshape(-1, nb, kb)
        else:
            n = dp                                       # (dp, nb, kb) layout
        rows = jnp.broadcast_to(
            jnp.arange(nb, dtype=jnp.int32)[None, :, None], idx.shape)
        buf = jnp.zeros((nb, block), dtype).at[rows, idx].add(vals) / n
        return buf.reshape(-1)[:d]

    def wire_words(self, comp, d):
        nb, _, kb = self._geom(comp, d)
        return 2.0 * nb * kb                             # values + int32 idx


# ---------------------------------------------------------------------------
# fused Pallas
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedPallasCarrier(DenseCarrier):
    """Dense wire + the whole EF21-SGD(M) client update in one HBM pass.

    ``fused_update`` replaces pre_compress → C(·) → post_compress for
    EF21SGDM / EF21SGD with a BlockTopK compressor by a single call into
    ``kernels/ef_update.py::ef21_sgdm_update`` per leaf (EF21SGD is the η = 1
    special case: v' = grad). The kernel needs a *static* momentum, so the
    plan degrades to 'dense' whenever η is traced (time-varying schedules).

    ``interpret=None`` auto-selects the Pallas interpreter off-TPU so the
    carrier runs (slowly but correctly) in CPU containers and under tests.
    """

    name: str = "fused"
    interpret: Optional[bool] = None

    _LANES = 128                     # TPU vector lane width (f32)

    def _interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    @classmethod
    def _kernel_geom(cls, comp, d: int) -> Tuple[int, int, int]:
        """(nb, launch_block, kb): the d-aware selection geometry
        (``BlockTopK.geom``) with a SINGLE-block leaf's launch block rounded
        up to whole TPU lanes, so the sub-block leaves this geometry
        introduced never hand Mosaic an unaligned tile — padding zeros in
        the row cannot change the top-kb selection. Multi-block leaves keep
        the geometry block untouched (rounding it would shift the row
        boundaries off the block boundaries); their alignment is whatever
        the compressor's own block is, as it always was."""
        nb, block, kb = comp.geom(d)
        if nb == 1:
            block = -(-block // cls._LANES) * cls._LANES
        return nb, block, kb

    def plan_with_reason(self, method, eta=None):
        if method.name not in ("ef21_sgdm", "ef21_sgd"):
            return "dense", (
                f"the fused kernel implements the EF21-SGD(M) client chain "
                f"only, not {method.name!r}")
        if not isinstance(method.compressor, comp_lib.BlockTopK):
            return "dense", (
                f"the fused kernel compresses with BlockTopK only, not "
                f"{type(method.compressor).__name__}")
        if not (eta is None or isinstance(eta, (int, float))):
            return "dense", ("momentum η is traced (time-varying schedule); "
                             "the kernel needs a static η to bake in")
        return "fused", ""

    def plan_down_with_reason(self, comp):
        return "dense", (
            "the fused kernel fuses the UPLINK client update; the downlink "
            "broadcast has no fused path — use dense, sparse or quant")

    def fused_update(self, method, grads, state, *, eta=None,
                     batched: bool = False):
        """One fused HBM pass per leaf. ``grads``/``state`` leaves are either
        client-local (shard_map runtime, ``batched=False``) or carry a leading
        client axis (vmap runtimes, ``batched=True`` — clients become extra
        tile rows, so no vmap-of-pallas_call is ever emitted).
        Returns (c_tree, new_state)."""
        from repro.kernels import ef_update as ef_kernel

        comp = method.compressor
        if method.name == "ef21_sgd":
            eta_f = 1.0                                  # v' = grad exactly
            v_tree = state["g"]
        else:
            eta_f = float(eta) if eta is not None else float(method.eta)
            v_tree = state["v"]
        interp = self._interpret()

        g_leaves, treedef = jax.tree_util.tree_flatten(state["g"])
        v_leaves = jax.tree_util.tree_leaves(v_tree)
        grad_leaves = jax.tree_util.tree_leaves(grads)

        v_out, g_out, c_out = [], [], []
        for grad, v, g in zip(grad_leaves, v_leaves, g_leaves):
            # d-aware geometry per leaf (BlockTopK.geom): the kernel selects
            # the same kb the dense reference selection uses, so sub-block
            # leaves stay consistent across carriers. The LAUNCH block is
            # rounded up to whole TPU lanes (zeros pad the row — exactly the
            # trailing-partial-block case the kernel always handled: padding
            # never outranks a real value, and a 0 threshold keeps
            # everything, so the selection over the padded row equals the
            # selection over the geometry block).
            if batched:
                # pad each client's leaf to whole blocks FIRST so client
                # boundaries and block boundaries coincide in the flat view
                dp = grad.shape[0]
                d = grad[0].size
                nb, block, kb = self._kernel_geom(comp, d)
                pad = nb * block - d

                def prep(x):
                    return jnp.pad(x.reshape(dp, d), ((0, 0), (0, pad)))

                v2, g2, c = ef_kernel.ef21_sgdm_update(
                    prep(grad), prep(v), prep(g), eta=eta_f, block=block,
                    k=kb, interpret=interp)
                unprep = lambda x: x[:, :d].reshape(grad.shape)  # noqa: E731
                v2, g2, c = unprep(v2), unprep(g2), unprep(c)
            else:
                _, block, kb = self._kernel_geom(comp, grad.size)
                v2, g2, c = ef_kernel.ef21_sgdm_update(
                    grad, v, g, eta=eta_f, block=block, k=kb,
                    interpret=interp)
            v_out.append(v2)
            g_out.append(g2)
            c_out.append(c)

        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)  # noqa: E731
        c_tree = unf(c_out)
        g_new = method._cast(unf(g_out))
        if method.name == "ef21_sgd":
            new_state = {"g": g_new}
        else:
            new_state = {"v": method._cast(unf(v_out)), "g": g_new}
        return c_tree, new_state


# ---------------------------------------------------------------------------
# quantized wires
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantCarrier(Carrier):
    """Block-quantized wire: per-block absmax scale (1 f32 word) + ``bits``-bit
    mantissas (int8, or two uint4 packed per byte), in one of two payloads:

      sparse-block  for the TopK family (the sparse carrier's geometry): the
                    kb selected values of each block are quantized against one
                    shared scale and travel with their block-local indices
                    (int16 when the block fits, else int32) —
                    nb·(1 + kb·(bits/32 + idx_words)) words/client.
      dense         for every other deterministic compressor: C(δ) itself is
                    quantized in ``qblock``-sized blocks —
                    nbq·(1 + qblock·bits/32) words/client.

    ``local_c`` is the decode of the wire (base-class invariant), so EF21's
    residual absorbs the quantization error and re-sends it in later rounds —
    the same contraction argument that lets Fatkhullin et al. treat C as a
    black box covers the extra bounded wire distortion (``composed_err_factor``
    gives the predicted Definition-1 constant of decode∘quantize∘C).

    Aggregation ALWAYS dequantizes before the collective arithmetic: int8
    mantissas under different per-block scales do not form an associative
    monoid, so an int8 all-reduce would be wrong. On the shard_map runtime the
    sparse payload all-gathers the still-quantized wire (the savings are on
    the links) and dequantizes locally; the dense payload dequantizes locally
    and psums f32 (its collective operand is dense — the wire savings of the
    dense payload are client→server bytes, not all-reduce bytes).

    The unbatched (shard_map) encode runs the Pallas codec
    (kernels/quantize.py, interpreter off-TPU); the vmap runtimes run the
    bit-compatible pure-jnp oracle (kernels/ref.py) so no vmap-of-pallas_call
    is ever emitted.
    """

    name: str = "quant8"
    bits: int = 8
    qblock: int = 256          # dense-payload quantization block (even)

    # -- plan ---------------------------------------------------------------
    def plan_with_reason(self, method, eta=None):
        if not method.wire_is_msg:
            return "dense", (
                f"method {method.name!r} transmits a transform of c "
                "(wire_is_msg=False); a non-dense wire cannot ship it")
        if method.compressor.needs_rng:
            return "dense", (
                f"compressor {type(method.compressor).__name__} draws "
                "randomness inside encode; the quantized wire ships "
                "deterministic compressors only")
        return "wire", ""

    def plan_down_with_reason(self, comp):
        if comp.needs_rng:
            return "dense", (
                f"compressor {type(comp).__name__} draws randomness inside "
                "encode; the quantized wire ships deterministic compressors "
                "only")
        return "wire", ""

    def _sparse_ok(self, comp) -> bool:
        return (comp.has_sparse_carrier
                and isinstance(comp, (comp_lib.TopK, comp_lib.BlockTopK)))

    @staticmethod
    def _idx_dtype(block: int):
        # block-LOCAL indices: int16 halves the index words whenever the
        # block fits (the common case); the single-block TopK wire on a large
        # leaf falls back to int32
        return jnp.int16 if block <= 2 ** 15 - 1 else jnp.int32

    # -- wire ---------------------------------------------------------------
    def encode(self, comp, delta, rng=None):
        from repro.kernels import ref as kref
        if self._sparse_ok(comp):
            _, block, _ = sparse_geom(comp, delta.size)
            vals, idx = sparse_select(comp, delta)
            q, scales = kref.block_quantize_ref(vals, self.bits)
            return q, scales, idx.astype(self._idx_dtype(block))
        c = comp(delta, rng).astype(jnp.float32)
        nbq = -(-delta.size // self.qblock)
        cb = jnp.pad(c, (0, nbq * self.qblock - c.size)).reshape(nbq, self.qblock)
        q, scales = kref.block_quantize_ref(cb, self.bits)
        return q, scales

    def encode_local(self, comp, delta, rng=None):
        # client-local (shard_map) context: the Pallas codec quantizes the
        # dense payload in one kernel pass (interpreter off-TPU); the sparse
        # payload quantizes (nb, kb) value rows — lane-unfriendly tiles, so it
        # stays on the jnp oracle everywhere
        if self._sparse_ok(comp):
            return self.encode(comp, delta, rng)
        from repro.kernels import quantize as qz
        c = comp(delta, rng).astype(jnp.float32)
        interpret = jax.default_backend() != "tpu"
        return qz.block_quantize(c, block=self.qblock, bits=self.bits,
                                 interpret=interpret)

    def decode(self, comp, wire, *, d, dtype):
        # payload dispatch on the same predicate encode used — never on the
        # wire's shape, so a future layout change fails loudly instead of
        # being sniffed into the wrong branch
        from repro.kernels import ref as kref
        if self._sparse_ok(comp):                        # sparse payload
            q, scales, idx = wire
            nb, block, kb = sparse_geom(comp, d)
            vals = kref.block_dequantize_ref(q, scales, bits=self.bits,
                                             cols=kb)
            return scatter_blocks(vals, idx, nb=nb, block=block, d=d,
                                  dtype=jnp.float32).astype(dtype)
        q, scales = wire                                 # dense payload
        vals = kref.block_dequantize_ref(q, scales, bits=self.bits,
                                         cols=self.qblock)
        return vals.reshape(-1)[:d].astype(dtype)

    def decode_add(self, comp, wire, base, *, d, dtype):
        # dense payload on TPU: dequantize + integrate in ONE Pallas launch
        # (kernels/fused_round.py::dequant_add). Off-TPU the default jnp
        # expression already compiles to one fused XLA computation, and the
        # sparse payload's scatter decode has no tiled kernel — both take
        # the base-class path. Same math either way, so the h-integration
        # stays within float-compilation tolerance across backends.
        if self._sparse_ok(comp) or jax.default_backend() != "tpu":
            return super().decode_add(comp, wire, base, d=d, dtype=dtype)
        from repro.kernels import fused_round as fr
        q, scales = wire
        out = fr.dequant_add(q, scales, base.astype(jnp.float32), d=d,
                             block=self.qblock, bits=self.bits,
                             interpret=False)
        return out.astype(dtype)

    def aggregate(self, comp, wire, *, d, dtype, dp=None, axes=None):
        from repro.kernels import ref as kref
        if self._sparse_ok(comp):                        # sparse payload
            q, scales, idx = wire
            nb, block, kb = sparse_geom(comp, d)
            if axes is not None and len(axes) == 1:
                # gather the QUANTIZED wire (the savings live on the links)
                # and decode each client's chunk as it arrives — under
                # ``overlap`` the ring keeps the next permute in flight while
                # this chunk dequantizes; the blocking path applies the same
                # per-chunk decode to the gathered stack (same bits)
                n = axis_size(axes[0])
                vals, idx = self._gather(
                    (q, scales, idx), axes[0],
                    fn=lambda w: (kref.block_dequantize_ref(
                        w[0], w[1], bits=self.bits, cols=kb), w[2]))
                vals = vals.reshape(-1, nb, kb)
                idx = idx.reshape(-1, nb, kb)
            else:
                if axes is not None:
                    n = 1
                    for a in axes:
                        n = n * axis_size(a)
                        q, scales, idx = self._gather((q, scales, idx), a)
                    q = q.reshape(-1, nb, q.shape[-1])
                    scales = scales.reshape(-1, nb)
                    idx = idx.reshape(-1, nb, kb)
                else:
                    n = dp                               # (dp, nb, ·) layout
                vals = kref.block_dequantize_ref(
                    q.reshape(-1, q.shape[-1]), scales.reshape(-1),
                    bits=self.bits, cols=kb).reshape(-1, nb, kb)
            rows = jnp.broadcast_to(
                jnp.arange(nb, dtype=jnp.int32)[None, :, None], idx.shape)
            buf = jnp.zeros((nb, block), jnp.float32)
            buf = buf.at[rows, idx.astype(jnp.int32)].add(vals) / n
            return buf.reshape(-1)[:d].astype(dtype)
        if axes is not None:                             # dense payload:
            deq = self.decode(comp, wire, d=d, dtype=jnp.float32)
            return jax.lax.pmean(deq, axes).astype(dtype)  # dequant THEN psum
        q, scales = wire                                 # (dp, nbq, ·) layout
        dp_, nbq = scales.shape
        vals = kref.block_dequantize_ref(
            q.reshape(dp_ * nbq, q.shape[-1]), scales.reshape(-1),
            bits=self.bits, cols=self.qblock)
        return vals.reshape(dp_, -1)[:, :d].mean(0).astype(dtype)

    # -- accounting ---------------------------------------------------------
    def wire_words(self, comp, d):
        frac = self.bits / 32.0                          # 4-bit = 1/8 word
        if self._sparse_ok(comp):
            nb, block, kb = sparse_geom(comp, d)
            idx_words = 0.5 if block <= 2 ** 15 - 1 else 1.0
            return nb * (1.0 + kb * (frac + idx_words))
        nbq = -(-d // self.qblock)
        return nbq * (1.0 + self.qblock * frac)

    def quant_eps(self, comp, d: int) -> float:
        """Relative per-message quantization error bound: with B elements per
        scale, ‖Q(x) − x‖² ≤ Σ_b B·(absmax_b/2qmax)² ≤ B/(4·qmax²)·‖x‖²."""
        qmax = 2 ** (self.bits - 1) - 1
        if self._sparse_ok(comp):
            _, _, kb = sparse_geom(comp, d)
            per_scale = kb
        else:
            per_scale = min(self.qblock, d)
        return per_scale / (4.0 * qmax * qmax)

    def composed_err_factor(self, comp, d: int) -> float:
        """Definition-1 constant of the composed compressor decode∘Q∘C:
        ‖QC(x) − x‖ ≤ ‖QC(x) − C(x)‖ + ‖C(x) − x‖ ≤ (√ε + √(1−α))·‖x‖
        (C is a norm-contraction, so ‖C(x)‖ ≤ ‖x‖). Returns (√(1−α) + √ε)²."""
        root = ((1.0 - comp.alpha(d)) ** 0.5
                + self.quant_eps(comp, d) ** 0.5)
        return root * root

    def composed_alpha(self, comp, d: int) -> float:
        """Predicted α of the composed compressor (0 when the bound is
        vacuous — the wire still works, EF just loses the rate guarantee)."""
        return max(0.0, 1.0 - self.composed_err_factor(comp, d))


# ---------------------------------------------------------------------------
# fused quantized wires (the one-launch mega-kernel carriers)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedQuantCarrier(QuantCarrier):
    """Quantized wire + the ENTIRE uplink client round in one kernel launch.

    Where ``fused`` fuses the EF21-SGD(M) update but still ships a dense f32
    wire, and ``quant8``/``quant4`` quantize the wire but launch the update,
    selection, and codec as separate kernels, this carrier runs
    ``kernels/fused_round.py::ef21_sgdm_topk_quant`` — momentum update,
    Block-TopK selection, absmax quantization, AND the EF-invariant
    g' = g + decode(wire) integration — as ONE HBM pass (plan
    ``'fused_wire'``), and the quantized payload is what travels.

    Payload: the BLOCK-DENSE quantized form of the k-sparse innovation at the
    selection geometry — q (nb, block·bits/8 bytes) + one f32 scale per
    selection block. The quantization row IS the selection block, so the
    masked row's absmax equals the selected values' absmax and masked-out
    zeros get mantissa 0 exactly: this payload decodes bit-identically to the
    (vals, idx) sparse payload, without the TPU-hostile in-kernel compaction
    a sparse payload would need. The honest cost is on the links:
    nb·(1 + block·bits/32) words/client — bits/32 of the dense/fused
    carriers' d words, but MORE than quant8/quant4's kb-sized sparse payload.
    Pick this carrier when the round is launch/HBM-bound (the mega-kernel is
    the win); pick plain quant8/quant4 when the links are the bottleneck.

    For methods/compressors the mega-kernel does not cover, the plan degrades
    to the ordinary unfused ``'wire'`` (same payload, oracle codec) — still
    correct, just three launches — or to ``'dense'`` under the base
    QuantCarrier's own degradations. Launch surfaces treat a degraded
    fused_quant like a degraded ``fused``: a hard misconfiguration error.

    Aggregation dequantizes locally and pmeans f32 (the dense-payload rule:
    mantissas under different scales are not associative), so ``overlap`` is
    a no-op here — there is no per-client gather to pipeline.
    """

    name: str = "fused_quant8"
    interpret: Optional[bool] = None

    def _interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def _fused_geom(self, comp, d: int) -> Tuple[int, int, int]:
        # the mega-kernel's launch geometry: selection geometry with
        # single-block leaves lane-rounded (FusedPallasCarrier._kernel_geom).
        # A degraded plan still routes the wire codec here with a plain
        # (global) TopK compressor — one block spanning the leaf, same lane
        # rounding, no ``BlockTopK.geom`` to call
        if isinstance(comp, comp_lib.BlockTopK):
            return FusedPallasCarrier._kernel_geom(comp, d)
        nb, block, kb = sparse_geom(comp, d)
        if nb == 1:
            lanes = FusedPallasCarrier._LANES
            block = -(-block // lanes) * lanes
        return nb, block, kb

    # -- plan ---------------------------------------------------------------
    def plan_with_reason(self, method, eta=None):
        plan, reason = super().plan_with_reason(method, eta)
        if plan != "wire":
            return plan, reason                          # dense degradation
        if method.name not in ("ef21_sgdm", "ef21_sgd"):
            return "wire", (
                f"the fused wire kernel implements the EF21-SGD(M) client "
                f"chain only, not {method.name!r}; running the unfused "
                "quantized wire")
        if not isinstance(method.compressor, comp_lib.BlockTopK):
            return "wire", (
                f"the fused wire kernel compresses with BlockTopK only, not "
                f"{type(method.compressor).__name__}; running the unfused "
                "quantized wire")
        if not (eta is None or isinstance(eta, (int, float))):
            return "wire", (
                "momentum η is traced (time-varying schedule); the kernel "
                "needs a static η to bake in — running the unfused "
                "quantized wire")
        if self.bits == 4 and method.compressor.block % 2:
            # multi-block leaves launch at the compressor's own block width;
            # single-block leaves lane-round to 128 and can never be odd
            return "wire", (
                "uint4 packing needs an even BlockTopK block; running the "
                "unfused quantized wire")
        return "fused_wire", ""

    # -- wire (block-dense payload at the selection geometry) ----------------
    def encode(self, comp, delta, rng=None):
        from repro.kernels import ref as kref
        if not self._sparse_ok(comp):
            return super().encode(comp, delta, rng)
        nb, block, _ = self._fused_geom(comp, delta.size)
        c = comp(delta, rng).astype(jnp.float32)         # threshold-mask C(δ)
        cb = jnp.pad(c, (0, nb * block - c.size)).reshape(nb, block)
        return kref.block_quantize_ref(cb, self.bits)

    def encode_local(self, comp, delta, rng=None):
        if not self._sparse_ok(comp):
            return super().encode_local(comp, delta, rng)
        return self.encode(comp, delta, rng)

    def decode(self, comp, wire, *, d, dtype):
        from repro.kernels import ref as kref
        if not self._sparse_ok(comp):
            return super().decode(comp, wire, d=d, dtype=dtype)
        q, scales = wire
        _, block, _ = self._fused_geom(comp, d)
        vals = kref.block_dequantize_ref(q, scales, bits=self.bits,
                                         cols=block)
        return vals.reshape(-1)[:d].astype(dtype)

    def decode_add(self, comp, wire, base, *, d, dtype):
        # the block-dense payload at the fused launch geometry runs the same
        # one-launch dequantize+add kernel as the dense quant payload; an
        # explicit ``interpret`` field (tests) or a real TPU selects the
        # kernel, otherwise the default jnp expression (one fused XLA
        # computation off-TPU) — bit-compatible within float-compilation
        # tolerance either way
        use_kernel = (self.interpret is not None
                      or jax.default_backend() == "tpu")
        if not self._sparse_ok(comp) or not use_kernel:
            return super().decode_add(comp, wire, base, d=d, dtype=dtype)
        from repro.kernels import fused_round as fr
        q, scales = wire
        _, block, _ = self._fused_geom(comp, d)
        out = fr.dequant_add(q, scales, base.astype(jnp.float32), d=d,
                             block=block, bits=self.bits,
                             interpret=self._interpret())
        return out.astype(dtype)

    def aggregate(self, comp, wire, *, d, dtype, dp=None, axes=None):
        from repro.kernels import ref as kref
        if not self._sparse_ok(comp):
            return super().aggregate(comp, wire, d=d, dtype=dtype, dp=dp,
                                     axes=axes)
        if axes is not None:                             # dense-payload rule:
            deq = self.decode(comp, wire, d=d, dtype=jnp.float32)
            return jax.lax.pmean(deq, axes).astype(dtype)  # dequant THEN psum
        q, scales = wire                                 # (dp, nb, ·) layout
        _, block, _ = self._fused_geom(comp, d)
        dp_, nb = scales.shape
        vals = kref.block_dequantize_ref(
            q.reshape(dp_ * nb, q.shape[-1]), scales.reshape(-1),
            bits=self.bits, cols=block)
        return vals.reshape(dp_, -1)[:, :d].mean(0).astype(dtype)

    # -- accounting ---------------------------------------------------------
    def wire_words(self, comp, d):
        if not self._sparse_ok(comp):
            return super().wire_words(comp, d)
        nb, block, _ = self._fused_geom(comp, d)
        return nb * (1.0 + block * self.bits / 32.0)

    def quant_eps(self, comp, d: int) -> float:
        # one scale per SELECTION block (not per kb values): the absmax is
        # still the selected values' absmax, and only the ≤ block selected
        # slots carry error mass, but the bound must count the slots a scale
        # covers — use the selection block for an honest constant
        if not self._sparse_ok(comp):
            return super().quant_eps(comp, d)
        qmax = 2 ** (self.bits - 1) - 1
        _, _, kb = sparse_geom(comp, d)
        return kb / (4.0 * qmax * qmax)

    # -- the one-launch round ------------------------------------------------
    def fused_wire_round(self, method, grads: PyTree, state: dict, *,
                         eta=None, batched: bool = False,
                         axes: Optional[Tuple[str, ...]] = None,
                         dp: Optional[int] = None):
        """The 'fused_wire' plan: one mega-kernel launch per leaf produces
        (v', g', wire) with g' = g + decode(wire) integrated in-kernel, then
        the wire aggregates under the dense-payload rule. ``grads``/``state``
        leaves are client-local (shard_map, ``batched=False``) or carry a
        leading client axis (vmap runtimes, ``batched=True`` — clients become
        extra tile rows; no vmap-of-pallas_call is ever emitted).
        Returns (msg_mean_tree, new_state)."""
        from repro.kernels import fused_round as fr
        from repro.kernels import ref as kref

        comp = method.compressor
        if method.name == "ef21_sgd":
            eta_f = 1.0                                  # v' = grad exactly
            v_tree = state["g"]
        else:
            eta_f = float(eta) if eta is not None else float(method.eta)
            v_tree = state["v"]
        interp = self._interpret()

        g_leaves, treedef = jax.tree_util.tree_flatten(state["g"])
        v_leaves = jax.tree_util.tree_leaves(v_tree)
        grad_leaves = jax.tree_util.tree_leaves(grads)

        v_out, g_out, msg_out = [], [], []
        for grad, v, g in zip(grad_leaves, v_leaves, g_leaves):
            if batched:
                # pad each client's leaf to whole launch blocks FIRST so
                # client boundaries and tile-row boundaries coincide
                dpn = grad.shape[0]
                d = grad[0].size
                nb, block, kb = self._fused_geom(comp, d)
                pad = nb * block - d

                def prep(x):
                    return jnp.pad(x.reshape(dpn, d), ((0, 0), (0, pad)))

                v2, g2, q, scales = fr.ef21_sgdm_topk_quant(
                    prep(grad), prep(v), prep(g), eta=eta_f, block=block,
                    k=kb, bits=self.bits, interpret=interp)
                v2 = v2[:, :d].reshape(grad.shape)
                g2 = g2[:, :d].reshape(grad.shape)
                vals = kref.block_dequantize_ref(q, scales, bits=self.bits,
                                                 cols=block)
                msg = (vals.reshape(dpn, -1)[:, :d].mean(0)
                       .reshape(grad.shape[1:]).astype(grad.dtype))
            else:
                d = grad.size
                nb, block, kb = self._fused_geom(comp, d)
                v2, g2, q, scales = fr.ef21_sgdm_topk_quant(
                    grad, v, g, eta=eta_f, block=block, k=kb,
                    bits=self.bits, interpret=interp)
                vals = kref.block_dequantize_ref(q, scales, bits=self.bits,
                                                 cols=block)
                dec = vals.reshape(-1)[:d].astype(jnp.float32)
                msg = (jax.lax.pmean(dec, axes)      # dense-payload rule:
                       .reshape(grad.shape)          # dequant THEN psum
                       .astype(grad.dtype))
            v_out.append(v2)
            g_out.append(g2)
            msg_out.append(msg)

        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)  # noqa: E731
        msg_mean = unf(msg_out)
        g_new = method._cast(unf(g_out))
        if method.name == "ef21_sgd":
            new_state = {"g": g_new}
        else:
            new_state = {"v": method._cast(unf(v_out)), "g": g_new}
        return msg_mean, new_state


# ---------------------------------------------------------------------------
# shared per-leaf dispatch for the 'wire' plan (used by every runtime)
# ---------------------------------------------------------------------------

def wire_round_batched(carrier: Carrier, comp, deltas: PyTree, dp: int
                       ) -> Tuple[PyTree, PyTree]:
    """encode → local_c → aggregate per leaf, clients on a leading axis (vmap
    runtimes). Returns (c_tree, msg_mean_tree)."""
    dleaves, dtree = jax.tree_util.tree_flatten(deltas)
    c_leaves, agg_leaves = [], []
    for leaf in dleaves:
        d = int(leaf[0].size)
        flat = leaf.reshape(dp, d)
        wire = jax.vmap(lambda x: carrier.encode(comp, x))(flat)
        c_loc = jax.vmap(lambda x, w: carrier.local_c(comp, x, w))(flat, wire)
        agg = carrier.aggregate(comp, wire, d=d, dtype=leaf.dtype, dp=dp)
        c_leaves.append(c_loc.reshape(leaf.shape))
        agg_leaves.append(agg.reshape(leaf.shape[1:]))
    return (jax.tree_util.tree_unflatten(dtree, c_leaves),
            jax.tree_util.tree_unflatten(dtree, agg_leaves))


def wire_round_local(carrier: Carrier, comp, deltas: PyTree,
                     axes: Tuple[str, ...], rng=None) -> Tuple[PyTree, PyTree]:
    """encode → local_c → aggregate per leaf, client-local inside shard_map
    (explicit named-axis collectives). Returns (c_tree, msg_mean_tree)."""
    dleaves, dtree = jax.tree_util.tree_flatten(deltas)
    c_leaves, agg_leaves = [], []
    for leaf in dleaves:
        flat = leaf.reshape(-1)
        wire = carrier.encode_local(comp, flat, rng)
        c_leaves.append(carrier.local_c(comp, flat, wire).reshape(leaf.shape))
        agg_leaves.append(carrier.aggregate(
            comp, wire, d=leaf.size, dtype=leaf.dtype, axes=axes)
            .reshape(leaf.shape))
    return (jax.tree_util.tree_unflatten(dtree, c_leaves),
            jax.tree_util.tree_unflatten(dtree, agg_leaves))


# ---------------------------------------------------------------------------
# downlink (server → client broadcast) — shared by every runtime
# ---------------------------------------------------------------------------

def downlink_round(carrier: Carrier, comp, delta: PyTree,
                   rng: Optional[jax.Array] = None) -> PyTree:
    """One downlink broadcast leg, per leaf: the server encodes C(delta) into
    the carrier's wire and every client returns the decode — which is also
    exactly what the server adds to its own broadcast memory h, so server and
    clients provably hold identical reconstructions (the decode IS the wire;
    there is nothing client-specific to diverge on). Aggregation is a no-op:
    one server, one message, nothing to mean over. On the degraded 'dense'
    plan the broadcast ships the dense C(delta) tensor itself.

    The pure-jnp ``encode`` runs on every runtime (never ``encode_local``):
    the broadcast is one unbatched message, and keeping all three runtimes on
    one code path is what makes the round-trip state-sync tests bit-exact
    across them."""
    plan = carrier.plan_down(comp)
    leaves, treedef = jax.tree_util.tree_flatten(delta)
    out = []
    for i, leaf in enumerate(leaves):
        flat = leaf.reshape(-1)
        r = None if rng is None else jax.random.fold_in(rng, i)
        if plan == "wire":
            wire = carrier.encode(comp, flat, r)
            dec = carrier.decode(comp, wire, d=flat.size, dtype=flat.dtype)
        else:
            dec = comp(flat, r).astype(flat.dtype)
        out.append(dec.reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def downlink_encode(carrier: Carrier, comp, delta: PyTree,
                    rng: Optional[jax.Array] = None) -> List:
    """The per-leaf WIRES of one downlink broadcast — the exact payload the
    server puts on the wire (and what core/stream.py persists for serving
    replicas). Per leaf ``i`` the rng is ``fold_in(rng, i)``; on the 'wire'
    plan the payload is ``carrier.encode(C(delta))``, on the degraded 'dense'
    plan it is the dense C(delta) tensor itself. This is the single encode
    path: ``downlink_round_integrate`` (the in-step trainer leg) and the
    stream publisher both call it, so a published record is the same bits the
    trainer integrated."""
    plan = carrier.plan_down(comp)
    leaves = jax.tree_util.tree_leaves(delta)
    wires = []
    for i, leaf in enumerate(leaves):
        flat = leaf.reshape(-1)
        r = None if rng is None else jax.random.fold_in(rng, i)
        if plan == "wire":
            wires.append(carrier.encode(comp, flat, r))
        else:
            wires.append(comp(flat, r).astype(flat.dtype))
    return wires


def downlink_apply(carrier: Carrier, comp, wires: List, h: PyTree) -> PyTree:
    """h' = h + decode(wire), per leaf — the integration EVERY subscriber of
    the broadcast runs: the trainer inside its jitted step, and serving
    replicas between request batches (core/stream.py). Dispatched through
    ``Carrier.decode_add`` so quantized wires can run the one-launch
    dequantize+add Pallas kernel on TPU; the default decode_add IS
    ``h + decode(wire)``, so all consumers agree bit-exactly off-TPU."""
    plan = carrier.plan_down(comp)
    h_leaves, treedef = jax.tree_util.tree_flatten(h)
    out = []
    for wire, hl in zip(wires, h_leaves):
        flat_h = hl.reshape(-1)
        if plan == "wire":
            new = carrier.decode_add(comp, wire, flat_h,
                                     d=flat_h.size, dtype=hl.dtype)
        else:
            new = flat_h + wire.astype(hl.dtype)
        out.append(new.reshape(hl.shape).astype(hl.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def downlink_round_integrate(carrier: Carrier, comp, delta: PyTree,
                             h: PyTree, rng: Optional[jax.Array] = None
                             ) -> PyTree:
    """One downlink broadcast leg WITH the h-integration fused in:
    ``downlink_apply(downlink_encode(delta))`` — encode and integration live
    in those two helpers so the stream publisher/replicas (core/stream.py)
    run literally the same code as this in-step leg. Bit-compatible with
    ``tree_add(h, downlink_round(...))`` (decode_add defaults to that
    expression; the TPU kernel path stays within float-compilation
    tolerance). Same encode/rng discipline as ``downlink_round`` — the wire
    that travels is identical."""
    wires = downlink_encode(carrier, comp, delta, rng)
    return downlink_apply(carrier, comp, wires, h)


def downlink_words(carrier: Carrier, comp, d: int) -> float:
    """Words the server puts on the wire per broadcast message of dimension
    d — the downlink twin of ``Carrier.wire_words`` (the degraded dense plan
    ships the dense d-word tensor)."""
    if carrier.plan_down(comp) == "wire":
        return carrier.wire_words(comp, d)
    return float(d)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _quant8() -> "QuantCarrier":
    return QuantCarrier(name="quant8", bits=8)


def _quant4() -> "QuantCarrier":
    return QuantCarrier(name="quant4", bits=4)


def _fused_quant8() -> "FusedQuantCarrier":
    return FusedQuantCarrier(name="fused_quant8", bits=8)


def _fused_quant4() -> "FusedQuantCarrier":
    return FusedQuantCarrier(name="fused_quant4", bits=4)


REGISTRY = {
    "dense": DenseCarrier,
    "sparse": SparseBlockCarrier,
    "fused": FusedPallasCarrier,
    "quant8": _quant8,
    "quant4": _quant4,
    "fused_quant8": _fused_quant8,
    "fused_quant4": _fused_quant4,
}


def make(name) -> Carrier:
    if isinstance(name, Carrier):
        return name
    if name not in REGISTRY:
        raise ValueError(f"unknown carrier {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]()
