"""Compression operators from the paper (Definitions 1 & 2) and production variants.

Contractive compressors (Definition 1):   E‖C(x) − x‖² ≤ (1 − α)‖x‖²
Absolute compressors  (Definition 2):     E‖C(x) − x‖² ≤ Δ²

All compressors operate on flat 1-D arrays; the EF layer (core/ef.py) flattens /
unflattens pytree leaves. Each compressor returns a *dense* array of the same shape
(the canonical mathematical object C(x)); TopK-family compressors additionally expose
``sparse()`` returning a fixed-size ``(values, indices)`` carrier used by the
wire-optimized collective path (core/distributed.py).

Randomized compressors accept a PRNG key; deterministic ones ignore it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _k_for(size: int, ratio: float, k: Optional[int]) -> int:
    if k is not None:
        return max(1, min(int(k), size))
    return max(1, min(size, int(round(ratio * size))))


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base class. Subclasses must implement ``__call__``."""

    def __call__(self, x: Array, rng: Optional[Array] = None) -> Array:
        raise NotImplementedError

    def alpha(self, d: int) -> float:
        """Contraction parameter α for a d-dimensional input (1.0 = lossless)."""
        return 1.0

    @property
    def is_contractive(self) -> bool:
        return True

    @property
    def has_sparse_carrier(self) -> bool:
        return False

    @property
    def needs_rng(self) -> bool:
        """True iff ``__call__`` draws randomness — such compressors cannot
        ride deterministic wire formats (core/carriers.py degrades to dense)."""
        return False


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    """C(x) = x. α = 1; EF21-SGDM with Identity reduces to plain SGDM (App. J)."""

    def __call__(self, x: Array, rng=None) -> Array:
        return x


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Greedy TopK sparsifier [Stich et al., 2018]: keep K largest |x|. α = K/d."""

    ratio: float = 0.01
    k: Optional[int] = None

    def _k(self, d: int) -> int:
        return _k_for(d, self.ratio, self.k)

    def alpha(self, d: int) -> float:
        return self._k(d) / d

    @property
    def has_sparse_carrier(self) -> bool:
        return True

    def sparse(self, x: Array, rng=None) -> Tuple[Array, Array]:
        k = self._k(x.size)
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        return x[idx], idx.astype(jnp.int32)

    def __call__(self, x: Array, rng=None) -> Array:
        # threshold-mask form (no scatter — shards cleanly under vmap, and is
        # exactly what the Pallas bisection kernel computes); ties may keep a
        # few extra coordinates, which only *reduces* the compression error
        k = self._k(x.size)
        ax = jnp.abs(x)
        vals = jax.lax.top_k(ax, k)[0]
        thresh = vals[..., -1]
        return jnp.where(ax >= thresh, x, jnp.zeros_like(x))


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Uniformly random K-sparsification.

    scaled=False: contractive (Def 1) with α = K/d (plain coordinate keep).
    scaled=True:  multiplies kept coords by d/K → *unbiased* (used by MARINA-style
                  methods); E‖C(x)−x‖² = (d/K − 1)‖x‖², NOT contractive for K < d/2.
    """

    ratio: float = 0.01
    k: Optional[int] = None
    scaled: bool = False

    def _k(self, d: int) -> int:
        return _k_for(d, self.ratio, self.k)

    def alpha(self, d: int) -> float:
        return self._k(d) / d if not self.scaled else 0.0

    @property
    def is_contractive(self) -> bool:
        return not self.scaled

    @property
    def has_sparse_carrier(self) -> bool:
        return True

    @property
    def needs_rng(self) -> bool:
        return True

    def sparse(self, x: Array, rng=None) -> Tuple[Array, Array]:
        assert rng is not None, "RandK requires a PRNG key"
        k = self._k(x.size)
        idx = jax.random.choice(rng, x.size, shape=(k,), replace=False).astype(jnp.int32)
        vals = x[idx]
        if self.scaled:
            vals = vals * (x.size / k)
        return vals, idx

    def __call__(self, x: Array, rng=None) -> Array:
        vals, idx = self.sparse(x, rng)
        return jnp.zeros_like(x).at[idx].set(vals)


@dataclasses.dataclass(frozen=True)
class BlockTopK(Compressor):
    """TPU-native TopK: exact TopK *within* contiguous blocks (DESIGN.md §4).

    Satisfies Definition 1 with α = K_b/B = ratio (per-block TopK discards, within
    every block, the smallest-magnitude mass: ‖C(x)−x‖² ≤ (1−K_b/B)‖x‖² summed over
    blocks). The fixed per-block budget produces an aligned (values, indices) carrier.
    The Pallas kernel (kernels/topk_compress.py) implements this selection via
    threshold bisection; this class is the pure-jnp reference semantics.
    """

    ratio: float = 0.01
    block: int = 1024
    k_per_block: Optional[int] = None

    def geom(self, d: int) -> Tuple[int, int, int]:
        """(nb, block_eff, kb) — the d-AWARE wire geometry. A leaf smaller
        than one block becomes a single block of its own size, so the
        per-block budget scales with the actual leaf: a (64,) norm under
        ratio=0.05/block=1024 keeps round(0.05·64)=3 coordinates, not the
        degenerate round(0.05·1024)=51 a fixed block would grant it (tiny
        tensors used to get K larger than themselves). Leaves of at least
        one block keep the exact legacy geometry."""
        block = min(self.block, max(1, int(d)))
        if self.k_per_block is not None:
            kb = max(1, min(self.k_per_block, block))
        else:
            kb = max(1, min(block, int(round(self.ratio * block))))
        nb = -(-d // block) if d > 0 else 1
        return nb, block, kb

    def alpha(self, d: int) -> float:
        _, block, kb = self.geom(d)
        return kb / block

    @property
    def has_sparse_carrier(self) -> bool:
        return True

    def _blocks(self, x: Array) -> Tuple[Array, int]:
        nb, block, _ = self.geom(x.size)
        pad = nb * block - x.size
        xb = jnp.pad(x, (0, pad)).reshape(nb, block)
        return xb, pad

    def sparse(self, x: Array, rng=None) -> Tuple[Array, Array]:
        xb, _ = self._blocks(x)
        _, block, kb = self.geom(x.size)
        _, idx = jax.lax.top_k(jnp.abs(xb), kb)              # (nb, kb) local indices
        vals = jnp.take_along_axis(xb, idx, axis=1)
        gidx = idx + jnp.arange(xb.shape[0])[:, None] * block
        return vals.reshape(-1), gidx.reshape(-1).astype(jnp.int32)

    def __call__(self, x: Array, rng=None) -> Array:
        # per-block threshold mask (scatter-free; the Pallas kernel's semantics)
        xb, _ = self._blocks(x)
        ab = jnp.abs(xb)
        vals = jax.lax.top_k(ab, self.geom(x.size)[2])[0]
        thresh = vals[:, -1:]
        out = jnp.where(ab >= thresh, xb, jnp.zeros_like(xb))
        return out.reshape(-1)[: x.size].reshape(x.shape)


@dataclasses.dataclass(frozen=True)
class HardThreshold(Compressor):
    """Hard-threshold sparsifier [Sahu et al., 2021]: C(x) = x·1{|x| ≥ λ}.

    An *absolute* compressor (Definition 2) with Δ = λ√d (each dropped coordinate
    contributes < λ²). Used by EF21-SGDM-abs (Algorithm 4 / Theorem 6).
    """

    lam: float = 1e-3

    @property
    def is_contractive(self) -> bool:
        return False

    def delta(self, d: int) -> float:
        return self.lam * (d ** 0.5)

    def __call__(self, x: Array, rng=None) -> Array:
        return jnp.where(jnp.abs(x) >= self.lam, x, jnp.zeros_like(x))


@dataclasses.dataclass(frozen=True)
class NaturalCompression(Compressor):
    """Natural compression [Horváth et al., 2019a]: stochastic rounding of |x| to a
    power of two (keeps sign + exponent, drops mantissa). Unbiased; contractive-type
    bound E‖C(x) − x‖² ≤ (1/8)‖x‖² → satisfies Definition 1 with α = 7/8 (wire: 9
    bits/coord instead of 32)."""

    def alpha(self, d: int) -> float:
        return 7.0 / 8.0

    @property
    def needs_rng(self) -> bool:
        return True

    def __call__(self, x: Array, rng=None) -> Array:
        assert rng is not None, "NaturalCompression requires a PRNG key"
        ax = jnp.abs(x)
        lo = jnp.where(ax > 0, jnp.exp2(jnp.floor(jnp.log2(jnp.maximum(ax, 1e-38)))), 0.0)
        hi = 2.0 * lo
        p_hi = jnp.where(lo > 0, (ax - lo) / jnp.maximum(hi - lo, 1e-38), 0.0)
        u = jax.random.uniform(rng, x.shape)
        mag = jnp.where(u < p_hi, hi, lo)
        return (jnp.sign(x) * mag).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class Rank1(Compressor):
    """PowerSGD-style rank-1 approximation [Vogels et al., 2019] via one round of
    power iteration on the (n×m) reshape of x. Contractive (greedy best rank-1 would
    give α = σ₁²/‖x‖²; one power-iteration is a practical surrogate — projection onto
    a rank-1 subspace never increases the error above ‖x‖²)."""

    rows: int = 64

    def alpha(self, d: int) -> float:
        return 1.0 / max(2, min(self.rows, d // max(1, self.rows)))  # conservative

    def __call__(self, x: Array, rng=None) -> Array:
        d = x.size
        r = min(self.rows, d)
        m = -(-d // r)
        M = jnp.pad(x.reshape(-1), (0, r * m - d)).reshape(r, m)
        v = jnp.ones((m,), x.dtype) / jnp.sqrt(m)
        u = M @ v
        u = u / jnp.maximum(jnp.linalg.norm(u), 1e-12)
        v = M.T @ u
        approx = jnp.outer(u, v).reshape(-1)[:d]
        return approx.reshape(x.shape)


@dataclasses.dataclass(frozen=True)
class BlockQuant(Compressor):
    """Per-block absmax quantization as a standalone compressor:
    C(x) = dequantize(quantize(x)) with ``bits``-bit mantissas and one f32
    scale per ``block`` elements — the dense payload of the quantized wire
    carriers (core/carriers.py::QuantCarrier), exposed so the *naive*
    no-error-feedback quantized baseline is expressible (ship Q(∇f) directly).

    Deterministic round-to-nearest, hence BIASED. Contractive (Definition 1)
    with α = 1 − block/(4·qmax²) when that is positive: the per-block error is
    ≤ block·(absmax/2qmax)² against ‖x_block‖² ≥ absmax². For 4-bit mantissas
    at block ≥ 4·49 the bound is vacuous (α = 0) — exactly the regime where
    naive quantized compression stalls and EF21-SGDM still converges
    (tests/test_paper_claims.py)."""

    bits: int = 8
    block: int = 256

    def alpha(self, d: int) -> float:
        qmax = 2 ** (self.bits - 1) - 1
        return max(0.0, 1.0 - min(self.block, d) / (4.0 * qmax * qmax))

    @property
    def is_contractive(self) -> bool:
        return self.alpha(self.block) > 0.0

    def __call__(self, x: Array, rng=None) -> Array:
        from repro.kernels import ref as kref
        d = x.size
        nb = -(-d // self.block)
        xb = jnp.pad(x.reshape(-1).astype(jnp.float32),
                     (0, nb * self.block - d)).reshape(nb, self.block)
        q, scales = kref.block_quantize_ref(xb, self.bits)
        deq = kref.block_dequantize_ref(q, scales, bits=self.bits,
                                        cols=self.block)
        return deq.reshape(-1)[:d].reshape(x.shape).astype(x.dtype)


REGISTRY = {
    "identity": Identity,
    "topk": TopK,
    "randk": RandK,
    "block_topk": BlockTopK,
    "hard_threshold": HardThreshold,
    "natural": NaturalCompression,
    "rank1": Rank1,
    "block_quant": BlockQuant,
}


def make(name: str, **kwargs) -> Compressor:
    if name not in REGISTRY:
        raise ValueError(f"unknown compressor {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name](**kwargs)
