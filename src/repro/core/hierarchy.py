"""Two-tier hierarchical EF aggregation (DESIGN.md §13): clients → pod
aggregator → global server, each hop with its own carrier/compressor.

The flat round aggregates every client message straight into the server
update, so the expensive cross-pod hop pays exactly the same wire cost as
the cheap intra-pod hop. With ``Hops(pods=P, cross_carrier=..., ...)`` the
round becomes two hops:

  1. INTRA hop: the clients of pod p aggregate their messages over the fast
     intra-pod links exactly as today (same carriers, same plans), producing
     the pod mean u_p instead of the global mean.
  2. CROSS hop: each pod aggregator keeps its OWN EF memory — a target
     ``t_p`` (what the pod wants the server to know) and a broadcast state
     ``b_p`` (what the server actually holds of this pod) — and ships only
     the compressed innovation C_cross(t_p' − b_p) across the slow inter-pod
     links; the server integrates the decode. This is the uplink twin of the
     §8 downlink memory: ``b_p' = b_p + decode(C_cross(t_p' − b_p))`` via the
     SAME ``ef_lib.downlink_sync`` leg, so compounding compression error is
     error-fed at both levels (EF21 composes across heterogeneous links —
     "EF21 with Bells & Whistles", PAPERS.md).

Pod target update and server update reuse the method's server semantics:

  delta mode:     t_p' = t_p + u_p        g' = g + mean_p(b_p' − b_p)
  absolute mode:  t_p' = u_p              g' = mean_p(b_p')

Both pod memories initialize to zeros; in delta mode the server increment
mean_p(b_p' − b_p) is exact regardless of how g⁰ itself was initialized.

A TRIVIAL cross hop (dense carrier + identity compressor) makes the pod
aggregator transparent: ``b_p' = t_p'`` bit-exactly, the round executes the
legacy flat aggregation ops verbatim (the flat-equivalence anchor
tests/test_hierarchy.py pins bit-identity), and the pod memories degenerate
to tracking the global innovation sum. ``pods=1`` (or hops=None) is a pure
no-op: no pod state exists and the emitted jaxpr is the legacy one.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import carriers as carrier_lib
from repro.core import compressors as comp_lib
from repro.core import ef as ef_lib

# rng fold for the cross-pod hop, derived from the ROUND rng before the
# per-client fold (exactly like carriers.DOWNLINK_FOLD = 1 << 20 for the
# broadcast leg) and then per-pod: fold_in(fold_in(rng, CROSS_FOLD), pod).
# Distinct from DOWNLINK_FOLD so a bidirectional hierarchical round never
# reuses a stream between the cross hop and the broadcast.
CROSS_FOLD = 1 << 21


@dataclasses.dataclass(frozen=True)
class Hops:
    """The two-hop topology knob: how many pod aggregators, and the
    cross-pod carrier/compressor. Frozen → hashable, usable as a jit
    static (SimConfig carries one). The intra hop has no fields here — it
    runs the round's existing carrier/compressor/schedule unchanged, just
    aggregated over the intra-pod axes only."""

    pods: int = 1
    cross_carrier: str = "dense"
    cross_compressor: Optional[comp_lib.Compressor] = None

    def cross_comp(self) -> comp_lib.Compressor:
        return (self.cross_compressor if self.cross_compressor is not None
                else comp_lib.Identity())

    @property
    def trivial_cross(self) -> bool:
        """True when the cross hop ships the exact pod target (dense carrier,
        identity compressor) — the flat-equivalence regime."""
        return (carrier_lib.make(self.cross_carrier).name == "dense"
                and isinstance(self.cross_comp(), comp_lib.Identity))


def effective(hops: Optional[Hops]) -> Optional[Hops]:
    """Normalize to None when the topology is flat (pods <= 1): callers gate
    ALL hierarchical machinery on ``effective(hops) is not None``, so a
    pods=1 config creates no pod state and traces the legacy jaxpr."""
    if hops is None or hops.pods <= 1:
        return None
    return hops


def check_pods(hops: Hops, n: int) -> None:
    if n % hops.pods != 0:
        raise ValueError(
            f"hops.pods={hops.pods} must divide the client count {n}")


def pod_init(params_like) -> dict:
    """Per-pod EF memory: target t (what the pod wants upstream) and
    broadcast state b (what the server holds of this pod). Both zeros —
    the server increment mean_p(b' − b) is exact under any g⁰ init."""
    return {"t": ef_lib.tree_zeros_like(params_like),
            "b": ef_lib.tree_zeros_like(params_like)}


def pod_target(method, t, u):
    """Fold the pod's intra-hop mean u into its target: the method's own
    server semantics (delta accumulates, absolute replaces)."""
    return ef_lib.server_step(method, t, u)


def pod_message(method, b, b_new):
    """One pod's contribution to the server update: the cross-hop decode
    increment (delta mode) or the synced absolute target. The server then
    runs ``server_step(method, g, mean_p(pod_message))``."""
    if method.mode == "delta":
        return ef_lib.tree_sub(b_new, b)
    return b_new


def cross_sync(hops: Hops, schedule, t_new, b, rng):
    """The cross hop for ONE pod: b' = b + decode(C_cross(t' − b)), reusing
    the §8 downlink leg (same encode/decode/rng-per-leaf discipline). With a
    per-group schedule the group's cross fields are authoritative
    (schedule.cross_round_grouped); otherwise the uniform Hops knobs run."""
    if schedule is not None:
        from repro.core import schedule as sched_lib
        return sched_lib.cross_round_grouped(schedule, t_new, b, rng)
    car = carrier_lib.make(hops.cross_carrier)
    return ef_lib.downlink_sync(car, hops.cross_comp(), t_new, b, rng=rng)[1]


def cross_is_trivial(hops: Hops, schedule) -> bool:
    """Flat-equivalence predicate for the whole cross hop: with a schedule,
    EVERY group's cross must be trivial."""
    if schedule is None:
        return hops.trivial_cross
    return all(g.trivial_cross for g in schedule.groups)


def pod_mean(tree, pods: int):
    """Per-pod means of a clients-leading-axis tree: (n, ...) → (pods, ...)
    with pod-major contiguous blocks (client i belongs to pod i // (n/pods)
    — the same pod-major order the sharded runtime's client_index
    composes, so both runtimes agree on who is in which pod)."""
    def one(leaf):
        m = leaf.shape[0] // pods
        return leaf.reshape(pods, m, *leaf.shape[1:]).mean(1)
    return jax.tree_util.tree_map(one, tree)


def round_pods_batched(hops: Hops, schedule, method, u_pods, pods_st,
                       g_server, rng):
    """The full pod tier for the vmap runtimes: per-pod target update, the
    cross hop (per-pod rng = fold_in(fold_in(rng, CROSS_FOLD), pod) — the
    same stream the sharded runtime folds), and the server integration.

    ``u_pods``/``pods_st`` carry pods on a leading axis. Returns
    ``(new_pods_st, new_server)``."""
    pods = hops.pods
    r_cross = None if rng is None else jax.random.fold_in(rng, CROSS_FOLD)
    t_out, b_out, msgs = [], [], []
    for p in range(pods):
        take = lambda tr: jax.tree_util.tree_map(lambda l: l[p], tr)
        t_p, b_p = take(pods_st["t"]), take(pods_st["b"])
        t_new = pod_target(method, t_p, take(u_pods))
        r_p = None if r_cross is None else jax.random.fold_in(r_cross, p)
        b_new = cross_sync(hops, schedule, t_new, b_p, r_p)
        t_out.append(t_new)
        b_out.append(b_new)
        msgs.append(pod_message(method, b_p, b_new))
    stack = lambda ts: jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *ts)
    msg_mean = jax.tree_util.tree_map(
        lambda *ls: sum(ls[1:], ls[0]) / pods, *msgs)
    new_server = ef_lib.server_step(method, g_server, msg_mean)
    return {"t": stack(t_out), "b": stack(b_out)}, new_server


def trivial_bookkeeping(method, pods_st, msg_mean):
    """Pod-memory update under a TRIVIAL cross hop: the aggregator is
    transparent (b' = t'), the server consumed the legacy GLOBAL mean
    bit-exactly, and the pod memories track that same global innovation —
    one rule shared by all three runtimes so they agree bit-for-bit.
    ``msg_mean`` broadcasts against the pod state, which carries a leading
    pods axis in the vmap runtimes and none inside shard_map."""
    def up(t_leaf, m_leaf):
        m = jnp.broadcast_to(m_leaf, t_leaf.shape)
        return t_leaf + m if method.mode == "delta" else m
    t_new = jax.tree_util.tree_map(up, pods_st["t"], msg_mean)
    return {"t": t_new, "b": t_new}


def wire_words_cross(hops: Hops, schedule, method, tree_or_d) -> float:
    """Cross-pod words per ROUND: each pod ships one compressed innovation,
    so the per-message count (the §8 ``downlink_words`` twin — the cross
    wire is one message, no aggregation) × pods."""
    if schedule is not None:
        from repro.core import schedule as sched_lib
        _, total = sched_lib.wire_words_tree(schedule, method, tree_or_d,
                                             direction="cross")
        return total * hops.pods
    car = carrier_lib.make(hops.cross_carrier)
    d = tree_or_d if isinstance(tree_or_d, (int, float)) else int(
        ef_lib.tree_dim(tree_or_d))
    return carrier_lib.downlink_words(car, hops.cross_comp(), int(d)) \
        * hops.pods
