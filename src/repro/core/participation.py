"""Partial participation + asynchronous EF rounds (DESIGN.md §11).

"EF21 with Bells & Whistles" (Fatkhullin, Sokolov, Richtárik 2021) extends
EF21 to rounds where only a sampled cohort S ⊆ [n] uploads: sampled clients
run their usual update gᵢ ← gᵢ + cᵢ, NON-sampled clients keep gᵢ (and any
momentum buffer vᵢ) frozen, and the server folds g ← g + (1/n)·Σ_{i∈S} cᵢ —
divide by n, not |S|, so the invariant g_server = meanᵢ gᵢ survives every
round. The source paper's EF21-SGDM momentum buffer is exactly the per-client
state that must stay consistent across skipped rounds, which is why the
freeze is a hard tree-level ``where`` and not a "small update".

Both synchronous runtimes (core/simulate.py, core/distributed.py) implement
the rule by MASKING: the sampled cohort is a seeded 0/1 mask over clients
(:func:`cohort_mask` — a pure function of (seed, round), so resume replays
identical cohorts), non-sampled wire contributions are zeroed BEFORE the
aggregation collective (C(0) = 0 exactly for every deterministic wire
compressor, so a zero-masked delta produces an exactly-zero decode), and the
whole per-client state tree is frozen afterwards with :func:`freeze_tree`.
A fraction-1.0 cohort multiplies by 1.0 and ``where(True, …)`` everywhere —
IEEE-exact — so the masked path is BIT-identical to full participation
(tests/test_participation.py pins this on all three runtimes).

Absolute-mode methods (EF14, SGDM, …) have no server increment to divide by
n; their server state is the cohort mean (1/|S|)·Σ_{i∈S} msgᵢ, i.e. the
masked mean rescaled by n/|S| (:func:`rescale_message`).

``mode='async'`` never runs on the synchronous runtimes (they are barrier
loops); :func:`run_async` is the event-driven simulator — an ADPSGD-style
client loop with per-client compute-time models (uniform / heavy-tail /
dropout), per-arrival server folds c/n, staleness tracking with an optional
cap, and honest wall-clock-vs-round accounting against the synchronous
barrier baseline (tests/test_async_scenarios.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

PyTree = Any

PART_MODES = ("full", "sampled", "async")


@dataclasses.dataclass(frozen=True)
class Participation:
    """Who uploads each round. Frozen/hashable → usable inside the jit-static
    EFConfig/SimConfig. ``fraction``/``seed`` only matter for mode='sampled'
    (and as defaults for the async simulator's cohort bookkeeping)."""

    mode: str = "full"          # 'full' | 'sampled' | 'async'
    fraction: float = 1.0       # sampled cohort size = max(1, round(f·n))
    seed: int = 0               # cohort stream seed (independent of data rng)

    def __post_init__(self):
        if self.mode not in PART_MODES:
            raise ValueError(f"participation mode {self.mode!r} not in "
                             f"{list(PART_MODES)}")
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"participation fraction must be in (0, 1], "
                             f"got {self.fraction}")

    @property
    def is_sampling(self) -> bool:
        """True when the synchronous runtimes must run the masked-cohort
        path (mode='sampled'; a full mode or an absent Participation runs
        the legacy path untouched)."""
        return self.mode == "sampled"

    def cohort_size(self, n: int) -> int:
        """|S| = max(1, round(fraction·n)) — mirrored jax-free in
        launch/spec.py::participation_preview (sync-tested)."""
        if self.mode == "full":
            return n
        return max(1, int(round(self.fraction * n)))


def cohort_mask(part: Participation, n: int, step) -> "Any":
    """The round's 0/1 client mask, shape (n,) f32: a seeded permutation of
    [n] keeps the first ``cohort_size`` entries. Pure in (seed, step) — the
    SAME (seed, step) yields the same cohort on every runtime and across a
    kill-and-resume — and jit-traceable in ``step`` (cohort_size is static).
    fraction=1.0 returns all-ones (perm[:n] covers [n])."""
    import jax
    import jax.numpy as jnp
    m = part.cohort_size(n)
    key = jax.random.fold_in(jax.random.PRNGKey(part.seed), step)
    perm = jax.random.permutation(key, n)
    return jnp.zeros((n,), jnp.float32).at[perm[:m]].set(1.0)


def cohort_mask_np(part: Participation, n: int, step: int) -> np.ndarray:
    """``cohort_mask`` materialized to numpy (property tests / accounting)."""
    import jax
    return np.asarray(jax.device_get(cohort_mask(part, n, step)))


# ---------------------------------------------------------------------------
# masking / freezing primitives the runtimes share
# ---------------------------------------------------------------------------

def apply_mask(mask, tree: PyTree) -> PyTree:
    """Zero the non-cohort entries of a per-client tree. ``mask`` is either
    the (n,) round mask (batched vmap layouts — broadcast over the leading
    client axis) or this device's scalar entry (shard_map layouts). The
    multiply is cast to each leaf's dtype, so ×1.0 / ×0.0 stay IEEE-exact in
    f32 and bf16 alike — the masked path at fraction=1.0 is bitwise the
    unmasked one."""
    import jax

    def one(x):
        m = mask.astype(x.dtype)
        if m.ndim == 1:
            m = m.reshape((m.shape[0],) + (1,) * (x.ndim - 1))
        return x * m
    return jax.tree_util.tree_map(one, tree)


def freeze_tree(mask, new: PyTree, old: PyTree) -> PyTree:
    """The frozen-client invariant: non-sampled clients keep their ENTIRE
    EF state (gᵢ, momentum, …) — ``where(mask, new, old)`` leaf-wise, never
    arithmetic (a += 0 could still flip -0.0). Same mask layouts as
    ``apply_mask``."""
    import jax
    import jax.numpy as jnp

    def one(nw, od):
        m = mask
        if m.ndim == 1:
            m = m.reshape((m.shape[0],) + (1,) * (nw.ndim - 1))
        return jnp.where(m.astype(bool), nw, od)
    return jax.tree_util.tree_map(one, new, old)


def rescale_message(method, msg_mean: PyTree, n: int, m: int) -> PyTree:
    """Masked aggregates come back as (1/n)·Σ_{i∈S}. For delta-mode methods
    that IS the Bells & Whistles server increment — untouched. Absolute-mode
    methods average over the cohort, so the masked mean rescales by n/m
    (×1.0 exact when m = n)."""
    import jax
    if method.mode != "absolute":
        return msg_mean
    scale = float(n) / float(m)
    return jax.tree_util.tree_map(lambda x: x * scale, msg_mean)


# ---------------------------------------------------------------------------
# event-driven asynchronous rounds (mode='async')
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArrivalModel:
    """Per-client compute-time model for the async event loop.

    'uniform'     τ ~ U[0.5, 1.5]·mean — homogeneous fleet, the sanity model
    'heavy_tail'  τ ~ Pareto(alpha) scaled to E[τ] = mean — stragglers: the
                  per-round max (what a synchronous barrier pays) is far
                  above the mean an async server pays
    'dropout'     uniform times, but each compute is LOST with prob
                  drop_prob (client restarts) — the liveness scenario
    """

    kind: str = "uniform"       # 'uniform' | 'heavy_tail' | 'dropout'
    mean: float = 1.0
    alpha: float = 1.3          # Pareto tail index (heavy_tail; > 1)
    drop_prob: float = 0.2      # P[one compute is lost] (dropout)

    def __post_init__(self):
        if self.kind not in ("uniform", "heavy_tail", "dropout"):
            raise ValueError(f"unknown arrival kind {self.kind!r}")
        if self.kind == "heavy_tail" and self.alpha <= 1.0:
            raise ValueError("heavy_tail needs alpha > 1 (finite mean), "
                             f"got {self.alpha}")
        if not 0.0 <= self.drop_prob < 1.0:
            raise ValueError("drop_prob must be in [0, 1) — a client that "
                             f"always drops deadlocks, got {self.drop_prob}")

    def sample(self, rs: np.random.RandomState, size: int) -> np.ndarray:
        if self.kind == "heavy_tail":
            # Pareto(alpha) with minimum xm has mean xm·alpha/(alpha-1);
            # pick xm so E[τ] = mean
            xm = self.mean * (self.alpha - 1.0) / self.alpha
            return xm * (1.0 + rs.pareto(self.alpha, size=size))
        return self.mean * rs.uniform(0.5, 1.5, size=size)

    def dropped(self, rs: np.random.RandomState, size: int) -> np.ndarray:
        if self.kind != "dropout":
            return np.zeros(size, dtype=bool)
        return rs.uniform(size=size) < self.drop_prob


def sync_barrier_wallclock(arrival: ArrivalModel, n: int, rounds: int,
                           seed: int = 0) -> float:
    """What a synchronous barrier pays under the same compute-time model:
    each round waits for the SLOWEST client (dropped computes retry within
    the round — the barrier cannot proceed without every upload)."""
    rs = np.random.RandomState(seed)
    total = 0.0
    for _ in range(rounds):
        t = arrival.sample(rs, n)
        pending = arrival.dropped(rs, n)
        while pending.any():                 # resample lost computes
            k = int(pending.sum())
            t[pending] += arrival.sample(rs, k)
            pending[pending] = arrival.dropped(rs, k)
        total += float(t.max())
    return total


def run_async(problem, method, n: int, gamma: float, rounds: int,
              arrival: ArrivalModel = ArrivalModel(),
              batch_size: int = 1, b_init: int = 1, eta=None,
              staleness_cap: Optional[int] = None, seed: int = 0) -> Dict:
    """Event-driven asynchronous EF rounds, ADPSGD-style client loop.

    Every client perpetually (fetch x → compute a stochastic gradient,
    taking τ ~ ``arrival`` → upload). The server processes uploads in
    arrival-time order: each accepted upload folds the client's compressed
    innovation as g ← g + c/n (the Bells & Whistles rule with a singleton
    cohort — all other clients are implicitly frozen because only the
    uploader's state advances) and immediately takes a model step
    x ← x − γ·g. One ROUND = n accepted uploads, so round counts compare
    1:1 against the synchronous runtimes; wall-clock is the event time of
    the last accepted upload.

    Staleness of an upload = server model version now − version the client
    fetched. With ``staleness_cap`` set, an upload older than the cap is
    DISCARDED (the client's state never advanced — it simply refetches and
    recomputes), bounding the stale-wire age histogram by construction.
    Dropout ('dropout' arrivals) loses computes but never deadlocks: a lost
    compute reschedules immediately and drop_prob < 1 guarantees progress.

    Delta-mode methods only (the EF21 family — the per-arrival fold IS the
    partial-participation rule; absolute-mode methods have no incremental
    server memory to fold into)."""
    import jax
    from repro.core import ef as ef_lib

    if method.mode == "absolute":
        raise ValueError(
            f"run_async supports delta-mode (EF21-family) methods only; "
            f"{method.name!r} is absolute-mode — its server state is a "
            "cohort mean, which has no per-arrival incremental fold")

    rs = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed)

    x = problem.init_x()
    # Alg 1 line 2 init handshake (synchronous, like the sync runtimes)
    g0 = []
    for i in range(n):
        key, k = jax.random.split(key)
        gs = [problem.stoch_grad(x, i, kk, batch_size)
              for kk in jax.random.split(k, b_init)]
        g0.append(jax.tree_util.tree_map(
            lambda *g: sum(g[1:], g[0]) / len(g), *gs))
    states = [method.init(x, init_grads=g) for g in g0]
    g_server = ef_lib.server_init(
        method, x, jax.tree_util.tree_map(lambda *g: sum(g[1:], g[0]) / n,
                                          *g0))

    def start_compute(i, now):
        """Client i fetches the current model and schedules its upload."""
        key_i = jax.random.fold_in(key, counter[0])
        counter[0] += 1
        tau = float(arrival.sample(rs, 1)[0])
        clients[i] = {
            "arrival": now + tau,
            "version": version[0],
            "x": x_now[0],
            "rng": key_i,
            "lost": bool(arrival.dropped(rs, 1)[0]),
        }

    counter = [0]
    version = [0]                # server model version (accepted uploads)
    x_now = [x]
    clients: Dict[int, Dict] = {}
    for i in range(n):
        start_compute(i, 0.0)

    target = n * rounds
    applied = dropped = discarded = 0
    wall_clock = 0.0
    ages: list = []
    gns_round = []

    while applied < target:
        i = min(clients, key=lambda c: clients[c]["arrival"])
        ev = clients[i]
        now = ev["arrival"]
        if ev["lost"]:                      # dropout: compute never arrived
            dropped += 1
            start_compute(i, now)
            continue
        age = version[0] - ev["version"]
        if staleness_cap is not None and age > staleness_cap:
            discarded += 1                   # too stale: refetch, recompute
            start_compute(i, now)
            continue
        # accepted upload: the client's EF update against the model it saw
        grads = problem.stoch_grad(ev["x"], i, ev["rng"], batch_size)
        msg, states[i] = method.update(grads, states[i],
                                       jax.random.fold_in(ev["rng"], 1),
                                       eta=eta)
        g_server = ef_lib.tree_add(
            g_server, jax.tree_util.tree_map(lambda c: c / n, msg))
        x_now[0] = jax.tree_util.tree_map(lambda p, g: p - gamma * g,
                                          x_now[0], g_server)
        version[0] += 1
        applied += 1
        wall_clock = now
        ages.append(age)
        if applied % n == 0:
            gns_round.append(float(ef_lib.tree_norm_sq(
                problem.full_grad(x_now[0]))))
        start_compute(i, now)

    ages_arr = np.asarray(ages, dtype=np.int64)
    hist = np.bincount(ages_arr) if ages_arr.size else np.zeros(1, np.int64)
    return {
        "wall_clock": wall_clock,
        "rounds": rounds,
        "arrivals_applied": applied,
        "arrivals_dropped": dropped,
        "arrivals_discarded": discarded,
        "stale_age_hist": hist,
        "max_staleness": int(ages_arr.max()) if ages_arr.size else 0,
        "mean_staleness": float(ages_arr.mean()) if ages_arr.size else 0.0,
        "grad_norm_sq_per_round": np.asarray(gns_round),
        "grad_norm_sq": gns_round[-1] if gns_round else float("nan"),
        "loss": float(problem.loss(x_now[0])),
        "x_final": jax.device_get(x_now[0]),
        "sync_wall_clock": sync_barrier_wallclock(arrival, n, rounds,
                                                  seed=seed + 1),
    }
