"""Per-parameter-group compression schedules (DESIGN.md §9).

The paper's contractive-compressor framework (Definition 1) is per-message,
not per-model: nothing in the EF21-SGDM analysis requires every parameter
tensor to share one compressor, and a product of contractive maps is
contractive with α = min over factors (Richtárik et al. 2021), so EF21's
theory composes over any partition of the parameter pytree. Real systems
exploit exactly that freedom — norms/biases are a rounding error on the wire
and ship dense, embeddings tolerate aggressive quantization, attention/MLP
matrices are where TopK earns its keep.

:class:`CompressionSchedule` makes the partition first-class: an ordered
tuple of :class:`Group` entries, each naming a path pattern plus its own
compressor, uplink carrier, downlink carrier/compressor and EF-state dtype.
Leaves are assigned **first-match-wins** against the pattern order, and the
last group MUST be the catch-all ``"*"`` — so every leaf lands in exactly
one group by construction. Patterns are ``|``-separated substring tokens
matched against the leaf's ``/``-joined lower-cased key path (``"norm|bias"``
matches ``layers/mlp/norm``; ``"*"`` matches everything).

This module also hosts the *grouped execution engine* every runtime
dispatches through (the vmap simulator in core/simulate.py, ``ef_round`` and
``ef_round_sharded`` in core/distributed.py): per group, the existing
single-compressor machinery runs unchanged on that group's leaf list — the
same pre_compress → C(·) → post_compress chain, the same carrier plans
('dense' | 'wire' | 'fused' | 'fused_wire'), the same downlink broadcast
leg — and the
results are scattered back into the full tree. A uniform single-group
schedule therefore executes the *identical* operation sequence (including
rng folding: the group rng is the round rng untouched when there is only
one group) and is bit-identical to the legacy single-compressor path — the
regression anchor tests/test_schedule.py pins.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import carriers as carrier_lib
from repro.core import compressors as comp_lib
from repro.core import ef as ef_lib
from repro.core import participation as part_lib

PyTree = Any

# characters the flag grammar reserves — a pattern containing one could never
# round-trip through `--schedule "pat=carrier:ratio@comp,…"`
PATTERN_RESERVED = set("=,:@")

# per-group EF-state dtype universe ('float32' exists so one group can force
# full precision under a bfloat16 spec-level default)
GROUP_STATE_DTYPES = (None, "bfloat16", "float32")


def pattern_token_errors(pattern: str) -> List[str]:
    """Malformed-token diagnostics shared by both validators (the schedule's
    own ``__post_init__`` and the jax-free RunSpec mirror). An EMPTY token —
    a ``'norm|'`` typo — is a substring of every path and would silently
    swallow the whole model into one group; a ``'*'`` token inside a
    composite pattern would shadow every later group the same way."""
    toks = pattern.split("|")
    errs = []
    if any(not t for t in toks):
        errs.append("empty '|' token (matches every leaf)")
    if "*" in toks and pattern != "*":
        errs.append("'*' may only be the standalone catch-all pattern")
    return errs


def pattern_matches(pattern: str, path: str) -> bool:
    """``|``-separated substring tokens; ``*`` matches everything. Matching
    is case-insensitive (leaf paths are lower-cased, so tokens must be
    too — a pattern written in a tree's literal mixed case still hits)."""
    for tok in pattern.lower().split("|"):
        if tok == "*" or tok in path:
            return True
    return False


def _key_str(entry) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def leaf_paths(tree: PyTree) -> Tuple[str, ...]:
    """The ``/``-joined lower-cased key path of every leaf, in
    ``tree_flatten`` order — the strings schedule patterns match against."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return tuple("/".join(_key_str(k) for k in path).lower()
                 for path, _ in flat)


@dataclasses.dataclass(frozen=True)
class Group:
    """One partition cell: pattern + its full transport configuration.
    Frozen/hashable → a schedule is usable as a jit static argument."""

    pattern: str
    compressor: comp_lib.Compressor = comp_lib.Identity()
    carrier: str = "dense"
    down_carrier: str = "dense"
    down_compressor: Optional[comp_lib.Compressor] = None
    state_dtype: Optional[str] = None   # None → inherit the method's
    # per-hop fields (DESIGN.md §13): under a hierarchical topology
    # (EFConfig.hops) the CROSS-pod hop of this group's leaves ships
    # C_cross(t_pod − b_pod) on its own carrier/compressor. The defaults are
    # the trivial cross (dense + identity): the pod aggregator is
    # transparent for this group and the flat bits are preserved.
    cross_carrier: str = "dense"
    cross_compressor: Optional[comp_lib.Compressor] = None

    @property
    def name(self) -> str:
        return self.pattern

    @property
    def has_downlink(self) -> bool:
        return self.down_carrier != "dense" or self.down_compressor is not None

    def down_comp(self) -> comp_lib.Compressor:
        return (self.down_compressor if self.down_compressor is not None
                else comp_lib.Identity())

    @property
    def trivial_cross(self) -> bool:
        return (self.cross_carrier == "dense"
                and isinstance(self.cross_comp(), comp_lib.Identity))

    def cross_comp(self) -> comp_lib.Compressor:
        return (self.cross_compressor if self.cross_compressor is not None
                else comp_lib.Identity())


@dataclasses.dataclass(frozen=True)
class CompressionSchedule:
    """An ordered, first-match-wins partition of the param pytree. The last
    group must be the mandatory catch-all ``"*"`` so resolution is total."""

    groups: Tuple[Group, ...] = ()

    def __post_init__(self):
        errs: List[str] = []
        if not self.groups:
            errs.append("a schedule needs at least one group")
        else:
            if self.groups[-1].pattern != "*":
                errs.append("the last group must be the catch-all '*' "
                            f"(got {self.groups[-1].pattern!r}) so every "
                            "leaf lands in exactly one group")
            seen = set()
            for i, g in enumerate(self.groups):
                if not g.pattern:
                    errs.append(f"group {i} has an empty pattern")
                if g.pattern == "*" and i != len(self.groups) - 1:
                    errs.append("the catch-all '*' must be the LAST group "
                                "(first-match-wins would shadow everything "
                                "after it)")
                if g.pattern in seen:
                    errs.append(f"duplicate group pattern {g.pattern!r}")
                seen.add(g.pattern)
                bad = PATTERN_RESERVED & set(g.pattern)
                if bad:
                    errs.append(f"pattern {g.pattern!r} uses reserved "
                                f"characters {sorted(bad)}")
                errs.extend(f"group {g.pattern!r}: {e}"
                            for e in pattern_token_errors(g.pattern))
                if g.carrier not in carrier_lib.REGISTRY:
                    errs.append(f"group {g.pattern!r}: unknown carrier "
                                f"{g.carrier!r}")
                if g.down_carrier not in carrier_lib.REGISTRY \
                        or g.down_carrier == "fused":
                    errs.append(f"group {g.pattern!r}: downlink carrier "
                                f"{g.down_carrier!r} is not a thing (the "
                                "fused kernel is the uplink client update)")
                if g.cross_carrier not in carrier_lib.REGISTRY \
                        or g.cross_carrier == "fused":
                    errs.append(f"group {g.pattern!r}: cross-pod carrier "
                                f"{g.cross_carrier!r} is not a thing (the "
                                "cross hop is one message per pod — same "
                                "rules as the downlink broadcast)")
                if g.state_dtype not in GROUP_STATE_DTYPES:
                    errs.append(f"group {g.pattern!r}: state_dtype "
                                f"{g.state_dtype!r} not in "
                                f"{list(GROUP_STATE_DTYPES)}")
        if errs:
            raise ValueError("invalid CompressionSchedule:\n  - "
                             + "\n  - ".join(errs))

    @classmethod
    def uniform(cls, compressor: comp_lib.Compressor, carrier: str = "dense",
                down_carrier: str = "dense",
                down_compressor: Optional[comp_lib.Compressor] = None,
                state_dtype: Optional[str] = None,
                cross_carrier: str = "dense",
                cross_compressor: Optional[comp_lib.Compressor] = None
                ) -> "CompressionSchedule":
        """The one-group schedule equivalent to today's single-knob config —
        the regression anchor (bit-identical to the legacy path)."""
        return cls((Group(pattern="*", compressor=compressor, carrier=carrier,
                          down_carrier=down_carrier,
                          down_compressor=down_compressor,
                          state_dtype=state_dtype,
                          cross_carrier=cross_carrier,
                          cross_compressor=cross_compressor),))

    @property
    def has_downlink(self) -> bool:
        return any(g.has_downlink for g in self.groups)

    def match(self, path: str) -> int:
        """First-match-wins group index for one leaf path."""
        for i, g in enumerate(self.groups):
            if pattern_matches(g.pattern, path):
                return i
        raise ValueError(             # unreachable: '*' is mandatory
            f"leaf {path!r} matched no group (no catch-all?)")

    def resolve(self, tree: PyTree) -> Tuple[int, ...]:
        """Per-leaf group index in ``tree_flatten`` order. Every leaf lands
        in exactly one group (first-match-wins over a total pattern list)."""
        return tuple(self.match(p) for p in leaf_paths(tree))


# ---------------------------------------------------------------------------
# per-group method view
# ---------------------------------------------------------------------------

def group_method(method: "ef_lib.Method", grp: Group) -> "ef_lib.Method":
    """The method as one group sees it: same semantics, the group's
    compressor and EF-state dtype."""
    if grp.state_dtype is None:
        dt = method.state_dtype
    elif grp.state_dtype == "bfloat16":
        dt = jnp.bfloat16
    else:
        dt = jnp.float32
    return dataclasses.replace(method, compressor=grp.compressor,
                               state_dtype=dt)


# ---------------------------------------------------------------------------
# tree partition plumbing
# ---------------------------------------------------------------------------

def _leaves(tree: PyTree) -> List:
    return jax.tree_util.tree_flatten(tree)[0]


def _group_indices(schedule: CompressionSchedule, base: PyTree
                   ) -> List[Tuple[int, ...]]:
    gids = schedule.resolve(base)
    return [tuple(i for i, g in enumerate(gids) if g == gi)
            for gi in range(len(schedule.groups))]


def _take(tree: PyTree, ii: Tuple[int, ...]) -> List:
    leaves = _leaves(tree)
    return [leaves[i] for i in ii]


def _take_grads(grads: PyTree, method, ii: Tuple[int, ...]):
    """Grads for one group — a leaf list, or a pair of leaf lists for
    paired-gradient methods (STORM / ideal)."""
    if method.needs_paired_grads:
        return (_take(grads[0], ii), _take(grads[1], ii))
    return _take(grads, ii)


def _take_state(state: Dict, ii: Tuple[int, ...]) -> Dict:
    return {k: _take(v, ii) for k, v in state.items()}


def _scatter(out: List, ii: Tuple[int, ...], parts: List) -> None:
    for i, leaf in zip(ii, parts):
        out[i] = leaf


def _group_rng(rng, gi: int, n_groups: int):
    """One group → the round rng untouched (bit-identity with the legacy
    single-compressor path); several → decorrelate by group index."""
    if rng is None or n_groups == 1:
        return rng
    return jax.random.fold_in(rng, gi)


# ---------------------------------------------------------------------------
# EF state init, grouped
# ---------------------------------------------------------------------------

def init_state_grouped(schedule: CompressionSchedule, method,
                       params_like: PyTree,
                       init_grads: Optional[PyTree] = None) -> Dict:
    """``method.init`` per group (per-group EF-state dtype), merged back onto
    the full param treedef. One client's state — callers vmap for the client
    axis exactly as with ``method.init``."""
    treedef = jax.tree_util.tree_structure(params_like)
    n = treedef.num_leaves
    idx = _group_indices(schedule, params_like)
    merged: Optional[Dict[str, List]] = None
    for gi, grp in enumerate(schedule.groups):
        ii = idx[gi]
        if not ii:
            continue
        m_g = group_method(method, grp)
        g0 = None if init_grads is None else _take(init_grads, ii)
        st = m_g.init(_take(params_like, ii), init_grads=g0)
        if merged is None:
            merged = {k: [None] * n for k in st}
        for k, part in st.items():
            _scatter(merged[k], ii, part)
    if not merged:
        return {}
    return {k: jax.tree_util.tree_unflatten(treedef, v)
            for k, v in merged.items()}


# ---------------------------------------------------------------------------
# one grouped client round — shared scaffolding + the two layouts
# ---------------------------------------------------------------------------

def _grouped_round(schedule: CompressionSchedule, method, grads: PyTree,
                   states: Dict, rng, eta, leg,
                   overlap: bool = False) -> Tuple[PyTree, Dict]:
    """The scaffolding both layouts share: resolve leaves → per-group take →
    ``leg(m_g, carrier, plan, grads_g, states_g, r_g) -> (agg_g, new_st)`` →
    scatter-merge back onto the full treedef. Keeping this in ONE place is
    what keeps the vmap and shard_map runtimes mechanically equivalent —
    only the per-plan leg bodies (collectives vs leading-axis means) differ.
    Returns ``(msg_mean, new_states)``."""
    base = grads[0] if method.needs_paired_grads else grads
    treedef = jax.tree_util.tree_structure(base)
    n_leaves = treedef.num_leaves
    idx = _group_indices(schedule, base)
    ng = len(schedule.groups)

    agg_out: List = [None] * n_leaves
    state_out: Optional[Dict[str, List]] = None
    for gi, grp in enumerate(schedule.groups):
        ii = idx[gi]
        if not ii:
            continue
        m_g = group_method(method, grp)
        carrier = carrier_lib.make(grp.carrier)
        if overlap:
            carrier = dataclasses.replace(carrier, overlap=True)
        plan = carrier.plan(m_g, eta)
        agg_g, new_st = leg(m_g, carrier, plan,
                            _take_grads(grads, method, ii),
                            _take_state(states, ii),
                            _group_rng(rng, gi, ng))
        _scatter(agg_out, ii, agg_g)
        if state_out is None:
            state_out = {k: [None] * n_leaves for k in new_st}
        for k, part in new_st.items():
            _scatter(state_out[k], ii, part)

    msg_mean = jax.tree_util.tree_unflatten(treedef, agg_out)
    if not state_out:
        return msg_mean, {}
    new_states = {k: jax.tree_util.tree_unflatten(treedef, v)
                  for k, v in state_out.items()}
    return msg_mean, new_states


def round_batched(schedule: CompressionSchedule, method, grads: PyTree,
                  states: Dict, dp: int, rng, eta=None, mask=None,
                  pods: int = 1) -> Tuple[PyTree, Dict]:
    """Per-group client legs with clients on a leading axis (the vmap
    runtimes). Each group independently picks its carrier's plan and builds
    its own wire; results merge back onto the full treedef. ``mask`` is an
    optional (dp,) cohort mask (DESIGN.md §11): each group zeroes the
    non-sampled clients' contribution before its own aggregation — the
    freeze/rescale postlude stays at the CALLER (one method/mode across all
    groups). ``pods > 1`` (DESIGN.md §13) returns PER-POD means on a leading
    pods axis (pod-major client blocks) instead of the global mean — the
    intra hop of the hierarchical topology; the caller's pod tier owns the
    cross hop. Returns ``(msg_mean, new_states)``."""
    if pods > 1 and dp % pods:
        raise ValueError(f"pods={pods} must divide the client count {dp}")

    def agg(leaves_list):
        if pods > 1:
            m = dp // pods
            return jax.tree_util.tree_map(
                lambda c: c.reshape(pods, m, *c.shape[1:]).mean(1),
                leaves_list)
        return jax.tree_util.tree_map(lambda c: c.mean(0), leaves_list)

    def leg(m_g, carrier, plan, grads_g, states_g, r_g):
        if plan == "fused":
            c_tree, new_st = carrier.fused_update(
                m_g, grads_g, states_g, eta=eta, batched=True)
            if mask is not None:
                c_tree = part_lib.apply_mask(mask, c_tree)
            return agg(c_tree), new_st
        if plan == "fused_wire":
            if mask is not None:
                # unreachable behind the spec/build construction errors
                raise ValueError("sampled participation cannot run the "
                                 "fused_wire plan")
            if pods > 1:
                # unreachable behind the spec/build construction errors
                raise ValueError("the fused_wire plan cannot run under a "
                                 "hierarchical topology (its wire IS the "
                                 "global aggregation)")
            return carrier.fused_wire_round(
                m_g, grads_g, states_g, eta=eta, batched=True, dp=dp)
        if plan == "wire":
            deltas, ctxs = jax.vmap(
                lambda g, s, m=m_g: m.pre_compress(g, s, eta=eta))(
                grads_g, states_g)
            if mask is not None:
                deltas = part_lib.apply_mask(mask, deltas)
            c_tree, agg_g = carrier_lib.wire_round_batched(
                carrier, m_g.compressor, deltas, dp)
            _, new_st = jax.vmap(m_g.post_compress)(c_tree, ctxs)
            # per-pod means of the decoded client messages; the global
            # aggregate the carrier built is unused and DCE'd under jit
            return (agg(c_tree) if pods > 1 else agg_g), new_st
        if r_g is None:
            msgs, new_st = jax.vmap(
                lambda g, s, m=m_g: m.update(g, s, None, eta=eta))(
                grads_g, states_g)
        else:
            rngs = jax.random.split(r_g, dp)
            msgs, new_st = jax.vmap(
                lambda g, s, r, m=m_g: m.update(g, s, r, eta=eta))(
                grads_g, states_g, rngs)
        if mask is not None:
            msgs = part_lib.apply_mask(mask, msgs)
        return agg(msgs), new_st

    return _grouped_round(schedule, method, grads, states, rng, eta, leg)


def round_local(schedule: CompressionSchedule, method, grads: PyTree,
                states: Dict, axes: Tuple[str, ...], rng, eta=None,
                overlap: bool = False, mask=None) -> Tuple[PyTree, Dict]:
    """Per-group client legs with client-local leaves and explicit named-axis
    collectives (``ef_round_sharded``). ``overlap`` turns each group
    carrier's gather-wire aggregation into the ppermute ring
    (carriers.ring_all_gather — bit-identical transport). ``mask`` is this
    device's SCALAR cohort membership (DESIGN.md §11): each group zeroes a
    non-sampled device's contribution before its collective — the
    freeze/rescale postlude stays at the CALLER. Returns
    ``(msg_mean, new_states)``."""
    def leg(m_g, carrier, plan, grads_g, states_g, r_g):
        if plan == "fused":
            c_tree, new_st = carrier.fused_update(
                m_g, grads_g, states_g, eta=eta)
            if mask is not None:
                c_tree = part_lib.apply_mask(mask, c_tree)
            return jax.tree_util.tree_map(
                lambda c: jax.lax.pmean(c, axes), c_tree), new_st
        if plan == "fused_wire":
            if mask is not None:
                # unreachable behind the spec/build construction errors
                raise ValueError("sampled participation cannot run the "
                                 "fused_wire plan")
            return carrier.fused_wire_round(
                m_g, grads_g, states_g, eta=eta, axes=axes)
        if plan == "wire":
            deltas, ctx = m_g.pre_compress(grads_g, states_g, eta=eta)
            if mask is not None:
                deltas = part_lib.apply_mask(mask, deltas)
            c_tree, agg_g = carrier_lib.wire_round_local(
                carrier, m_g.compressor, deltas, axes, r_g)
            _, new_st = m_g.post_compress(c_tree, ctx)
            return agg_g, new_st
        msg, new_st = m_g.update(grads_g, states_g, r_g, eta=eta)
        if mask is not None:
            msg = part_lib.apply_mask(mask, msg)
        return jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, axes), msg), new_st

    return _grouped_round(schedule, method, grads, states, rng, eta, leg,
                          overlap=overlap)


# ---------------------------------------------------------------------------
# grouped downlink (server → client broadcast)
# ---------------------------------------------------------------------------

def downlink_round_grouped(schedule: CompressionSchedule, g_server: PyTree,
                           h: PyTree, rng, memory: bool = True
                           ) -> Tuple[PyTree, PyTree]:
    """Per-group downlink legs. Groups WITH a downlink carrier run the exact
    ``ef.downlink_sync`` semantics on their leaves (broadcast the wire of
    C(g − h), everyone integrates the decode); groups without ship the
    implicit dense broadcast — g_est is g_server and h simply tracks it.
    Returns ``(g_est, h_new)`` on the full treedef."""
    treedef = jax.tree_util.tree_structure(g_server)
    n_leaves = treedef.num_leaves
    idx = _group_indices(schedule, g_server)
    ng = len(schedule.groups)

    est_out: List = [None] * n_leaves
    h_out: List = [None] * n_leaves
    for gi, grp in enumerate(schedule.groups):
        ii = idx[gi]
        if not ii:
            continue
        s_g = _take(g_server, ii)
        if not grp.has_downlink:
            _scatter(est_out, ii, s_g)
            _scatter(h_out, ii, s_g)
            continue
        car = carrier_lib.make(grp.down_carrier)
        comp = grp.down_comp()
        r_g = _group_rng(rng, gi, ng)
        h_g = _take(h, ii)
        est_g, h_new_g = ef_lib.downlink_sync(car, comp, s_g, h_g, rng=r_g,
                                              memory=memory)
        _scatter(est_out, ii, est_g)
        _scatter(h_out, ii, h_new_g if h_new_g is not None else est_g)

    return (jax.tree_util.tree_unflatten(treedef, est_out),
            jax.tree_util.tree_unflatten(treedef, h_out))


# ---------------------------------------------------------------------------
# grouped cross-pod hop (pod aggregator → server, DESIGN.md §13)
# ---------------------------------------------------------------------------

def cross_round_grouped(schedule: CompressionSchedule, t_new: PyTree,
                        b: PyTree, rng) -> PyTree:
    """Per-group CROSS-pod hop for ONE pod aggregator: groups with a
    non-trivial cross carrier ship C_cross(t' − b) and integrate the decode
    (the exact ``ef.downlink_sync`` semantics — the uplink twin of the §8
    broadcast memory); trivial groups are transparent, ``b' = t'``
    bit-exactly. ``rng`` is the pod's cross rng (already folded with
    CROSS_FOLD and the pod index by the caller); groups decorrelate via the
    same ``_group_rng`` fold every other grouped leg uses. Returns the new
    broadcast state ``b'`` on the full treedef."""
    treedef = jax.tree_util.tree_structure(t_new)
    n_leaves = treedef.num_leaves
    idx = _group_indices(schedule, t_new)
    ng = len(schedule.groups)

    out: List = [None] * n_leaves
    for gi, grp in enumerate(schedule.groups):
        ii = idx[gi]
        if not ii:
            continue
        t_g = _take(t_new, ii)
        if grp.trivial_cross:
            _scatter(out, ii, t_g)
            continue
        car = carrier_lib.make(grp.cross_carrier)
        _, b_new_g = ef_lib.downlink_sync(car, grp.cross_comp(), t_g,
                                          _take(b, ii),
                                          rng=_group_rng(rng, gi, ng))
        _scatter(out, ii, b_new_g)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# accounting — per-group wire words (DESIGN.md §9 rules)
# ---------------------------------------------------------------------------

def wire_words_tree(schedule: CompressionSchedule, method, tree: PyTree,
                    direction: str = "up", eta=None
                    ) -> Tuple[Tuple[float, ...], float]:
    """Honest per-client wire words of one message over ``tree``, summed per
    group and in total. Follows the plan that would EXECUTE: a group whose
    carrier degrades to the dense plan (or fuses — the fused wire is dense)
    ships its dense word count. ``direction='down'`` counts the broadcast
    instead (a group with no downlink honestly ships its dense leaves);
    ``direction='cross'`` counts ONE pod aggregator's cross-pod message
    (DESIGN.md §13 — callers multiply by pods)."""
    idx = _group_indices(schedule, tree)
    leaves = _leaves(tree)
    per: List[float] = []
    for gi, grp in enumerate(schedule.groups):
        total = 0.0
        if direction == "down":
            car = carrier_lib.make(grp.down_carrier)
            comp = grp.down_comp()
            for i in idx[gi]:
                d = int(leaves[i].size)
                total += (carrier_lib.downlink_words(car, comp, d)
                          if grp.has_downlink else float(d))
        elif direction == "cross":
            car = carrier_lib.make(grp.cross_carrier)
            comp = grp.cross_comp()
            for i in idx[gi]:
                d = int(leaves[i].size)
                total += (float(d) if grp.trivial_cross
                          else carrier_lib.downlink_words(car, comp, d))
        else:
            m_g = group_method(method, grp)
            car = carrier_lib.make(grp.carrier)
            plan = car.plan(m_g, eta)
            for i in idx[gi]:
                d = int(leaves[i].size)
                # the fused_wire plan ships the quantized payload, so it
                # counts the carrier's wire words exactly like 'wire'
                total += (car.wire_words(m_g.compressor, d)
                          if plan in ("wire", "fused_wire") else float(d))
        per.append(total)
    return tuple(per), float(sum(per))


def coords_tree(schedule: CompressionSchedule, method, tree: PyTree) -> float:
    """Idealized transmitted-coordinate count (the paper's x-axis), summed
    over groups — the schedule form of ``Method.coords_per_message(d)``."""
    idx = _group_indices(schedule, tree)
    leaves = _leaves(tree)
    total = 0.0
    for gi, grp in enumerate(schedule.groups):
        m_g = group_method(method, grp)
        for i in idx[gi]:
            total += m_g.coords_per_message(int(leaves[i].size))
    return total


def alpha_min(schedule: CompressionSchedule, tree: PyTree) -> float:
    """The composed contraction parameter: a product of contractive maps over
    a partition is contractive with α = min over the factors."""
    idx = _group_indices(schedule, tree)
    leaves = _leaves(tree)
    alphas = []
    for gi, grp in enumerate(schedule.groups):
        for i in idx[gi]:
            alphas.append(grp.compressor.alpha(int(leaves[i].size)))
    return min(alphas) if alphas else 1.0


# ---------------------------------------------------------------------------
# the resolved group table (launch surfaces print this)
# ---------------------------------------------------------------------------

def plan_table(schedule: CompressionSchedule, method, tree: PyTree,
               eta=None) -> str:
    """Human-readable resolved table: one row per group with its leaf/param
    counts, transport plan (and degradation reason, if any), downlink plan
    and per-message wire words — what build/train/session print so a
    mixed-schedule run is legible in logs."""
    idx = _group_indices(schedule, tree)
    leaves = _leaves(tree)
    up_per, up_total = wire_words_tree(schedule, method, tree, "up", eta)
    dn_per, dn_total = wire_words_tree(schedule, method, tree, "down", eta)
    rows = [f"{'group':18s} {'leaves':>6s} {'params':>10s} "
            f"{'compressor':14s} {'carrier':12s} {'plan':10s} "
            f"{'down':8s} {'wire_up':>10s} {'wire_down':>10s}"]
    for gi, grp in enumerate(schedule.groups):
        m_g = group_method(method, grp)
        car = carrier_lib.make(grp.carrier)
        plan, reason = car.plan_with_reason(m_g, eta)
        params = sum(int(leaves[i].size) for i in idx[gi])
        rows.append(
            f"{grp.pattern:18s} {len(idx[gi]):6d} {params:10d} "
            f"{type(grp.compressor).__name__:14s} {grp.carrier:12s} "
            f"{plan:10s} {grp.down_carrier:8s} {up_per[gi]:10.0f} "
            f"{dn_per[gi]:10.0f}"
            + (f"  (degraded: {reason})" if reason else ""))
    rows.append(f"{'TOTAL':18s} {len(leaves):6d} "
                f"{sum(int(x.size) for x in leaves):10d} "
                f"{'':14s} {'':12s} {'':10s} {'':8s} {up_total:10.0f} "
                f"{dn_total:10.0f}")
    return "\n".join(rows)
