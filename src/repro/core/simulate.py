"""n-client distributed-training simulator (Algorithm 1's outer loop).

This is the exact loop of Algorithm 1 / 3 / 4 / 5 (and the EF14/SGD baselines) run
over an arbitrary :class:`repro.core.problems.Problem`, with all n clients carried as
a leading axis and stepped by ``vmap`` — a faithful single-host emulation of the
distributed method that the paper's own experiments use. The production multi-chip
path lives in core/distributed.py; both share the Method implementations AND the
wire carrier (core/carriers.py), so what is validated here is what runs on the
mesh: ``SimConfig.carrier`` selects dense / sparse / fused / quant8 / quant4
exactly like ``EFConfig.carrier`` does on the production path, and
``SimConfig.down_carrier`` / ``down_compressor`` add the same downlink
broadcast leg (EF21 server memory h, DESIGN.md §8) the production runtimes
run — plus the simulator-only ``down_memory=False`` naive-broadcast ablation
the paper-claims tests use.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import carriers as carrier_lib
from repro.core import compressors as comp_lib
from repro.core import ef as ef_lib
from repro.core import hierarchy as hier_lib
from repro.core import participation as part_lib
from repro.core import schedule as sched_lib

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n: int = 1                      # number of clients
    batch_size: int = 1             # per-client minibatch B
    gamma: float = 1e-3             # step size γ
    eta: Optional[float] = None     # momentum η override (None → method default)
    steps: int = 1000               # T
    b_init: int = 1                 # initial batch size B_init (Alg 1 line 2)
    time_varying: bool = False      # γₜ = γ/√(t+1), ηₜ = η/√(t+1) (App. J / Fig 4)
    record_every: int = 1
    carrier: str = "dense"     # any core/carriers.py REGISTRY name:
    # 'dense'|'sparse'|'fused'|'quant8'|'quant4'|'fused_quant8'|'fused_quant4'
    # downlink (server → client broadcast) leg, DESIGN.md §8. The default
    # ('dense', no compressor) is the unidirectional simulator, bit-identical
    # to pre-downlink behavior. ``down_memory=False`` is the NAIVE ablation
    # (broadcast C(g) with no server memory — nothing re-sends the
    # compression error; the paper-claims tests show it stalling).
    down_carrier: str = "dense"
    down_compressor: Optional[Any] = None   # a Compressor (frozen → hashable)
    down_memory: bool = True
    # per-parameter-group compression (DESIGN.md §9): when set, the round and
    # the wire accounting dispatch through the grouped engine in
    # core/schedule.py, exactly like EFConfig.schedule on the production
    # runtimes; the single-knob carrier/down_* fields above are ignored.
    schedule: Optional[sched_lib.CompressionSchedule] = None
    # partial participation (DESIGN.md §11): mode='sampled' masks each round
    # to a seeded cohort — non-sampled clients' wires are zeroed before the
    # aggregation and their ENTIRE EF state (gᵢ, momentum) stays bit-frozen
    # (the "EF21 with Bells & Whistles" rule). None / mode='full' is the
    # legacy full-cohort loop; fraction=1.0 sampling is bit-identical to it.
    # mode='async' never runs here — core/participation.py::run_async is the
    # event-driven simulator.
    participation: Optional[part_lib.Participation] = None
    # two-tier hierarchical aggregation (DESIGN.md §13): clients → pod
    # aggregator → global server, with the cross-pod hop on its own
    # carrier/compressor and its own EF memory per pod. None or pods=1 is
    # the flat loop, bit-identical to today. Mirrors EFConfig.hops exactly
    # (same Hops knob, same trivial-cross flat-equivalence regime).
    hops: Optional[hier_lib.Hops] = None

    @property
    def effective_hops(self) -> Optional[hier_lib.Hops]:
        return hier_lib.effective(self.hops)

    @property
    def has_downlink(self) -> bool:
        if self.schedule is not None:
            return self.schedule.has_downlink
        return (self.down_carrier != "dense"
                or self.down_compressor is not None)


def _client_rngs(rng, n):
    return jax.random.split(rng, n)


@partial(jax.jit, static_argnames=("problem", "method", "cfg"))
def run(problem, method: ef_lib.Method, cfg: SimConfig, rng: jax.Array) -> Dict:
    """Run T steps; returns per-recorded-step metrics (grad norm², f(x), coords sent).

    problem: frozen dataclass with
        init_x()                          -> pytree x⁰
        stoch_grad(x, client, rng, B)     -> pytree (client ∈ [0, n))
        full_grad(x)                      -> pytree ∇f(x)
        loss(x)                           -> scalar f(x)
    """
    x0 = problem.init_x()
    rng, r_init = jax.random.split(rng)

    clients = jnp.arange(cfg.n)

    def init_grad_one(c, r):
        ks = jax.random.split(r, cfg.b_init)
        gs = jax.vmap(lambda k: problem.stoch_grad(x0, c, k, cfg.batch_size))(ks)
        return jax.tree_util.tree_map(lambda g: g.mean(0), gs)

    g0 = jax.vmap(init_grad_one)(clients, _client_rngs(r_init, cfg.n))
    if cfg.schedule is not None:
        states = jax.vmap(lambda g: sched_lib.init_state_grouped(
            cfg.schedule, method, x0, init_grads=g))(g0)
    else:
        states = jax.vmap(lambda g: method.init(x0, init_grads=g))(g0)
    g_server = ef_lib.server_init(
        method, x0, jax.tree_util.tree_map(lambda g: g.mean(0), g0))

    carrier = carrier_lib.make(cfg.carrier)
    has_down = cfg.has_downlink
    down_car = carrier_lib.make(cfg.down_carrier)
    down_comp = cfg.down_compressor if cfg.down_compressor is not None \
        else comp_lib.Identity()
    part = cfg.participation
    if part is not None and part.mode == "async":
        raise ValueError(
            "participation mode 'async' does not run on the synchronous "
            "simulator (every scan step is a barrier); drive the "
            "event-driven simulator instead: "
            "repro.core.participation.run_async")
    sampling = part is not None and part.is_sampling
    m_cohort = part.cohort_size(cfg.n) if sampling else cfg.n

    # two-tier hierarchy (DESIGN.md §13): mirrors ef_round exactly — a
    # non-trivial cross hop pod-means the intra aggregation (pod-major
    # client blocks) and runs the per-pod cross sync; a trivial cross keeps
    # the legacy global aggregation ops verbatim (flat-equivalence anchor)
    hops = cfg.effective_hops
    trivial_cross = hops is None or hier_lib.cross_is_trivial(
        hops, cfg.schedule)
    want_pods = hops is not None and not trivial_cross
    if hops is not None:
        hier_lib.check_pods(hops, cfg.n)
        if sampling:
            raise ValueError(
                "sampled participation does not compose with hierarchical "
                "aggregation (guarded at spec/build construction)")

    def agg_mean(tree):
        if want_pods:
            return hier_lib.pod_mean(tree, hops.pods)
        return jax.tree_util.tree_map(lambda m: m.mean(0), tree)

    def step(carry, t):
        pods_st = carry[-1] if hops is not None else None
        if has_down:
            # g_est is what the clients reconstructed last round — the
            # broadcast memory h under EF21-BC, or the latest naive decode
            x, states, g_server, g_est, rng = carry[:5]
        else:
            x, states, g_server, rng = carry[:4]
            g_est = g_server        # implicit dense broadcast
        rng, r_grad, r_comp = jax.random.split(rng, 3)
        eta0 = cfg.eta if cfg.eta is not None else getattr(method, "eta", 1.0)
        if cfg.time_varying:
            # App. J schedule: γₜ = γ/√(t+1), ηₜ = 1/√(t+1)
            scale = 1.0 / jnp.sqrt(t + 1.0)
            gamma_t = cfg.gamma * scale
            eta_t = jnp.minimum(scale, 1.0)
        else:
            # constant-parameter setting of Theorems 2/3 — η stays a python
            # float so the fused carrier can bake it into the Pallas kernel
            gamma_t, eta_t = cfg.gamma, eta0

        x_next = jax.tree_util.tree_map(lambda p, g: p - gamma_t * g, x, g_est)

        def client_grads(c, rg):
            if method.needs_paired_grads:
                g_new = problem.stoch_grad(x_next, c, rg, cfg.batch_size)
                if method.name == "ef21_sgdm_ideal":
                    exact = getattr(problem, "client_grad",
                                    lambda xx, cc: problem.full_grad(xx))
                    return (g_new, exact(x_next, c))
                # STORM: two stochastic grads under the SAME ξ
                return (g_new, problem.stoch_grad(x, c, rg, cfg.batch_size))
            return problem.stoch_grad(x_next, c, rg, cfg.batch_size)

        r_grads = _client_rngs(r_grad, cfg.n)
        # cohort mask for this round (DESIGN.md §11): seeded pure in
        # (seed, t), so kill-and-resume replays the exact cohort sequence
        mask = part_lib.cohort_mask(part, cfg.n, t) if sampling else None
        plan = carrier.plan(method, eta_t)   # static: traced ηₜ forces 'dense'
        if cfg.schedule is not None:
            grads = jax.vmap(client_grads)(clients, r_grads)
            msg_mean, states_new = sched_lib.round_batched(
                cfg.schedule, method, grads, states, cfg.n, r_comp, eta_t,
                mask=mask, pods=hops.pods if want_pods else 1)
        elif plan == "fused":
            grads = jax.vmap(client_grads)(clients, r_grads)
            c_tree, states_new = carrier.fused_update(
                method, grads, states, eta=eta_t, batched=True)
            if mask is not None:
                c_tree = part_lib.apply_mask(mask, c_tree)
            msg_mean = agg_mean(c_tree)
        elif plan == "fused_wire":
            if mask is not None:
                # unreachable behind the spec/build construction errors: the
                # mega-kernel aggregates inside, no per-client wire to mask
                raise ValueError(
                    "sampled participation cannot run the fused_wire plan")
            if hops is not None:
                raise ValueError(
                    "fused_wire carriers aggregate all clients inside the "
                    "mega-kernel — there is no per-pod message to "
                    "re-aggregate (guarded at spec/build construction)")
            grads = jax.vmap(client_grads)(clients, r_grads)
            msg_mean, states_new = carrier.fused_wire_round(
                method, grads, states, eta=eta_t, batched=True, dp=cfg.n)
        elif plan == "wire":
            grads = jax.vmap(client_grads)(clients, r_grads)
            deltas, ctxs = jax.vmap(
                lambda g, s: method.pre_compress(g, s, eta=eta_t))(
                grads, states)
            if mask is not None:
                # zero-masked wires: C(0) = 0 exactly, the carrier's own
                # aggregation then folds only the sampled cohort
                deltas = part_lib.apply_mask(mask, deltas)
            c_tree, wire_mean = carrier_lib.wire_round_batched(
                carrier, method.compressor, deltas, cfg.n)
            # non-trivial hops pod-mean the per-client messages (local_c IS
            # the decode of what traveled); the global aggregate is DCE'd
            msg_mean = agg_mean(c_tree) if want_pods else wire_mean
            _, states_new = jax.vmap(method.post_compress)(c_tree, ctxs)
        else:
            def client_update(c, st, rg, rc):
                return method.update(client_grads(c, rg), st, rc, eta=eta_t)
            msgs, states_new = jax.vmap(client_update)(
                clients, states, r_grads, _client_rngs(r_comp, cfg.n))
            if mask is not None:
                msgs = part_lib.apply_mask(mask, msgs)
            msg_mean = agg_mean(msgs)
        if mask is not None:
            # Bells & Whistles: delta methods fold (1/n)Σ_S as-is, absolute
            # methods rescale to the cohort mean; non-sampled clients keep
            # their ENTIRE state tree (gᵢ, momentum, …) bit-frozen
            msg_mean = part_lib.rescale_message(
                method, msg_mean, cfg.n, m_cohort)
            states_new = part_lib.freeze_tree(mask, states_new, states)
        if want_pods:
            # the pod tier: per-pod target update + cross hop + server
            # integration, rng off the round key exactly like ef_round
            pods_new, g_server_new = hier_lib.round_pods_batched(
                hops, cfg.schedule, method, msg_mean, pods_st, g_server,
                r_comp)
        else:
            g_server_new = ef_lib.server_step(method, g_server, msg_mean)
            pods_new = None if hops is None else \
                hier_lib.trivial_bookkeeping(method, pods_st, msg_mean)
        pods_tail = (pods_new,) if hops is not None else ()

        gn = ef_lib.tree_norm_sq(problem.full_grad(x_next))
        fl = problem.loss(x_next)
        if has_down:
            r_down = jax.random.fold_in(r_comp, carrier_lib.DOWNLINK_FOLD)
            if cfg.schedule is not None:
                g_est_new, _ = sched_lib.downlink_round_grouped(
                    cfg.schedule, g_server_new, g_est, r_down,
                    memory=cfg.down_memory)
            else:
                g_est_new, _ = ef_lib.downlink_sync(
                    down_car, down_comp, g_server_new, g_est, rng=r_down,
                    memory=cfg.down_memory)
            return (x_next, states_new, g_server_new, g_est_new,
                    rng) + pods_tail, (gn, fl)
        return (x_next, states_new, g_server_new, rng) + pods_tail, (gn, fl)

    # h⁰ = g⁰ (downlink_init): the init handshake ships dense state once
    carry0 = (x0, states, g_server, ef_lib.downlink_init(g_server), rng) \
        if has_down else (x0, states, g_server, rng)
    if hops is not None:
        # per-pod EF memory rides the scan carry (kill-and-resume of the
        # production runtimes carries the same tree via ef_state['pods'])
        carry0 = carry0 + (jax.vmap(lambda _: hier_lib.pod_init(x0))(
            jnp.arange(hops.pods)),)
    (x_fin, *_), (gns, fls) = jax.lax.scan(
        step, carry0, jnp.arange(cfg.steps))
    d_total = ef_lib.tree_dim(x0)
    # honest wire accounting follows the plan that actually EXECUTED: when the
    # carrier degrades to the dense plan (unsupported compressor/method,
    # traced ηₜ), what went on the wire was the dense tensor — d words
    eta_static = None if cfg.time_varying else (
        cfg.eta if cfg.eta is not None else getattr(method, "eta", 1.0))
    # Sampled participation: only the m = cohort_size(fraction·n) sampled
    # clients upload, so the honest uplink budget is per-message words × m
    # (DESIGN.md §11). The downlink broadcast still reaches all n links —
    # that is how absent clients stay in sync with the server memory h.
    if cfg.schedule is not None:
        # per-group accounting (DESIGN.md §9): each group's executed wire,
        # summed over its leaves — exposed per group AND in total
        up_per, up_each = sched_lib.wire_words_tree(
            cfg.schedule, method, x0, "up", eta_static)
        dn_per, dn_each = sched_lib.wire_words_tree(
            cfg.schedule, method, x0, "down", eta_static)
        up_words, down_words = up_each * m_cohort, dn_each * cfg.n
        coords = sched_lib.coords_tree(cfg.schedule, method, x0) * m_cohort
        group_words = {
            "wire_words_up_per_group": tuple(w * m_cohort for w in up_per),
            "wire_words_down_per_group": tuple(w * cfg.n for w in dn_per),
        }
    else:
        executed = cfg.carrier \
            if carrier.plan(method, eta_static) != "dense" else "dense"
        up_words = method.coords_per_message(
            d_total, carrier=executed) * m_cohort
        # downlink: one broadcast message per client link; without a downlink
        # carrier the server ships the dense f32 estimate — d words per client
        down_each = carrier_lib.downlink_words(down_car, down_comp, d_total) \
            if has_down else float(d_total)
        down_words = down_each * cfg.n
        coords = method.coords_per_message(d_total) * m_cohort
        group_words = {}
    # per-hop accounting (DESIGN.md §13): under a flat topology the only
    # client→server hop IS the cross-pod wire (cross := up, intra := 0);
    # under hops the n client messages ride the fast intra-pod links and the
    # slow cross-pod links carry one compressed innovation per pod
    if hops is None:
        intra_words, cross_words = 0.0, up_words
    else:
        intra_words = up_words
        cross_words = hier_lib.wire_words_cross(hops, cfg.schedule, method,
                                                x0)
    return {
        "grad_norm_sq": gns,
        "loss": fls,
        "x_final": x_fin,
        # paper x-axis: idealized transmitted-coordinate count
        "coords_per_round": coords,
        # honest word count of the executed wire (values + indices; dense
        # all-reduce ships d) — see Carrier.wire_words. The legacy key is
        # the UPLINK leg; the split keys make the total wire budget per
        # round (the paper's communication-complexity story) explicit.
        "wire_words_per_round": up_words,
        "wire_words_up_per_round": up_words,
        "wire_words_down_per_round": down_words,
        "wire_words_total_per_round": intra_words + cross_words + down_words,
        # per-hop split (DESIGN.md §13): intra = per-message words × n
        # clients, cross = per-pod innovation words × pods
        "wire_words_intra_per_round": intra_words,
        "wire_words_cross_per_round": cross_words,
        **group_words,
    }


def run_numpy(problem, method, cfg: SimConfig, seed: int = 0) -> Dict:
    """Convenience wrapper returning numpy arrays."""
    out = run(problem, method, cfg, jax.random.PRNGKey(seed))
    return {k: jax.device_get(v) for k, v in out.items()}
