"""Error-feedback methods from the paper, as functional pytree transforms.

Every method is a pair ``(init, update)``:

  state  = method.init(params_like, init_grads=None)       # per-CLIENT state
  msg, state' = method.update(grads, state, rng)           # one client step

``msg`` is the vector the client transmits. The server/aggregation rule is given by
``method.mode``:

  'delta'    : the server maintains gᵗ and applies   gᵗ⁺¹ = gᵗ + meanᵢ(msgᵢ)
               (EF21 family — msg is the compressed innovation cᵢ; Algorithm 1 line 10)
  'absolute' : the server uses                        gᵗ⁺¹ = meanᵢ(msgᵢ)
               (EF14 / SGD / SGDM — msg is the full local estimate)

The model update is then ``x ← x − γ·gᵗ⁺¹`` (launch/train.py composes this with a full
optimizer; benchmarks use the paper's plain step).

Two-phase decomposition
-----------------------
``update`` factors as  pre_compress → C(·) → post_compress.  The distributed runtime
(core/distributed.py) exploits this to swap the compression carrier (dense tensor vs
fixed-K (values, indices)) and to fuse the whole client update into a single Pallas
kernel (kernels/ef_update.py) without touching method semantics.

Paper ↔ code map
----------------
  EF21-SGD        (5a)+(5ab)              → EF21SGD
  EF21-SGDM       Algorithm 1             → EF21SGDM
  EF21-SGD2M      Algorithm 3 / eq (10)   → EF21SGD2M
  EF21-SGDM (abs) Algorithm 4             → EF21SGDMAbs
  EF21-STORM/MVR  Algorithm 5 / eq (12)   → EF21STORM     (paired-noise gradients)
  EF14-SGD        eq (64)–(65)            → EF14SGD
  SGDM            eq (3) / Appendix J     → SGDM (== EF21SGDM with Identity)
  NEOLITHIC       [Huang et al., 2022]    → Neolithic (R residual-compression rounds)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compressors as comp_lib

PyTree = Any


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def tree_rngs(rng: jax.Array, tree: PyTree) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))


def tree_compress(comp: comp_lib.Compressor, tree: PyTree, rng: Optional[jax.Array]) -> PyTree:
    """Apply a flat-vector compressor leaf-wise (K budget ∝ leaf size)."""
    if rng is None:
        return jax.tree_util.tree_map(
            lambda x: comp(x.reshape(-1)).reshape(x.shape), tree)
    rngs = tree_rngs(rng, tree)
    return jax.tree_util.tree_map(
        lambda x, k: comp(x.reshape(-1), k).reshape(x.shape), tree, rngs)


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_lerp(a, b, eta):
    """(1-eta)*a + eta*b — the Polyak momentum update, leaf-wise."""
    return jax.tree_util.tree_map(
        lambda x, y: ((1.0 - eta) * x.astype(jnp.float32)
                      + eta * y.astype(jnp.float32)).astype(x.dtype), a, b)


def tree_dim(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_norm_sq(tree) -> jax.Array:
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
               for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    if dtype is None:
        return tree
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


# ---------------------------------------------------------------------------
# method base
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Method:
    """Base EF method. Frozen dataclass → usable as a jit static argument."""

    compressor: comp_lib.Compressor = comp_lib.Identity()
    state_dtype: Optional[Any] = None   # None → follow grads; jnp.bfloat16 at LLM scale

    name: str = "base"
    mode: str = "delta"            # 'delta' | 'absolute'
    needs_paired_grads: bool = False
    # True iff the transmitted message IS the compressed tensor c (post_compress
    # returns c unchanged) — the condition for non-dense carriers to aggregate
    # the wire directly (core/carriers.py). False for methods whose message is a
    # transform of c (Abs scaling) or that bypass the two-phase API entirely.
    wire_is_msg: bool = True

    # -- client: two-phase API ----------------------------------------------
    def init(self, params_like: PyTree, init_grads: Optional[PyTree] = None) -> Dict:
        raise NotImplementedError

    def pre_compress(self, grads: PyTree, state: Dict, *, eta=None
                     ) -> Tuple[PyTree, Dict]:
        """→ (delta_to_compress, ctx)."""
        raise NotImplementedError

    def post_compress(self, c: PyTree, ctx: Dict) -> Tuple[PyTree, Dict]:
        """→ (msg, new_state)."""
        raise NotImplementedError

    def update(self, grads: PyTree, state: Dict, rng: Optional[jax.Array] = None,
               *, eta=None, **kw) -> Tuple[PyTree, Dict]:
        delta, ctx = self.pre_compress(grads, state, eta=eta)
        c = tree_compress(self.compressor, delta, rng)
        return self.post_compress(c, ctx)

    # -- accounting (paper plots use "# transmitted coordinates") -----------
    def coords_per_message(self, d: int, carrier=None, direction: str = "up",
                           compressor=None) -> float:
        """Idealized transmitted-coordinate count (paper x-axes) when
        ``carrier`` is None; otherwise delegates to ``Carrier.wire_words`` —
        the honest word count of the actual wire format (dense all-reduce
        ships d words even for a sparse-valued c; the sparse carrier ships
        values AND indices). ``direction='down'`` counts the server
        broadcast instead (``carriers.downlink_words``: one message, no
        aggregation, dense d words on a degraded plan); pass ``compressor``
        to account a downlink compressor different from the uplink one."""
        comp = compressor if compressor is not None else self.compressor
        if direction == "down":
            from repro.core import carriers as carrier_lib
            car = carrier_lib.make(carrier if carrier is not None else "dense")
            return carrier_lib.downlink_words(car, comp, d)
        if carrier is not None:
            from repro.core import carriers as carrier_lib
            return carrier_lib.make(carrier).wire_words(comp, d)
        c = comp
        if isinstance(c, comp_lib.TopK):
            return c._k(d)
        if isinstance(c, comp_lib.RandK):
            return c._k(d)
        if isinstance(c, comp_lib.BlockTopK):
            nb, _, kb = c.geom(d)       # d-aware: sub-block leaves keep K ≤ d
            return nb * kb
        if isinstance(c, comp_lib.NaturalCompression):
            return d * 9.0 / 32.0
        if isinstance(c, comp_lib.HardThreshold):
            return d  # data-dependent; upper bound
        return d

    def coords_per_message_tree(self, tree, schedule=None, carrier=None,
                                direction: str = "up", compressor=None,
                                eta=None) -> float:
        """The pytree/schedule form of ``coords_per_message``, summed over
        groups with per-leaf geometry. The units follow the flat-d form
        exactly: no ``carrier`` → the idealized transmitted-coordinate count
        (the paper's x-axis; ``direction='down'`` counts the broadcast
        words, as flat-d does); with a schedule each group already names its
        own carrier, so passing ``carrier``/``compressor`` alongside one is
        an error rather than silently ignored — for the honest executed
        wire-word sums use ``schedule.wire_words_tree`` directly. Without a
        schedule this collapses to the flat-d form over the whole tree."""
        if schedule is None:
            return self.coords_per_message(tree_dim(tree), carrier, direction,
                                           compressor)
        if carrier is not None or compressor is not None:
            raise ValueError(
                "coords_per_message_tree: with a schedule every group names "
                "its own carrier/compressor — pass only the schedule (use "
                "schedule.wire_words_tree for executed wire-word sums)")
        from repro.core import schedule as sched_lib
        if direction == "down":
            _, total = sched_lib.wire_words_tree(schedule, self, tree,
                                                 direction="down", eta=eta)
            return total
        return sched_lib.coords_tree(schedule, self, tree)

    def _cast(self, tree):
        return tree_cast(tree, self.state_dtype)

    def _eta(self, eta):
        if eta is not None:
            return eta
        return getattr(self, "eta", 1.0)


@dataclasses.dataclass(frozen=True)
class EF21SGD(Method):
    """EF21 with (mini/mega-batch) stochastic gradients — eq (5a)+(5ab).

    The paper proves (Thm 1, idealized; Figs 1 & 4 empirically) that this method
    fails near stationarity unless B = Ω(σ²/ε²).
    """
    name: str = "ef21_sgd"
    mode: str = "delta"

    def init(self, params_like, init_grads=None):
        g = init_grads if init_grads is not None else tree_zeros_like(params_like)
        return {"g": self._cast(g)}

    def pre_compress(self, grads, state, *, eta=None):
        return tree_sub(grads, state["g"]), {"g": state["g"]}

    def post_compress(self, c, ctx):
        g_new = tree_add(ctx["g"], c)
        return c, {"g": self._cast(g_new)}


@dataclasses.dataclass(frozen=True)
class EF21SGDM(Method):
    """EF21-SGDM — **Algorithm 1**, the paper's main contribution.

      vᵗ⁺¹ = (1−η)vᵗ + η ∇f(xᵗ⁺¹, ξ)      (client momentum, line 6)
      cᵗ⁺¹ = C(vᵗ⁺¹ − gᵗ)                  (line 7)
      gᵗ⁺¹ = gᵗ + cᵗ⁺¹                     (line 8)

    Theorem 3: batch-free, no BG/BGS, asymptotically optimal O(σ²/(nε⁴)) samples.
    """
    eta: float = 0.1
    name: str = "ef21_sgdm"
    mode: str = "delta"

    def init(self, params_like, init_grads=None):
        v = init_grads if init_grads is not None else tree_zeros_like(params_like)
        return {"v": self._cast(v), "g": self._cast(v)}

    def pre_compress(self, grads, state, *, eta=None):
        v_new = tree_lerp(state["v"], grads, self._eta(eta))
        return tree_sub(v_new, state["g"]), {"v": v_new, "g": state["g"]}

    def post_compress(self, c, ctx):
        g_new = tree_add(ctx["g"], c)
        return c, {"v": self._cast(ctx["v"]), "g": self._cast(g_new)}


@dataclasses.dataclass(frozen=True)
class EF21SGD2M(Method):
    """EF21-SGD2M — **Algorithm 3** (double momentum, eq (10)).

      vᵗ⁺¹ = (1−η)vᵗ + η ∇f(xᵗ⁺¹, ξ);  uᵗ⁺¹ = (1−η)uᵗ + η vᵗ⁺¹;  c = C(uᵗ⁺¹ − gᵗ)

    Corollary 3: removes the O(α^{-1/2}ε^{-3}) middle complexity term.
    """
    eta: float = 0.1
    name: str = "ef21_sgd2m"
    mode: str = "delta"

    def init(self, params_like, init_grads=None):
        v = init_grads if init_grads is not None else tree_zeros_like(params_like)
        return {"v": self._cast(v), "u": self._cast(v), "g": self._cast(v)}

    def pre_compress(self, grads, state, *, eta=None):
        e = self._eta(eta)
        v_new = tree_lerp(state["v"], grads, e)
        u_new = tree_lerp(state["u"], v_new, e)
        return tree_sub(u_new, state["g"]), \
            {"v": v_new, "u": u_new, "g": state["g"]}

    def post_compress(self, c, ctx):
        g_new = tree_add(ctx["g"], c)
        return c, {"v": self._cast(ctx["v"]), "u": self._cast(ctx["u"]),
                   "g": self._cast(g_new)}


@dataclasses.dataclass(frozen=True)
class EF21SGDMIdeal(Method):
    """EF21-SGDM-ideal — eq (14)+(15), the *conceptual* method of Theorem 4
    (η=1 gives EF21-SGD-ideal, eq (5a)+(5aa), Theorem 1).

      gᵢᵗ⁺¹ = ∇fᵢ(xᵗ⁺¹) + C(η·(∇fᵢ(xᵗ⁺¹, ξ) − ∇fᵢ(xᵗ⁺¹)))

    Requires exact gradients (not implementable at paper-scale by design —
    used for the Theorem 1 lower-bound reproduction): ``update`` takes
    ``grads=(stoch_grad, exact_grad)``.
    """
    eta: float = 1.0
    name: str = "ef21_sgdm_ideal"
    mode: str = "absolute"          # server uses gᵗ = meanᵢ gᵢᵗ directly
    needs_paired_grads: bool = True  # (stochastic, exact) pair
    wire_is_msg: bool = False        # msg = ∇fᵢ + c, not c (no two-phase API)

    def init(self, params_like, init_grads=None):
        return {}

    def update(self, grads, state, rng=None, *, eta=None, **kw):
        e = self._eta(eta)
        g_stoch, g_exact = grads
        noise = tree_scale(tree_sub(g_stoch, g_exact), e)
        c = tree_compress(self.compressor, noise, rng)
        return tree_add(g_exact, c), state


@dataclasses.dataclass(frozen=True)
class EF21SGDMAbs(Method):
    """EF21-SGDM with an *absolute* compressor — **Algorithm 4**.

    The innovation is scaled by 1/γ before compression and by γ after, so the
    absolute error Δ enters the rate as γ²Δ² (Theorem 6):
        cᵗ⁺¹ = γ·C((vᵗ⁺¹ − gᵗ)/γ)
    """
    eta: float = 0.1
    gamma: float = 1e-2
    name: str = "ef21_sgdm_abs"
    mode: str = "delta"
    wire_is_msg: bool = False        # msg = γ·c — a transform of the wire

    def init(self, params_like, init_grads=None):
        v = init_grads if init_grads is not None else tree_zeros_like(params_like)
        return {"v": self._cast(v), "g": self._cast(v)}

    def pre_compress(self, grads, state, *, eta=None):
        v_new = tree_lerp(state["v"], grads, self._eta(eta))
        innov = tree_scale(tree_sub(v_new, state["g"]), 1.0 / self.gamma)
        return innov, {"v": v_new, "g": state["g"]}

    def post_compress(self, c, ctx):
        c = tree_scale(c, self.gamma)
        g_new = tree_add(ctx["g"], c)
        return c, {"v": self._cast(ctx["v"]), "g": self._cast(g_new)}


@dataclasses.dataclass(frozen=True)
class EF21STORM(Method):
    """EF21-STORM/MVR — **Algorithm 5** (variance-reduced estimator, eq (12)).

      wᵗ⁺¹ = ∇f(xᵗ⁺¹, ξᵗ⁺¹) + (1−η)(wᵗ − ∇f(xᵗ, ξᵗ⁺¹))

    Requires TWO stochastic gradients under the SAME noise ξᵗ⁺¹ (the paper flags
    this as a practical limitation, App. B): update takes ``grads=(g_new, g_prev)``.
    """
    eta: float = 0.1
    name: str = "ef21_storm"
    mode: str = "delta"
    needs_paired_grads: bool = True

    def init(self, params_like, init_grads=None):
        w = init_grads if init_grads is not None else tree_zeros_like(params_like)
        return {"w": self._cast(w), "g": self._cast(w)}

    def pre_compress(self, grads, state, *, eta=None):
        e = self._eta(eta)
        g_new, g_prev = grads
        w_new = tree_add(g_new, tree_scale(tree_sub(state["w"], g_prev), 1.0 - e))
        return tree_sub(w_new, state["g"]), {"w": w_new, "g": state["g"]}

    def post_compress(self, c, ctx):
        g_out = tree_add(ctx["g"], c)
        return c, {"w": self._cast(ctx["w"]), "g": self._cast(g_out)}


@dataclasses.dataclass(frozen=True)
class EF14SGD(Method):
    """EF14-SGD [Seide et al., 2014] — eq (64)–(65), in gradient units.

    pᵗ = eᵗ + ∇f(xᵗ, ξ);  msg = C(pᵗ);  eᵗ⁺¹ = pᵗ − msg.
    For a constant step size this is exactly (64)–(65) with e and g divided by γ
    (the standard implementation form, cf. Karimireddy et al. 2019).
    """
    name: str = "ef14_sgd"
    mode: str = "absolute"

    def init(self, params_like, init_grads=None):
        return {"e": self._cast(tree_zeros_like(params_like))}

    def pre_compress(self, grads, state, *, eta=None):
        p = tree_add(state["e"], grads)
        return p, {"p": p}

    def post_compress(self, c, ctx):
        e_new = tree_sub(ctx["p"], c)
        return c, {"e": self._cast(e_new)}


@dataclasses.dataclass(frozen=True)
class SGDM(Method):
    """Plain Polyak SGDM — eq (3); analyzed untuned in Appendix J. No compression."""
    eta: float = 0.1
    name: str = "sgdm"
    mode: str = "absolute"

    def init(self, params_like, init_grads=None):
        v = init_grads if init_grads is not None else tree_zeros_like(params_like)
        return {"v": self._cast(v)}

    def pre_compress(self, grads, state, *, eta=None):
        v_new = tree_lerp(state["v"], grads, self._eta(eta))
        return v_new, {"v": v_new}

    def post_compress(self, c, ctx):
        return c, {"v": self._cast(ctx["v"])}


@dataclasses.dataclass(frozen=True)
class SGD(Method):
    """Uncompressed distributed SGD (reference)."""
    name: str = "sgd"
    mode: str = "absolute"

    def init(self, params_like, init_grads=None):
        return {}

    def pre_compress(self, grads, state, *, eta=None):
        return grads, {}

    def post_compress(self, c, ctx):
        return c, {}


@dataclasses.dataclass(frozen=True)
class Neolithic(Method):
    """NEOLITHIC-style baseline [Huang et al., 2022]: R rounds of residual
    compression per iteration (R = ⌈d/K⌉ per their Thm 3 → effectively transmits
    every coordinate, which is why the paper's Fig 2 shows it losing per-bit)."""
    rounds: int = 4
    name: str = "neolithic"
    mode: str = "absolute"
    wire_is_msg: bool = False        # R-round accumulator, no two-phase API

    def init(self, params_like, init_grads=None):
        return {}

    def update(self, grads, state, rng=None, **kw):
        acc = tree_zeros_like(grads)
        resid = grads
        for r in range(self.rounds):
            k = None if rng is None else jax.random.fold_in(rng, r)
            c = tree_compress(self.compressor, resid, k)
            acc = tree_add(acc, c)
            resid = tree_sub(resid, c)
        return acc, state

    def coords_per_message(self, d: int, carrier=None, direction: str = "up",
                           compressor=None) -> float:
        base = super().coords_per_message(d, carrier, direction, compressor)
        if direction == "down":
            return base     # one broadcast regardless of the R uplink rounds
        return self.rounds * base


# ---------------------------------------------------------------------------
# server-side aggregation
# ---------------------------------------------------------------------------

def server_init(method: Method, params_like: PyTree,
                init_grads_mean: Optional[PyTree] = None) -> PyTree:
    """The aggregated estimate gᵗ the server maintains (g⁰ = (1/n)Σ gᵢ⁰)."""
    if method.mode == "delta":
        g = init_grads_mean if init_grads_mean is not None \
            else tree_zeros_like(params_like)
        return g
    return tree_zeros_like(params_like)


def server_step(method: Method, g_server: PyTree, msg_mean: PyTree) -> PyTree:
    if method.mode == "delta":
        return tree_add(g_server, msg_mean)
    return msg_mean


# ---------------------------------------------------------------------------
# downlink: the server → client broadcast leg (bidirectional compression)
# ---------------------------------------------------------------------------

def downlink_init(g_server: PyTree) -> PyTree:
    """h⁰ — the server's EF21 broadcast memory, initialized to g⁰ (the init
    handshake already ships dense state once: params, and under Alg 1 line 2
    the g⁰ mean — so server and clients agree on h⁰ exactly). Works the same
    for both server modes: h tracks whatever estimate ``server_step``
    produces ('delta' methods integrate messages into g; 'absolute' methods
    replace it), because the downlink contraction argument only needs the
    broadcast target, never the method semantics."""
    return g_server


def downlink_sync(carrier, comp, g_server: PyTree, h: Optional[PyTree],
                  rng: Optional[jax.Array] = None, memory: bool = True
                  ) -> Tuple[PyTree, Optional[PyTree]]:
    """One downlink broadcast: returns ``(g_est, h_new)`` where ``g_est`` is
    the estimate every client (and the server) steps the model with.

    With ``memory`` (EF21-BC, Fatkhullin et al. 2021): the server broadcasts
    the wire of C(g − h) and everyone integrates the decode,
    hᵗ⁺¹ = hᵗ + decode(wire) — so g_est = hᵗ⁺¹ is bit-identical on server and
    clients, and the compression error is re-sent in later rounds (the same
    contraction that makes uplink EF21 work). Without ``memory`` (the naive
    baseline the paper-claims tests stall): the broadcast is C(g) itself each
    round, nothing absorbs the compression error, and ``h_new`` is None."""
    from repro.core import carriers as carrier_lib
    if not memory:
        return carrier_lib.downlink_round(carrier, comp, g_server, rng), None
    # decode + h-integration in one fused leg (downlink_round_integrate):
    # quantized wires run the one-launch dequantize+add kernel on TPU;
    # everywhere else this is exactly h + decode(wire)
    h_new = carrier_lib.downlink_round_integrate(
        carrier, comp, tree_sub(g_server, h), h, rng)
    return h_new, h_new


REGISTRY = {
    "ef21_sgdm_ideal": EF21SGDMIdeal,
    "ef21_sgd": EF21SGD,
    "ef21_sgdm": EF21SGDM,
    "ef21_sgd2m": EF21SGD2M,
    "ef21_sgdm_abs": EF21SGDMAbs,
    "ef21_storm": EF21STORM,
    "ef14_sgd": EF14SGD,
    "sgdm": SGDM,
    "sgd": SGD,
    "neolithic": Neolithic,
}


def make(name: str, **kwargs) -> Method:
    if name not in REGISTRY:
        raise ValueError(f"unknown EF method {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name](**kwargs)
