"""Train→serve streaming: the downlink wire as an ordered, seekable log.

DESIGN.md §8 made the server's EF21 broadcast memory h a bit-exact compressed
model-distribution channel: every round the server broadcasts the carrier
wire of C_down(g − h) and ALL subscribers integrate h' = h + decode(wire).
This module turns that broadcast into a durable transport so serving replicas
can be subscribers too (DESIGN.md §12):

  * ``WireRecord`` — one group's wire for one step, with an explicit
    ``(step, spec_hash, group)`` header. ``kind='delta'`` records carry the
    per-leaf carrier wires (apply: h += decode); ``kind='dense'`` records
    carry the group's dense server leaves (the implicit dense broadcast of a
    group without a downlink carrier — g_est IS the payload).
  * ``WireLog`` — a directory of one-file-per-record npz entries (atomic
    tmp+rename like checkpoint.py), ordered and seekable by step, plus the
    ``bootstrap/`` checkpoints a replica joins from (checkpoint + replay).
  * ``Publisher`` — the trainer-side hook: re-encodes each round's broadcast
    OUTSIDE the jitted step with the exact rng fold discipline the step used
    (``fold_in(fold_in(fold_in(rng0, step), 1), DOWNLINK_FOLD)``, then
    per-group / per-leaf folds), and REFUSES to append any record whose
    wires do not reproduce the trainer's own post-step h bit-exactly — a
    published record is proven-correct at write time, never trusted.
  * ``Subscriber`` — the replica-side state machine: holds
    (params, opt_state, h, step) and advances them record-by-record through
    the exact train-step tail (h-integration → optimizer update) via the
    SAME ``carriers.downlink_apply`` the trainer ran, so each applied record
    lands the replica bit-identical to the trainer's post-step model.

Integrity rules (mirrors the checkpoint foreign-spec guard): out-of-order
application raises ``StreamOrderError``; a missing record raises
``StreamGapError`` (the replica must resync via a later bootstrap + replay,
never skip — see launch/fleet.py); a record written by a different RunSpec
raises ``StreamSpecMismatch``. Republishing after trainer kill-and-resume is
idempotent: an append that bit-matches the existing record is a no-op, a
conflicting one raises ``StreamIntegrityError``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import tempfile
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import carriers as carrier_lib
from repro.core import compressors as comp_lib
from repro.core import schedule as sched_lib

PyTree = Any

STREAM_SCHEMA = "wire/v1"
_NATIVE_KINDS = set("biufc")          # npz round-trips these dtypes natively


class StreamError(RuntimeError):
    """Base class for wire-stream failures."""


class StreamOrderError(StreamError):
    """A record was applied out of order (step != subscriber step + 1)."""


class StreamGapError(StreamError):
    """A needed record is missing from the log — resync, never skip."""


class StreamSpecMismatch(StreamError):
    """Record and subscriber were built from different RunSpecs."""


class StreamIntegrityError(StreamError):
    """A record conflicts with the log or fails the bit-exact verify."""


# ---------------------------------------------------------------------------
# records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireRecord:
    """One group's downlink payload for one step. ``step`` is the trainer's
    POST-step counter: applying this record advances a replica holding the
    step-1 model to the trainer's exact step-``step`` model."""

    step: int
    spec_hash: str
    group: str                 # group pattern ('*' on the uniform path)
    group_index: int
    n_records: int             # records that make up this step (non-empty groups)
    kind: str                  # 'delta' (h += decode) | 'dense' (g_est = payload)
    payload: Tuple[Any, ...]   # per leaf: np.ndarray | tuple of np.ndarray


def _arrays_equal(a: np.ndarray, b: np.ndarray) -> bool:
    if a.dtype != b.dtype or a.shape != b.shape:
        return False
    if a.dtype.kind == "f":
        return bool(np.array_equal(a, b, equal_nan=True))
    return bool(np.array_equal(a, b))


def _record_arrays(rec: WireRecord) -> List[np.ndarray]:
    out: List[np.ndarray] = []
    for leaf in rec.payload:
        comps = leaf if isinstance(leaf, tuple) else (leaf,)
        out.extend(np.asarray(c) for c in comps)
    return out


def records_equal(a: WireRecord, b: WireRecord) -> bool:
    if (a.step, a.spec_hash, a.group, a.group_index, a.n_records, a.kind) != \
            (b.step, b.spec_hash, b.group, b.group_index, b.n_records, b.kind):
        return False
    aa, bb = _record_arrays(a), _record_arrays(b)
    return len(aa) == len(bb) and all(
        _arrays_equal(x, y) for x, y in zip(aa, bb))


def record_nbytes(rec: WireRecord) -> int:
    """On-the-wire payload bytes of one record (arrays only, no header)."""
    return sum(arr.nbytes for arr in _record_arrays(rec))


# ---------------------------------------------------------------------------
# the log
# ---------------------------------------------------------------------------

_REC_RE = re.compile(r"^rec_(\d{8})_g(\d{2})\.npz$")


class WireLog:
    """Directory-backed record log: ``records/rec_<step>_g<group>.npz`` plus
    the ``bootstrap/step_<step>.npz`` full-state checkpoints replicas join
    from. Writes are atomic (mkstemp + rename; ``*.tmp.npz`` partials from a
    killed writer are never listed — the checkpoint.py idiom)."""

    def __init__(self, root: str):
        self.root = root
        self.records_dir = os.path.join(root, "records")
        self.bootstrap_dir = os.path.join(root, "bootstrap")

    # ------------------------------------------------------------- filenames
    def record_path(self, step: int, group_index: int) -> str:
        return os.path.join(self.records_dir,
                            f"rec_{step:08d}_g{group_index:02d}.npz")

    def bootstrap_path(self, step: int) -> str:
        return os.path.join(self.bootstrap_dir, f"step_{step:08d}.npz")

    def _listing(self) -> Dict[int, List[int]]:
        """{step: [group indices present]} over complete FILES only."""
        if not os.path.isdir(self.records_dir):
            return {}
        out: Dict[int, List[int]] = {}
        for f in os.listdir(self.records_dir):
            m = _REC_RE.match(f)
            if m:
                out.setdefault(int(m.group(1)), []).append(int(m.group(2)))
        return out

    def steps(self) -> List[int]:
        """Steps with at least one record file, sorted."""
        return sorted(self._listing())

    def last_step(self) -> Optional[int]:
        """Newest step whose record set is COMPLETE (a writer killed between
        the group files of one step must not surface a partial step)."""
        listing = self._listing()
        for step in sorted(listing, reverse=True):
            try:
                recs = self.read_step(step)
            except StreamError:
                continue
            if recs:
                return step
        return None

    def bootstrap_steps(self) -> List[int]:
        if not os.path.isdir(self.bootstrap_dir):
            return []
        out = []
        for f in os.listdir(self.bootstrap_dir):
            m = re.match(r"^step_(\d{8})\.npz$", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_bootstrap(self, upto: Optional[int] = None) -> Optional[str]:
        steps = [s for s in self.bootstrap_steps()
                 if upto is None or s <= upto]
        return self.bootstrap_path(steps[-1]) if steps else None

    # ------------------------------------------------------------ read/write
    def append(self, rec: WireRecord) -> bool:
        """Write one record atomically. Idempotent on republish (trainer
        kill-and-resume replays already-published steps): a bit-identical
        existing record is a no-op (returns False), a conflicting one raises
        ``StreamIntegrityError`` — the log never silently forks."""
        path = self.record_path(rec.step, rec.group_index)
        if os.path.exists(path):
            existing = self.read(rec.step, rec.group_index)
            if records_equal(existing, rec):
                return False
            raise StreamIntegrityError(
                f"refusing to overwrite {path}: a record for step {rec.step} "
                f"group {rec.group!r} already exists with different bits "
                "(a diverged republish would silently fork the stream)")
        os.makedirs(self.records_dir, exist_ok=True)
        flat: Dict[str, np.ndarray] = {}
        struct: List[int] = []
        dtypes: List[List[str]] = []
        for i, leaf in enumerate(rec.payload):
            comps = leaf if isinstance(leaf, tuple) else (leaf,)
            struct.append(len(comps) if isinstance(leaf, tuple) else -1)
            names = []
            for j, c in enumerate(comps):
                arr = np.asarray(jax.device_get(c))
                names.append(str(arr.dtype))
                # extension dtypes (bfloat16, fp8) round-trip poorly through
                # npz: store as f32, cast back on read (lossless for bf16)
                if arr.dtype.kind not in _NATIVE_KINDS:
                    arr = np.asarray(
                        jax.numpy.asarray(arr).astype(jax.numpy.float32))
                flat[f"l{i}_c{j}"] = arr
            dtypes.append(names)
        meta = {"stream": STREAM_SCHEMA, "step": rec.step,
                "spec_hash": rec.spec_hash, "group": rec.group,
                "group_index": rec.group_index, "n_records": rec.n_records,
                "kind": rec.kind, "struct": struct, "dtypes": dtypes}
        flat["__meta__"] = np.frombuffer(json.dumps(meta).encode(),
                                         dtype=np.uint8)
        fd, tmp = tempfile.mkstemp(dir=self.records_dir, suffix=".tmp.npz")
        os.close(fd)
        np.savez(tmp, **flat)
        os.replace(tmp, path)
        return True

    def read(self, step: int, group_index: int) -> WireRecord:
        path = self.record_path(step, group_index)
        if not os.path.exists(path):
            raise StreamGapError(
                f"no record for step {step} group {group_index} under "
                f"{self.records_dir!r}")
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            if meta.get("stream") != STREAM_SCHEMA:
                raise StreamIntegrityError(
                    f"{path}: unknown stream schema {meta.get('stream')!r} "
                    f"(this reader speaks {STREAM_SCHEMA!r})")
            payload: List[Any] = []
            for i, (nc, names) in enumerate(zip(meta["struct"],
                                                meta["dtypes"])):
                comps = []
                for j, name in enumerate(names if nc != -1 else names[:1]):
                    arr = z[f"l{i}_c{j}"]
                    if np.dtype(name).kind not in _NATIVE_KINDS \
                            or str(arr.dtype) != name:
                        arr = np.asarray(jax.numpy.asarray(arr).astype(name))
                    comps.append(arr)
                payload.append(tuple(comps) if nc != -1 else comps[0])
        return WireRecord(step=meta["step"], spec_hash=meta["spec_hash"],
                          group=meta["group"],
                          group_index=meta["group_index"],
                          n_records=meta["n_records"], kind=meta["kind"],
                          payload=tuple(payload))

    def read_step(self, step: int) -> List[WireRecord]:
        """Every group record of one step, ordered by group index. Raises
        ``StreamGapError`` when the step is absent and
        ``StreamIntegrityError`` when only PART of the step's record set is
        on disk (a half-published step must never be applied)."""
        present = sorted(self._listing().get(step, []))
        if not present:
            raise StreamGapError(
                f"no records for step {step} under {self.records_dir!r}")
        recs = [self.read(step, gi) for gi in present]
        want = recs[0].n_records
        if len(recs) != want or any(r.n_records != want for r in recs):
            raise StreamIntegrityError(
                f"step {step} has {len(recs)} of {want} group records — "
                "partial publish; refusing to apply an incomplete step")
        return recs


# ---------------------------------------------------------------------------
# transport legs — the resolved downlink plan shared by both ends
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Leg:
    """One group's transport: which leaves it covers and how they travel.
    ``carrier is None`` means the group has no downlink — its server leaves
    ship dense (``kind='dense'``), exactly the implicit dense broadcast of
    ``schedule.downlink_round_grouped``."""

    name: str
    index: int                  # schedule group index — the rng fold index
    n_groups: int
    leaf_ii: Tuple[int, ...]    # leaf positions in the full flat param list
    carrier: Optional[Any] = None
    comp: Optional[Any] = None


def resolve_legs(params_like: PyTree, schedule=None,
                 down_carrier: str = "dense",
                 down_compressor=None) -> List[Leg]:
    """The downlink transport legs for one spec, resolved once against the
    param treedef and shared verbatim by the publisher and every subscriber
    (same group indices → same rng folds → same wires)."""
    n_leaves = jax.tree_util.tree_structure(params_like).num_leaves
    if schedule is None:
        ii = tuple(range(n_leaves))
        if down_carrier == "dense" and down_compressor is None:
            return [Leg(name="*", index=0, n_groups=1, leaf_ii=ii)]
        comp = down_compressor if down_compressor is not None \
            else comp_lib.Identity()
        return [Leg(name="*", index=0, n_groups=1, leaf_ii=ii,
                    carrier=carrier_lib.make(down_carrier), comp=comp)]
    idx = sched_lib._group_indices(schedule, params_like)
    legs: List[Leg] = []
    ng = len(schedule.groups)
    for gi, grp in enumerate(schedule.groups):
        if not idx[gi]:
            continue                       # trainer skips empty groups too
        if grp.has_downlink:
            legs.append(Leg(name=grp.pattern, index=gi, n_groups=ng,
                            leaf_ii=tuple(idx[gi]),
                            carrier=carrier_lib.make(grp.down_carrier),
                            comp=grp.down_comp()))
        else:
            legs.append(Leg(name=grp.pattern, index=gi, n_groups=ng,
                            leaf_ii=tuple(idx[gi])))
    return legs


def legs_wire_words(legs: Sequence[Leg], params_like: PyTree) -> float:
    """Honest per-sync broadcast words over all legs (DESIGN.md §9 rules:
    a leg without a downlink ships its dense leaves). One wire serves both
    training sync and the serving fleet, so fleet downlink bytes are THESE
    words × 4 per subscriber — never accounted twice."""
    leaves = jax.tree_util.tree_leaves(params_like)
    total = 0.0
    for leg in legs:
        for i in leg.leaf_ii:
            d = int(leaves[i].size)
            if leg.carrier is None:
                total += float(d)
            else:
                total += carrier_lib.downlink_words(leg.carrier, leg.comp, d)
    return total


def _round_down_rng(rng0: jax.Array, step: int) -> jax.Array:
    """The downlink rng of the round that PRODUCED post-step ``step``:
    the train step ran with fold_in(rng0, step-1), compression folds 1,
    the downlink leg folds DOWNLINK_FOLD (core/distributed.py)."""
    r_round = jax.random.fold_in(rng0, step - 1)
    r_comp = jax.random.fold_in(r_round, 1)
    return jax.random.fold_in(r_comp, carrier_lib.DOWNLINK_FOLD)


# ---------------------------------------------------------------------------
# trainer side — publisher
# ---------------------------------------------------------------------------

class Publisher:
    """Appends one WireRecord per leg after each trainer step, re-encoding
    the broadcast outside the jitted step and verifying the wires reproduce
    the trainer's own post-step h bit-exactly before anything is written.
    A failed verify raises — the log never carries a record that would
    silently drift a replica."""

    def __init__(self, log: WireLog, spec_hash: str, legs: Sequence[Leg],
                 rng0: jax.Array):
        self.log = log
        self.spec_hash = spec_hash
        self.legs = list(legs)
        self.rng0 = rng0
        self._encode_jit: Dict[int, Any] = {}

    def _leg_encode(self, leg: Leg):
        """encode + integrate for one leg, JITTED: eager op-by-op dispatch
        can round quantization scales one ulp away from the trainer's
        compiled step (seen on CPU), so the re-encode must go through XLA
        exactly like the step did — the verify below then proves the wires
        reproduce the trainer's h bit-for-bit."""
        if leg.index not in self._encode_jit:
            carrier, comp = leg.carrier, leg.comp

            def enc(s_g, h_g, r):
                delta = [s - h for s, h in zip(s_g, h_g)]
                wires = carrier_lib.downlink_encode(carrier, comp, delta, r)
                return wires, carrier_lib.downlink_apply(
                    carrier, comp, wires, h_g)

            self._encode_jit[leg.index] = jax.jit(enc)
        return self._encode_jit[leg.index]

    def publish(self, step: int, server: PyTree,
                h_prev: Optional[PyTree], h_new: Optional[PyTree]) -> int:
        """Publish the wire of the round that produced post-step ``step``.
        Returns the number of NEW records written (0 when a resumed trainer
        republishes steps already in the log — verified-identical, skipped).
        """
        s_leaves = jax.tree_util.tree_leaves(server)
        hp_leaves = None if h_prev is None \
            else jax.tree_util.tree_leaves(h_prev)
        hn_leaves = None if h_new is None \
            else jax.tree_util.tree_leaves(h_new)
        needs_rng = any(leg.carrier is not None for leg in self.legs)
        r_down = _round_down_rng(self.rng0, step) if needs_rng else None
        written = 0
        for leg in self.legs:
            if leg.carrier is None:
                payload = tuple(np.asarray(jax.device_get(s_leaves[i]))
                                for i in leg.leaf_ii)
                kind = "dense"
            else:
                assert hp_leaves is not None and hn_leaves is not None, \
                    "downlink legs need the broadcast memory h"
                r_leg = sched_lib._group_rng(r_down, leg.index, leg.n_groups)
                # the proof obligation: these wires, applied through the same
                # downlink_apply every subscriber runs, must land on the
                # trainer's own h — else publishing would fork the stream
                wires, got = self._leg_encode(leg)(
                    [s_leaves[i] for i in leg.leaf_ii],
                    [hp_leaves[i] for i in leg.leaf_ii], r_leg)
                for gi, i in enumerate(leg.leaf_ii):
                    a = np.asarray(jax.device_get(got[gi]))
                    b = np.asarray(jax.device_get(hn_leaves[i]))
                    if not _arrays_equal(a, b):
                        raise StreamIntegrityError(
                            f"step {step} group {leg.name!r}: re-encoded "
                            "wire does not reproduce the trainer's post-step "
                            "h bit-exactly; refusing to publish a drifting "
                            "record")
                payload = tuple(
                    tuple(np.asarray(jax.device_get(c)) for c in w)
                    if isinstance(w, tuple)
                    else np.asarray(jax.device_get(w)) for w in wires)
                kind = "delta"
            rec = WireRecord(step=step, spec_hash=self.spec_hash,
                             group=leg.name, group_index=leg.index,
                             n_records=len(self.legs), kind=kind,
                             payload=payload)
            written += int(self.log.append(rec))
        return written


# ---------------------------------------------------------------------------
# replica side — subscriber
# ---------------------------------------------------------------------------

class Subscriber:
    """The replica-side state machine (DESIGN.md §12): subscribe → apply →
    (serve) → resync. Holds exactly the state the train-step tail touches —
    params, opt_state, the broadcast memory h, and the step cursor — and
    advances it one record-set at a time. The h-integration runs through the
    SAME ``carriers.downlink_apply`` as the trainer's in-step leg and the
    optimizer update is the same ``optimizer.update`` + ``apply_updates``
    composition, so an applied step is bit-identical to the trainer's.

    Resync (checkpoint + replay on a gap) lives in launch/fleet.py — this
    class only guarantees it never applies out of order and never skips."""

    def __init__(self, log: WireLog, spec_hash: str, legs: Sequence[Leg],
                 params: PyTree, opt_state: PyTree, h: Optional[PyTree],
                 step: int, optimizer):
        self.log = log
        self.spec_hash = spec_hash
        self.legs = list(legs)
        self.params = params
        self.opt_state = opt_state
        self.h = h
        self.step = int(step)
        self.optimizer = optimizer
        self._advance_jit = None

    # ----------------------------------------------------------- validation
    def _check(self, recs: List[WireRecord]) -> List[WireRecord]:
        if not recs:
            raise StreamGapError("empty record set")
        for rec in recs:
            if rec.spec_hash != self.spec_hash:
                raise StreamSpecMismatch(
                    f"record step {rec.step} group {rec.group!r} was "
                    f"published by a different RunSpec (hash "
                    f"{rec.spec_hash} != {self.spec_hash}); refusing to "
                    "apply a foreign stream (the checkpoint foreign-spec "
                    "rule, DESIGN.md §7)")
            if rec.step != self.step + 1:
                raise StreamOrderError(
                    f"out-of-order record: got step {rec.step}, replica is "
                    f"at {self.step} (next applicable is {self.step + 1}); "
                    "applying out of order would silently drift h")
        by_index = {r.group_index: r for r in recs}
        want = [leg.index for leg in self.legs]
        if sorted(by_index) != sorted(want) or len(by_index) != len(recs):
            raise StreamIntegrityError(
                f"step {recs[0].step}: record groups {sorted(by_index)} do "
                f"not match the spec's transport legs {sorted(want)}")
        ordered = [by_index[leg.index] for leg in self.legs]
        for leg, rec in zip(self.legs, ordered):
            want_kind = "dense" if leg.carrier is None else "delta"
            if rec.kind != want_kind:
                raise StreamIntegrityError(
                    f"step {rec.step} group {rec.group!r}: kind "
                    f"{rec.kind!r} does not match the leg's {want_kind!r}")
            if len(rec.payload) != len(leg.leaf_ii):
                raise StreamIntegrityError(
                    f"step {rec.step} group {rec.group!r}: {len(rec.payload)}"
                    f" payload leaves for {len(leg.leaf_ii)} group leaves")
        return ordered

    # ---------------------------------------------------------------- apply
    def _build_advance(self):
        legs = self.legs
        optimizer = self.optimizer
        from repro.optim.optimizer import apply_updates

        def advance(params, opt_state, h, payloads, opt_step):
            p_leaves, treedef = jax.tree_util.tree_flatten(params)
            n = len(p_leaves)
            h_leaves = jax.tree_util.tree_leaves(h) \
                if h is not None else [None] * n
            est_out: List[Any] = [None] * n
            h_out: List[Any] = [None] * n
            for leg, payload in zip(legs, payloads):
                if leg.carrier is None:
                    for pos, i in enumerate(leg.leaf_ii):
                        est_out[i] = payload[pos]
                        h_out[i] = payload[pos]
                else:
                    new_h = carrier_lib.downlink_apply(
                        leg.carrier, leg.comp, list(payload),
                        [h_leaves[i] for i in leg.leaf_ii])
                    for pos, i in enumerate(leg.leaf_ii):
                        est_out[i] = new_h[pos]
                        h_out[i] = new_h[pos]
            g_est = jax.tree_util.tree_unflatten(treedef, est_out)
            new_h_tree = None if h is None \
                else jax.tree_util.tree_unflatten(treedef, h_out)
            updates, opt_state = optimizer.update(
                g_est, opt_state, params, opt_step)
            params = apply_updates(params, updates)
            return params, opt_state, new_h_tree

        return jax.jit(advance)

    def _payload_jax(self, rec: WireRecord):
        return tuple(
            tuple(jax.numpy.asarray(c) for c in leaf)
            if isinstance(leaf, tuple) else jax.numpy.asarray(leaf)
            for leaf in rec.payload)

    def apply(self, recs: List[WireRecord]) -> None:
        """Apply one step's full record set; the replica lands bit-identical
        to the trainer's post-step model at ``recs[0].step``."""
        ordered = self._check(recs)
        if self._advance_jit is None:
            self._advance_jit = self._build_advance()
        payloads = [self._payload_jax(r) for r in ordered]
        # the trainer's optimizer.update ran with the PRE-increment step
        self.params, self.opt_state, self.h = self._advance_jit(
            self.params, self.opt_state, self.h, payloads, self.step)
        self.step += 1

    def sync(self, upto: Optional[int] = None) -> int:
        """Apply every available record in order, up to ``upto`` (default:
        the log's last complete step). Returns the number of steps applied.
        Raises ``StreamGapError`` when a needed record is missing while later
        ones exist — the caller must resync from a bootstrap (fleet layer),
        because skipping would serve silently-drifted weights."""
        last = self.log.last_step()
        if last is None:
            return 0
        target = last if upto is None else min(int(upto), last)
        applied = 0
        while self.step < target:
            recs = self.log.read_step(self.step + 1)
            self.apply(recs)
            applied += 1
        return applied
