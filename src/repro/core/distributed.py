"""Distributed EF gradient synchronization on a (pod, data, model) mesh.

Mapping (DESIGN.md §3): the paper's n clients are the data-parallel groups of the
mesh. Per-client EF state carries a leading ``dp`` axis sharded over the data mesh
axes, so client i's (vᵢ, gᵢ, …) live exactly on client i's chips. Per-client
gradients are obtained *inside* the jitted step by reshaping the global batch to
(dp, B/dp, …) and vmapping the loss gradient — no collective is needed to keep them
per-client, because batch and state shardings agree on the leading axis.

Aggregation carriers (core/carriers.py, DESIGN.md §6): both runtimes here
dispatch the wire format of meanᵢ(cᵢ) through :mod:`repro.core.carriers` —

  'dense'  — paper-faithful: a d-word all-reduce over the data axes (what the
             paper's own simulations do; no wire savings — the §Perf baseline).
  'sparse' — fixed-(values, block-local indices) wire for the TopK family: an
             all-gather of the small arrays over the data axes plus a local
             scatter-add. Collective bytes drop by ~d/(2·dp·K) on the
             gradient-sync path. Identical math (validated against 'dense').
  'fused'  — dense wire, but the whole EF21-SGD(M) client update runs as ONE
             Pallas HBM pass (kernels/ef_update.py) instead of the unfused
             pre_compress → C(·) → post_compress chain.
  'quant8' / 'quant4'
           — block-quantized wire (per-block absmax scale + int8 or packed
             uint4 mantissas, kernels/quantize.py): sparse-block payloads
             all-gather the still-quantized (mantissas, scales, indices)
             arrays; dense payloads dequantize locally before the psum (an
             int8 all-reduce across differing scales is not associative).
             EF re-sends the quantization error — local_c is the wire decode.
  'fused_quant8' / 'fused_quant4'
           — the one-launch round: the whole client chain (EF update +
             Block-TopK + quantize + EF-invariant integration) runs as ONE
             Pallas mega-kernel (kernels/fused_round.py) and the quantized
             block-dense payload is what aggregates (dequantize, then pmean).

Bidirectional compression (DESIGN.md §8): ``EFConfig.down_carrier`` /
``down_compressor`` add a DOWNLINK leg to the round — the server keeps an
EF21 broadcast memory h (``ef_state['h']``), broadcasts the carrier wire of
C_down(g_server − h), and the model steps with the decode-integrated
hᵗ⁺¹ = hᵗ + decode(wire) on server and clients alike, so both provably hold
identical models without ever shipping dense f32 down. The default
(down_carrier='dense', no compressor) runs NO downlink machinery and is
bit-identical to the unidirectional runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import carriers as carrier_lib
from repro.core import compressors as comp_lib
from repro.core import ef as ef_lib
from repro.core import hierarchy as hier_lib
from repro.core import participation as part_lib
from repro.core import schedule as sched_lib

PyTree = Any

# re-exported for callers that only import the runtime module
DOWNLINK_FOLD = carrier_lib.DOWNLINK_FOLD
CROSS_FOLD = hier_lib.CROSS_FOLD


@dataclasses.dataclass(frozen=True)
class EFConfig:
    method: ef_lib.Method
    carrier: str = "dense"     # any core/carriers.py REGISTRY name
    data_axes: Tuple[str, ...] = ("data",)  # mesh axes forming the client dim
    b_init_scale: bool = True              # Alg 1 line 2: init v⁰=g⁰ to first grads
    # downlink (server → client broadcast) leg, DESIGN.md §8: 'dense' with no
    # compressor means NO downlink machinery at all — the broadcast is the
    # implicit dense g_server, bit-identical to the unidirectional runtime
    down_carrier: str = "dense"
    down_compressor: Optional[comp_lib.Compressor] = None
    # per-parameter-group compression (DESIGN.md §9): when set, BOTH runtimes
    # dispatch every leg (uplink wire, aggregation, downlink, state init)
    # through the grouped engine in core/schedule.py and the single-knob
    # fields above (carrier / down_*) are ignored — each group carries its
    # own. None runs the legacy single-compressor path unchanged; a uniform
    # one-group schedule is bit-identical to it (tests/test_schedule.py).
    schedule: Optional[sched_lib.CompressionSchedule] = None
    # comm/compute overlap (DESIGN.md §10): gather-wire aggregations on the
    # shard_map runtime transport their all-gathers as a ppermute ring and
    # decode each chunk while the next is in flight. Bit-identical to the
    # blocking anchor (the ring reproduces all_gather's axis order exactly);
    # a no-op for all-reduce wires and for the vmap runtimes (no collectives)
    overlap: bool = False
    # partial participation (DESIGN.md §11): mode='sampled' runs the masked
    # cohort path — a seeded per-round mask zeroes non-sampled wires before
    # the aggregation collective and freezes their whole EF state tree (the
    # "EF21 with Bells & Whistles" rule). None (or mode='full') runs the
    # legacy full-cohort path untouched; a sampled fraction=1.0 cohort is
    # bit-identical to it (tests/test_participation.py). mode='async' never
    # runs here — core/participation.py::run_async is the async simulator.
    participation: Optional[part_lib.Participation] = None
    # two-tier hierarchical aggregation (DESIGN.md §13): clients → pod
    # aggregator → global server. The intra hop runs this config's existing
    # carrier/schedule over the intra-pod axes only; each pod keeps its own
    # EF memory (ef_state['pods'] = {t, b}) and ships the compressed
    # cross-pod innovation via hops.cross_carrier/cross_compressor. None or
    # pods=1 runs ZERO hierarchical machinery (bit-identical legacy jaxpr).
    hops: Optional[hier_lib.Hops] = None

    @property
    def effective_hops(self) -> Optional[hier_lib.Hops]:
        return hier_lib.effective(self.hops)

    @property
    def has_downlink(self) -> bool:
        if self.schedule is not None:
            return self.schedule.has_downlink
        return self.down_carrier != "dense" or self.down_compressor is not None

    def down_comp(self) -> comp_lib.Compressor:
        return self.down_compressor if self.down_compressor is not None \
            else comp_lib.Identity()


# ---------------------------------------------------------------------------
# per-client gradients
# ---------------------------------------------------------------------------

def per_client_value_and_grad(loss_fn: Callable, params: PyTree, batch: PyTree,
                              dp: int) -> Tuple[jax.Array, PyTree, PyTree]:
    """loss_fn(params, sub_batch) -> (loss, aux). Returns (mean loss, aux,
    per-client grads with a leading dp axis)."""
    def reshape(leaf):
        b = leaf.shape[0]
        assert b % dp == 0, f"global batch {b} not divisible by dp={dp}"
        return leaf.reshape(dp, b // dp, *leaf.shape[1:])

    batch_g = jax.tree_util.tree_map(reshape, batch)

    def one(b):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
        return loss, aux, grads

    losses, auxs, grads = jax.vmap(one)(batch_g)
    aux = jax.tree_util.tree_map(lambda a: a.mean(0), auxs)
    return losses.mean(), aux, grads


# ---------------------------------------------------------------------------
# EF state init
# ---------------------------------------------------------------------------

def init_ef_state(efc: EFConfig, params: PyTree, dp: int,
                  init_grads: Optional[PyTree] = None) -> Dict:
    """init_grads: optional per-client grads (dp leading) for Alg 1 line 2."""
    method = efc.method
    if efc.schedule is not None:
        # per-group init (per-group EF-state dtypes), merged onto the full
        # treedef — bit-identical to method.init for a uniform schedule
        init_one = lambda p, g=None: sched_lib.init_state_grouped(  # noqa: E731
            efc.schedule, method, p, init_grads=g)
    else:
        init_one = method.init
    if init_grads is None:
        clients = jax.vmap(lambda _: init_one(params))(jnp.arange(dp))
        server = ef_lib.server_init(method, params)
    else:
        clients = jax.vmap(lambda g: init_one(params, g))(init_grads)
        server = ef_lib.server_init(
            method, params,
            jax.tree_util.tree_map(lambda g: g.mean(0), init_grads))
    state = {"clients": clients, "server": server}
    if efc.has_downlink:
        # the broadcast memory h⁰ = g⁰ rides along as a state sibling; the
        # unidirectional state tree stays byte-for-byte what it always was
        state["h"] = ef_lib.downlink_init(server)
    hops = efc.effective_hops
    if hops is not None:
        # per-pod EF memory on a leading pods axis (sharded over the 'pod'
        # mesh axis on the production path) — a flat config's state tree is
        # untouched, exactly like the downlink's 'h' sibling
        hier_lib.check_pods(hops, dp)
        state["pods"] = jax.vmap(lambda _: hier_lib.pod_init(params))(
            jnp.arange(hops.pods))
    return state


# ---------------------------------------------------------------------------
# one synchronization round
# ---------------------------------------------------------------------------

def _participation_mask(efc: EFConfig, n: int, step):
    """The round's cohort mask for a sampled-participation config, or None
    on the legacy full path. Hard-errors on async (a barrier runtime cannot
    honor arrival order) and on a missing step (the cohort is a pure
    function of (seed, round) so resume replays it)."""
    part = efc.participation
    if part is None or part.mode == "full":
        return None
    if part.mode == "async":
        raise ValueError(
            "participation mode 'async' does not run on the synchronous "
            "runtimes (every round is a barrier); drive the event-driven "
            "simulator instead: repro.core.participation.run_async")
    if step is None:
        raise ValueError(
            "sampled participation derives the round cohort from the step "
            "index; pass step= into ef_round / ef_round_sharded")
    return part_lib.cohort_mask(part, n, step)


def ef_round_sharded(efc: EFConfig, grads: PyTree, ef_state: Dict,
                     rng: Optional[jax.Array], mesh, grads_specs: PyTree,
                     state_specs: Dict, eta: Optional[float] = None,
                     step: Optional[jax.Array] = None
                     ) -> Tuple[PyTree, Dict]:
    """shard_map EF sync: each device runs its client's update on its LOCAL param
    shard (per-shard Block-TopK — contractive with the same α, DESIGN.md §4), then
    the aggregation collective is issued *explicitly* by the carrier
    (core/carriers.py):

      'dense'  : psum(cᵢ)/n over the client axes — an all-reduce of d/tp words
                 per device (the paper-faithful wire format)
      'sparse' : all_gather of the local (values, block-local indices) over the
                 client axes — 2·dp·K/tp words per device — followed by a local
                 scatter-add (the beyond-paper wire format)
      'fused'  : dense aggregation, but the client chain ran as one Pallas pass

    This keeps compression 100% collective-free (no flatten-induced gathers) and
    makes the collective schedule ours rather than the SPMD partitioner's.
    """
    from jax.experimental.shard_map import shard_map

    method = efc.method
    c_axes = efc.data_axes
    sched = efc.schedule
    carrier = carrier_lib.make(efc.carrier)
    if efc.overlap:
        carrier = dataclasses.replace(carrier, overlap=True)
    plan = carrier.plan(method, eta)
    down_carrier = carrier_lib.make(efc.down_carrier)
    down_comp = efc.down_comp()

    n_total = 1
    for a in c_axes:
        n_total *= mesh.shape[a]
    mask_full = _participation_mask(efc, n_total, step)
    m_cohort = efc.participation.cohort_size(n_total) \
        if mask_full is not None else n_total

    # two-tier hierarchical aggregation (DESIGN.md §13): the intra hop
    # aggregates over the intra-pod axes only, then each pod's aggregator
    # runs the cross hop. A trivial cross (dense + identity) keeps the
    # legacy global collective verbatim — the flat-equivalence anchor.
    hops = efc.effective_hops
    trivial_cross = hops is None or hier_lib.cross_is_trivial(hops, sched)
    if hops is not None:
        if "pod" not in c_axes:
            raise ValueError(
                "hierarchical aggregation needs a 'pod' client axis; "
                f"got data_axes={c_axes}")
        if hops.pods != mesh.shape["pod"]:
            raise ValueError(
                f"hops.pods={hops.pods} must equal the mesh pod axis "
                f"({mesh.shape['pod']})")
        if mask_full is not None:
            raise ValueError(
                "sampled participation does not compose with hierarchical "
                "aggregation (guarded at spec/build construction)")
        if plan == "fused_wire":
            raise ValueError(
                "fused_wire carriers aggregate all clients inside the "
                "mega-kernel — there is no per-pod message to re-aggregate "
                "(guarded at spec/build construction)")
    # the collective axes of the intra hop: everything when flat or when the
    # cross hop is trivial (legacy bits), the non-pod axes otherwise
    intra_axes = c_axes if trivial_cross \
        else tuple(a for a in c_axes if a != "pod")

    def client_index():
        # this device's global client index over the client axes
        idx = 0
        for a in c_axes:
            idx = idx * carrier_lib.axis_size(a) + jax.lax.axis_index(a)
        return idx

    def client_leg(grads_l, clients_l, rng_l, mask_l=None):
        sq = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
        ex = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
        g, cl = sq(grads_l), sq(clients_l)        # strip the client dim (local=1)
        # this client's scalar cohort entry: zero-masked wires make the
        # collective fold only the sampled cohort (C(0) = 0 exactly)
        mask_m = None if mask_l is None else mask_l[client_index()]

        if sched is not None:
            # grouped engine: one wire (and one aggregation collective) per
            # group, each on its group's carrier/compressor
            msg_mean, new_cl = sched_lib.round_local(
                sched, method, g, cl, intra_axes, rng_l, eta,
                overlap=efc.overlap, mask=mask_m)
        elif plan == "fused":
            c_tree, new_cl = carrier.fused_update(method, g, cl, eta=eta)
            if mask_m is not None:
                c_tree = part_lib.apply_mask(mask_m, c_tree)
            msg_mean = jax.tree_util.tree_map(
                lambda c: jax.lax.pmean(c, intra_axes), c_tree)
        elif plan == "fused_wire":
            if mask_m is not None:
                # unreachable behind the spec/build construction errors: the
                # mega-kernel aggregates inside, no per-client wire to mask
                raise ValueError(
                    "sampled participation cannot run the fused_wire plan")
            # one mega-kernel launch per leaf: update + select + quantize +
            # EF-invariant integration; the aggregated mean comes back with
            # the new client state (aggregation needs the wire)
            msg_mean, new_cl = carrier.fused_wire_round(
                method, g, cl, eta=eta, axes=intra_axes)
        elif plan == "wire":
            deltas, ctx = method.pre_compress(g, cl, eta=eta)
            if mask_m is not None:
                deltas = part_lib.apply_mask(mask_m, deltas)
            c_tree, msg_mean = carrier_lib.wire_round_local(
                carrier, method.compressor, deltas, intra_axes, rng_l)
            _, new_cl = method.post_compress(c_tree, ctx)
        else:
            # dense plan: aggregate the method's actual MESSAGE (for
            # wire_is_msg=False methods msg ≠ c, e.g. Abs ships γ·c), and go
            # through method.update so methods without a two-phase API
            # (neolithic, ideal) also run on the sharded path
            msg, new_cl = method.update(g, cl, rng_l, eta=eta)
            if mask_m is not None:
                msg = part_lib.apply_mask(mask_m, msg)
            msg_mean = jax.tree_util.tree_map(
                lambda m: jax.lax.pmean(m, intra_axes), msg)
        if mask_m is not None:
            # Bells & Whistles: delta methods fold (1/n)Σ_S as-is, absolute
            # methods rescale to the cohort mean; non-sampled clients keep
            # their ENTIRE state tree (gᵢ, momentum, …) bit-frozen
            msg_mean = part_lib.rescale_message(method, msg_mean, n_total,
                                                m_cohort)
            new_cl = part_lib.freeze_tree(mask_m, new_cl, cl)
        return ex(new_cl), msg_mean

    def fold_client(rng_l):
        # local client index for rng decorrelation
        if rng_l is None:
            return None
        return jax.random.fold_in(rng_l, client_index())

    server_specs = state_specs["server"]
    # conditional shard_map operands (same arity pattern for both: the
    # legacy path's jaxpr stays byte-stable) — the cohort mask as one
    # replicated (n,) array, or the pod EF memory sharded over the pod axis.
    # Mutually exclusive: sampled × hops is a construction error above.
    extra_args = () if mask_full is None else (mask_full,)
    extra_specs = () if mask_full is None else (P(),)
    if hops is not None:
        extra_args = (ef_state["pods"],)
        extra_specs = (state_specs["pods"],)
    pod_out_specs = () if hops is None else (state_specs["pods"],)

    def split_rest(rest):
        if hops is not None:
            return rest[0], None
        return None, (rest[0] if rest else None)

    def pod_leg(msg_mean, pods_l, rng_l):
        """The pod tier, per device: fold the intra-hop mean into this pod's
        target, run the cross hop (per-pod rng = fold_in(fold_in(rng,
        CROSS_FOLD), pod_index) — off the ROUND rng, like the downlink
        fold), and return the server-bound message — pmean over the pod
        axis of each pod's contribution — plus the new pod memory. Under a
        trivial cross msg_mean is already the legacy GLOBAL mean and the
        pod memory is bookkeeping only."""
        sq = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
        ex = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
        st = sq(pods_l)
        if trivial_cross:
            return msg_mean, ex(
                hier_lib.trivial_bookkeeping(method, st, msg_mean))
        r_pod = None if rng_l is None else jax.random.fold_in(
            jax.random.fold_in(rng_l, CROSS_FOLD),
            jax.lax.axis_index("pod"))
        t_new = hier_lib.pod_target(method, st["t"], msg_mean)
        b_new = hier_lib.cross_sync(hops, sched, t_new, st["b"], r_pod)
        pod_msg = hier_lib.pod_message(method, st["b"], b_new)
        server_msg = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, ("pod",)), pod_msg)
        return server_msg, ex({"t": t_new, "b": b_new})

    if efc.has_downlink:
        def body(grads_l, clients_l, server_l, h_l, rng_l, *rest):
            pods_l, mask_l = split_rest(rest)
            # the downlink key comes off the round rng BEFORE the per-client
            # fold: the broadcast must be one identical message everywhere
            r_down = None if rng_l is None \
                else jax.random.fold_in(rng_l, DOWNLINK_FOLD)
            new_cl, msg_mean = client_leg(
                grads_l, clients_l, fold_client(rng_l), mask_l)
            if hops is not None:
                msg_mean, new_pods = pod_leg(msg_mean, pods_l, rng_l)
            new_server = ef_lib.server_step(method, server_l, msg_mean)
            # every device runs the same encode of the replicated-in-value
            # new_server (that IS the broadcast — the encoded wire is what
            # travels) and the same decode its client would run. Sampling
            # composes for free: h is server-side, so a client absent for k
            # rounds still integrated every broadcast and re-enters in sync
            if sched is not None:
                g_est, h_new = sched_lib.downlink_round_grouped(
                    sched, new_server, h_l, r_down)
            else:
                g_est, h_new = ef_lib.downlink_sync(
                    down_carrier, down_comp, new_server, h_l, rng=r_down)
            out = (new_cl, new_server, h_new, g_est)
            return out + ((new_pods,) if hops is not None else ())

        h_specs = state_specs.get("h", server_specs)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(grads_specs, state_specs["clients"], server_specs,
                      h_specs, P()) + extra_specs,
            out_specs=(state_specs["clients"], server_specs, h_specs,
                       server_specs) + pod_out_specs,
            check_rep=False)
        out = fn(
            grads, ef_state["clients"], ef_state["server"], ef_state["h"],
            rng, *extra_args)
        new_clients, new_server, h_new, g_est = out[:4]
        new_state = {"clients": new_clients, "server": new_server,
                     "h": h_new}
        if hops is not None:
            new_state["pods"] = out[4]
        return g_est, new_state

    def body(grads_l, clients_l, server_l, rng_l, *rest):
        pods_l, mask_l = split_rest(rest)
        new_cl, msg_mean = client_leg(
            grads_l, clients_l, fold_client(rng_l), mask_l)
        if hops is not None:
            msg_mean, new_pods = pod_leg(msg_mean, pods_l, rng_l)
        new_server = ef_lib.server_step(method, server_l, msg_mean)
        out = (new_cl, new_server, msg_mean)
        return out + ((new_pods,) if hops is not None else ())

    out_specs = (state_specs["clients"], server_specs, server_specs) \
        + pod_out_specs
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(grads_specs, state_specs["clients"], server_specs, P())
        + extra_specs,
        out_specs=out_specs, check_rep=False)
    out = fn(grads, ef_state["clients"], ef_state["server"], rng,
             *extra_args)
    new_clients, new_server = out[0], out[1]
    new_state = {"clients": new_clients, "server": new_server}
    if hops is not None:
        new_state["pods"] = out[3]
    return new_server, new_state


def ef_round(efc: EFConfig, grads: PyTree, ef_state: Dict,
             rng: Optional[jax.Array], eta: Optional[float] = None,
             step: Optional[jax.Array] = None) -> Tuple[PyTree, Dict]:
    """vmap EF sync (single-device tests, exact global-TopK semantics).
    grads: per-client (dp leading). Returns (gᵗ⁺¹ estimate, new ef_state)."""
    method, dp = efc.method, jax.tree_util.tree_leaves(grads)[0].shape[0]
    clients, server = ef_state["clients"], ef_state["server"]
    carrier = carrier_lib.make(efc.carrier)
    plan = carrier.plan(method, eta)
    rngs = jax.random.split(rng, dp) if rng is not None else None
    mask = _participation_mask(efc, dp, step)

    # two-tier hierarchy (DESIGN.md §13): under a NON-trivial cross hop the
    # intra aggregation produces per-pod means (pods on a leading axis —
    # pod-major contiguous client blocks) instead of the global mean; a
    # trivial cross keeps the legacy global aggregation ops verbatim
    hops = efc.effective_hops
    trivial_cross = hops is None or hier_lib.cross_is_trivial(
        hops, efc.schedule)
    want_pods = hops is not None and not trivial_cross
    if hops is not None:
        hier_lib.check_pods(hops, dp)
        if mask is not None:
            raise ValueError(
                "sampled participation does not compose with hierarchical "
                "aggregation (guarded at spec/build construction)")
        if plan == "fused_wire":
            raise ValueError(
                "fused_wire carriers aggregate all clients inside the "
                "mega-kernel — there is no per-pod message to re-aggregate "
                "(guarded at spec/build construction)")
    agg = (lambda t: hier_lib.pod_mean(t, hops.pods)) if want_pods \
        else (lambda t: jax.tree_util.tree_map(lambda m: m.mean(0), t))

    if efc.schedule is not None:
        msg_mean, new_clients = sched_lib.round_batched(
            efc.schedule, method, grads, clients, dp, rng, eta, mask=mask,
            pods=hops.pods if want_pods else 1)
    elif plan == "fused":
        c_tree, new_clients = carrier.fused_update(
            method, grads, clients, eta=eta, batched=True)
        if mask is not None:
            c_tree = part_lib.apply_mask(mask, c_tree)
        msg_mean = agg(c_tree)
    elif plan == "fused_wire":
        if mask is not None:
            # unreachable behind the spec/build construction errors: the
            # mega-kernel aggregates inside, no per-client wire to mask
            raise ValueError(
                "sampled participation cannot run the fused_wire plan")
        msg_mean, new_clients = carrier.fused_wire_round(
            method, grads, clients, eta=eta, batched=True, dp=dp)
    elif plan == "wire":
        deltas, ctxs = jax.vmap(
            lambda g, s: method.pre_compress(g, s, eta=eta))(grads, clients)
        if mask is not None:
            # zero-masked wires: C(0) = 0 exactly, so the carrier's own
            # aggregation folds only the sampled cohort
            deltas = part_lib.apply_mask(mask, deltas)
        c_tree, wire_mean = carrier_lib.wire_round_batched(
            carrier, method.compressor, deltas, dp)
        # non-trivial hops pod-mean the per-client messages (local_c IS the
        # decode of what traveled); the unused global aggregate is DCE'd
        msg_mean = agg(c_tree) if want_pods else wire_mean
        _, new_clients = jax.vmap(method.post_compress)(c_tree, ctxs)
    else:
        def upd(g, s, r):
            return method.update(g, s, r, eta=eta)
        if rngs is None:
            msgs, new_clients = jax.vmap(lambda g, s: upd(g, s, None))(
                grads, clients)
        else:
            msgs, new_clients = jax.vmap(upd)(grads, clients, rngs)
        if mask is not None:
            msgs = part_lib.apply_mask(mask, msgs)
        msg_mean = agg(msgs)

    if mask is not None:
        # Bells & Whistles: delta methods fold (1/n)Σ_S as-is, absolute
        # methods rescale to the cohort mean; non-sampled clients keep
        # their ENTIRE state tree (gᵢ, momentum, …) bit-frozen
        msg_mean = part_lib.rescale_message(
            method, msg_mean, dp, efc.participation.cohort_size(dp))
        new_clients = part_lib.freeze_tree(mask, new_clients, clients)
    if want_pods:
        new_pods, new_server = hier_lib.round_pods_batched(
            hops, efc.schedule, method, msg_mean, ef_state["pods"], server,
            rng)
    else:
        new_server = ef_lib.server_step(method, server, msg_mean)
    new_state = {"clients": new_clients, "server": new_server}
    if hops is not None:
        new_state["pods"] = new_pods if want_pods else \
            hier_lib.trivial_bookkeeping(method, ef_state["pods"], msg_mean)
    if not efc.has_downlink:
        return new_server, new_state
    r_down = None if rng is None else jax.random.fold_in(rng, DOWNLINK_FOLD)
    if efc.schedule is not None:
        g_est, h_new = sched_lib.downlink_round_grouped(
            efc.schedule, new_server, ef_state["h"], r_down)
    else:
        g_est, h_new = ef_lib.downlink_sync(
            carrier_lib.make(efc.down_carrier), efc.down_comp(), new_server,
            ef_state["h"], rng=r_down)
    new_state["h"] = h_new
    return g_est, new_state


# ---------------------------------------------------------------------------
# full train step (composed in launch/train.py; kept here for reuse/tests)
# ---------------------------------------------------------------------------

def make_train_step(loss_fn: Callable, efc: EFConfig, optimizer, dp: int,
                    eta: Optional[float] = None, mesh=None,
                    grads_specs: Optional[PyTree] = None,
                    state_specs: Optional[Dict] = None):
    """Returns train_step(params, opt_state, ef_state, batch, rng, step).
    With mesh+specs, the EF sync runs in explicit shard_map (production path);
    otherwise the vmap path (single-device tests, exact global-TopK semantics)."""
    from repro.optim.optimizer import apply_updates

    def train_step(params, opt_state, ef_state, batch, rng, step):
        loss, aux, grads = per_client_value_and_grad(loss_fn, params, batch, dp)
        r_comp = jax.random.fold_in(rng, 1)
        if mesh is not None and grads_specs is not None:
            g_est, ef_state = ef_round_sharded(
                efc, grads, ef_state, r_comp, mesh, grads_specs, state_specs,
                eta=eta, step=step)
        else:
            g_est, ef_state = ef_round(efc, grads, ef_state, r_comp, eta=eta,
                                       step=step)
        updates, opt_state = optimizer.update(g_est, opt_state, params, step)
        params = apply_updates(params, updates)
        metrics = {"loss": loss,
                   "g_norm": jnp.sqrt(ef_lib.tree_norm_sq(g_est))}
        return params, opt_state, ef_state, metrics

    return train_step
