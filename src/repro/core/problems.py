"""Test problems used by the paper's experiments (§4, App. C).

Each problem is an identity-hashable object (usable as a jit static argument) with:
    init_x()                      -> pytree x⁰
    stoch_grad(x, client, rng, B) -> pytree  (unbiased minibatch gradient of f_client)
    full_grad(x)                  -> pytree  ∇f(x)   (metrics only)
    loss(x)                       -> scalar  f(x)

The container is offline, so the paper's MNIST / real-sim / CIFAR10 are replaced by
shape-matched synthetic datasets with the *heterogeneous label split across clients*
the paper uses ("we split the dataset across nodes by labels"). See EXPERIMENTS.md for
the claim-by-claim validity discussion of this substitution.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class Problem:
    __hash__ = object.__hash__


# ---------------------------------------------------------------------------
# Theorem 1 / Figure 1 construction
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class QuadraticT1(Problem):
    """f(x) = (L/2)‖x‖², x ∈ ℝ², with the *adversarial* 3-point noise of Theorem 1:

        ξ ∈ {(2,0), (0,1), (−2,−1)}·√(3σ²/(10B)) each w.p. 1/3.

    E[ξ] = 0, E‖ξ‖² = σ²/B, but E[Top1(ξ)] = √(3σ²/10)·(0,1/3) ≠ 0 — the biased
    compressor turns zero-mean noise into a systematic drift. EF21-SGD run on this
    problem drifts away from the optimum along −e₂ (Figures 1 & 4)."""

    L: float = 1.0
    sigma: float = 1.0
    x0: Tuple[float, float] = (0.0, -0.01)

    def init_x(self):
        return jnp.array(self.x0, dtype=jnp.float32)

    def _zs(self, B):
        s = jnp.sqrt(3.0 * self.sigma ** 2 / (10.0 * B))
        return jnp.array([[2.0, 0.0], [0.0, 1.0], [-2.0, -1.0]], jnp.float32) * s

    def stoch_grad(self, x, client, rng, B):
        zs = self._zs(B)
        ks = jax.random.split(rng, B)
        xi = jax.vmap(lambda k: zs[jax.random.randint(k, (), 0, 3)])(ks).mean(0)
        return self.L * x + xi

    def full_grad(self, x):
        return self.L * x

    def client_grad(self, x, client):
        return self.L * x          # homogeneous clients

    def loss(self, x):
        return 0.5 * self.L * jnp.sum(x * x)


# ---------------------------------------------------------------------------
# Algorithm 2: stochastic quadratic generator (Experiment 3 / Figure 7)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class RandomQuadratics(Problem):
    """fᵢ(x) = ½xᵀQᵢx − xᵀbᵢ with Qᵢ generated exactly by the paper's Algorithm 2
    (noisy scaled tridiagonal, mean-matrix min-eigenvalue normalized to λ).
    ∇fᵢ(x, ξ) = ∇fᵢ(x) + ξᵢ, ξᵢ ~ N(0, σ²I)."""

    n: int = 100
    d: int = 1000
    lam: float = 0.01
    scale: float = 1.0
    sigma: float = 0.001
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        n, d, s = self.n, self.d, self.scale
        mu_s = 1.0 + s * rng.randn(n)
        mu_b = s * rng.randn(n)
        base = (np.diag(2.0 * np.ones(d)) + np.diag(-np.ones(d - 1), 1)
                + np.diag(-np.ones(d - 1), -1))
        Qs = np.stack([(m / 4.0) * base for m in mu_s])          # (n, d, d)
        bs = np.zeros((n, d))
        bs[:, 0] = (mu_s / 4.0) * (-1.0 + mu_b)
        Qmean = Qs.mean(0)
        lam_min = np.linalg.eigvalsh(Qmean).min()
        Qs = Qs + (self.lam - lam_min) * np.eye(d)
        object.__setattr__(self, "_Q", jnp.asarray(Qs, jnp.float32))
        object.__setattr__(self, "_b", jnp.asarray(bs, jnp.float32))

    def init_x(self):
        x = np.zeros(self.d, np.float32)
        x[0] = np.sqrt(self.d)
        return jnp.asarray(x)

    def stoch_grad(self, x, client, rng, B):
        g = self._Q[client] @ x - self._b[client]
        noise = self.sigma * jax.random.normal(rng, (B, self.d)).mean(0)
        return g + noise

    def client_grad(self, x, client):
        return self._Q[client] @ x - self._b[client]

    def full_grad(self, x):
        return jnp.einsum("nij,j->i", self._Q, x) / self.n - self._b.mean(0)

    def loss(self, x):
        q = 0.5 * jnp.einsum("i,nij,j->", x, self._Q, x) / self.n
        return q - x @ self._b.mean(0)


# ---------------------------------------------------------------------------
# Experiments 1 & 2: nonconvex-regularized softmax logistic regression
# ---------------------------------------------------------------------------

def _make_classification(rng: np.random.RandomState, m: int, l: int, c: int,
                         label_noise: float = 0.15):
    """Synthetic classification data with class structure. ``label_noise``
    flips a fraction of labels so the problem is NOT interpolable — otherwise
    σ → 0 at the optimum and the paper's small-batch pathology (which needs
    persistent gradient noise) disappears."""
    centers = rng.randn(c, l) * 1.5
    y = rng.randint(0, c, size=m)
    a = centers[y] + rng.randn(m, l)
    flip = rng.rand(m) < label_noise
    y = np.where(flip, rng.randint(0, c, size=m), y)
    return a.astype(np.float32), y.astype(np.int32)


@dataclasses.dataclass(frozen=True, eq=False)
class LogisticRegression(Problem):
    """§4: fᵢ = −(1/m)Σⱼ log softmax(aᵢⱼᵀ x_{yᵢⱼ}) + λ Σ_{y,k} x²/(1+x²)
    with the nonconvex regularizer; data split across clients BY LABEL (the paper's
    heterogeneous protocol, App. C "Implementation Details")."""

    n: int = 10
    m_per_client: int = 512
    l: int = 64          # features (paper: 784 MNIST / 20958 real-sim; scaled)
    c: int = 10          # classes
    lam: float = 1e-3
    seed: int = 0
    heterogeneous: bool = True

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        m_total = self.n * self.m_per_client
        a, y = _make_classification(rng, m_total, self.l, self.c)
        if self.heterogeneous and self.n > 1:
            order = np.argsort(y, kind="stable")      # label split
            a, y = a[order], y[order]
        a = np.concatenate([a, np.ones((m_total, 1), np.float32)], axis=1)  # bias
        A = a.reshape(self.n, self.m_per_client, self.l + 1)
        Y = y.reshape(self.n, self.m_per_client)
        object.__setattr__(self, "_A", jnp.asarray(A))
        object.__setattr__(self, "_Y", jnp.asarray(Y))

    @property
    def dim(self):
        return self.c * (self.l + 1)

    def init_x(self):
        return jnp.zeros((self.c, self.l + 1), jnp.float32)

    def _loss_client(self, x, a, y):
        logits = a @ x.T                                   # (B, c)
        ce = -jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                  y[:, None], axis=1).mean()
        return ce

    def _reg(self, x):
        return self.lam * jnp.sum(x * x / (1.0 + x * x))

    def stoch_grad(self, x, client, rng, B):
        idx = jax.random.randint(rng, (B,), 0, self.m_per_client)
        a = self._A[client][idx]
        y = self._Y[client][idx]
        return jax.grad(lambda w: self._loss_client(w, a, y) + self._reg(w))(x)

    def full_grad(self, x):
        def fg(a, y):
            return jax.grad(lambda w: self._loss_client(w, a, y))(x)
        g = jax.vmap(fg)(self._A, self._Y)
        return g.mean(0) + jax.grad(self._reg)(x)

    def loss(self, x):
        ls = jax.vmap(lambda a, y: self._loss_client(x, a, y))(self._A, self._Y)
        return ls.mean() + self._reg(x)


# ---------------------------------------------------------------------------
# Experiment 4: neural-network training (scaled-down ResNet stand-in)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class MLPClassification(Problem):
    """Two-hidden-layer MLP classifier on synthetic data with the label-split client
    partition — the container-scale stand-in for the paper's ResNet18/CIFAR10 run
    (Figures 8–9). Same qualitative claim: method ordering under compression."""

    n: int = 5
    m_per_client: int = 256
    in_dim: int = 32
    hidden: int = 64
    c: int = 10
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        m_total = self.n * self.m_per_client
        a, y = _make_classification(rng, m_total, self.in_dim, self.c)
        order = np.argsort(y, kind="stable")
        a, y = a[order], y[order]
        object.__setattr__(self, "_A", jnp.asarray(
            a.reshape(self.n, self.m_per_client, self.in_dim)))
        object.__setattr__(self, "_Y", jnp.asarray(
            y.reshape(self.n, self.m_per_client)))

    def init_x(self):
        r = np.random.RandomState(self.seed + 1)
        def glorot(i, o):
            return jnp.asarray(r.randn(i, o).astype(np.float32)
                               * np.sqrt(2.0 / (i + o)))
        return {
            "w1": glorot(self.in_dim, self.hidden), "b1": jnp.zeros(self.hidden),
            "w2": glorot(self.hidden, self.hidden), "b2": jnp.zeros(self.hidden),
            "w3": glorot(self.hidden, self.c), "b3": jnp.zeros(self.c),
        }

    def _forward(self, p, a):
        h = jax.nn.relu(a @ p["w1"] + p["b1"])
        h = jax.nn.relu(h @ p["w2"] + p["b2"])
        return h @ p["w3"] + p["b3"]

    def _loss_batch(self, p, a, y):
        logits = self._forward(p, a)
        return -jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                    y[:, None], axis=1).mean()

    def stoch_grad(self, x, client, rng, B):
        idx = jax.random.randint(rng, (B,), 0, self.m_per_client)
        return jax.grad(self._loss_batch)(x, self._A[client][idx],
                                          self._Y[client][idx])

    def full_grad(self, x):
        g = jax.vmap(lambda a, y: jax.grad(self._loss_batch)(x, a, y))(
            self._A, self._Y)
        return jax.tree_util.tree_map(lambda v: v.mean(0), g)

    def loss(self, x):
        return jax.vmap(lambda a, y: self._loss_batch(x, a, y))(
            self._A, self._Y).mean()

    def accuracy(self, x):
        logits = self._forward(x, self._A.reshape(-1, self.in_dim))
        pred = logits.argmax(-1)
        return (pred == self._Y.reshape(-1)).mean()
