"""Minimal functional optimizer stack (no optax in the container; built in JAX).

An optimizer is (init, update):
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)        # params + updates

The EF layer (core/distributed.py) produces the aggregated gradient estimate gᵗ;
composing it with these optimizers gives:
  * ``sgd(lr)``            — the paper's exact server step x ← x − γ·gᵗ
  * ``sgd(lr, momentum)``  — server-side heavy ball (≈ EF21-HB; NOT Algorithm 1 —
                             the paper's momentum lives on the clients)
  * ``adamw(...)``         — beyond-paper production composition (EF-compressed
                             first moment feeding Adam; noted in EXPERIMENTS.md)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]        # (grads, state, params, step) -> (upd, st)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)
                      ).astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return sched


def rsqrt_schedule(lr: float):
    """γₜ = γ/√(t+1) — the paper's Appendix J time-varying choice."""
    return lambda step: lr / jnp.sqrt(jnp.asarray(step, jnp.float32) + 1.0)


def _as_sched(lr):
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    sched = _as_sched(lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params=None, step=0):
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        lr_t = sched(step)
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr_t * g, g32), state
        m = jax.tree_util.tree_map(
            lambda mo, g: momentum * mo + g, state["m"], g32)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda mo, g: -(lr_t * (momentum * mo + g)), m, g32)
        else:
            upd = jax.tree_util.tree_map(lambda mo: -lr_t * mo, m)
        return upd, {"m": m}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    sched = _as_sched(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step=0):
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        t = jnp.asarray(step, jnp.float32) + 1.0
        m = jax.tree_util.tree_map(
            lambda mo, g: b1 * mo + (1 - b1) * g, state["m"], g32)
        v = jax.tree_util.tree_map(
            lambda vo, g: b2 * vo + (1 - b2) * g * g, state["v"], g32)
        mh = jax.tree_util.tree_map(lambda mo: mo / (1 - b1 ** t), m)
        vh = jax.tree_util.tree_map(lambda vo: vo / (1 - b2 ** t), v)
        lr_t = sched(step)
        upd = jax.tree_util.tree_map(
            lambda mm, vv, p: -lr_t * (mm / (jnp.sqrt(vv) + eps)
                                       + weight_decay * p.astype(jnp.float32)),
            mh, vh, params)
        return upd, {"m": m, "v": v}

    return Optimizer(init, update)


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    def update(grads, state, params=None, step=0):
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        return opt.update(grads, state, params, step)
    return Optimizer(opt.init, update)


REGISTRY = {"sgd": sgd, "adamw": adamw}


def make(name: str, **kw) -> Optimizer:
    return REGISTRY[name](**kw)
