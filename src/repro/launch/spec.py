"""RunSpec: the single declarative, serializable name of one experiment.

The paper's point is that one small knob (Polyak momentum on EF21) changes the
complexity class — so the core workload of this repo is sweeping the
(method × compressor × carrier × mesh × arch) grid. A ``RunSpec`` names one
cell of that grid *completely*: arch + input geometry, mesh + ShardPlan,
method/compressor/carrier (η, ratio, state dtype), optimizer + lr, data
config, checkpoint policy, and seed. Every driver (launch/train.py,
launch/serve.py, launch/dryrun.py), example, and benchmark constructs a
RunSpec and hands it to :class:`repro.launch.session.Session` — there is no
other assembly path.

Design constraints:

* **Import-light.** This module imports NO jax (and configs/base.py, the arch
  registry it reads, stays jax-free too), so sweep tooling can emit spec
  files via ``python -m repro.launch.spec --print`` without paying a jax
  import, and the validation below runs in any process. The name/flag
  universes that logically live in jax-importing registries (methods,
  compressors, carriers, optimizers, mesh geometry, carrier degradation
  rules) are mirrored here as pure data; ``tests/test_spec.py`` asserts the
  mirrors equal the registries, so drift fails tier-1 loudly.
* **Fail at construction, not mid-driver.** ``__post_init__`` validates every
  field, including the carrier execution plan: a ``--carrier fused`` spec
  whose (method, compressor) would silently degrade to the unfused dense
  plan is a ``ValueError`` the moment the spec exists (mirroring the
  ``plan_with_reason`` hard error in launch/build.py, which still runs as the
  authoritative check when the EFConfig is built).
* **Stable serialization.** ``to_json``/``from_json`` round-trip exactly
  (``RunSpec.from_json(s.to_json()) == s``). The schema is versioned
  (``SCHEMA_VERSION``) and ``from_json`` REJECTS unknown keys — a spec
  written by a newer schema never silently drops experiment-defining fields.
  New fields must ship with defaults (additive evolution); renames/removals
  bump ``SCHEMA_VERSION``. ``results/specs/*.json`` holds golden fixtures
  that fail tier-1 on any drift.
* **Checkpoint compatibility.** ``spec_hash()`` hashes the canonical JSON of
  every experiment-defining field (checkpoint *policy* — ckpt_dir/ckpt_every
  — is excluded, so moving a checkpoint directory never invalidates it).
  ``Session.save`` embeds spec + hash in checkpoint meta; ``Session.resume``
  refuses a checkpoint written under a different hash unless overridden.

See DESIGN.md §7.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Optional, Tuple

from repro.configs import base as cb

# v2: bidirectional compression — the downlink_carrier / downlink_ratio
# fields change what a spec EXECUTES (a second compressed leg per round), so
# the bump made pre-downlink readers reject v2 specs loudly instead of
# silently running unidirectional rounds against a bidirectional definition.
# v3: per-parameter-group compression schedules — the ``groups`` field
# partitions the param pytree into named groups, each with its own
# (compressor × carrier × ratio × downlink × EF-state dtype). v2 specs are
# AUTO-UPGRADED on read: an absent ``groups`` IS the uniform one-group
# schedule derived from the single-knob fields, so every v2 spec names the
# same experiment it always named (and hashes identically — groups=[] is
# the default, excluded from the sparse spec_hash). One deliberate
# execution change rides the same release, independent of the schema: the
# BlockTopK sub-block geometry fix (compressors.py::BlockTopK.geom) gives
# leaves smaller than one block a proportional K instead of the degenerate
# full-block K, so a resumed v2 checkpoint whose model has sub-block
# leaves continues under the corrected compression, not the old bug.
# v4: partial participation — the ``participation`` field selects which
# clients upload each round ({mode: full|sampled|async, fraction, seed},
# DESIGN.md §11). v3 specs are AUTO-UPGRADED on read: an absent
# ``participation`` IS mode='full' (every client, every round — exactly what
# every v3 spec always executed), and the empty dict is the default, excluded
# from the sparse spec_hash, so v3 checkpoints stay resumable. v2 chains
# through the v3 upgrade first.
# v5: two-tier hierarchical aggregation — the ``hops`` field selects the
# pod topology ({pods, cross_carrier, cross_ratio}, DESIGN.md §13): clients
# → pod aggregator → global server, the cross-pod hop on its own carrier.
# v4 specs are AUTO-UPGRADED on read: an absent ``hops`` IS the flat
# topology (pods=1, zero hierarchical machinery — exactly what every v4
# spec always executed), and the empty dict is the default, excluded from
# the sparse spec_hash, so v4 checkpoints stay resumable (byte-stable
# hashes). v2/v3 chain through the earlier upgrades first.
SCHEMA_VERSION = 5

# ---------------------------------------------------------------------------
# jax-free mirrors of the jax-importing registries (sync-tested in
# tests/test_spec.py::test_name_universes_match_registries)
# ---------------------------------------------------------------------------

METHODS = frozenset({
    "ef21_sgdm_ideal", "ef21_sgd", "ef21_sgdm", "ef21_sgd2m", "ef21_sgdm_abs",
    "ef21_storm", "ef14_sgd", "sgdm", "sgd", "neolithic",
})
COMPRESSORS = frozenset({
    "identity", "topk", "randk", "block_topk", "hard_threshold", "natural",
    "rank1", "block_quant",
})
CARRIERS = frozenset({"dense", "sparse", "fused", "quant8", "quant4",
                      "fused_quant8", "fused_quant4"})
# the downlink broadcast has no fused path (the fused kernel IS the uplink
# client update) — naming it is a construction error, mirroring the carrier's
# own plan_down_with_reason degradation
DOWN_CARRIERS = frozenset(CARRIERS - {"fused"})
OPTIMIZERS = frozenset({"sgd", "adamw"})

MESHES = ("smoke", "pod", "multi_pod")
# geometry mirror of launch/mesh.py (PROD_DATA/PROD_MODEL/PROD_PODS)
MESH_GEOM: Dict[str, Dict[str, int]] = {
    "smoke": {"data": 1, "model": 1},
    "pod": {"data": 16, "model": 16},
    "multi_pod": {"pod": 2, "data": 16, "model": 16},
}

GRANULARITIES = ("group", "pod")
STATE_SHARDINGS = ("client", "zero")
EF_STATE_DTYPES = (None, "bfloat16")
MOE_IMPLS = ("dispatch", "dense")

# per-group schedule surface (mirror of core/schedule.py, sync-tested):
# the keys one ``groups`` entry may carry, the per-group EF-state dtype
# universe ('float32' exists so one group can force full precision under a
# bfloat16 spec-level default), and the characters the --schedule grammar
# reserves (a pattern containing one could never round-trip)
GROUP_KEYS = frozenset({"pattern", "carrier", "compressor", "ratio",
                        "compressor_kw", "downlink_carrier", "downlink_ratio",
                        "ef_state_dtype", "cross_carrier", "cross_ratio"})
GROUP_STATE_DTYPES = (None, "bfloat16", "float32")
PATTERN_RESERVED = set("=,:@")

# partial participation surface (mirror of core/participation.py,
# sync-tested): the modes a spec may name and the keys one ``participation``
# dict may carry. 'full' is the legacy every-client barrier; 'sampled' runs
# the masked-cohort synchronous path; 'async' names the event-driven
# simulator (core/participation.py::run_async) and is a construction error
# on the synchronous runtimes (launch/build.py).
PART_MODES = ("full", "sampled", "async")
PART_KEYS = frozenset({"mode", "fraction", "seed"})

# two-tier hierarchical aggregation surface (mirror of core/hierarchy.py,
# sync-tested): the keys a ``hops`` dict may carry. The cross-pod hop is one
# message per pod integrated like a broadcast, so its carrier universe is
# the downlink's (no fused — the fused kernel is the uplink client update).
HOP_KEYS = frozenset({"pods", "cross_carrier", "cross_ratio"})
CROSS_CARRIERS = DOWN_CARRIERS


def pattern_token_errors(pattern: str) -> List[str]:
    """Jax-free mirror of ``core.schedule.pattern_token_errors`` (sync-tested
    in tests/test_schedule.py): an empty ``|`` token matches every leaf, and
    an embedded ``'*'`` token would shadow every later group."""
    toks = pattern.split("|")
    errs = []
    if any(not t for t in toks):
        errs.append("empty '|' token (matches every leaf)")
    if "*" in toks and pattern != "*":
        errs.append("'*' may only be the standalone catch-all pattern")
    return errs

# methods with an ``eta`` field — the spec's eta drives ALL of them (a spec
# that records η=0.3 must never run a class default instead; method_kw can
# still override). Mirror of {cls has 'eta' field} — sync-tested.
ETA_METHODS = frozenset({"ef21_sgdm", "ef21_sgd2m", "sgdm", "ef21_storm",
                         "ef21_sgdm_abs", "ef21_sgdm_ideal"})

# attribute mirrors used by plan_preview (sync-tested against
# Method.wire_is_msg / Compressor.needs_rng / the carriers' own support sets)
WIRE_IS_NOT_MSG = frozenset({"ef21_sgdm_ideal", "ef21_sgdm_abs", "neolithic"})
NEEDS_RNG = frozenset({"randk", "natural"})
SPARSE_WIRE_OK = frozenset({"topk", "block_topk"})
FUSED_METHODS = frozenset({"ef21_sgdm", "ef21_sgd"})
FUSED_COMPRESSORS = frozenset({"block_topk"})


def plan_preview(method: str, compressor: str, carrier: str,
                 block: Optional[int] = None) -> Tuple[str, str]:
    """Pure-python mirror of ``Carrier.plan_with_reason`` (core/carriers.py)
    by name: (plan, reason) where plan ∈ {'dense','wire','fused',
    'fused_wire'} and reason is non-empty iff the carrier degraded to a
    less-fused plan (dense for sparse/quant/fused; the unfused quantized
    wire for fused_quant). η is always a static float in a RunSpec, so the
    traced-η degradations can never trigger here. ``block`` is the BlockTopK
    block width when the spec sets one (fused_quant4's uint4 packing needs
    it even; None = the even default). The plan (and reason emptiness) is
    asserted equal to the real carriers over the whole
    (method × compressor × carrier) grid in tests/test_spec.py."""
    if carrier == "dense":
        return "dense", ""
    if method in WIRE_IS_NOT_MSG:
        return "dense", (
            f"method {method!r} transmits a transform of c "
            "(wire_is_msg=False); a non-dense wire cannot ship it")
    if carrier == "sparse":
        if compressor not in SPARSE_WIRE_OK:
            return "dense", (
                f"compressor {compressor!r} has no deterministic fixed-size "
                "(values, indices) wire")
        return "wire", ""
    if carrier == "fused":
        if method not in FUSED_METHODS:
            return "dense", ("the fused kernel implements the EF21-SGD(M) "
                             f"client chain only, not {method!r}")
        if compressor not in FUSED_COMPRESSORS:
            return "dense", ("the fused kernel compresses with BlockTopK "
                             f"only, not {compressor!r}")
        return "fused", ""
    # quant8 / quant4 / fused_quant8 / fused_quant4
    if compressor in NEEDS_RNG:
        return "dense", (
            f"compressor {compressor!r} draws randomness inside encode; the "
            "quantized wire ships deterministic compressors only")
    if carrier in ("fused_quant8", "fused_quant4"):
        if method not in FUSED_METHODS:
            return "wire", (
                "the fused wire kernel implements the EF21-SGD(M) client "
                f"chain only, not {method!r}; running the unfused quantized "
                "wire")
        if compressor not in FUSED_COMPRESSORS:
            return "wire", (
                "the fused wire kernel compresses with BlockTopK only, not "
                f"{compressor!r}; running the unfused quantized wire")
        if carrier == "fused_quant4" and block is not None and block % 2:
            return "wire", (
                "uint4 packing needs an even BlockTopK block; running the "
                "unfused quantized wire")
        return "fused_wire", ""
    return "wire", ""


def downlink_plan_preview(compressor: str, carrier: str) -> Tuple[str, str]:
    """Pure-python mirror of ``Carrier.plan_down_with_reason``
    (core/carriers.py) by name: the DOWNLINK broadcast plan. No method enters
    — the broadcast payload is always the compressed innovation C(g − h), so
    only the compressor gates the wire. Asserted equal to the real carriers
    over the (compressor × carrier) grid in tests/test_spec.py."""
    if carrier == "dense":
        return "dense", ""
    if carrier == "fused":
        return "dense", (
            "the fused kernel fuses the UPLINK client update; the downlink "
            "broadcast has no fused path — use dense, sparse or quant")
    if carrier == "sparse":
        if compressor not in SPARSE_WIRE_OK:
            return "dense", (
                f"compressor {compressor!r} has no deterministic fixed-size "
                "(values, indices) wire")
        return "wire", ""
    # quant8 / quant4
    if compressor in NEEDS_RNG:
        return "dense", (
            f"compressor {compressor!r} draws randomness inside encode; the "
            "quantized wire ships deterministic compressors only")
    return "wire", ""


# ---------------------------------------------------------------------------
# per-group schedule: jax-free grammar + previews (DESIGN.md §9)
# ---------------------------------------------------------------------------

def parse_schedule_flag(s: str) -> List[Dict[str, Any]]:
    """Parse the ``--schedule`` value into a ``groups`` list. Two forms:

      grammar   ``"embed=dense,norm|bias=dense,*=quant4:0.05"`` — comma-
                separated ``pattern=carrier[:ratio][@compressor]`` entries
                (``dense`` with no ``@compressor`` means ship-uncompressed,
                i.e. the identity compressor; other carriers default to the
                spec's compressor at the given ratio)
      JSON      a ``[...]`` list of group dicts, for per-group knobs the
                grammar cannot express (downlink, state dtype, compressor_kw)

    ``format_schedule_flag`` is the inverse; grammar-expressible schedules
    round-trip exactly (tier-1 tested)."""
    if s.lstrip().startswith("["):
        return json.loads(s)
    out: List[Dict[str, Any]] = []
    for part in s.split(","):
        part = part.strip()
        pattern, sep, rhs = part.partition("=")
        if not sep or not pattern or not rhs:
            raise ValueError(
                f"bad --schedule entry {part!r}: want "
                "'pattern=carrier[:ratio][@compressor]'")
        comp = None
        if "@" in rhs:
            rhs, comp = rhs.split("@", 1)
        carrier, sep, ratio = rhs.partition(":")
        entry: Dict[str, Any] = {"pattern": pattern, "carrier": carrier}
        if sep:
            entry["ratio"] = float(ratio)
        if comp is not None:
            entry["compressor"] = comp
        out.append(entry)
    return out


def format_schedule_flag(groups: List[Dict[str, Any]]) -> str:
    """The canonical ``--schedule`` value for a ``groups`` list: the compact
    grammar when every entry is grammar-expressible, JSON otherwise."""
    parts = []
    for e in groups:
        if not ({"pattern", "carrier"} <= set(e)
                and set(e) <= {"pattern", "carrier", "ratio", "compressor"}):
            return json.dumps(groups, sort_keys=True)
        s = f"{e['pattern']}={e['carrier']}"
        if "ratio" in e:
            s += f":{e['ratio']}"
        if "compressor" in e:
            s += f"@{e['compressor']}"
        parts.append(s)
    return ",".join(parts)


def resolved_groups(spec: "RunSpec") -> List[Dict[str, Any]]:
    """The spec's schedule with every per-group default filled in. An empty
    ``groups`` IS the uniform one-group schedule of the single-knob fields
    (the v2 auto-upgrade); explicit entries default each absent key from the
    spec — except ``compressor``, which defaults to ``identity`` for a
    ``dense``-carrier group (ship-uncompressed, the grammar's reading of
    ``norm=dense``) and to the spec's compressor otherwise, and
    ``compressor_kw``, which only carries over when the group runs the
    spec's own compressor class."""
    # per-group cross-hop defaults come from the spec's hops (--hops sets
    # the uniform cross; a group entry overrides it for its own leaves)
    hop_car = spec.hops.get("cross_carrier", "dense") \
        if isinstance(spec.hops, dict) else "dense"
    hop_ratio = spec.hops.get("cross_ratio", spec.ratio) \
        if isinstance(spec.hops, dict) else spec.ratio
    if not spec.groups:
        return [{"pattern": "*", "carrier": spec.carrier,
                 "compressor": spec.compressor, "ratio": spec.ratio,
                 "compressor_kw": dict(spec.compressor_kw),
                 "downlink_carrier": spec.downlink_carrier,
                 "downlink_ratio": spec.downlink_ratio,
                 "ef_state_dtype": spec.ef_state_dtype,
                 "cross_carrier": hop_car, "cross_ratio": hop_ratio}]
    out = []
    for e in spec.groups:
        carrier = e.get("carrier", "dense")
        comp = e.get("compressor",
                     "identity" if carrier == "dense" else spec.compressor)
        kw = e.get("compressor_kw",
                   dict(spec.compressor_kw) if comp == spec.compressor
                   else {})
        out.append({
            "pattern": e.get("pattern"),
            "carrier": carrier,
            "compressor": comp,
            "ratio": e.get("ratio", spec.ratio),
            "compressor_kw": kw,
            "downlink_carrier": e.get("downlink_carrier",
                                      spec.downlink_carrier),
            "downlink_ratio": e.get("downlink_ratio", spec.downlink_ratio),
            "ef_state_dtype": e.get("ef_state_dtype", spec.ef_state_dtype),
            "cross_carrier": e.get("cross_carrier", hop_car),
            "cross_ratio": e.get("cross_ratio", hop_ratio),
        })
    return out


def schedule_preview(spec: "RunSpec") -> List[Dict[str, Any]]:
    """Jax-free mirror of the resolved group table: one row per group with
    the uplink plan (``plan_preview``) and downlink plan
    (``downlink_plan_preview``) that would execute — sync-tested against the
    real carriers/schedule objects in tests/test_schedule.py. Leaf/param
    counts need the real param tree and live in
    ``Session.schedule_table()``."""
    rows = []
    for g in resolved_groups(spec):
        blk = g["compressor_kw"].get("block") \
            if isinstance(g.get("compressor_kw"), dict) else None
        plan, reason = plan_preview(spec.method, g["compressor"],
                                    g["carrier"],
                                    blk if isinstance(blk, int) else None)
        dplan, dreason = downlink_plan_preview(g["compressor"],
                                               g["downlink_carrier"])
        rows.append({**g, "plan": plan, "plan_reason": reason,
                     "downlink_plan": dplan, "downlink_reason": dreason})
    return rows


# ---------------------------------------------------------------------------
# partial participation: jax-free grammar + preview (DESIGN.md §11)
# ---------------------------------------------------------------------------

def parse_participation_flag(s: str) -> Dict[str, Any]:
    """Parse the ``--participation`` value into a participation dict. Two
    forms:

      grammar   ``"sampled:0.25:7"`` — colon-separated ``mode[:fraction
                [:seed]]`` (``"full"``, ``"sampled:0.25"``, …)
      JSON      a ``{...}`` dict, for exact round-trips of any keyset

    ``format_participation_flag`` is the inverse; grammar-expressible dicts
    round-trip exactly (tier-1 tested)."""
    if s.lstrip().startswith("{"):
        return json.loads(s)
    parts = s.split(":")
    if len(parts) > 3 or not parts[0]:
        raise ValueError(f"bad --participation value {s!r}: want "
                         "'mode[:fraction[:seed]]' or a JSON dict")
    out: Dict[str, Any] = {"mode": parts[0]}
    if len(parts) >= 2:
        out["fraction"] = float(parts[1])
    if len(parts) == 3:
        out["seed"] = int(parts[2])
    return out


def format_participation_flag(p: Dict[str, Any]) -> str:
    """The canonical ``--participation`` value for a participation dict: the
    compact grammar when the keyset is a grammar prefix, JSON otherwise."""
    keys = set(p)
    if keys == {"mode"}:
        return str(p["mode"])
    if keys == {"mode", "fraction"}:
        return f"{p['mode']}:{p['fraction']}"
    if keys == {"mode", "fraction", "seed"}:
        return f"{p['mode']}:{p['fraction']}:{p['seed']}"
    return json.dumps(p, sort_keys=True)


def participation_preview(spec: "RunSpec") -> Dict[str, Any]:
    """Jax-free resolved participation: mode/fraction/seed with defaults
    filled in, plus the paper's n for this spec and the per-round cohort
    size m = max(1, round(fraction·n)) — EXACTLY the arithmetic of
    ``core.participation.Participation.cohort_size`` (sync-tested in
    tests/test_participation_properties.py)."""
    p = spec.participation
    mode = p.get("mode", "full") if p else "full"
    fraction = float(p.get("fraction", 1.0)) if p else 1.0
    seed = int(p.get("seed", 0)) if p else 0
    n = spec.n_clients_preview()
    cohort = n if mode == "full" else max(1, int(round(fraction * n)))
    return {"mode": mode, "fraction": fraction, "seed": seed,
            "n": n, "cohort": cohort}


# ---------------------------------------------------------------------------
# two-tier hierarchical aggregation: jax-free grammar + preview (§13)
# ---------------------------------------------------------------------------

def parse_hops_flag(s: str) -> Dict[str, Any]:
    """Parse the ``--hops`` value into a hops dict. Two forms:

      grammar   ``"pods=2,cross=quant4:0.05"`` — comma-separated
                ``pods=<int>`` and ``cross=carrier[:ratio]`` entries
      JSON      a ``{...}`` dict, for exact round-trips of any keyset

    ``format_hops_flag`` is the inverse; grammar-expressible dicts
    round-trip exactly (tier-1 tested)."""
    if s.lstrip().startswith("{"):
        return json.loads(s)
    out: Dict[str, Any] = {}
    for part in s.split(","):
        part = part.strip()
        key, sep, rhs = part.partition("=")
        if not sep or not rhs:
            raise ValueError(f"bad --hops entry {part!r}: want "
                             "'pods=<int>' or 'cross=carrier[:ratio]'")
        if key == "pods":
            out["pods"] = int(rhs)
        elif key == "cross":
            carrier, sep, ratio = rhs.partition(":")
            out["cross_carrier"] = carrier
            if sep:
                out["cross_ratio"] = float(ratio)
        else:
            raise ValueError(f"bad --hops key {key!r}: want 'pods' or "
                             "'cross'")
    return out


def format_hops_flag(h: Dict[str, Any]) -> str:
    """The canonical ``--hops`` value for a hops dict: the compact grammar
    when the keyset is grammar-expressible, JSON otherwise."""
    if not set(h) <= HOP_KEYS:
        return json.dumps(h, sort_keys=True)
    parts = []
    if "pods" in h:
        parts.append(f"pods={h['pods']}")
    if "cross_carrier" in h:
        s = f"cross={h['cross_carrier']}"
        if "cross_ratio" in h:
            s += f":{h['cross_ratio']}"
        parts.append(s)
    elif "cross_ratio" in h:
        return json.dumps(h, sort_keys=True)
    return ",".join(parts)


def hops_preview(spec: "RunSpec") -> Dict[str, Any]:
    """Jax-free resolved hop topology: pods/cross carrier/ratio with
    defaults filled in, the per-pod client count, and the flat-equivalence
    predicate (``trivial_cross`` — a dense cross ships the exact pod target,
    so the round is bit-identical to the flat path). Mirrors
    ``core.hierarchy.Hops`` semantics exactly (sync-tested in
    tests/test_hierarchy.py)."""
    h = spec.hops
    pods = int(h.get("pods", 1)) if h else 1
    cross_carrier = h.get("cross_carrier", "dense") if h else "dense"
    cross_ratio = float(h.get("cross_ratio", spec.ratio)) if h else spec.ratio
    n = spec.n_clients_preview()
    return {"pods": pods, "cross_carrier": cross_carrier,
            "cross_ratio": cross_ratio, "n": n,
            "clients_per_pod": n // pods if pods and n % pods == 0 else None,
            "hierarchical": pods > 1,
            "trivial_cross": cross_carrier == "dense"}


def _known_arch(arch: str) -> bool:
    return arch in cb.ARCH_ALIASES or arch in cb.ARCH_IDS


_JSON_SCALARS = (str, int, float, bool, type(None))


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """The full, JSON-round-trippable name of one experiment. Frozen; every
    field is validated in ``__post_init__`` so an invalid spec never exists.

    ``shape`` selects a *named* production InputShape (configs/base.py) for
    ``Session.lower()`` / the dry-run; interactive training and serving use
    the explicit (``seq_len``, ``global_batch``) geometry. ``clients`` is the
    number of emulated EF clients on the single-device (smoke-mesh) path; on
    multi-device meshes n is derived from mesh × client_granularity exactly
    as DESIGN.md §3 maps clients onto data-parallel groups."""

    version: int = SCHEMA_VERSION

    # -- experiment identity -------------------------------------------------
    arch: str = "smollm-360m"
    smoke: bool = False                    # reduced per-arch config (CPU-sized)
    shape: Optional[str] = None            # named InputShape for lower()/dryrun
    seq_len: int = 256
    global_batch: int = 16

    # -- mesh / placement (ShardPlan) ----------------------------------------
    mesh: str = "smoke"                    # 'smoke' | 'pod' | 'multi_pod'
    client_granularity: str = "group"      # ShardPlan: 'group' | 'pod'
    state_sharding: str = "client"         # ShardPlan: 'client' | 'zero'
    ef_state_dtype: Optional[str] = None   # ShardPlan: None | 'bfloat16'
    clients: int = 8                       # emulated clients on 1-device mesh

    # -- method / transport --------------------------------------------------
    method: str = "ef21_sgdm"
    compressor: str = "block_topk"
    ratio: float = 0.05
    eta: float = 0.1
    carrier: str = "dense"
    # downlink (server → client broadcast) leg, DESIGN.md §8: 'dense' = no
    # downlink machinery (the implicit dense broadcast — pre-v2 behavior,
    # bit-identical). Any other carrier adds the EF21 server memory h and
    # broadcasts C(g − h) as that carrier's wire; the downlink compressor is
    # the uplink compressor class re-budgeted to downlink_ratio
    # (launch/session.py::make_down_compressor).
    downlink_carrier: str = "dense"
    downlink_ratio: float = 0.05
    # per-parameter-group compression schedule (DESIGN.md §9): an ordered
    # list of group dicts (keys ⊆ GROUP_KEYS; 'pattern' mandatory, the last
    # entry must be the catch-all '*'), first-match-wins over the param
    # pytree's leaf paths. Empty = the uniform one-group schedule of the
    # single-knob fields above (the v2 meaning, bit-identical). Absent keys
    # default from the spec (resolved_groups); the --schedule flag grammar
    # is 'pattern=carrier[:ratio][@compressor],…' (parse_schedule_flag).
    groups: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    # comm/compute overlap (DESIGN.md §10): gather-wire aggregations on the
    # shard_map runtime transport their all-gather as a ppermute ring and
    # decode each chunk while the next is in flight. Bit-identical to the
    # blocking anchor; a no-op for all-reduce wires and the vmap runtimes.
    overlap: bool = False
    # partial participation (DESIGN.md §11): which clients upload each round.
    # Empty dict = mode 'full' (every client, every round — the v3 meaning,
    # bit-identical, excluded from the sparse spec_hash). mode='sampled'
    # draws a seeded cohort of max(1, round(fraction·n)) clients per round
    # (--participation sampled:0.25:7); fraction=1.0 sampling is
    # bit-identical to 'full' (tests/test_participation.py). mode='async'
    # names the event-driven simulator and never runs the synchronous
    # drivers. Keys ⊆ PART_KEYS.
    participation: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # two-tier hierarchical aggregation (DESIGN.md §13): clients → pod
    # aggregator → global server, the cross-pod hop on its own carrier.
    # Empty dict = the flat topology (pods=1, zero hierarchical machinery —
    # the v4 meaning, bit-identical, excluded from the sparse spec_hash).
    # {"pods": 2, "cross_carrier": "quant4", "cross_ratio": 0.05} keeps the
    # intra-pod hop on the spec's carrier/schedule and ships one quant4
    # innovation per pod across the slow links, error-fed by a per-pod EF
    # memory (--hops pods=2,cross=quant4:0.05). Keys ⊆ HOP_KEYS. The cross
    # compressor is the uplink compressor class re-budgeted to cross_ratio
    # (launch/session.py::make_hops), exactly like the downlink's.
    hops: Dict[str, Any] = dataclasses.field(default_factory=dict)
    method_kw: Dict[str, Any] = dataclasses.field(default_factory=dict)
    compressor_kw: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- arch overrides (dry-run sweep knobs, applied to the ArchConfig) -----
    tp_pad_heads: int = 0
    moe_impl: str = "dispatch"

    # -- optimizer -----------------------------------------------------------
    optimizer: str = "sgd"
    lr: float = 0.5

    # -- data ----------------------------------------------------------------
    heterogeneity: float = 0.5
    seed: int = 0

    # -- checkpoint policy (excluded from spec_hash) -------------------------
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0                    # 0 → save only at end of train()

    # ------------------------------------------------------------------ valid
    def __post_init__(self):
        errs: List[str] = []
        if self.version != SCHEMA_VERSION:
            errs.append(f"schema version {self.version} unsupported "
                        f"(this build reads v{SCHEMA_VERSION})")
        if not _known_arch(self.arch):
            errs.append(f"unknown arch {self.arch!r}; have "
                        f"{sorted(cb.ARCH_ALIASES)}")
        if self.shape is not None and self.shape not in cb.INPUT_SHAPES:
            errs.append(f"unknown shape {self.shape!r}; have "
                        f"{sorted(cb.INPUT_SHAPES)}")
        if self.mesh not in MESHES:
            errs.append(f"unknown mesh {self.mesh!r}; have {list(MESHES)}")
        for field, val, allowed in [
                ("client_granularity", self.client_granularity, GRANULARITIES),
                ("state_sharding", self.state_sharding, STATE_SHARDINGS),
                ("ef_state_dtype", self.ef_state_dtype, EF_STATE_DTYPES),
                ("moe_impl", self.moe_impl, MOE_IMPLS)]:
            if val not in allowed:
                errs.append(f"{field}={val!r} not in {list(allowed)}")
        for field, val, universe in [
                ("method", self.method, METHODS),
                ("compressor", self.compressor, COMPRESSORS),
                ("carrier", self.carrier, CARRIERS),
                ("downlink_carrier", self.downlink_carrier, DOWN_CARRIERS),
                ("optimizer", self.optimizer, OPTIMIZERS)]:
            if val not in universe:
                errs.append(f"unknown {field} {val!r}; have {sorted(universe)}")
        if self.seq_len <= 0:
            errs.append(f"seq_len must be positive, got {self.seq_len}")
        if self.global_batch <= 0:
            errs.append(f"global_batch must be positive, got "
                        f"{self.global_batch}")
        if self.clients < 1:
            errs.append(f"clients must be >= 1, got {self.clients}")
        if not 0.0 < self.eta <= 1.0:
            errs.append(f"eta must be in (0, 1], got {self.eta}")
        if not 0.0 < self.ratio <= 1.0:
            errs.append(f"ratio must be in (0, 1], got {self.ratio}")
        if not 0.0 < self.downlink_ratio <= 1.0:
            errs.append(f"downlink_ratio must be in (0, 1], got "
                        f"{self.downlink_ratio}")
        if not 0.0 <= self.heterogeneity <= 1.0:
            errs.append(f"heterogeneity must be in [0, 1], got "
                        f"{self.heterogeneity}")
        if self.tp_pad_heads < 0:
            errs.append(f"tp_pad_heads must be >= 0, got {self.tp_pad_heads}")
        if self.ckpt_every < 0:
            errs.append(f"ckpt_every must be >= 0, got {self.ckpt_every}")
        for kw_name, kw in [("method_kw", self.method_kw),
                            ("compressor_kw", self.compressor_kw)]:
            if not isinstance(kw, dict) or not all(
                    isinstance(k, str) and isinstance(v, _JSON_SCALARS)
                    for k, v in kw.items()):
                errs.append(f"{kw_name} must map str keys to JSON scalars, "
                            f"got {kw!r}")
        errs.extend(self._validate_groups())
        errs.extend(self._validate_participation())
        errs.extend(self._validate_hops())
        # the (batch % clients) divisibility the runtime would assert
        # mid-step — checked for BOTH batch geometries a spec can run: the
        # interactive train geometry (global_batch, Session.train) and,
        # when set, the named dry-run shape (Session.lower)
        shape_ok = self.shape is None or self.shape in cb.INPUT_SHAPES
        n = self.n_clients_preview() if self.mesh in MESHES else 1
        batches = [self.global_batch]
        if shape_ok and self.shape is not None \
                and self.train_kind() == "train":
            batches.append(self.train_batch())
        for batch in batches:
            if batch > 0 and n >= 1 and batch % n != 0:
                errs.append(f"global batch {batch} not divisible by the "
                            f"{n} EF clients of mesh={self.mesh!r} "
                            f"granularity={self.client_granularity!r}")
        # the fused-misconfig hard error, at construction time (the same check
        # runs authoritatively against the real carrier in launch/build.py)
        if self.carrier in CARRIERS and self.method in METHODS \
                and self.compressor in COMPRESSORS:
            plan, reason = self.plan()
            if self.carrier == "fused" and plan != "fused":
                errs.append(
                    "carrier='fused' would silently run the UNFUSED dense "
                    f"plan: {reason}. Pick carrier='dense' or 'sparse' for "
                    f"method={self.method!r} compressor={self.compressor!r}")
            if self.carrier in ("fused_quant8", "fused_quant4") \
                    and plan != "fused_wire":
                errs.append(
                    f"carrier={self.carrier!r} would silently run a "
                    f"DEGRADED plan ({plan!r}): {reason}. Pick "
                    "carrier='quant8'/'quant4' (the unfused quantized wire) "
                    f"for method={self.method!r} "
                    f"compressor={self.compressor!r}")
        if errs:
            raise ValueError("invalid RunSpec:\n  - " + "\n  - ".join(errs))

    def _validate_groups(self) -> List[str]:
        """Construction-time schedule validation, jax-free (the real
        CompressionSchedule re-validates authoritatively in
        session.make_schedule)."""
        errs: List[str] = []
        if not isinstance(self.groups, list):
            return [f"groups must be a list of dicts, got {self.groups!r}"]
        if not self.groups:
            return errs
        seen = set()
        for i, e in enumerate(self.groups):
            if not isinstance(e, dict):
                errs.append(f"groups[{i}] must be a dict, got {e!r}")
                continue
            unknown = sorted(set(e) - GROUP_KEYS)
            if unknown:
                errs.append(f"groups[{i}]: unknown keys {unknown}; have "
                            f"{sorted(GROUP_KEYS)}")
            pat = e.get("pattern")
            if not pat or not isinstance(pat, str):
                errs.append(f"groups[{i}] needs a non-empty 'pattern'")
                continue
            bad = PATTERN_RESERVED & set(pat)
            if bad:
                errs.append(f"groups[{i}] pattern {pat!r} uses reserved "
                            f"characters {sorted(bad)}")
            errs.extend(f"groups[{i}] pattern {pat!r}: {e}"
                        for e in pattern_token_errors(pat))
            if pat in seen:
                errs.append(f"duplicate group pattern {pat!r}")
            seen.add(pat)
            if pat == "*" and i != len(self.groups) - 1:
                errs.append("the catch-all '*' must be the LAST group "
                            "(first-match-wins shadows everything after it)")
            carrier = e.get("carrier", "dense")
            if carrier not in CARRIERS:
                errs.append(f"groups[{i}]: unknown carrier {carrier!r}")
                continue
            comp = e.get("compressor",
                         "identity" if carrier == "dense"
                         else self.compressor)
            if comp not in COMPRESSORS:
                errs.append(f"groups[{i}]: unknown compressor {comp!r}")
                continue
            if e.get("downlink_carrier", "dense") not in DOWN_CARRIERS:
                errs.append(f"groups[{i}]: downlink carrier "
                            f"{e['downlink_carrier']!r} not in "
                            f"{sorted(DOWN_CARRIERS)}")
            if e.get("cross_carrier", "dense") not in CROSS_CARRIERS:
                errs.append(f"groups[{i}]: cross carrier "
                            f"{e['cross_carrier']!r} not in "
                            f"{sorted(CROSS_CARRIERS)}")
            if e.get("ef_state_dtype") not in GROUP_STATE_DTYPES:
                errs.append(f"groups[{i}]: ef_state_dtype "
                            f"{e['ef_state_dtype']!r} not in "
                            f"{list(GROUP_STATE_DTYPES)}")
            for key in ("ratio", "downlink_ratio", "cross_ratio"):
                if key in e and not (isinstance(e[key], (int, float))
                                     and 0.0 < e[key] <= 1.0):
                    errs.append(f"groups[{i}]: {key} must be in (0, 1], "
                                f"got {e[key]!r}")
            kw = e.get("compressor_kw", {})
            if not isinstance(kw, dict) or not all(
                    isinstance(k, str) and isinstance(v, _JSON_SCALARS)
                    for k, v in kw.items()):
                errs.append(f"groups[{i}]: compressor_kw must map str keys "
                            f"to JSON scalars, got {kw!r}")
            # the fused-misconfig hard error, per group (mirrors the
            # authoritative per-group check in launch/build.py)
            if self.method in METHODS:
                blk = kw.get("block") if isinstance(kw, dict) else None
                plan, reason = plan_preview(
                    self.method, comp, carrier,
                    blk if isinstance(blk, int) else None)
                if carrier == "fused" and plan != "fused":
                    errs.append(
                        f"groups[{i}] ({pat!r}): carrier='fused' would "
                        f"silently run the UNFUSED dense plan: {reason}")
                if carrier in ("fused_quant8", "fused_quant4") \
                        and plan != "fused_wire":
                    errs.append(
                        f"groups[{i}] ({pat!r}): carrier={carrier!r} would "
                        f"silently run a DEGRADED plan ({plan!r}): {reason}")
        # reported alongside any per-entry errors (one fix-and-rerun pass,
        # like the authoritative CompressionSchedule.__post_init__)
        if isinstance(self.groups[-1], dict) \
                and self.groups[-1].get("pattern") != "*":
            errs.append("the last group must be the mandatory catch-all "
                        "'*' so every leaf lands in exactly one group")
        return errs

    def _validate_participation(self) -> List[str]:
        """Construction-time participation validation, jax-free (the real
        Participation re-validates authoritatively in
        session.make_participation / launch/build.py)."""
        p = self.participation
        if not isinstance(p, dict):
            return [f"participation must be a dict, got {p!r}"]
        if not p:
            return []
        errs: List[str] = []
        unknown = sorted(set(p) - PART_KEYS)
        if unknown:
            errs.append(f"participation: unknown keys {unknown}; have "
                        f"{sorted(PART_KEYS)}")
        mode = p.get("mode", "full")
        if mode not in PART_MODES:
            errs.append(f"participation: unknown mode {mode!r}; have "
                        f"{list(PART_MODES)}")
        frac = p.get("fraction", 1.0)
        if not (isinstance(frac, (int, float)) and not isinstance(frac, bool)
                and 0.0 < frac <= 1.0):
            errs.append(f"participation: fraction must be in (0, 1], got "
                        f"{frac!r}")
        seed = p.get("seed", 0)
        if not isinstance(seed, int) or isinstance(seed, bool):
            errs.append(f"participation: seed must be an int, got {seed!r}")
        if mode in ("sampled", "async"):
            # the fused wire aggregates INSIDE the mega-kernel — there is no
            # per-client wire left to mask, so a sampled cohort cannot ride
            # it. Fail at construction, like the fused-misconfig errors.
            fused_wire_carriers = {"fused_quant8", "fused_quant4"}
            bad = []
            if self.carrier in fused_wire_carriers:
                bad.append(f"carrier={self.carrier!r}")
            for i, e in enumerate(self.groups):
                if isinstance(e, dict) \
                        and e.get("carrier") in fused_wire_carriers:
                    bad.append(f"groups[{i}] "
                               f"(pattern={e.get('pattern')!r})")
            if bad:
                errs.append(
                    f"participation mode {mode!r} cannot run the fused "
                    f"quantized wire ({', '.join(bad)}): the mega-kernel "
                    "aggregates all clients inside, leaving no per-client "
                    "wire to mask — use carrier='quant8'/'quant4'")
        return errs

    def _validate_hops(self) -> List[str]:
        """Construction-time hop-topology validation, jax-free (the real
        Hops re-validates authoritatively in session.make_hops /
        launch/build.py)."""
        h = self.hops
        if not isinstance(h, dict):
            return [f"hops must be a dict, got {h!r}"]
        if not h:
            return []
        errs: List[str] = []
        unknown = sorted(set(h) - HOP_KEYS)
        if unknown:
            errs.append(f"hops: unknown keys {unknown}; have "
                        f"{sorted(HOP_KEYS)}")
        pods = h.get("pods", 1)
        if not isinstance(pods, int) or isinstance(pods, bool) or pods < 1:
            errs.append(f"hops: pods must be an int >= 1, got {pods!r}")
            return errs
        cross = h.get("cross_carrier", "dense")
        if cross not in CROSS_CARRIERS:
            errs.append(f"hops: unknown cross carrier {cross!r}; have "
                        f"{sorted(CROSS_CARRIERS)}")
        ratio = h.get("cross_ratio", self.ratio)
        if not (isinstance(ratio, (int, float))
                and not isinstance(ratio, bool) and 0.0 < ratio <= 1.0):
            errs.append(f"hops: cross_ratio must be in (0, 1], got {ratio!r}")
        if pods == 1:
            return errs
        # pods > 1: the topology constraints
        n = self.n_clients_preview() if self.mesh in MESHES else pods
        if n % pods != 0:
            errs.append(f"hops: pods={pods} must divide the {n} EF clients "
                        f"of mesh={self.mesh!r} "
                        f"granularity={self.client_granularity!r}")
        if self.mesh == "pod":
            errs.append("hops: mesh='pod' has no pod axis — hierarchical "
                        "aggregation (pods > 1) needs mesh='multi_pod' or "
                        "the single-device smoke mesh (vmap emulation)")
        if self.mesh == "multi_pod" \
                and pods != MESH_GEOM["multi_pod"]["pod"]:
            errs.append(f"hops: pods={pods} must equal the multi_pod mesh's "
                        f"pod axis ({MESH_GEOM['multi_pod']['pod']})")
        if self.client_granularity == "pod":
            errs.append("hops: client_granularity='pod' makes each pod ONE "
                        "client — there is no intra-pod hop left to "
                        "aggregate; use granularity='group'")
        mode = self.participation.get("mode", "full") \
            if isinstance(self.participation, dict) else "full"
        if mode in ("sampled", "async"):
            errs.append(
                f"hops: participation mode {mode!r} does not compose with "
                "hierarchical aggregation (a partial cohort breaks the "
                "pod-major client blocks) — use mode='full'")
        # the fused wire aggregates all clients inside the mega-kernel:
        # there is no per-pod message left to re-aggregate
        fused_wire_carriers = {"fused_quant8", "fused_quant4"}
        bad = []
        if self.carrier in fused_wire_carriers:
            bad.append(f"carrier={self.carrier!r}")
        for i, e in enumerate(self.groups):
            if isinstance(e, dict) \
                    and e.get("carrier") in fused_wire_carriers:
                bad.append(f"groups[{i}] (pattern={e.get('pattern')!r})")
        if bad:
            errs.append(
                f"hops: hierarchical aggregation cannot run the fused "
                f"quantized wire ({', '.join(bad)}): the mega-kernel "
                "aggregates all clients inside, leaving no per-pod message "
                "— use carrier='quant8'/'quant4'")
        return errs

    # -------------------------------------------------------------- previews
    def plan(self) -> Tuple[str, str]:
        """(execution plan, degradation reason) for this spec's carrier —
        see plan_preview."""
        block = self.compressor_kw.get("block") \
            if isinstance(self.compressor_kw, dict) else None
        return plan_preview(self.method, self.compressor, self.carrier,
                            block if isinstance(block, int) else None)

    def downlink_plan(self) -> Tuple[str, str]:
        """(execution plan, degradation reason) for the downlink broadcast —
        see downlink_plan_preview."""
        return downlink_plan_preview(self.compressor, self.downlink_carrier)

    def train_kind(self) -> str:
        """'train' | 'prefill' | 'decode' of the named shape (custom
        geometry is always a train shape)."""
        if self.shape is not None:
            return cb.INPUT_SHAPES[self.shape].kind
        return "train"

    def train_batch(self) -> int:
        if self.shape is not None:
            return cb.INPUT_SHAPES[self.shape].global_batch
        return self.global_batch

    def n_clients_preview(self) -> int:
        """The paper's n for this spec, computable without jax: the emulated
        client count on the 1-device smoke mesh, else derived from mesh
        geometry × client granularity (DESIGN.md §3)."""
        if self.mesh == "smoke":
            return self.clients
        geom = MESH_GEOM[self.mesh]
        if self.client_granularity == "pod":
            return geom.get("pod", 1)
        n = 1
        for ax in ("pod", "data"):
            n *= geom.get(ax, 1)
        return n

    # --------------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunSpec":
        if "version" not in d:
            raise ValueError("spec dict has no 'version' key — refusing to "
                             "guess the schema")
        # v2 → v3 → v4 → v5 chained auto-upgrade: each bump is purely
        # additive (v3's ``groups`` defaults to the uniform one-group
        # schedule of the single-knob fields; v4's ``participation``
        # defaults to mode 'full'; v5's ``hops`` defaults to the flat
        # topology — exactly what every older spec always executed), so old
        # dicts upgrade mechanically and round-trip at the current schema.
        # v1 (pre-downlink) stays rejected: its absence of downlink fields
        # changed execution.
        if d.get("version") == 2 and "groups" not in d:
            d = dict(d, version=3)
        if d.get("version") == 3 and "participation" not in d:
            d = dict(d, version=4)
        if d.get("version") == 4 and "hops" not in d:
            d = dict(d, version=SCHEMA_VERSION)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(
                f"unknown RunSpec keys {unknown} (schema v{d['version']}, "
                f"this build reads v{SCHEMA_VERSION}) — refusing to silently "
                "drop experiment-defining fields")
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "RunSpec":
        return cls.from_dict(json.loads(s))

    def spec_hash(self) -> str:
        """Hash of the experiment-defining fields, in SPARSE canonical form:
        only fields that differ from their defaults are hashed, and
        ``version`` / checkpoint policy (ckpt_dir, ckpt_every) are excluded.
        Consequences: moving a ckpt dir never invalidates a checkpoint, and
        the documented additive schema evolution (new field + default) keeps
        every existing checkpoint resumable — an old hash and a new one
        agree whenever the explicitly-set fields agree. The flip side is
        that changing a field's DEFAULT silently preserves hashes, so
        semantic default changes must bump SCHEMA_VERSION (which gates
        ``from_dict`` before any hash comparison happens)."""
        d = self.to_dict()
        base = dataclasses.asdict(_DEFAULT) if _DEFAULT is not None else {}
        sparse = {k: v for k, v in d.items()
                  if k not in ("version", "ckpt_dir", "ckpt_every")
                  and v != base.get(k)}
        return hashlib.sha256(
            json.dumps(sparse, sort_keys=True).encode()).hexdigest()[:16]

    def diff(self, other: "RunSpec") -> List[str]:
        """Human-readable list of differing fields (for resume refusals)."""
        out = []
        for f in dataclasses.fields(self):
            a, b = getattr(self, f.name), getattr(other, f.name)
            if a != b:
                out.append(f"{f.name}: {a!r} != {b!r}")
        return out

    # ------------------------------------------------------------------ flags
    def to_flags(self) -> List[str]:
        """CLI flags reconstructing this spec:
        ``RunSpec.from_flags(s.to_flags()) == s`` (tier-1 tested)."""
        default = _DEFAULT
        out: List[str] = []
        for flag, field, kind in _FLAGS:
            val = getattr(self, field)
            if val == getattr(default, field):
                continue
            if kind == "bool":
                if val:
                    out.append(flag)
            elif kind == "json":
                out.extend([flag, json.dumps(val, sort_keys=True)])
            elif kind == "schedule":
                out.extend([flag, format_schedule_flag(val)])
            elif kind == "participation":
                out.extend([flag, format_participation_flag(val)])
            elif kind == "hops":
                out.extend([flag, format_hops_flag(val)])
            else:
                out.extend([flag, str(val)])
        return out

    @classmethod
    def from_flags(cls, argv: Optional[List[str]] = None) -> "RunSpec":
        ap = argparse.ArgumentParser(add_help=False)
        add_flags(ap)
        return cls.from_args(ap.parse_args(argv))

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "RunSpec":
        """Build a spec from parsed flags. ``--spec FILE`` (when present in
        the namespace) loads a JSON spec as the base; explicitly passed flags
        override it (unset flags parse as None and never override)."""
        base = cls()
        spec_file = getattr(args, "spec_file", None)
        if spec_file:
            with open(spec_file) as f:
                base = cls.from_json(f.read())
        overrides = {}
        for _, field, kind in _FLAGS:
            val = getattr(args, field, None)
            if val is None:
                continue
            overrides[field] = val
        return dataclasses.replace(base, **overrides) if overrides else base


_DEFAULT = None  # set after class definition (RunSpec() self-validates)

# (flag, field, kind) — dest is always the field name, so argparse namespaces
# map 1:1 onto RunSpec fields
_FLAGS: List[Tuple[str, str, str]] = [
    ("--arch", "arch", "str"),
    ("--smoke", "smoke", "bool"),
    ("--shape", "shape", "str"),
    ("--seq", "seq_len", "int"),
    ("--global-batch", "global_batch", "int"),
    ("--mesh", "mesh", "str"),
    ("--granularity", "client_granularity", "str"),
    ("--state-sharding", "state_sharding", "str"),
    ("--ef-state-dtype", "ef_state_dtype", "str"),
    ("--clients", "clients", "int"),
    ("--method", "method", "str"),
    ("--compressor", "compressor", "str"),
    ("--ratio", "ratio", "float"),
    ("--eta", "eta", "float"),
    ("--carrier", "carrier", "str"),
    ("--downlink-carrier", "downlink_carrier", "str"),
    ("--downlink-ratio", "downlink_ratio", "float"),
    ("--schedule", "groups", "schedule"),
    ("--overlap", "overlap", "bool"),
    ("--participation", "participation", "participation"),
    ("--hops", "hops", "hops"),
    ("--method-kw", "method_kw", "json"),
    ("--compressor-kw", "compressor_kw", "json"),
    ("--tp-pad-heads", "tp_pad_heads", "int"),
    ("--moe-impl", "moe_impl", "str"),
    ("--optimizer", "optimizer", "str"),
    ("--lr", "lr", "float"),
    ("--heterogeneity", "heterogeneity", "float"),
    ("--seed", "seed", "int"),
    ("--ckpt-dir", "ckpt_dir", "str"),
    ("--ckpt-every", "ckpt_every", "int"),
]

_FLAG_HELP = {
    "--smoke": "reduced per-arch config (CPU-sized)",
    "--shape": "named production InputShape for lower()/dryrun",
    "--carrier": "wire carrier for the EF sync (core/carriers.py): dense "
                 "all-reduce, sparse (values,indices) all-gather, the fused "
                 "Pallas client update, block-quantized wires, or the "
                 "one-launch fused quantized wires (fused_quant8/4)",
    "--overlap": "comm/compute overlap (DESIGN.md §10): ring-transport "
                 "gather-wire aggregations on the shard_map runtime, "
                 "decoding each chunk while the next is in flight; "
                 "bit-identical to the blocking anchor",
    "--downlink-carrier": "wire carrier for the server → client broadcast "
                          "(DESIGN.md §8): 'dense' keeps the implicit dense "
                          "f32 broadcast; sparse/quant8/quant4 add the EF21 "
                          "server memory h and ship C(g − h) as that "
                          "carrier's wire",
    "--downlink-ratio": "compression budget of the downlink compressor (the "
                        "uplink compressor class, re-budgeted; like --ratio "
                        "it only applies to ratio-bearing compressors — "
                        "others reuse their compressor-kw budget unchanged)",
    "--schedule": "per-parameter-group compression schedule (DESIGN.md §9): "
                  "'pattern=carrier[:ratio][@compressor],…' entries matched "
                  "first-match-wins against param leaf paths, last must be "
                  "the catch-all '*' — e.g. "
                  "'norm|bias=dense,embed=quant4:0.05,*=sparse:0.02'; a JSON "
                  "[...] list unlocks per-group downlink / state-dtype knobs",
    "--participation": "partial participation (DESIGN.md §11): "
                       "'mode[:fraction[:seed]]' — 'full' (every client, "
                       "every round), 'sampled:0.25:7' (a seeded cohort of "
                       "max(1, round(fraction·n)) clients per round; "
                       "non-sampled clients' EF state stays frozen), or a "
                       "JSON {...} dict; 'async' names the event-driven "
                       "simulator (core/participation.py) and refuses the "
                       "synchronous drivers",
    "--hops": "two-tier hierarchical aggregation (DESIGN.md §13): "
              "'pods=<int>,cross=carrier[:ratio]' — clients aggregate over "
              "the fast intra-pod links on the spec's carrier/schedule, "
              "then each pod's aggregator error-feeds one compressed "
              "innovation per round across the slow cross-pod links, e.g. "
              "'pods=2,cross=quant4:0.05'; 'cross=dense' (or pods=1) is "
              "bit-identical to the flat path; a JSON {...} dict also "
              "round-trips",
    "--clients": "emulated EF clients on the single-device mesh",
    "--method-kw": "JSON dict of extra Method kwargs (e.g. "
                   "'{\"gamma\": 0.01}')",
    "--compressor-kw": "JSON dict of extra Compressor kwargs (e.g. "
                       "'{\"block\": 1024, \"k_per_block\": 16}')",
}

_FLAG_CHOICES = {
    "--shape": sorted(cb.INPUT_SHAPES),
    "--mesh": list(MESHES),
    "--granularity": list(GRANULARITIES),
    "--state-sharding": list(STATE_SHARDINGS),
    "--ef-state-dtype": ["bfloat16"],
    "--method": sorted(METHODS),
    "--compressor": sorted(COMPRESSORS),
    "--carrier": sorted(CARRIERS),
    "--downlink-carrier": sorted(DOWN_CARRIERS),
    "--moe-impl": list(MOE_IMPLS),
    "--optimizer": sorted(OPTIMIZERS),
}

_TYPES = {"int": int, "float": float, "str": str}


def add_flags(ap: argparse.ArgumentParser) -> None:
    """Register the RunSpec flag surface on a driver's parser. All defaults
    are None so ``RunSpec.from_args`` can distinguish 'unset' from an
    explicit value (needed for --spec overrides and resume handling)."""
    ap.add_argument("--spec", dest="spec_file", default=None, metavar="FILE",
                    help="JSON RunSpec file used as the base; explicit flags "
                         "override its fields")
    for flag, field, kind in _FLAGS:
        kw: Dict[str, Any] = {"dest": field, "default": None,
                              "help": _FLAG_HELP.get(flag)}
        if kind == "bool":
            kw["action"] = "store_true"
            # --no-<flag> lets a CLI override a truthy bool in a --spec
            # file back to False (None stays 'unset' → file/default wins)
            ap.add_argument(flag.replace("--", "--no-", 1), dest=field,
                            action="store_false", default=None,
                            help=f"negate {flag}")
        elif kind == "json":
            kw["type"] = json.loads
        elif kind == "schedule":
            kw["type"] = parse_schedule_flag
        elif kind == "participation":
            kw["type"] = parse_participation_flag
        elif kind == "hops":
            kw["type"] = parse_hops_flag
        else:
            kw["type"] = _TYPES[kind]
            if flag in _FLAG_CHOICES:
                kw["choices"] = _FLAG_CHOICES[flag]
        ap.add_argument(flag, **kw)


_DEFAULT = RunSpec()

# ---------------------------------------------------------------------------
# golden fixtures (results/specs/*.json): the DEFINITIONS live here so the
# files are regenerated mechanically (`python -m repro.launch.spec
# --regen-goldens`) instead of hand-edited — tests/test_spec.py byte-compares
# the files against these and fails on any drift either way
# ---------------------------------------------------------------------------

GOLDEN_SPECS: Dict[str, Dict[str, Any]] = {
    "train_smoke_ef21_sgdm": {"smoke": True},
    "fused_quickstart": {"carrier": "fused", "eta": 0.2,
                         "compressor_kw": {"block": 1024, "k_per_block": 16}},
    "dryrun_sparse_pod": {"arch": "gemma2-9b", "carrier": "sparse",
                          "compressor": "topk", "ratio": 0.01, "mesh": "pod",
                          "shape": "train_4k"},
    "quant4_multipod_zero": {"arch": "grok-1-314b", "carrier": "quant4",
                             "mesh": "multi_pod", "shape": "train_4k",
                             "client_granularity": "pod",
                             "state_sharding": "zero",
                             "ef_state_dtype": "bfloat16"},
    "bidir_quant4_down": {"smoke": True, "carrier": "quant4", "clients": 4,
                          "global_batch": 8, "seq_len": 64,
                          "downlink_carrier": "quant4",
                          "downlink_ratio": 0.02},
    # v3: a mixed 3-group schedule — dense norms/biases, quant4 embeds,
    # sparse everything else, with a quant4 downlink on the catch-all
    "mixed_schedule": {"smoke": True, "clients": 4, "global_batch": 8,
                       "seq_len": 64,
                       "groups": [
                           {"pattern": "norm|bias", "carrier": "dense"},
                           {"pattern": "embed", "carrier": "quant4",
                            "ratio": 0.05},
                           {"pattern": "*", "carrier": "sparse",
                            "ratio": 0.02, "downlink_carrier": "quant4",
                            "downlink_ratio": 0.05},
                       ]},
    # the one-launch fused quantized wire with comm/compute overlap
    # (DESIGN.md §10): the mega-kernel uplink on a production mesh
    "fused_quant8_overlap": {"carrier": "fused_quant8", "mesh": "pod",
                             "shape": "train_4k", "eta": 0.2,
                             "overlap": True,
                             "compressor_kw": {"block": 1024,
                                               "k_per_block": 16}},
    # v4: partial participation — a quarter cohort per round, seeded
    # (DESIGN.md §11; `--participation sampled:0.25:7`)
    "sampled_quarter": {"smoke": True, "clients": 4, "global_batch": 8,
                        "seq_len": 64,
                        "participation": {"mode": "sampled",
                                          "fraction": 0.25, "seed": 7}},
    # v5: two-tier hierarchical aggregation — 2 pods of 4 clients, dense
    # intra hop, quant4 cross-pod hop with its own EF memory per pod
    # (DESIGN.md §13; `--hops pods=2,cross=quant4:0.05`)
    "hierarchy_quant4_cross": {"smoke": True, "clients": 8, "global_batch": 8,
                               "seq_len": 64,
                               "hops": {"pods": 2,
                                        "cross_carrier": "quant4",
                                        "cross_ratio": 0.05}},
}


def regen_goldens(out_dir: str) -> List[str]:
    """Rewrite every golden fixture from GOLDEN_SPECS at the current schema.
    Returns the written paths."""
    import os
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for name in sorted(GOLDEN_SPECS):
        path = os.path.join(out_dir, f"{name}.json")
        with open(path, "w") as f:
            f.write(RunSpec(**GOLDEN_SPECS[name]).to_json(indent=1) + "\n")
        paths.append(path)
    return paths


def explicit_fields(args: argparse.Namespace,
                    ignore: Tuple[str, ...] = ()) -> List[str]:
    """RunSpec field names the user EXPLICITLY set on the command line (every
    flag defaults to None, so non-None means passed — an explicit flag equal
    to the field's default still counts). Drivers use this to decide whether
    a ``--resume`` should enforce the flag-built spec against the
    checkpoint's embedded one."""
    out = [field for _, field, _ in _FLAGS
           if field not in ignore and getattr(args, field, None) is not None]
    if getattr(args, "spec_file", None):
        out.append("spec_file")
    return out


def main(argv: Optional[List[str]] = None) -> None:
    """Emit RunSpec JSON without importing jax — the sweep-tooling entry:

      python -m repro.launch.spec --print --arch gemma2-9b --carrier sparse
      python -m repro.launch.spec --out sweep/cell_017.json --method ef21_sgd
    """
    ap = argparse.ArgumentParser(
        "repro.launch.spec",
        description="validate and print/write a RunSpec as canonical JSON")
    add_flags(ap)
    ap.add_argument("--print", dest="do_print", action="store_true",
                    help="print the canonical JSON to stdout")
    ap.add_argument("--out", default=None, help="write the JSON to a file")
    ap.add_argument("--regen-goldens", dest="regen_goldens",
                    action="store_true",
                    help="mechanically rewrite the golden fixtures under "
                         "--goldens-dir from spec.GOLDEN_SPECS at the "
                         "current schema, then exit")
    ap.add_argument("--goldens-dir", default="results/specs",
                    help="target directory for --regen-goldens")
    args = ap.parse_args(argv)
    if args.regen_goldens:
        for path in regen_goldens(args.goldens_dir):
            print(path)
        return
    spec = RunSpec.from_args(args)
    text = spec.to_json(indent=1)
    if args.out:
        import os
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.do_print or not args.out:
        print(text)


if __name__ == "__main__":
    main()
