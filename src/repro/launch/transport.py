"""Stream transports — how a replica TAILS the wire log across process (and
host) boundaries (DESIGN.md §12).

PR 8's ``ServeReplica`` read the ``WireLog`` directory directly, which quietly
assumed every replica lives in the publisher's process (or at least shares a
cwd-relative path). This module makes the read side a first-class interface:

  * ``StreamTail`` — the read-only transport contract a subscriber needs:
    ``last_step`` / ``read_step`` (exactly the surface
    ``core/stream.py::Subscriber`` consumes) plus the bootstrap listing and a
    LOCAL filesystem path to any bootstrap checkpoint (``bootstrap_path`` —
    remote backends download into a cache so ``checkpoint.restore`` never
    learns about sockets).
  * ``FileTail`` — the shared-filesystem backend: a file-watch poller over a
    ``WireLog`` that caches the verified head keyed on the record listing, so
    a replica polling between decode steps pays one ``listdir`` per poll, not
    a re-verification of the newest step's npz files.
  * ``SocketTail`` / ``TailServer`` — the RPC backend: a line-JSON +
    length-prefixed-binary protocol over TCP. The server ships record and
    bootstrap FILES verbatim; the client mirrors them into a local cache
    directory and parses through its own ``WireLog``, so both backends run
    the identical decode path and every integrity rule (partial-step refusal,
    schema checks, idempotent overwrite refusal) is enforced by the same
    code. Records are immutable once complete, which makes the mirror safe:
    a fetched file never needs re-fetching.

``make_tail`` picks the backend from the address: ``tcp://host:port`` → RPC,
anything else → a stream directory. ``python -m repro.launch.transport DIR
--port P`` exposes a stream directory to remote tails.
"""
from __future__ import annotations

import abc
import json
import os
import re
import socket
import socketserver
import struct
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.core import stream as stream_lib


# ---------------------------------------------------------------------------
# the interface
# ---------------------------------------------------------------------------

class StreamTail(abc.ABC):
    """Read-side transport of one wire stream. The record methods mirror
    ``WireLog`` exactly (a ``Subscriber`` takes either); the bootstrap
    methods always resolve to LOCAL paths so checkpoint restore stays
    transport-agnostic."""

    @abc.abstractmethod
    def last_step(self) -> Optional[int]:
        """Newest step whose record set is complete (None = no records)."""

    @abc.abstractmethod
    def read_step(self, step: int) -> List[stream_lib.WireRecord]:
        """Every group record of one step (StreamGapError when absent)."""

    @abc.abstractmethod
    def bootstrap_steps(self) -> List[int]:
        """Steps with a bootstrap checkpoint, sorted ascending."""

    @abc.abstractmethod
    def bootstrap_path(self, step: int) -> str:
        """LOCAL filesystem path to the bootstrap for ``step`` (remote
        backends fetch into their cache first)."""

    def latest_bootstrap(self, upto: Optional[int] = None) -> Optional[str]:
        steps = [s for s in self.bootstrap_steps()
                 if upto is None or s <= upto]
        return self.bootstrap_path(steps[-1]) if steps else None

    def close(self) -> None:
        """Release transport resources (sockets, cache dirs stay)."""


# ---------------------------------------------------------------------------
# file backend — the shared-filesystem poller
# ---------------------------------------------------------------------------

class FileTail(StreamTail):
    """Poll a ``WireLog`` directory. ``last_step`` caches the verified head
    keyed on the newest step's record listing: an unchanged directory costs
    one ``listdir``, never a re-load of record files — cheap enough to call
    between decode steps (the continuous-sync path in launch/fleet.py)."""

    def __init__(self, root: str):
        self.log = stream_lib.WireLog(root)
        self._key: Optional[Tuple[int, Tuple[int, ...]]] = None
        self._head: Optional[int] = None

    def last_step(self) -> Optional[int]:
        listing = self.log._listing()
        if not listing:
            self._key = self._head = None
            return None
        newest = max(listing)
        key = (newest, tuple(sorted(listing[newest])))
        if key != self._key:
            self._head = self.log.last_step()
            self._key = key
        return self._head

    def read_step(self, step: int) -> List[stream_lib.WireRecord]:
        return self.log.read_step(step)

    def bootstrap_steps(self) -> List[int]:
        return self.log.bootstrap_steps()

    def bootstrap_path(self, step: int) -> str:
        return self.log.bootstrap_path(step)


# ---------------------------------------------------------------------------
# socket RPC backend
# ---------------------------------------------------------------------------
#
# Framing: each request is one JSON line. Each response is one JSON header
# line ({"ok": bool, ...}; on ok=False an "error" field) followed, for file
# ops, by the raw bytes of every file in header["files"] order, each
# prefixed with an 8-byte big-endian length. Connections are persistent.

def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise stream_lib.StreamError("transport connection closed "
                                         "mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def _recv_line(sock: socket.socket, buf: bytearray) -> bytes:
    while b"\n" not in buf:
        chunk = sock.recv(65536)
        if not chunk:
            raise stream_lib.StreamError("transport connection closed "
                                         "mid-line")
        buf.extend(chunk)
    line, _, rest = bytes(buf).partition(b"\n")
    buf.clear()
    buf.extend(rest)
    return line


class _TailHandler(socketserver.StreamRequestHandler):
    def handle(self):
        tail: FileTail = self.server.tail            # type: ignore[attr-defined]
        log = tail.log
        for raw in self.rfile:
            raw = raw.strip()
            if not raw:
                continue
            try:
                req = json.loads(raw.decode())
                op = req.get("op")
                if op == "head":
                    self._reply({"ok": True, "head": tail.last_step()})
                elif op == "bootstraps":
                    self._reply({"ok": True, "steps": tail.bootstrap_steps()})
                elif op == "step_files":
                    step = int(req["step"])
                    present = sorted(log._listing().get(step, []))
                    paths = [log.record_path(step, gi) for gi in present]
                    self._reply_files([(os.path.basename(p), p)
                                       for p in paths])
                elif op == "bootstrap_file":
                    path = log.bootstrap_path(int(req["step"]))
                    if not os.path.exists(path):
                        self._reply({"ok": False,
                                     "error": f"no bootstrap {path}"})
                    else:
                        self._reply_files([(os.path.basename(path), path)])
                else:
                    self._reply({"ok": False, "error": f"unknown op {op!r}"})
            except BrokenPipeError:
                return
            except Exception as e:                   # noqa: BLE001 — RPC edge
                try:
                    self._reply({"ok": False, "error": repr(e)})
                except OSError:
                    return

    def _reply(self, header: Dict[str, Any]) -> None:
        self.wfile.write(json.dumps(header).encode() + b"\n")
        self.wfile.flush()

    def _reply_files(self, files: List[Tuple[str, str]]) -> None:
        blobs = []
        meta = []
        for name, path in files:
            with open(path, "rb") as f:
                data = f.read()
            blobs.append(data)
            meta.append({"name": name, "size": len(data)})
        self._reply({"ok": True, "files": meta})
        for data in blobs:
            self.wfile.write(struct.pack(">Q", len(data)))
            self.wfile.write(data)
        self.wfile.flush()


class TailServer:
    """Expose one stream directory to ``SocketTail`` clients. Threaded —
    each replica keeps a persistent connection."""

    def __init__(self, root: str, host: str = "127.0.0.1", port: int = 0):
        self._srv = socketserver.ThreadingTCPServer(
            (host, port), _TailHandler, bind_and_activate=True)
        self._srv.daemon_threads = True
        self._srv.tail = FileTail(root)              # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> str:
        host, port = self._srv.server_address[:2]
        return f"tcp://{host}:{port}"

    def start(self) -> "TailServer":
        self._thread = threading.Thread(target=self._srv.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)


class SocketTail(StreamTail):
    """Tail a remote stream over the TailServer RPC, mirroring fetched
    record/bootstrap files into ``cache_dir`` and parsing them through a
    local ``WireLog`` — one decode path, both transports."""

    def __init__(self, host: str, port: int,
                 cache_dir: Optional[str] = None):
        self.addr = (host, int(port))
        self.cache_dir = cache_dir or tempfile.mkdtemp(prefix="wire_tail_")
        self.mirror = stream_lib.WireLog(self.cache_dir)
        self._sock: Optional[socket.socket] = None
        self._buf = bytearray()
        self._complete: set = set()
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ rpc
    def _connect(self) -> socket.socket:
        if self._sock is None:
            self._sock = socket.create_connection(self.addr, timeout=30)
            self._buf.clear()
        return self._sock

    def _call(self, op: str, **kw) -> Tuple[Dict[str, Any], List[bytes]]:
        with self._lock:
            try:
                return self._call_once(op, **kw)
            except (OSError, stream_lib.StreamError):
                # one reconnect: the server may have restarted between polls
                self.close_socket()
                return self._call_once(op, **kw)

    def _call_once(self, op: str, **kw) -> Tuple[Dict[str, Any], List[bytes]]:
        sock = self._connect()
        sock.sendall(json.dumps({"op": op, **kw}).encode() + b"\n")
        header = json.loads(_recv_line(sock, self._buf).decode())
        if not header.get("ok"):
            raise stream_lib.StreamError(
                f"tail rpc {op!r} failed: {header.get('error')}")
        blobs: List[bytes] = []
        for meta in header.get("files", []):
            # the length prefix and the size in the header must agree — a
            # mismatch means a corrupt frame, never silently resync
            n = struct.unpack(">Q", self._pull(8))[0]
            if n != meta["size"]:
                raise stream_lib.StreamIntegrityError(
                    f"tail rpc frame size {n} != header size {meta['size']}")
            blobs.append(self._pull(n))
        return header, blobs

    def _pull(self, n: int) -> bytes:
        if len(self._buf) >= n:
            out = bytes(self._buf[:n])
            del self._buf[:n]
            return out
        need = n - len(self._buf)
        out = bytes(self._buf) + _recv_exact(self._sock, need)
        self._buf.clear()
        return out

    def _mirror_file(self, subdir: str, name: str, data: bytes) -> str:
        d = os.path.join(self.cache_dir, subdir)
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, name)
        if not os.path.exists(path):
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp.npz")
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        return path

    # ------------------------------------------------------------ interface
    def last_step(self) -> Optional[int]:
        header, _ = self._call("head")
        return header["head"]

    def read_step(self, step: int) -> List[stream_lib.WireRecord]:
        if step not in self._complete:
            header, blobs = self._call("step_files", step=step)
            for meta, data in zip(header["files"], blobs):
                self._mirror_file("records", meta["name"], data)
        recs = self.mirror.read_step(step)     # gap/partial raise here
        self._complete.add(step)
        return recs

    def bootstrap_steps(self) -> List[int]:
        header, _ = self._call("bootstraps")
        return list(header["steps"])

    def bootstrap_path(self, step: int) -> str:
        path = self.mirror.bootstrap_path(step)
        if not os.path.exists(path):
            header, blobs = self._call("bootstrap_file", step=step)
            path = self._mirror_file("bootstrap", header["files"][0]["name"],
                                     blobs[0])
        return path

    def close_socket(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            finally:
                self._sock = None
                self._buf.clear()

    def close(self) -> None:
        self.close_socket()


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

_TCP_RE = re.compile(r"^tcp://([^:/]+):(\d+)$")


def make_tail(stream, cache_dir: Optional[str] = None) -> StreamTail:
    """Resolve a stream address to a tail: a ``StreamTail`` passes through,
    ``tcp://host:port`` opens the RPC backend, anything else is a stream
    directory on a (shared) filesystem."""
    if isinstance(stream, StreamTail):
        return stream
    m = _TCP_RE.match(str(stream))
    if m:
        return SocketTail(m.group(1), int(m.group(2)), cache_dir=cache_dir)
    return FileTail(str(stream))


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        "repro.launch.transport",
        description="Serve a wire-stream directory to remote SocketTails")
    ap.add_argument("root", help="stream directory (WireLog root)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    args = ap.parse_args(argv)
    srv = TailServer(args.root, host=args.host, port=args.port)
    print(f"serving {args.root} at {srv.address}", flush=True)
    srv.start()
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        srv.stop()


if __name__ == "__main__":
    main()
