"""While-loop-aware analysis of post-SPMD optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts every while-loop body ONCE
(verified empirically: a scan of 10 matmuls reports the FLOPs of 1). All of our
models scan over layers, query chunks and CE chunks, so both FLOPs and collective
bytes would be undercounted by 1–3 orders of magnitude. This module re-derives

  * dot FLOPs            (2 · output_elems · contracted_elems per dot op)
  * collective operand bytes, per collective type

from the optimized HLO *text*, walking the call graph (fusions, calls, whiles) and
multiplying while bodies by their trip counts (recovered from the loop-condition
constant — exact for lax.scan/fori loops, which is all we emit).

Shapes in the partitioned module are per-device, so all results are per-device.
Elementwise FLOPs are ignored (irrelevant at roofline granularity); dots and convs
dominate. Results are validated against XLA's own cost analysis on loop-free
modules in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

def cost_analysis_dict(compiled) -> Dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions: older
    releases return a one-element **list** of per-module dicts, newer ones the
    dict itself (and it may be None/empty for some backends)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_OPCODE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}*/ ]+?))\s([\w\-]+)\(")


def _shape_dims(tok: str) -> List[Tuple[str, List[int]]]:
    """All dtype[dims] tokens in a shape string (tuples yield several)."""
    return [(d, [int(x) for x in dims.split(",") if x])
            for d, dims in _SHAPE_TOKEN.findall(tok)]


def _shape_bytes(tok: str) -> int:
    return sum(_DTYPE_BYTES.get(d, 4) * math.prod(dims or [1])
               for d, dims in _shape_dims(tok))


@dataclasses.dataclass
class Instr:
    name: str
    shape: str          # raw output-shape string
    opcode: str
    rest: str           # text after the opcode's '('


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        head = _COMP_HEAD.match(line)
        if head and line.rstrip().endswith("{"):
            cur = Computation(head.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        om = _OPCODE.match(rhs)
        if not om:
            continue
        shape, opcode = om.groups()
        rest = rhs[om.end():]
        ins = Instr(name, shape.strip(), opcode, rest)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps


def _operands(rest: str) -> List[str]:
    """Operand instruction names: %foo tokens before the closing paren."""
    depth, out, i = 1, [], 0
    while i < len(rest) and depth > 0:
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    body = rest[: i - 1]
    return re.findall(r"%([\w.\-]+)", body)


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=((?:\{[^}]*\})|(?:\[[^\]]*\][^,]*)|[^,\s]+)", rest)
    return m.group(1) if m else None


def _dims_list(attr: Optional[str]) -> List[int]:
    if not attr:
        return []
    return [int(x) for x in re.findall(r"\d+", attr)]


def _group_size(rest: str, n_devices: int) -> int:
    """Parse replica_groups=[G,S]<=... or explicit {{...},{...}}."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return n_devices


@dataclasses.dataclass
class Totals:
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0):
        self.dot_flops += mult * other.dot_flops
        self.conv_flops += mult * other.conv_flops
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + mult * v
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = (self.collective_counts.get(k, 0.0)
                                         + mult * v)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


class Analyzer:
    def __init__(self, text: str, n_devices: int = 1):
        self.comps = parse_module(text)
        self.n_devices = n_devices
        self._memo: Dict[str, Totals] = {}
        self.entry = self._find_entry(text)

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        if m:
            return m.group(1)
        # fall back: the largest computation
        return max(self.comps, key=lambda c: len(self.comps[c].instrs))

    # -- trip count ----------------------------------------------------------
    def trip_count(self, cond_name: str) -> float:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1.0
        consts = []
        for ins in comp.instrs:
            m = re.match(r"constant\((\-?\d+)\)", ins.opcode + "(" + ins.rest) \
                if False else None
        for ins in comp.instrs:
            if ins.opcode == "constant":
                m = re.match(r"(\-?\d+)\)", ins.rest)
                if m:
                    consts.append(int(m.group(1)))
        return float(max(consts)) if consts else 1.0

    # -- per-instruction costs ------------------------------------------------
    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems = sum(math.prod(d or [1]) for _, d in _shape_dims(ins.shape))
        ops = _operands(ins.rest)
        lhs_cdims = _dims_list(_attr(ins.rest, "lhs_contracting_dims"))
        contracted = 1
        if ops:
            lhs = comp.by_name.get(ops[0])
            if lhs is not None:
                dims_all = _shape_dims(lhs.shape)
                if dims_all:
                    _, ld = dims_all[0]
                    for ci in lhs_cdims:
                        if ci < len(ld):
                            contracted *= ld[ci]
        return 2.0 * out_elems * contracted

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        # rough: 2 * out_elems * (kernel spatial × in_features per group)
        out_elems = sum(math.prod(d or [1]) for _, d in _shape_dims(ins.shape))
        ops = _operands(ins.rest)
        k_elems = 1
        if len(ops) > 1:
            ker = comp.by_name.get(ops[1])
            if ker is not None:
                dims_all = _shape_dims(ker.shape)
                if dims_all:
                    _, kd = dims_all[0]
                    k_elems = math.prod(kd or [1])
        return 2.0 * out_elems * max(k_elems, 1)

    def _collective(self, ins: Instr, t: Totals):
        op = ins.opcode.replace("-start", "")
        if op not in COLLECTIVES:
            return
        out_bytes = _shape_bytes(ins.shape)
        g = _group_size(ins.rest, self.n_devices)
        if op == "all-gather":
            operand = out_bytes / max(g, 1)
        elif op == "reduce-scatter":
            operand = out_bytes * g
        else:  # all-reduce, all-to-all, collective-permute: operand ≈ output
            operand = out_bytes
        t.collective_bytes[op] = t.collective_bytes.get(op, 0.0) + operand
        t.collective_counts[op] = t.collective_counts.get(op, 0.0) + 1

    # -- aggregation -----------------------------------------------------------
    def totals(self, comp_name: Optional[str] = None) -> Totals:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        t = Totals()
        self._memo[comp_name] = t          # cycles guard (shouldn't happen)
        comp = self.comps.get(comp_name)
        if comp is None:
            return t
        for ins in comp.instrs:
            if ins.opcode == "dot":
                t.dot_flops += self._dot_flops(comp, ins)
            elif ins.opcode in ("convolution",):
                t.conv_flops += self._conv_flops(comp, ins)
            elif ins.opcode.replace("-start", "") in COLLECTIVES:
                self._collective(ins, t)
            elif ins.opcode == "while":
                body = _attr(ins.rest, "body")
                cond = _attr(ins.rest, "condition")
                body = body.lstrip("%") if body else None
                cond = cond.lstrip("%") if cond else None
                trips = self.trip_count(cond) if cond else 1.0
                if body:
                    t.add(self.totals(body), trips)
                if cond:
                    t.add(self.totals(cond), trips)
            elif ins.opcode in ("fusion", "call", "custom-call"):
                callee = _attr(ins.rest, "calls")
                if callee:
                    t.add(self.totals(callee.lstrip("%")))
            elif ins.opcode == "conditional":
                for branch in re.findall(
                        r"(?:branch_computations=\{([^}]*)\}|"
                        r"(?:true|false)_computation=%([\w.\-]+))", ins.rest):
                    for b in branch:
                        for nm in re.findall(r"%?([\w.\-]+)", b or ""):
                            if nm in self.comps:
                                t.add(self.totals(nm))
        self._memo[comp_name] = t
        return t


def analyze(text: str, n_devices: int = 1) -> Dict:
    a = Analyzer(text, n_devices)
    t = a.totals()
    return {
        "dot_flops": t.dot_flops,
        "conv_flops": t.conv_flops,
        "collective_bytes": dict(t.collective_bytes),
        "collective_counts": dict(t.collective_counts),
        "total_collective_bytes": t.total_collective_bytes,
    }
