"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips across DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for a in data_axes(mesh):
        s *= mesh.shape[a]
    return s


def make_smoke_mesh():
    """1-device mesh for CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
