"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Version-portable ``jax.make_mesh``: newer JAX wants explicit
    ``axis_types`` (we always use Auto — shard_map handles Manual itself);
    older JAX (< 0.5) has neither ``jax.sharding.AxisType`` nor the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where it exists (JAX ≥ 0.6); the ``Mesh``
    context manager itself on older releases. Use as ``with mesh_context(m):``."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


# production mesh geometry (also consumed by benchmarks/roofline.py, which
# must not instantiate the mesh — that would lock the jax device count)
PROD_DATA = 16
PROD_MODEL = 16
PROD_PODS = 2


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips across DCI."""
    shape = (PROD_PODS, PROD_DATA, PROD_MODEL) if multi_pod \
        else (PROD_DATA, PROD_MODEL)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for a in data_axes(mesh):
        s *= mesh.shape[a]
    return s


def make_smoke_mesh():
    """1-device mesh for CPU tests."""
    return make_mesh((1, 1), ("data", "model"))
