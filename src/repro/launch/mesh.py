"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Version-portable ``jax.make_mesh``: newer JAX wants explicit
    ``axis_types`` (we always use Auto — shard_map handles Manual itself);
    older JAX (< 0.5) has neither ``jax.sharding.AxisType`` nor the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where it exists (JAX ≥ 0.6); the ``Mesh``
    context manager itself on older releases. Use as ``with mesh_context(m):``."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


# production mesh geometry (also consumed by benchmarks/roofline.py, which
# must not instantiate the mesh — that would lock the jax device count)
PROD_DATA = 16
PROD_MODEL = 16
PROD_PODS = 2


def _shrink_shape(shape: Tuple[int, ...], n_devices: int) -> Tuple[int, ...]:
    """Fit a production mesh shape onto fewer devices, left-to-right
    (pod-major): each axis takes the largest divisor of the remaining device
    count no bigger than its production size. The pod axis is first, so a
    forced-host-device run keeps the full pod count whenever it can —
    (2, 16, 16) on 8 devices becomes (2, 4, 1), preserving the two-pod
    topology the hierarchical tests exercise."""
    rem = n_devices
    out = []
    for want in shape:
        for d in range(min(want, rem), 0, -1):
            if rem % d == 0:
                out.append(d)
                rem //= d
                break
    return tuple(out)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips across DCI.
    On hosts with fewer devices (CI's forced-8-device CPU runs) the shape
    shrinks pod-major (``_shrink_shape``) instead of failing, so
    ``--mesh multi_pod`` is portable to any device count."""
    shape = (PROD_PODS, PROD_DATA, PROD_MODEL) if multi_pod \
        else (PROD_DATA, PROD_MODEL)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n_dev = len(jax.devices())
    need = 1
    for s in shape:
        need *= s
    if n_dev < need:
        shape = _shrink_shape(shape, n_dev)
    return make_mesh(shape, axes)


def client_axes(mesh) -> Tuple[str, ...]:
    """The client axes in POD-MAJOR order — ('pod', 'data') whenever the pod
    axis exists, regardless of the mesh's own axis order. This is the order
    ``shardings.ef_state_pspecs`` shards client state with and the order the
    hierarchical runtimes compose client_index with (client i belongs to pod
    i // (n/pods), core/hierarchy.pod_mean) — both runtimes must agree on
    who is in which pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_axes(mesh) -> Tuple[str, ...]:
    return client_axes(mesh)


def dp_size(mesh) -> int:
    s = 1
    for a in data_axes(mesh):
        s *= mesh.shape[a]
    return s


def make_smoke_mesh():
    """1-device mesh for CPU tests."""
    return make_mesh((1, 1), ("data", "model"))
