"""Multi-process jax.distributed initialization (DESIGN.md §13).

One process per pod (or per host) joins a single logical mesh through
``jax.distributed.initialize``; after init, ``jax.devices()`` spans every
process and the ordinary multi_pod mesh + shard_map hierarchical round run
unchanged — the cross-pod hop's psum physically crosses the process
boundary. Training drivers opt in with::

    train.py --coordinator host:1234 --num-processes 2 --process-id 0 ...

Idempotent by design: ``distributed_init`` is a no-op when this process
already initialized (re-entrant Session construction, tests calling through
the facade twice), and fails fast with the real constraint when jax has
already created backends — jax.distributed MUST win the race to first
device access, which is why drivers call this before touching any array.

The CLI smoke (wired into CI as the 2-process CPU cell) proves the fabric:
every process allgathers its process id and asserts the full roster::

    python -m repro.launch.multiproc --coordinator localhost:9911 \
        --num-processes 2 --process-id 0   # and 1, concurrently
"""
from __future__ import annotations

import argparse

_INITIALIZED: dict = {}


def distributed_init(coordinator: str, num_processes: int,
                     process_id: int) -> bool:
    """Join the multi-process fleet. Returns True when this call performed
    the initialization, False when it was already done (idempotent — same
    coordinates only; different coordinates after init is a hard error,
    there is one fleet per process)."""
    key = (coordinator, int(num_processes), int(process_id))
    if _INITIALIZED:
        prev = next(iter(_INITIALIZED))
        if prev != key:
            raise ValueError(
                f"jax.distributed already initialized as {prev}, refusing "
                f"to re-initialize as {key}: one fleet per process")
        return False
    if num_processes < 1 or not (0 <= process_id < num_processes):
        raise ValueError(
            f"process_id must be in [0, {num_processes}), got {process_id}")
    import jax
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=int(num_processes),
                               process_id=int(process_id))
    _INITIALIZED[key] = True
    return True


def smoke(coordinator: str, num_processes: int, process_id: int) -> str:
    """The 2-process CPU fabric proof. Cross-process XLA collectives are a
    TPU/GPU feature (the CPU backend refuses multiprocess computations), so
    the proof runs on what every backend shares — the coordination service:
    every process must see the full GLOBAL device roster (jax.devices()
    only lists another process's devices after a successful handshake with
    the coordinator), and all processes must clear one named barrier
    together. A process that failed to join, double-joined, or silently ran
    single-process cannot pass. Prints DISTRIBUTED_OK."""
    distributed_init(coordinator, num_processes, process_id)
    import jax
    assert jax.process_count() == num_processes, \
        f"process_count {jax.process_count()} != {num_processes}"
    assert jax.process_index() == process_id, \
        f"process_index {jax.process_index()} != {process_id}"
    roster = sorted({d.process_index for d in jax.devices()})
    assert roster == list(range(num_processes)), \
        f"fleet roster {roster} != {list(range(num_processes))}"
    sync = "roster"
    try:  # barrier API location varies across jax releases; roster is the
        # hard assertion, the barrier is belt-and-braces when available
        from jax._src import distributed as _dist
        client = getattr(_dist.global_state, "client", None)
        if client is not None:
            client.wait_at_barrier("repro_multiproc_smoke", 30_000)
            sync = "roster+barrier"
    except Exception:
        pass
    msg = (f"DISTRIBUTED_OK process {process_id}/{num_processes} "
           f"roster={roster} devices={len(jax.devices())} sync={sync}")
    print(msg, flush=True)
    return msg


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--coordinator", required=True,
                    help="host:port of process 0's coordinator service")
    ap.add_argument("--num-processes", type=int, required=True)
    ap.add_argument("--process-id", type=int, required=True)
    args = ap.parse_args(argv)
    smoke(args.coordinator, args.num_processes, args.process_id)


if __name__ == "__main__":
    main()
