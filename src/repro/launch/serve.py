"""Serving driver — a thin flags → RunSpec → Session shim.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --batch 4 --prompt-len 128 --decode-steps 32

``Session.serve`` routes prefill/decode through ``launch/build.py``'s
``build_prefill``/``build_decode`` on the spec's mesh, placing params, batch,
and cache onto the production shardings (launch/shardings.py) — the old
driver jitted unsharded lambdas and bypassed the sharding layer entirely.
"""
from __future__ import annotations

import argparse

from repro.launch import spec as spec_lib


def main(argv=None) -> None:
    ap = argparse.ArgumentParser("repro.launch.serve")
    spec_lib.add_flags(ap)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--decode-steps", type=int, default=32)
    args = ap.parse_args(argv)
    spec = spec_lib.RunSpec.from_args(args)

    from repro.launch.session import Session  # defer the jax-heavy import
    sess = Session(spec)
    out = sess.serve(batch=args.batch, prompt_len=args.prompt_len,
                     decode_steps=args.decode_steps)

    B, S = args.batch, args.prompt_len
    print(f"prefill {B}×{S}: {out['prefill_s']:.2f}s "
          f"({out['prefill_tok_s']:.0f} tok/s)")
    print(f"decode {args.decode_steps} steps: {out['decode_s']:.2f}s "
          f"({out['decode_tok_s']:.1f} tok/s)")
    print("sample generations (token ids):")
    for row in out["tokens"][:2]:
        print("  ", row[:16], "...")


if __name__ == "__main__":
    main()
