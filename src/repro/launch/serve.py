"""Batched serving driver: prefill a prompt batch, then greedy-decode.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --batch 4 --prompt-len 128 --decode-steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.models import model as model_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cb.get_smoke(args.arch) if args.smoke else cb.get(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, rng)

    B, S = args.batch, args.prompt_len
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    n_prefix = 0
    if cfg.frontend is not None:
        n_prefix = max(cfg.frontend_tokens, 8)
        batch["prefix_embeds"] = jnp.zeros((B, n_prefix, cfg.d_model),
                                           jnp.bfloat16)

    max_seq = n_prefix + S + args.decode_steps
    cache = model_lib.init_cache(cfg, B, max_seq)

    prefill = jax.jit(lambda p, b, c: model_lib.prefill(cfg, p, b, c))
    decode = jax.jit(lambda p, c, t, q: model_lib.decode_step(cfg, p, c, t, q))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill {B}×{S}: {t_prefill:.2f}s "
          f"({B*S/t_prefill:.0f} tok/s)")

    tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.decode_steps):
        pos = jnp.asarray(n_prefix + S + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode {args.decode_steps} steps: {dt:.2f}s "
          f"({args.decode_steps*B/dt:.1f} tok/s)")
    gen = jnp.concatenate(out_tokens, axis=1)
    print("sample generations (token ids):")
    for row in jax.device_get(gen)[:2]:
        print("  ", row[:16], "...")


if __name__ == "__main__":
    main()
