"""Serving driver — a thin flags → RunSpec → Session/Fleet shim.

Static one-shot serve (the spec comes from flags):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --batch 4 --prompt-len 128 --decode-steps 32

Fleet mode — subscribe serving replicas to a wire stream a trainer is
publishing (``repro.launch.train --publish-stream DIR``); the RunSpec comes
from the stream's bootstrap checkpoint, NOT from flags:

  PYTHONPATH=src python -m repro.launch.serve --serve-stream /tmp/wire \
      --replicas 2 --lags 0,4 --requests 32 --rate 8 --decode-budget 64

``Session.serve`` routes prefill/decode through ``launch/build.py``'s
``build_prefill``/``build_decode`` on the spec's mesh, placing params, batch,
and cache onto the production shardings (launch/shardings.py). In fleet mode
every replica's params stay bit-identical to the trainer's post-step model by
applying the compressed wire records (DESIGN.md §12) — dense f32 weights are
never pushed.
"""
from __future__ import annotations

import argparse

from repro.launch import spec as spec_lib


def _print_summary(out) -> None:
    line = (f"{len(out['requests'])} requests in {out['batches']} batches: "
            f"qps={out['qps']:.2f} p50={out['p50_ms']:.0f}ms "
            f"p99={out['p99_ms']:.0f}ms "
            f"staleness mean={out['staleness_mean']:.1f} "
            f"max={out['staleness_max']}")
    if out.get("short_requests"):
        line += (f" SHORT={out['short_requests']} "
                 f"(-{out['tokens_short']} tok)")
    if "restarts" in out:
        line += f" restarts={out['restarts']}"
    print(line)


def _fleet_main(args) -> None:
    from repro.launch import fleet as fleet_lib  # defer the jax-heavy import

    lags = [int(x) for x in args.lags.split(",")] if args.lags else None
    if args.processes:
        with fleet_lib.ProcessFleet(
                args.serve_stream, n_workers=args.replicas, lags=lags,
                decode_budget=args.decode_budget, max_batch=args.batch,
                prompt_len=args.prompt_len) as fl:
            steps = [w.step for w in fl.workers]
            print(f"fleet of {len(fl.workers)} worker PROCESSES on "
                  f"{args.serve_stream}: "
                  + ", ".join(f"{w.name}@{s}(lag {w.lag})"
                              for w, s in zip(fl.workers, steps)))
            reqs = fleet_lib.synthetic_requests(
                args.requests, rate=args.rate, prompt_len=args.prompt_len,
                max_new_tokens=args.max_new_tokens)
            out = fl.run(reqs)
        _print_summary(out)
        return

    fl = fleet_lib.Fleet(args.serve_stream, n_replicas=args.replicas,
                         lags=lags, decode_budget=args.decode_budget,
                         max_batch=args.batch, prompt_len=args.prompt_len)
    fl.sync()
    head = fl.replicas[0].log.last_step()
    print(f"fleet of {len(fl.replicas)} replicas on {args.serve_stream} "
          f"(head step {head}): "
          + ", ".join(f"{r.name}@{r.step}(lag {r.lag})" for r in fl.replicas))

    reqs = fleet_lib.synthetic_requests(
        args.requests, rate=args.rate, prompt_len=args.prompt_len,
        max_new_tokens=args.max_new_tokens,
        vocab_size=fl.replicas[0].session.cfg.vocab_size)
    out = fl.run(reqs, sync_every=args.sync_every)
    _print_summary(out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser("repro.launch.serve")
    spec_lib.add_flags(ap)
    ap.add_argument("--batch", type=int, default=4,
                    help="static mode: serve batch; fleet mode: max batch "
                         "per scheduler admit")
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--decode-steps", type=int, default=32)
    # fleet mode
    ap.add_argument("--serve-stream", default=None, metavar="DIR|tcp://H:P",
                    help="subscribe a replica fleet to this wire stream — a "
                         "stream directory on a (shared) filesystem, or "
                         "tcp://host:port of a remote TailServer "
                         "(python -m repro.launch.transport DIR --port P); "
                         "spec comes from the stream's bootstrap, not flags")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--lags", default=None,
                    help="comma-separated per-replica lags, e.g. '0,4'")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="request arrival rate (req/s); <=0 = all at t=0")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--decode-budget", type=int, default=64)
    ap.add_argument("--sync-every", type=int, default=1,
                    help="apply fresh wire records every N serving batches "
                         "(per replica; in-process fleet only)")
    ap.add_argument("--processes", action="store_true",
                    help="run each replica as its own worker PROCESS "
                         "(repro.launch.replica_worker) tailing the stream "
                         "over the transport layer, with continuous sync "
                         "during decode")
    args = ap.parse_args(argv)

    if args.serve_stream:
        _fleet_main(args)
        return

    spec = spec_lib.RunSpec.from_args(args)
    from repro.launch.session import Session  # defer the jax-heavy import
    sess = Session(spec)
    out = sess.serve(batch=args.batch, prompt_len=args.prompt_len,
                     decode_steps=args.decode_steps)

    B, S = args.batch, args.prompt_len
    print(f"prefill {B}×{S}: {out['prefill_s']:.2f}s "
          f"({out['prefill_tok_s']:.0f} tok/s)")
    print(f"decode {args.decode_steps} steps: {out['decode_s']:.2f}s "
          f"({out['decode_tok_s']:.1f} tok/s)")
    print("sample generations (token ids):")
    for row in out["tokens"][:2]:
        print("  ", row[:16], "...")


if __name__ == "__main__":
    main()
