"""Step-function + input-spec construction shared by dryrun / train / serve.

``build_step(cfg, shape, mesh, plan, efc)`` returns (fn, specs_tuple) such that
``jax.jit(fn).lower(*specs_tuple)`` is the multi-pod dry-run artifact, and calling
``fn`` on real arrays is the production step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core import compressors as comp_lib
from repro.core import distributed as dist
from repro.core import ef as ef_lib
from repro.launch import mesh as mesh_lib
from repro.launch import shardings as sh
from repro.models import model as model_lib
from repro.optim import optimizer as opt_lib


class PlanDegradationWarning(UserWarning):
    """A requested non-dense carrier degraded to the always-correct dense
    plan. Stable category so callers/tests can filter it, and so the
    once-per-(group, reason) dedup below has a well-defined identity."""


# (config, scope, reason) triples already warned. A Session builds its
# EFConfig more than once (lower() + train state) and sweeps construct
# hundreds — re-warning the identical degradation every time buried real
# signal — but the key includes the full transport-defining config, so a
# LATER session with a different spec that happens to degrade for the same
# textual reason still gets its own warning. ``reset_plan_warnings`` exists
# for tests.
_WARNED: set = set()


def reset_plan_warnings() -> None:
    _WARNED.clear()


def _warn_degraded(config, scope: str, reason: str) -> None:
    import warnings
    key = (config, scope, reason)
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(f"{scope} degrades to the dense plan: {reason}",
                  PlanDegradationWarning, stacklevel=3)


def _check_group_plans(config, schedule, method, eta) -> None:
    """The authoritative per-group carrier checks: a fused group that would
    silently run unfused is a hard error; any other degradation warns once
    per (group, reason)."""
    from repro.core import carriers as carrier_lib
    from repro.core import schedule as sched_lib
    for grp in schedule.groups:
        m_g = sched_lib.group_method(method, grp)
        plan, reason = carrier_lib.make(grp.carrier).plan_with_reason(
            m_g, eta)
        if grp.carrier == "fused" and plan != "fused":
            raise ValueError(
                f"group {grp.pattern!r}: carrier='fused' would silently run "
                f"the UNFUSED dense plan: {reason}")
        if grp.carrier in ("fused_quant8", "fused_quant4") \
                and plan != "fused_wire":
            raise ValueError(
                f"group {grp.pattern!r}: carrier={grp.carrier!r} would "
                f"silently run a DEGRADED plan ({plan!r}): {reason}")
        if grp.carrier != "dense" and plan == "dense":
            _warn_degraded(config,
                           f"group {grp.pattern!r} carrier {grp.carrier}",
                           reason)
        if grp.has_downlink:
            dplan, dreason = carrier_lib.make(
                grp.down_carrier).plan_down_with_reason(grp.down_comp())
            if grp.down_carrier != "dense" and dplan == "dense":
                _warn_degraded(
                    config,
                    f"group {grp.pattern!r} downlink {grp.down_carrier}",
                    dreason)


def default_ef_config(mesh, plan: sh.ShardPlan,
                      method_name: str = "ef21_sgdm",
                      compressor_name: str = "block_topk",
                      ratio: float = 0.01, eta: float = 0.1,
                      carrier: str = "dense",
                      method: Optional[ef_lib.Method] = None,
                      down_carrier: str = "dense",
                      down_compressor: Optional[comp_lib.Compressor] = None,
                      schedule=None, overlap: bool = False,
                      participation=None, hops=None) -> dist.EFConfig:
    """EFConfig assembly + the authoritative carrier-plan checks. Pass a
    prebuilt ``method`` (launch/session.py builds one from the RunSpec,
    including method_kw/compressor_kw) to skip the name-based construction
    here — the carrier validation below runs either way. With a
    ``schedule`` (core/schedule.py) the checks run PER GROUP and the
    single-knob carrier/downlink fields are recorded but ignored by the
    runtimes."""
    from repro.core import carriers as carrier_lib
    carrier_obj = carrier_lib.make(carrier)  # fail fast on unknown names
    if method is None:
        comp = (comp_lib.make(compressor_name, ratio=ratio)
                if compressor_name != "identity" else comp_lib.Identity())
        state_dtype = jnp.bfloat16 if plan.ef_state_dtype == "bfloat16" \
            else None
        kwargs: Dict[str, Any] = {"compressor": comp,
                                  "state_dtype": state_dtype}
        if method_name in ("ef21_sgdm", "ef21_sgd2m", "sgdm", "ef21_storm"):
            kwargs["eta"] = eta
        method = ef_lib.make(method_name, **kwargs)
    # the dedup key: everything that defines this config's transport — two
    # constructions of the same experiment share one warning, a different
    # experiment degrading for the same reason warns on its own
    config_key = (method, carrier, down_carrier, down_compressor, schedule)
    if schedule is not None:
        _check_group_plans(config_key, schedule, method, eta)
    # partial participation (DESIGN.md §11): the authoritative checks
    # mirroring RunSpec._validate_participation — async never builds a
    # synchronous step, and a sampled cohort cannot ride the fused wire
    # (the mega-kernel aggregates all clients inside; nothing to mask)
    if participation is not None and participation.mode == "async":
        raise ValueError(
            "participation mode 'async' does not build a synchronous step "
            "(every round is a barrier); drive the event-driven simulator "
            "instead: repro.core.participation.run_async")
    if participation is not None and participation.is_sampling:
        fused_wire_carriers = ("fused_quant8", "fused_quant4")
        bad = [f"carrier={carrier!r}"] \
            if schedule is None and carrier in fused_wire_carriers else []
        if schedule is not None:
            bad += [f"group {g.pattern!r} carrier={g.carrier!r}"
                    for g in schedule.groups
                    if g.carrier in fused_wire_carriers]
        if bad:
            raise ValueError(
                f"sampled participation cannot run the fused quantized wire "
                f"({', '.join(bad)}): the mega-kernel aggregates all clients "
                "inside, leaving no per-client wire to mask — use "
                "carrier='quant8'/'quant4'")
    # two-tier topology (DESIGN.md §13): the authoritative construction
    # checks mirroring RunSpec._validate_hops — pod clients are already one
    # level of hierarchy, a sampled cohort has no pod-stable membership, the
    # fused wire IS the global aggregation, and on a real mesh the pod count
    # must be the mesh's pod axis (the sharded runtime reduces over it)
    from repro.core import hierarchy as hier_lib
    hops_eff = hier_lib.effective(hops)
    if hops_eff is not None:
        if plan.client_granularity == "pod":
            raise ValueError(
                "hops with client_granularity='pod' stacks two pod "
                "hierarchies: pod-granularity clients ARE one EF client per "
                "pod already — pick one level")
        if participation is not None and participation.is_sampling:
            raise ValueError(
                "sampled participation cannot run under a hierarchical "
                "topology: a per-round cohort has no stable pod membership "
                "for the pod aggregator's EF memory")
        fused_wire_carriers = ("fused_quant8", "fused_quant4")
        bad = [f"carrier={carrier!r}"] \
            if schedule is None and carrier in fused_wire_carriers else []
        if schedule is not None:
            bad += [f"group {g.pattern!r} carrier={g.carrier!r}"
                    for g in schedule.groups
                    if g.carrier in fused_wire_carriers]
        if bad:
            raise ValueError(
                f"the fused quantized wire cannot run under a hierarchical "
                f"topology ({', '.join(bad)}): its wire IS the global "
                "aggregation — there is no per-pod innovation to re-compress")
        if mesh.size > 1:
            if "pod" not in mesh.axis_names:
                raise ValueError(
                    f"hops.pods={hops_eff.pods} needs a mesh with a 'pod' "
                    f"axis (got {mesh.axis_names}) — use --mesh multi_pod")
            if mesh.shape["pod"] != hops_eff.pods:
                raise ValueError(
                    f"hops.pods={hops_eff.pods} != mesh pod axis "
                    f"{mesh.shape['pod']}: the sharded runtime reduces the "
                    "intra-pod hop over the mesh's pod blocks")
    # the carrier itself is the source of truth for what it can execute; an
    # explicitly requested fused carrier that would silently degrade to the
    # unfused dense plan is a misconfiguration worth failing fast on, and any
    # other degraded carrier must at least say so in logs. With a schedule
    # the single-knob fields are recorded but never consulted by a runtime,
    # so NONE of their plan checks apply — the per-group checks above are
    # the authoritative ones.
    exec_plan, reason = carrier_obj.plan_with_reason(method, eta)
    if carrier == "fused" and exec_plan != "fused" and schedule is None:
        raise ValueError(
            "--carrier fused would silently run the UNFUSED dense plan: "
            f"{reason}. Pick --carrier dense or sparse for "
            f"method={method.name!r} "
            f"compressor={type(method.compressor).__name__!r}.")
    if carrier in ("fused_quant8", "fused_quant4") \
            and exec_plan != "fused_wire" and schedule is None:
        raise ValueError(
            f"--carrier {carrier} would silently run a DEGRADED plan "
            f"({exec_plan!r}): {reason}. Pick --carrier quant8 or quant4 "
            f"(the unfused quantized wire) for method={method.name!r} "
            f"compressor={type(method.compressor).__name__!r}.")
    if carrier != "dense" and exec_plan == "dense" and schedule is None:
        _warn_degraded(config_key, f"--carrier {carrier}", reason)
    # downlink (DESIGN.md §8): a fused downlink is a hard misconfiguration
    # (the fused kernel is the uplink client update); any other degradation
    # to the dense broadcast must at least say so in logs
    if schedule is None and (down_carrier != "dense"
                             or down_compressor is not None):
        down_obj = carrier_lib.make(down_carrier)
        down_plan, down_reason = down_obj.plan_down_with_reason(
            down_compressor if down_compressor is not None
            else comp_lib.Identity())
        if down_carrier == "fused":
            raise ValueError(
                f"--downlink-carrier fused is not a thing: {down_reason}")
        if down_carrier != "dense" and down_plan == "dense" \
                and schedule is None:
            _warn_degraded(config_key, f"--downlink-carrier {down_carrier}",
                           down_reason)
    # the EF client axes follow the plan's client granularity (pod clients
    # aggregate over 'pod' only; the within-pod mean happens in the vmapped
    # per-client loss)
    c_ax = sh.client_axis(mesh, plan)
    if c_ax is None:
        c_ax = ()
    elif isinstance(c_ax, str):
        c_ax = (c_ax,)
    return dist.EFConfig(method=method, carrier=carrier,
                         data_axes=tuple(c_ax), down_carrier=down_carrier,
                         down_compressor=down_compressor, schedule=schedule,
                         overlap=overlap, participation=participation,
                         hops=hops)


def _replicated(mesh, x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                sharding=NamedSharding(mesh, P()))


def arch_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Per-shape serving overrides (DESIGN.md §5): zamba2's shared attention gets a
    4k sliding window in the long-context config."""
    if shape.name == "long_500k" and cfg.family == "hybrid" \
            and cfg.sliding_window is None:
        return dataclasses.replace(cfg, sliding_window=4096)
    return cfg


def build_train_step(cfg: ArchConfig, shape: InputShape, mesh,
                     plan: sh.ShardPlan, efc: dist.EFConfig,
                     optimizer_name: str = "sgd", lr: float = 1e-2):
    """Returns (train_step, (params, opt_state, ef_state, batch, rng, step))."""
    n = sh.n_clients(mesh, plan)
    opt = opt_lib.make(optimizer_name, lr=lr)

    def loss_fn(p, b):
        return model_lib.train_loss(cfg, p, b)

    params = sh.param_specs(cfg, mesh)
    batch = sh.batch_specs(cfg, mesh, shape, "train")

    ef_shapes = jax.eval_shape(
        lambda: dist.init_ef_state(
            efc, model_lib.init_params(cfg, jax.random.PRNGKey(0)), n))
    ef_specs_p = sh.ef_state_pspecs(cfg, mesh, plan, efc.method,
                                    downlink=efc.has_downlink,
                                    schedule=efc.schedule, hops=efc.hops)
    ef_state = sh._sds(ef_shapes, ef_specs_p, mesh)

    # per-client grads share the client-state layout (leading client axis)
    grads_specs = sh._spec_map(
        lambda s: sh.P(sh.client_axis(mesh, plan), *s),
        sh.params_pspecs(cfg, mesh))
    step_fn = dist.make_train_step(
        loss_fn, efc, opt, n,
        mesh=mesh if mesh.size > 1 else None,
        grads_specs=grads_specs, state_specs=ef_specs_p)

    opt_shapes = jax.eval_shape(
        lambda: opt.init(model_lib.init_params(cfg, jax.random.PRNGKey(0))))
    opt_pspecs = {k: sh.params_pspecs(cfg, mesh) for k in opt_shapes.keys()} \
        if isinstance(opt_shapes, dict) and opt_shapes else opt_shapes
    opt_state = sh._sds(opt_shapes, opt_pspecs, mesh) if opt_shapes else {}

    rng = _replicated(mesh, jax.eval_shape(lambda: jax.random.PRNGKey(0)))
    step = _replicated(mesh, jax.eval_shape(lambda: jnp.zeros((), jnp.int32)))
    return step_fn, (params, opt_state, ef_state, batch, rng, step)


def _cache_shape(shape: InputShape, decode_budget: int) -> InputShape:
    """Serving sessions extend the cache past the prompt by the decode
    budget; the named dry-run shapes keep their exact cache length."""
    if not decode_budget:
        return shape
    return dataclasses.replace(shape, seq_len=shape.seq_len + decode_budget)


def build_prefill(cfg: ArchConfig, shape: InputShape, mesh,
                  decode_budget: int = 0):
    def fn(params, batch, cache):
        return model_lib.prefill(cfg, params, batch, cache)
    params = sh.param_specs(cfg, mesh)
    batch = sh.batch_specs(cfg, mesh, shape, "prefill")
    cache = sh.cache_specs(cfg, mesh, _cache_shape(shape, decode_budget))
    return fn, (params, batch, cache)


def build_decode(cfg: ArchConfig, shape: InputShape, mesh,
                 decode_budget: int = 0):
    def fn(params, cache, tokens, pos):
        return model_lib.decode_step(cfg, params, cache, tokens, pos)
    params = sh.param_specs(cfg, mesh)
    cache = sh.cache_specs(cfg, mesh, _cache_shape(shape, decode_budget))
    B = shape.global_batch
    b_ax = mesh_lib.data_axes(mesh) if B % mesh_lib.dp_size(mesh) == 0 else None
    tokens = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32, sharding=NamedSharding(mesh, P(b_ax, None)))
    pos = _replicated(mesh, jax.eval_shape(lambda: jnp.zeros((), jnp.int32)))
    return fn, (params, cache, tokens, pos)


def build_step(cfg: ArchConfig, shape: InputShape, mesh, plan: sh.ShardPlan,
               efc: Optional[dist.EFConfig] = None, **train_kw):
    cfg = arch_for_shape(cfg, shape)
    if shape.kind == "train":
        assert efc is not None
        return build_train_step(cfg, shape, mesh, plan, efc, **train_kw)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)
