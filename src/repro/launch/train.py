"""Training driver — a thin flags → RunSpec → Session shim.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 200 --clients 8 --method ef21_sgdm --compressor block_topk

All assembly (mesh, ShardPlan, EFConfig, data pipeline, jitted step,
checkpointing) lives in launch/session.py behind the RunSpec
(launch/spec.py); this module only parses flags and narrates. ``--spec
FILE`` loads a serialized RunSpec instead of (or as a base for) flags.

``--resume`` restores the FULL training state (params + opt_state + ef_state
+ data cursor) from the latest checkpoint under --ckpt-dir; the RunSpec
embedded in the checkpoint is the source of truth when no spec flags are
passed, and a mismatching flag-built spec is refused unless
--allow-spec-mismatch (DESIGN.md §7).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

from repro.launch import spec as spec_lib


def main(argv=None) -> None:
    ap = argparse.ArgumentParser("repro.launch.train")
    spec_lib.add_flags(ap)
    ap.add_argument("--steps", type=int, default=200,
                    help="train until this ABSOLUTE step count")
    ap.add_argument("--resume", action="store_true",
                    help="restore full state from the latest ckpt in "
                         "--ckpt-dir (spec embedded there wins unless other "
                         "spec flags are passed)")
    ap.add_argument("--allow-spec-mismatch", action="store_true",
                    help="resume even when the flag-built spec differs from "
                         "the checkpoint's")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--publish-stream", default=None, metavar="DIR",
                    help="publish every downlink wire record to this stream "
                         "dir (core/stream.py) so serving replicas can "
                         "subscribe (launch/fleet.py)")
    ap.add_argument("--bootstrap-every", type=int, default=0,
                    help="with --publish-stream: also write a bootstrap "
                         "checkpoint into the stream every N steps (0 = "
                         "only the initial one)")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="join a multi-process jax.distributed fleet at this "
                         "coordinator (process 0 hosts it) before any jax "
                         "device access; needs --num-processes/--process-id "
                         "(launch/multiproc.py)")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    args = ap.parse_args(argv)
    spec = spec_lib.RunSpec.from_args(args)

    if args.coordinator is not None:
        if args.num_processes is None or args.process_id is None:
            ap.error("--coordinator needs --num-processes and --process-id")
        # BEFORE the session import chain touches jax device state
        from repro.launch import multiproc
        multiproc.distributed_init(args.coordinator, args.num_processes,
                                   args.process_id)

    from repro.launch.session import Session  # defer the jax-heavy import

    if args.resume:
        if not spec.ckpt_dir:
            ap.error("--resume needs --ckpt-dir")
        # bare `--ckpt-dir D --resume` reconstructs the run purely from the
        # checkpoint's embedded RunSpec; any EXPLICITLY passed spec flag
        # (even one equal to a default — flags parse as None when unset)
        # enforces the flag-built spec against the checkpoint's hash
        explicit = spec_lib.explicit_fields(
            args, ignore=("ckpt_dir", "ckpt_every"))
        try:
            if args.spec_file:
                # an explicit spec FILE is the full experiment definition
                sess = Session.resume(
                    spec.ckpt_dir, spec=spec,
                    allow_spec_mismatch=args.allow_spec_mismatch)
            else:
                # explicit flags layer ONTO the checkpoint's embedded spec
                # ('--resume --eta 0.2' = same run, new eta — never
                # 'defaults plus eta')
                overrides = {f: getattr(args, f) for f in explicit}
                sess = Session.resume(
                    spec.ckpt_dir, overrides=overrides or None,
                    allow_spec_mismatch=args.allow_spec_mismatch)
            print(f"resumed {sess.spec.arch} from {spec.ckpt_dir} "
                  f"@ step {sess.step}")
        except FileNotFoundError:
            # idempotent-restart idiom: wrappers always pass --resume; an
            # empty/absent ckpt dir means first launch → start from scratch
            print(f"no checkpoint under {spec.ckpt_dir}; starting fresh")
            sess = Session(spec)
        # checkpoint POLICY is runtime-owned (excluded from spec_hash): an
        # explicit --ckpt-every on the resume command line applies even when
        # the embedded spec wins everything else
        if args.ckpt_every is not None:
            sess.spec = dataclasses.replace(sess.spec,
                                            ckpt_every=args.ckpt_every)
    else:
        sess = Session(spec)

    # printed from the spec the session ACTUALLY runs (a bare --resume
    # adopts the checkpoint's embedded spec, not the flag defaults)
    table = sess.schedule_table()
    if table is not None:
        # per-group schedule: the RESOLVED group table (leaf/param counts,
        # per-group plan + degradation reasons, wire words) IS the plan line
        print("compression schedule (first-match-wins):")
        print(table)
    else:
        plan, reason = sess.spec.plan()
        print(f"carrier={sess.spec.carrier} plan={plan}"
              + (f" (degraded: {reason})" if reason else ""))
        if sess.spec.downlink_carrier != "dense":
            dplan, dreason = sess.spec.downlink_plan()
            print(f"downlink={sess.spec.downlink_carrier} plan={dplan}"
                  + (f" (degraded: {dreason})" if dreason else ""))
    pp = spec_lib.participation_preview(sess.spec)
    if pp["mode"] != "full":
        print(f"participation mode={pp['mode']} fraction={pp['fraction']} "
              f"seed={pp['seed']} cohort={pp['cohort']}/{pp['n']} per round")
    hp = spec_lib.hops_preview(sess.spec)
    if hp["hierarchical"]:
        print(f"hops pods={hp['pods']} cross={hp['cross_carrier']}"
              f":{hp['cross_ratio']} "
              f"clients_per_pod={hp['clients_per_pod']}"
              + (" (trivial cross: flat-equivalent)"
                 if hp["trivial_cross"] else ""))

    if args.publish_stream:
        sess.publish_to(args.publish_stream,
                        bootstrap_every=args.bootstrap_every)
        print(f"publishing wire records to {args.publish_stream}")

    sess.train(args.steps, log_every=args.log_every, verbose=True)
    if sess.spec.ckpt_dir:
        print(f"saved checkpoint @ {sess.step}")
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(sess.history, f, indent=1)


if __name__ == "__main__":
    main()
