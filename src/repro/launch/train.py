"""End-to-end training driver: EF21-SGDM distributed training of any --arch.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 200 --clients 8 --method ef21_sgdm --compressor block_topk

--smoke uses the reduced per-arch config on the local device(s) (the CPU
container path); without it, the full config runs on whatever mesh the host set
exposes (real TPU). The EF clients are emulated faithfully either way — the same
Method/ef_round code runs on the production mesh via launch/build.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs import base as cb
from repro.core import distributed as dist
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch import build as build_lib
from repro.launch import mesh as mesh_lib
from repro.launch import shardings as sh
from repro.models import model as model_lib
from repro.optim import optimizer as opt_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--eta", type=float, default=0.1)
    ap.add_argument("--method", default="ef21_sgdm")
    ap.add_argument("--compressor", default="block_topk")
    ap.add_argument("--ratio", type=float, default=0.05)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--carrier", default="dense",
                    choices=["dense", "sparse", "fused", "quant8", "quant4"],
                    help="wire carrier for the EF sync (core/carriers.py): "
                         "dense all-reduce, sparse (values,indices) "
                         "all-gather, the fused Pallas client update, or "
                         "block-quantized wires (int8 / packed-uint4 "
                         "mantissas + per-block scales)")
    ap.add_argument("--b-init", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg = cb.get_smoke(args.arch) if args.smoke else cb.get(args.arch)
    n = args.clients
    assert args.global_batch % n == 0

    rng = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, rng)

    pipe = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.global_batch, seed=args.seed, dp_groups=n))

    def loss_fn(p, b):
        return model_lib.train_loss(cfg, p, b)

    def add_frontend(b):
        if cfg.frontend is not None:
            nt = max(cfg.frontend_tokens, 8)
            b = dict(b)
            b["prefix_embeds"] = jnp.zeros(
                (b["tokens"].shape[0], nt, cfg.d_model), jnp.bfloat16)
        return b

    plan = sh.ShardPlan()
    mesh = mesh_lib.make_smoke_mesh()
    efc = build_lib.default_ef_config(
        mesh, plan, method_name=args.method, compressor_name=args.compressor,
        ratio=args.ratio, eta=args.eta, carrier=args.carrier)
    from repro.core import carriers as carrier_lib
    ex_plan, reason = carrier_lib.make(args.carrier).plan_with_reason(
        efc.method, args.eta)
    print(f"carrier={args.carrier} plan={ex_plan}"
          + (f" (degraded: {reason})" if reason else ""))
    opt = opt_lib.make(args.optimizer, lr=args.lr)
    step_fn = jax.jit(dist.make_train_step(loss_fn, efc, opt, n))

    # Alg 1 line 2: v⁰ᵢ = g⁰ᵢ = (1/B_init)Σⱼ ∇fᵢ(x⁰, ξ⁰ᵢⱼ)
    b0 = add_frontend(pipe.batch(0))
    _, _, g0 = dist.per_client_value_and_grad(loss_fn, params, b0, n)
    ef_state = dist.init_ef_state(efc, params, n, init_grads=g0)
    opt_state = opt.init(params)
    start = 0

    if args.ckpt_dir and args.resume:
        path = ckpt_lib.latest(args.ckpt_dir)
        if path:
            params, meta = ckpt_lib.restore(path, params)
            start = meta["step"]
            print(f"resumed from {path} @ step {start}")

    history = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = add_frontend(pipe.batch(step))
        params, opt_state, ef_state, m = step_fn(
            params, opt_state, ef_state, batch,
            jax.random.fold_in(rng, step), step)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(m["loss"])
            history.append({"step": step, "loss": loss,
                            "g_norm": float(m["g_norm"])})
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"g_norm {float(m['g_norm']):.3e} "
                  f"({(time.time()-t0)/max(step-start+1,1):.2f}s/step)",
                  flush=True)
    if args.ckpt_dir:
        ckpt_lib.save(os.path.join(args.ckpt_dir,
                                   f"step_{args.steps:08d}.npz"),
                      params, step=args.steps)
        print(f"saved checkpoint @ {args.steps}")
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
