"""Sharding assembly: PartitionSpecs for params, EF/optimizer state, batches and
caches on the production mesh, plus ShapeDtypeStruct input_specs for the dry-run.

EF state layout knobs (DESIGN.md §4, grok-scale memory):
  client_granularity: 'group' — one EF client per data-parallel group (paper-
                       faithful n = dp); 'pod' — one client per pod (n = #pods;
                       Theorem 3 applies with smaller n; state ÷ dp/pods, and the
                       compressed wire crosses exactly the slow inter-pod links)
  state_sharding:     'client' — a client's (vᵢ,gᵢ) live on its own chips, sharded
                       over 'model' only; 'zero' — additionally sharded over the
                       data axes inside the client (ZeRO-style), dividing EF state
                       HBM by dp at the cost of gather/scatter on the update path
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.data import pipeline as pipe_lib
from repro.launch import mesh as mesh_lib
from repro.models import model as model_lib

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    client_granularity: str = "group"       # 'group' | 'pod'
    state_sharding: str = "client"          # 'client' | 'zero'
    ef_state_dtype: Optional[str] = None    # None → param dtype; 'bfloat16' at scale


def n_clients(mesh, plan: ShardPlan) -> int:
    if plan.client_granularity == "pod":
        return mesh.shape.get("pod", 1)
    return mesh_lib.dp_size(mesh)


def client_axis(mesh, plan: ShardPlan):
    if plan.client_granularity == "pod":
        return "pod" if "pod" in mesh.axis_names else None
    return mesh_lib.data_axes(mesh)


# ---------------------------------------------------------------------------
# pspec trees
# ---------------------------------------------------------------------------

def params_pspecs(cfg: ArchConfig, mesh) -> Dict:
    return model_lib.param_pspecs(cfg, tp=mesh.shape["model"])


def _zero_upgrade(spec: P, data_ax, shape=None, mesh=None) -> P:
    """ZeRO: also shard the first 'model'-sharded dim over the (free) data axes
    — only when the dim size divides the enlarged axis product."""
    ax_tuple = (data_ax,) if isinstance(data_ax, str) else tuple(data_ax)
    parts = list(spec)
    for i, s in enumerate(parts):
        if s == "model":
            if shape is not None and mesh is not None:
                total = mesh.shape["model"]
                for a in ax_tuple:
                    total *= mesh.shape[a]
                if i >= len(shape) or shape[i] % total != 0:
                    continue
            parts[i] = tuple([*ax_tuple, "model"])
            return P(*parts)
    return spec


def _spec_map(fn, tree):
    """tree_map over a PartitionSpec tree (P is a tuple subclass → force leaf)."""
    return jax.tree_util.tree_map(fn, tree,
                                  is_leaf=lambda x: isinstance(x, P))


def ef_state_pspecs(cfg: ArchConfig, mesh, plan: ShardPlan, method,
                    downlink: bool = False, schedule=None,
                    hops=None) -> Dict:
    """Mirror of distributed.init_ef_state structure. ``downlink`` adds the
    server broadcast memory h (DESIGN.md §8) — replicated-in-value like the
    server estimate, so it shares the server's param pspecs. With a
    ``schedule`` (core/schedule.py) the state-key sample comes from the
    grouped init, so per-group EF-state dtypes (and any future per-group
    state shape) flow through exactly the trees the runtime will build —
    pspecs themselves are per-leaf and identical across groups. ``hops``
    (core/hierarchy.Hops with pods > 1) adds the pod-aggregator memory
    {'t', 'b'} (DESIGN.md §13) — one slot per pod, leading dim sharded over
    the 'pod' axis, body sharded exactly like the server params (each pod's
    target/broadcast pair is a param-shaped tree living on that pod's
    chips)."""
    pspecs = params_pspecs(cfg, mesh)
    c_ax = client_axis(mesh, plan)
    d_ax = mesh_lib.data_axes(mesh)

    # ZeRO upgrade may only use mesh axes NOT already taken by the client dim
    c_used = set(c_ax) if isinstance(c_ax, tuple) else \
        ({c_ax} if c_ax else set())
    free_ax = tuple(a for a in d_ax if a not in c_used)

    from repro.models import model as model_lib
    param_shapes = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    shape_leaves = jax.tree_util.tree_leaves(param_shapes)
    spec_leaves = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    treedef = jax.tree_util.tree_structure(
        pspecs, is_leaf=lambda x: isinstance(x, P))

    def leaf_spec(spec, shape):
        body = _zero_upgrade(spec, free_ax, shape, mesh) \
            if (plan.state_sharding == "zero" and free_ax) else spec
        return P(c_ax, *body)

    client_tree = jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(s, sh_.shape)
                  for s, sh_ in zip(spec_leaves, shape_leaves)])
    dummy = _spec_map(lambda s: jnp.zeros((1,)), pspecs)
    if schedule is not None:
        from repro.core import schedule as sched_lib
        sample = jax.eval_shape(
            lambda: sched_lib.init_state_grouped(schedule, method, dummy))
    else:
        sample = jax.eval_shape(lambda: method.init(dummy))
    client_specs = {k: client_tree for k in sample.keys()}
    out = {"clients": client_specs, "server": pspecs}
    if downlink:
        out["h"] = pspecs
    from repro.core import hierarchy as hier_lib
    if hier_lib.effective(hops) is not None:
        if "pod" not in mesh.axis_names:
            raise ValueError(
                "hops.pods > 1 needs a mesh with a 'pod' axis "
                f"(got axes {mesh.axis_names}) — use --mesh multi_pod")
        pod_tree = _spec_map(lambda s: P("pod", *s), pspecs)
        out["pods"] = {"t": pod_tree, "b": pod_tree}
    return out


def batch_pspecs(cfg: ArchConfig, mesh, kind: str, global_batch: int) -> Dict:
    d_ax = mesh_lib.data_axes(mesh)
    b_ax = d_ax if global_batch % mesh_lib.dp_size(mesh) == 0 else None
    out = {"tokens": P(b_ax, None)}
    if kind == "train":
        out["labels"] = P(b_ax, None)
    if cfg.frontend is not None and kind in ("train", "prefill"):
        out["prefix_embeds"] = P(b_ax, None, None)
    return out


def cache_pspecs(cfg: ArchConfig, mesh, global_batch: int) -> Dict:
    """Caches: (L, B, S, KV, hd) attention / (L, B, …) SSM states.
    B sharded over data axes when divisible; otherwise the long dim (S for
    attention, d_inner/heads for SSM) absorbs all mesh axes (sequence/state
    parallel decode)."""
    d_ax = mesh_lib.data_axes(mesh)
    tp = mesh.shape["model"]
    b_ok = global_batch % mesh_lib.dp_size(mesh) == 0
    b_ax = d_ax if b_ok else None
    kv_ax = "model" if (cfg.num_kv_heads and cfg.num_kv_heads % tp == 0) else None
    # when KV can't shard, shard sequence over 'model'; when B can't shard,
    # shard sequence over everything
    if b_ok:
        s_ax = None if kv_ax else "model"
    else:
        s_ax = tuple([*d_ax, "model"]) if not kv_ax else d_ax
    attn_spec = P(None, b_ax, s_ax, kv_ax, None)

    di_ax = "model" if cfg.d_inner % tp == 0 else None
    specs: Dict[str, Any] = {}
    fam = cfg.family
    if fam in ("dense", "audio", "vlm", "moe") and not cfg.local_global:
        specs = {"k": attn_spec, "v": attn_spec}
    elif cfg.local_global:
        specs = {"k_local": attn_spec, "v_local": attn_spec,
                 "k_global": attn_spec, "v_global": attn_spec}
    elif fam == "ssm":
        specs = {"ssm": P(None, b_ax, di_ax, None),
                 "conv": P(None, b_ax, None, di_ax)}
    elif fam == "hybrid":
        nh = cfg.d_inner // cfg.ssm_head_dim
        h_ax = "model" if nh % tp == 0 else None
        conv_d = cfg.d_inner + 2 * cfg.ssm_state
        specs = {"ssm": P(None, b_ax, h_ax, None, None),
                 "conv": P(None, b_ax, None,
                           "model" if conv_d % tp == 0 else None),
                 "k_attn": attn_spec, "v_attn": attn_spec}
    return specs


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders (no allocation, shannon/kernels pattern)
# ---------------------------------------------------------------------------

def _sds(tree_shapes: PyTree, tree_specs: PyTree, mesh) -> PyTree:
    specs_flat = jax.tree_util.tree_leaves(
        tree_specs, is_leaf=lambda x: isinstance(x, P))
    shapes_flat, treedef = jax.tree_util.tree_flatten(tree_shapes)
    assert len(specs_flat) == len(shapes_flat), \
        f"spec/shape tree mismatch: {len(specs_flat)} vs {len(shapes_flat)}"
    out = [jax.ShapeDtypeStruct(s.shape, s.dtype,
                                sharding=NamedSharding(mesh, spec))
           for s, spec in zip(shapes_flat, specs_flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


def param_specs(cfg: ArchConfig, mesh) -> PyTree:
    shapes = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    return _sds(shapes, params_pspecs(cfg, mesh), mesh)


def batch_specs(cfg: ArchConfig, mesh, shape: InputShape, kind: str) -> Dict:
    B = shape.global_batch
    S = shape.seq_len if kind != "decode" else 1
    out_shapes: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if kind == "train":
        out_shapes["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.frontend is not None and kind in ("train", "prefill"):
        # the production-spec padding of the shared prefix-embed rule
        # (data/pipeline.py — drivers pad the same batches to PREFIX_PAD_MIN)
        nt = pipe_lib.prefix_token_count(cfg, pad_to=pipe_lib.PREFIX_PAD_SPEC)
        out_shapes["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, nt, cfg.d_model), jnp.bfloat16)
    return _sds(out_shapes, batch_pspecs(cfg, mesh, kind, B), mesh)


def cache_specs(cfg: ArchConfig, mesh, shape: InputShape) -> Dict:
    nt = pipe_lib.prefix_token_count(cfg, pad_to=pipe_lib.PREFIX_PAD_SPEC)
    shapes = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, shape.global_batch,
                                     shape.seq_len + nt))
    return _sds(shapes, cache_pspecs(cfg, mesh, shape.global_batch), mesh)
