import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on the
production mesh; record memory analysis, FLOPs/bytes, and the collective schedule.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  ... --mesh multi_pod     → (pod=2, data=16, model=16) = 512 chips
  ... --carrier sparse     → wire-optimized (values, indices) aggregation
  ... --granularity pod    → EF clients = pods (grok-scale memory plan)
  ... --state-sharding zero → ZeRO-sharded EF state

Every combo is one RunSpec (launch/spec.py) lowered through Session.lower()
(launch/session.py) — the same assembly path train/serve use, so a sweep is a
list of spec files, not a bespoke driver. A failure here (sharding mismatch,
OOM at compile, unsupported collective) is a bug in the system, per the
assignment spec; a spec-level ValueError (e.g. the fused misconfiguration) is
recorded as FAIL at construction, before anything is lowered. Skips
(long_500k on pure full-attention archs) are recorded explicitly with reasons.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

from repro.configs import base as cb
from repro.launch import hlo_analysis
from repro.launch import spec as spec_lib

# long_500k requires sub-quadratic state (assignment spec): skip pure
# full-attention archs, with reasons recorded in DESIGN.md §5 and the JSON.
LONG_SKIP = {
    "granite_34b": "pure full attention (MQA), no windowed variant published",
    "smollm_360m": "pure full attention, no windowed variant published",
    "musicgen_medium": "pure full attention over EnCodec tokens",
    "internvl2_76b": "pure full attention LLM decoder",
    "olmoe_1b_7b": "pure full attention MoE",
    "grok1_314b": "pure full attention MoE",
}


def run_one(arch: str, shape_name: str, *, mesh: str = "pod",
            carrier: str = "dense", method: str = "ef21_sgdm",
            compressor: str = "block_topk", ratio: float = 0.01,
            granularity: str = "group", state_sharding: str = "client",
            ef_state_dtype: Optional[str] = None, pad_heads: int = 0,
            moe_impl: str = "dispatch",
            optimizer: str = "sgd", extra_tag: str = "") -> Dict:
    mod = cb.ARCH_ALIASES.get(arch, arch)
    rec: Dict = {
        "arch": mod, "shape": shape_name, "multi_pod": mesh == "multi_pod",
        "carrier": carrier, "method": method, "compressor": compressor,
        "granularity": granularity, "state_sharding": state_sharding,
        "optimizer": optimizer, "tag": extra_tag,
    }
    if shape_name == "long_500k" and mod in LONG_SKIP:
        rec.update(status="SKIP", reason=LONG_SKIP[mod])
        return rec

    t0 = time.time()
    try:
        spec = spec_lib.RunSpec(
            arch=mod, shape=shape_name, mesh=mesh, carrier=carrier,
            method=method, compressor=compressor, ratio=ratio,
            client_granularity=granularity, state_sharding=state_sharding,
            ef_state_dtype=ef_state_dtype, tp_pad_heads=pad_heads,
            moe_impl=moe_impl, optimizer=optimizer)
        from repro.launch.session import Session
        sess = Session(spec)
        rec["spec_hash"] = spec.spec_hash()
        with sess.mesh_context():
            lowered = sess.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = hlo_analysis.cost_analysis_dict(compiled)
            hlo = hlo_analysis.analyze(compiled.as_text(), sess.mesh.size)
        rec.update(
            status="OK",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            n_devices=sess.mesh.size,
            # XLA-reported (while bodies counted ONCE — see hlo_analysis.py):
            xla_flops_loop_once=float(cost.get("flops", 0.0)),
            xla_bytes_loop_once=float(cost.get("bytes accessed", 0.0)),
            # loop-corrected per-device numbers from the HLO analyzer:
            flops=hlo["dot_flops"] + hlo["conv_flops"],
            collectives=hlo["collective_bytes"],
            collective_counts=hlo["collective_counts"],
            collective_bytes=hlo["total_collective_bytes"],
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            } if mem is not None else None,
        )
    except Exception as e:  # noqa: BLE001 — record, don't crash the sweep
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id (e.g. gemma2-9b); omit with --all")
    ap.add_argument("--shape", default=None, choices=[*cb.INPUT_SHAPES, None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="alias for --mesh multi_pod")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multi_pod"])
    ap.add_argument("--carrier", default="dense",
                    choices=sorted(spec_lib.CARRIERS))
    ap.add_argument("--method", default="ef21_sgdm")
    ap.add_argument("--compressor", default="block_topk")
    ap.add_argument("--ratio", type=float, default=0.01)
    ap.add_argument("--granularity", default="group", choices=["group", "pod"])
    ap.add_argument("--state-sharding", default="client",
                    choices=["client", "zero"])
    ap.add_argument("--ef-state-dtype", default=None)
    ap.add_argument("--pad-heads", type=int, default=0)
    ap.add_argument("--moe-impl", default="dispatch",
                    choices=["dispatch", "dense"])
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()
    mesh = "multi_pod" if args.multi_pod else args.mesh

    combos = []
    if args.all:
        for a in cb.ARCH_IDS:
            for s in cb.INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch + --shape, or --all"
        combos = [(args.arch, args.shape)]

    results = []
    for a, s in combos:
        rec = run_one(
            a, s, mesh=mesh, carrier=args.carrier,
            method=args.method, compressor=args.compressor, ratio=args.ratio,
            granularity=args.granularity, state_sharding=args.state_sharding,
            ef_state_dtype=args.ef_state_dtype, pad_heads=args.pad_heads,
            moe_impl=args.moe_impl,
            optimizer=args.optimizer, extra_tag=args.tag)
        results.append(rec)
        line = f"[{rec['status']:4s}] {rec['arch']:18s} {rec['shape']:12s}"
        if rec["status"] == "OK":
            line += (f" flops={rec['flops']:.3e}"
                     f" coll={rec['collective_bytes']:.3e}"
                     f" temp={rec['memory']['temp_bytes']/2**30:.2f}GiB"
                     f" compile={rec['compile_s']}s")
        elif rec["status"] == "FAIL":
            line += " " + rec["error"][:160]
        else:
            line += " " + rec["reason"]
        print(line, flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    sys.exit(0 if all(r["status"] != "FAIL" for r in results) else 1)


if __name__ == "__main__":
    main()
