"""Session: the single runtime facade over a RunSpec.

``Session(spec)`` owns everything a run needs — arch config, mesh, ShardPlan,
EFConfig, optimizer, data pipeline, the jitted step, metrics history, and
full-state checkpointing — so drivers, examples, and benchmarks are thin
flag→RunSpec→Session shims with no assembly logic of their own:

    spec = RunSpec(arch="smollm-360m", smoke=True, clients=4)
    sess = Session(spec)
    sess.train(200)                   # EF21-SGDM on the synthetic pipeline
    sess.evaluate()                   # held-out loss at the current params
    sess.serve(batch=4, ...)          # prefill+decode through build_* shardings
    sess.lower("train_4k")            # the dry-run artifact

Checkpointing is FULL-state (DESIGN.md §7): params + opt_state + ef_state +
the data cursor + the RunSpec itself (and its hash) in checkpoint meta.
``Session.resume(dir)`` reconstructs the run without re-passing any flags,
and a resumed run is bit-identical to an uninterrupted one — restoring only
params (the old ``train.py --resume`` behavior) silently violated the EF21
invariant that server and clients agree on g (Algorithm 1 line 8), because a
fresh ef_state re-initializes gᵢ from step-0 gradients while the restored
params are mid-trajectory. ``tests/test_session.py`` proves
save→restore→step equals the uninterrupted trajectory exactly.

EFConfig construction lives behind the spec (``ef_config``/``make_method``
below, delegating to launch/build.py for the authoritative carrier checks);
no driver builds an EFConfig or mesh by hand anymore.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs import base as cb
from repro.core import compressors as comp_lib
from repro.core import distributed as dist
from repro.core import ef as ef_lib
from repro.data import pipeline as pipe_lib
from repro.launch import build as build_lib
from repro.launch import mesh as mesh_lib
from repro.launch import shardings as sh
from repro.launch import spec as spec_lib
from repro.models import model as model_lib
from repro.optim import optimizer as opt_lib

PyTree = Any


# ---------------------------------------------------------------------------
# spec → objects factories (the only construction path for method/compressor)
# ---------------------------------------------------------------------------

def make_compressor(spec: spec_lib.RunSpec) -> comp_lib.Compressor:
    """Compressor named by the spec. ``ratio`` flows in only when the class
    has a ratio field (HardThreshold takes ``lam``, NaturalCompression takes
    nothing); ``compressor_kw`` overrides any field explicitly."""
    cls = comp_lib.REGISTRY[spec.compressor]
    fields = {f.name for f in dataclasses.fields(cls)}
    kw = dict(spec.compressor_kw)
    if "ratio" in fields and "ratio" not in kw:
        kw["ratio"] = spec.ratio
    unknown = sorted(set(kw) - fields)
    if unknown:
        raise ValueError(f"compressor_kw keys {unknown} are not fields of "
                         f"{cls.__name__}; have {sorted(fields)}")
    return cls(**kw)


def make_down_compressor(spec: spec_lib.RunSpec
                         ) -> Optional[comp_lib.Compressor]:
    """The DOWNLINK compressor named by the spec: None when
    downlink_carrier='dense' (no downlink machinery — the implicit dense
    broadcast), otherwise the uplink compressor class re-budgeted to
    ``downlink_ratio``. ``compressor_kw`` geometry (block sizes, lam, …)
    carries over, but the absolute-budget keys (k / k_per_block / ratio) are
    dropped so downlink_ratio actually drives the broadcast budget instead of
    being silently shadowed by an uplink override. Like the uplink
    ``ratio``, downlink_ratio only applies to ratio-bearing compressor
    classes — hard_threshold / rank1 / block_quant budgets are set by their
    own compressor_kw knobs, which the downlink reuses unchanged."""
    if spec.downlink_carrier == "dense":
        return None
    cls = comp_lib.REGISTRY[spec.compressor]
    fields = {f.name for f in dataclasses.fields(cls)}
    kw = {k: v for k, v in spec.compressor_kw.items()
          if k in fields and k not in ("k", "k_per_block", "ratio")}
    if "ratio" in fields:
        kw["ratio"] = spec.downlink_ratio
    return cls(**kw)


def _group_compressor(entry: Dict[str, Any]) -> comp_lib.Compressor:
    """Compressor for one RESOLVED group entry (spec_lib.resolved_groups):
    same rules as make_compressor — ratio flows in only when the class has a
    ratio field, compressor_kw overrides explicitly, unknown keys fail."""
    cls = comp_lib.REGISTRY[entry["compressor"]]
    fields = {f.name for f in dataclasses.fields(cls)}
    kw = dict(entry["compressor_kw"])
    if "ratio" in fields and "ratio" not in kw:
        kw["ratio"] = entry["ratio"]
    unknown = sorted(set(kw) - fields)
    if unknown:
        raise ValueError(f"group {entry['pattern']!r}: compressor_kw keys "
                         f"{unknown} are not fields of {cls.__name__}; have "
                         f"{sorted(fields)}")
    return cls(**kw)


def _group_down_compressor(entry: Dict[str, Any]
                           ) -> Optional[comp_lib.Compressor]:
    """The group's downlink compressor: None without a downlink carrier,
    otherwise the group's compressor class re-budgeted to the group's
    downlink_ratio (absolute-budget kwargs dropped — the make_down_compressor
    rule, per group)."""
    if entry["downlink_carrier"] == "dense":
        return None
    cls = comp_lib.REGISTRY[entry["compressor"]]
    fields = {f.name for f in dataclasses.fields(cls)}
    kw = {k: v for k, v in entry["compressor_kw"].items()
          if k in fields and k not in ("k", "k_per_block", "ratio")}
    if "ratio" in fields:
        kw["ratio"] = entry["downlink_ratio"]
    return cls(**kw)


def _group_cross_compressor(entry: Dict[str, Any]
                            ) -> Optional[comp_lib.Compressor]:
    """The group's CROSS-POD compressor: None for a dense cross carrier (the
    trivial cross — the pod target ships exactly), otherwise the group's
    compressor class re-budgeted to the group's cross_ratio
    (absolute-budget kwargs dropped — the make_down_compressor rule, per
    group, applied to the pod→server hop)."""
    if entry["cross_carrier"] == "dense":
        return None
    cls = comp_lib.REGISTRY[entry["compressor"]]
    fields = {f.name for f in dataclasses.fields(cls)}
    kw = {k: v for k, v in entry["compressor_kw"].items()
          if k in fields and k not in ("k", "k_per_block", "ratio")}
    if "ratio" in fields:
        kw["ratio"] = entry["cross_ratio"]
    return cls(**kw)


def make_hops(spec: spec_lib.RunSpec):
    """The two-tier topology named by the spec's ``hops`` (DESIGN.md §13),
    or None when absent / pods == 1 (the flat path — bit-identical, zero
    hierarchical machinery). The cross compressor follows the
    make_down_compressor rule: None for a dense cross carrier, otherwise the
    uplink compressor class re-budgeted to ``cross_ratio`` — the cross hop
    is one message per pod, integrated exactly like a broadcast."""
    h = spec_lib.hops_preview(spec)
    if not h["hierarchical"]:
        return None
    from repro.core import hierarchy as hier_lib
    cross_comp = None
    if h["cross_carrier"] != "dense":
        cls = comp_lib.REGISTRY[spec.compressor]
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in spec.compressor_kw.items()
              if k in fields and k not in ("k", "k_per_block", "ratio")}
        if "ratio" in fields:
            kw["ratio"] = h["cross_ratio"]
        cross_comp = cls(**kw)
    return hier_lib.Hops(pods=h["pods"], cross_carrier=h["cross_carrier"],
                         cross_compressor=cross_comp)


def make_schedule(spec: spec_lib.RunSpec):
    """The CompressionSchedule named by the spec's ``groups``, or None when
    the spec has no explicit groups (the legacy single-compressor path — a
    uniform one-group schedule would be bit-identical, but None keeps the
    regression anchor trivially exact and the state trees byte-stable)."""
    if not spec.groups:
        return None
    from repro.core import schedule as sched_lib
    groups = []
    for entry in spec_lib.resolved_groups(spec):
        groups.append(sched_lib.Group(
            pattern=entry["pattern"],
            compressor=_group_compressor(entry),
            carrier=entry["carrier"],
            down_carrier=entry["downlink_carrier"],
            down_compressor=_group_down_compressor(entry),
            state_dtype=entry["ef_state_dtype"],
            cross_carrier=entry["cross_carrier"],
            cross_compressor=_group_cross_compressor(entry)))
    return sched_lib.CompressionSchedule(tuple(groups))


def make_participation(spec: spec_lib.RunSpec):
    """The Participation named by the spec, or None when the spec has no
    explicit participation (the legacy full-cohort path — a mode='full'
    object would be equivalent, but None keeps the legacy runtimes'
    jaxprs byte-stable)."""
    if not spec.participation:
        return None
    from repro.core import participation as part_lib
    p = spec.participation
    return part_lib.Participation(
        mode=p.get("mode", "full"),
        fraction=float(p.get("fraction", 1.0)),
        seed=int(p.get("seed", 0)))


def make_method(spec: spec_lib.RunSpec) -> ef_lib.Method:
    """EF method named by the spec, usable standalone (simulator examples)
    or via ``ef_config`` on the production path."""
    cls = ef_lib.REGISTRY[spec.method]
    fields = {f.name for f in dataclasses.fields(cls)}
    kw: Dict[str, Any] = {
        "compressor": make_compressor(spec),
        "state_dtype": jnp.bfloat16 if spec.ef_state_dtype == "bfloat16"
        else None,
    }
    # every eta-bearing method gets the spec's eta: the spec records η, so a
    # class default must never run in its place (method_kw still overrides)
    if "eta" in fields:
        kw["eta"] = spec.eta
    kw.update(spec.method_kw)
    unknown = sorted(set(kw) - fields)
    if unknown:
        raise ValueError(f"method_kw keys {unknown} are not fields of "
                         f"{cls.__name__}; have {sorted(fields)}")
    return cls(**kw)


def ef_config(spec: spec_lib.RunSpec, mesh, plan: sh.ShardPlan
              ) -> dist.EFConfig:
    """The EFConfig for this spec on a concrete mesh — the authoritative
    carrier plan check (launch/build.py) runs here, after the spec's own
    jax-free preview already failed fast at construction."""
    return build_lib.default_ef_config(
        mesh, plan, method_name=spec.method, compressor_name=spec.compressor,
        ratio=spec.ratio, eta=spec.eta, carrier=spec.carrier,
        method=make_method(spec), down_carrier=spec.downlink_carrier,
        down_compressor=make_down_compressor(spec),
        schedule=make_schedule(spec), overlap=spec.overlap,
        participation=make_participation(spec), hops=make_hops(spec))


def distributed_init(coordinator: str, num_processes: int,
                     process_id: int) -> bool:
    """Facade re-export of launch/multiproc.distributed_init: join the
    multi-process jax.distributed fleet BEFORE constructing a Session (jax
    must not have created backends yet). Idempotent; see launch/multiproc.py
    for the CLI smoke that proves the fabric."""
    from repro.launch import multiproc
    return multiproc.distributed_init(coordinator, num_processes, process_id)


# ---------------------------------------------------------------------------
# session
# ---------------------------------------------------------------------------

class Session:
    """Runtime facade over one RunSpec. Training state (params, opt_state,
    ef_state, the jitted step) is materialized lazily on first use, so
    lower()/serve()-only sessions never pay for it."""

    def __init__(self, spec: spec_lib.RunSpec):
        self.spec = spec
        self.cfg = self._arch_config(spec)
        self.mesh = self._make_mesh(spec.mesh)
        self.plan = sh.ShardPlan(
            client_granularity=spec.client_granularity,
            state_sharding=spec.state_sharding,
            ef_state_dtype=spec.ef_state_dtype)
        self.step = 0                       # the data cursor: pipeline.batch(step)
        self.history: List[Dict[str, float]] = []
        self._tr: Optional[Dict[str, Any]] = None
        self._last_saved_step: Optional[int] = None
        self._serve_cache: Dict[Any, Any] = {}
        # serve-param placement derives from ONE source of truth: the params
        # version counter, bumped by every mutation path (step_once,
        # restore_from, set_serve_params). serve() re-places exactly when the
        # version moved — there is no second cache key to go stale.
        self._params_version = 0
        self._serve_params: Optional[PyTree] = None   # (version, placed tree)
        self._serve_src: Optional[PyTree] = None      # injected serving tree
        self._publisher = None                        # core/stream.py hook
        self._publish_log = None
        self._bootstrap_every = 0

    # ------------------------------------------------------------- assembly
    @staticmethod
    def _arch_config(spec: spec_lib.RunSpec) -> cb.ArchConfig:
        cfg = cb.get_smoke(spec.arch) if spec.smoke else cb.get(spec.arch)
        if spec.tp_pad_heads:
            cfg = dataclasses.replace(cfg, tp_pad_heads=spec.tp_pad_heads)
        if spec.moe_impl != "dispatch":
            cfg = dataclasses.replace(cfg, moe_impl=spec.moe_impl)
        return cfg

    @staticmethod
    def _make_mesh(name: str):
        if name == "smoke":
            return mesh_lib.make_smoke_mesh()
        return mesh_lib.make_production_mesh(multi_pod=(name == "multi_pod"))

    def mesh_context(self):
        """``with sess.mesh_context():`` — the spec's mesh as the ambient
        mesh (re-entrant; lower()/serve()/train() enter it themselves)."""
        return mesh_lib.mesh_context(self.mesh)

    def _ambient(self):
        # the 1-device smoke path keeps jit's default placement (bit-compat
        # with the pre-Session drivers); real meshes set the ambient mesh
        if self.mesh.size > 1:
            return self.mesh_context()
        return contextlib.nullcontext()

    @property
    def n_clients(self) -> int:
        if self.mesh.size == 1:
            return self.spec.clients
        return sh.n_clients(self.mesh, self.plan)

    @property
    def method(self) -> ef_lib.Method:
        return make_method(self.spec)

    def schedule_table(self) -> Optional[str]:
        """The RESOLVED per-group table for this session's arch — leaf and
        param counts per group, each group's transport plan (with its
        degradation reason, if any) and per-message wire words. None when
        the spec runs the uniform single-compressor path. Costs an
        ``eval_shape`` of init_params, never real allocation."""
        sched = make_schedule(self.spec)
        if sched is None:
            return None
        from repro.core import schedule as sched_lib
        shapes = jax.eval_shape(
            lambda: model_lib.init_params(self.cfg, jax.random.PRNGKey(0)))
        return sched_lib.plan_table(sched, make_method(self.spec), shapes,
                                    eta=self.spec.eta)

    # ------------------------------------------------------- training state
    def _ensure_train(self, template: bool = False) -> Dict[str, Any]:
        """Build the training bundle. With ``template=True`` the state trees
        (params/opt_state/ef_state) are ShapeDtypeStructs from
        ``jax.eval_shape`` — structure and dtypes without paying for
        init_params or the batch-0 gradient evaluation; ``restore_from``
        uses this as the checkpoint template and overwrites every leaf."""
        if self._tr is not None:
            return self._tr
        spec, cfg, mesh, plan = self.spec, self.cfg, self.mesh, self.plan
        n = self.n_clients
        efc = ef_config(spec, mesh, plan)
        opt = opt_lib.make(spec.optimizer, lr=spec.lr)
        pipe = pipe_lib.SyntheticTokens(pipe_lib.DataConfig(
            vocab_size=cfg.vocab_size, seq_len=spec.seq_len,
            global_batch=spec.global_batch, seed=spec.seed, dp_groups=n,
            heterogeneity=spec.heterogeneity))

        def loss_fn(p, b):
            return model_lib.train_loss(cfg, p, b)

        if mesh.size > 1:
            grads_specs = sh._spec_map(
                lambda s: sh.P(sh.client_axis(mesh, plan), *s),
                sh.params_pspecs(cfg, mesh))
            state_specs = sh.ef_state_pspecs(cfg, mesh, plan, efc.method,
                                             downlink=efc.has_downlink,
                                             schedule=efc.schedule,
                                             hops=efc.hops)
            step_fn = jax.jit(dist.make_train_step(
                loss_fn, efc, opt, n, mesh=mesh, grads_specs=grads_specs,
                state_specs=state_specs))
        else:
            step_fn = jax.jit(dist.make_train_step(loss_fn, efc, opt, n))

        rng = jax.random.PRNGKey(spec.seed)

        def init_state(b0):
            params = model_lib.init_params(cfg, rng)
            # Alg 1 line 2: v⁰ᵢ = g⁰ᵢ = (1/B_init)Σⱼ ∇fᵢ(x⁰, ξ⁰ᵢⱼ)
            _, _, g0 = dist.per_client_value_and_grad(loss_fn, params, b0, n)
            ef_state = dist.init_ef_state(efc, params, n, init_grads=g0)
            return {"params": params, "opt_state": opt.init(params),
                    "ef_state": ef_state}

        b0 = pipe_lib.with_prefix_embeds(cfg, pipe.batch(0))
        with self._ambient():
            state = jax.eval_shape(init_state, b0) if template \
                else init_state(b0)
        self._tr = {
            "efc": efc, "opt": opt, "pipe": pipe, "loss_fn": loss_fn,
            "step_fn": step_fn, "rng": rng, **state,
        }
        return self._tr

    @property
    def params(self) -> PyTree:
        return self._ensure_train()["params"]

    @property
    def opt_state(self) -> PyTree:
        return self._ensure_train()["opt_state"]

    @property
    def ef_state(self) -> PyTree:
        return self._ensure_train()["ef_state"]

    @property
    def step_fn(self):
        """The jitted production train step
        ``(params, opt_state, ef_state, batch, rng, step) → (…, metrics)`` —
        benchmarks time this directly against a fixed batch."""
        return self._ensure_train()["step_fn"]

    def batch_for(self, step: int) -> PyTree:
        """The (frontend-padded) global batch the pipeline yields for
        ``step`` — deterministic in (seed, step), restart-safe."""
        tr = self._ensure_train()
        return pipe_lib.with_prefix_embeds(self.cfg, tr["pipe"].batch(step))

    # -------------------------------------------------------------- training
    def step_once(self) -> Dict[str, jax.Array]:
        """Advance exactly one training step; returns the step metrics.
        The unit benchmarks time (benchmarks/kernel_bench.py)."""
        tr = self._ensure_train()
        h_prev = tr["ef_state"].get("h") if self._publisher is not None \
            else None
        with self._ambient():
            batch = self.batch_for(self.step)
            (tr["params"], tr["opt_state"], tr["ef_state"], m) = tr["step_fn"](
                tr["params"], tr["opt_state"], tr["ef_state"], batch,
                jax.random.fold_in(tr["rng"], self.step), self.step)
        self.step += 1
        self._params_version += 1
        if self._publisher is not None:
            # publish this round's downlink wire (verified bit-exact against
            # the step's own h before anything hits the log)
            self._publisher.publish(
                self.step, tr["ef_state"]["server"], h_prev,
                tr["ef_state"].get("h"))
            if self._bootstrap_every \
                    and self.step % self._bootstrap_every == 0:
                self._write_bootstrap()
        return m

    def train(self, steps: int, log_every: int = 10, verbose: bool = False
              ) -> List[Dict[str, float]]:
        """Train until the global step counter reaches ``steps`` (absolute —
        a resumed session continues where the checkpoint left off). Appends
        to ``self.history`` and returns the new entries. Saves a full-state
        checkpoint every ``spec.ckpt_every`` steps and at the end whenever
        ``spec.ckpt_dir`` is set."""
        spec = self.spec
        self._ensure_train()
        new: List[Dict[str, float]] = []
        t0, start = time.time(), self.step
        while self.step < steps:
            m = self.step_once()
            step = self.step - 1
            if (log_every and step % log_every == 0) or step == steps - 1:
                rec = {"step": step, "loss": float(m["loss"]),
                       "g_norm": float(m["g_norm"])}
                self.history.append(rec)
                new.append(rec)
                if verbose:
                    print(f"step {step:5d} loss {rec['loss']:8.4f} "
                          f"g_norm {rec['g_norm']:.3e} "
                          f"({(time.time()-t0)/max(step-start+1,1):.2f}s/step)",
                          flush=True)
            if (spec.ckpt_dir and spec.ckpt_every
                    and self.step % spec.ckpt_every == 0):
                self.save()
        # end-of-train save, unless the periodic save just wrote this step
        if spec.ckpt_dir and self._last_saved_step != self.step:
            self.save()
        return new

    def evaluate(self, batches: int = 2) -> float:
        """Mean loss over ``batches`` held-out batches (the synthetic stream
        at seed+1 — disjoint from every training batch) at current params."""
        tr = self._ensure_train()
        cfg, spec = self.cfg, self.spec
        eval_pipe = pipe_lib.SyntheticTokens(pipe_lib.DataConfig(
            vocab_size=cfg.vocab_size, seq_len=spec.seq_len,
            global_batch=spec.global_batch, seed=spec.seed + 1,
            dp_groups=self.n_clients, heterogeneity=spec.heterogeneity))
        if "eval_fn" not in tr:             # jit once, not per evaluate() call
            tr["eval_fn"] = jax.jit(lambda p, b: tr["loss_fn"](p, b)[0])
        loss_j = tr["eval_fn"]
        with self._ambient():
            losses = [float(loss_j(
                tr["params"], pipe_lib.with_prefix_embeds(
                    cfg, eval_pipe.batch(i)))) for i in range(batches)]
        return sum(losses) / max(len(losses), 1)

    # --------------------------------------------------------------- serving
    def serve_source(self) -> PyTree:
        """THE parameter tree serve() places — in priority order: the
        injected serving tree (the wire-subscriber path, launch/fleet.py),
        else the live training tree, else a fresh init. Every path that
        mutates the answer bumps ``_params_version``, which is the only
        cache key serve() consults."""
        if self._serve_src is not None:
            return self._serve_src
        if self._tr is not None:
            return self._tr["params"]
        return model_lib.init_params(self.cfg, jax.random.PRNGKey(
            self.spec.seed))

    def set_serve_params(self, params: PyTree) -> None:
        """Inject the tree serve() must use from now on (wire subscribers
        push their post-apply params here between request batches)."""
        self._serve_src = params
        self._params_version += 1

    def serve(self, tokens=None, batch: int = 4, prompt_len: int = 128,
              decode_steps: int = 32, prompt_lens=None,
              decode_hook=None) -> Dict[str, Any]:
        """Batched prefill + greedy decode THROUGH launch/build.py on the
        session mesh: inputs/params/cache are placed onto the
        ``build_prefill``/``build_decode`` shardings (trivial on the 1-device
        smoke mesh, real placement on pod meshes) instead of jitting
        unsharded lambdas. Returns token ids + timings.

        ``prompt_lens`` (per-row true lengths, ≤ S) makes prefill read each
        row's logits at its LAST REAL token instead of the padded tail, so
        right-padding never contaminates the first generated token (padding
        with id 0 is indistinguishable from a real vocab-0 token otherwise).
        ``decode_hook(i)`` is called between decode steps — the continuous
        wire-sync point (launch/fleet.py): if the hook moves the params
        version (set_serve_params), the remaining steps decode with the
        fresh tree."""
        cfg, mesh, spec = self.cfg, self.mesh, self.spec
        rng = jax.random.PRNGKey(spec.seed)
        if tokens is None:
            tokens = jax.random.randint(rng, (batch, prompt_len), 0,
                                        cfg.vocab_size)
        B, S = tokens.shape
        # serving uses the PRODUCTION padding (PREFIX_PAD_SPEC) so the
        # arrays run at exactly the shapes build_prefill/build_decode
        # lowered and dryrun validated — the deduped padding rule must not
        # diverge between the specs and the arrays inside one call
        pad = pipe_lib.PREFIX_PAD_SPEC
        n_prefix = pipe_lib.prefix_token_count(cfg, pad_to=pad)

        # the jitted pair + sharding specs are cached per serving geometry:
        # a Session used as a serving loop must not recompile per request
        key = (B, S, decode_steps)
        if key not in self._serve_cache:
            shape = cb.InputShape("serve", S, B, "prefill")
            fn_pre, (p_spec, b_spec, c_spec) = build_lib.build_prefill(
                cfg, shape, mesh, decode_budget=decode_steps)
            fn_dec, (_, _, t_spec, _) = build_lib.build_decode(
                cfg, dataclasses.replace(shape, kind="decode"), mesh,
                decode_budget=decode_steps)
            self._serve_cache[key] = (jax.jit(fn_pre), jax.jit(fn_dec),
                                      p_spec, b_spec, c_spec, t_spec)
        prefill, decode, p_spec, b_spec, c_spec, t_spec = \
            self._serve_cache[key]
        shard_of = lambda tree: jax.tree_util.tree_map(
            lambda s: s.sharding, tree)

        with mesh_lib.mesh_context(mesh):
            # placed params are cached on the params VERSION — the single
            # source of truth every mutation path bumps (step_once,
            # restore_from, set_serve_params) — so a serving loop never
            # re-places an unchanged tree and never serves a stale one
            # (restoring a checkpoint at the same step counter used to slip
            # past the old step-keyed cache)
            if self._serve_params is None \
                    or self._serve_params[0] != self._params_version:
                self._serve_params = (
                    self._params_version,
                    jax.device_put(self.serve_source(), shard_of(p_spec)))
            params = self._serve_params[1]
            raw = pipe_lib.with_prefix_embeds(cfg, {"tokens": tokens},
                                              pad_to=pad)
            batch_in = dict(jax.device_put(raw, shard_of(b_spec)))
            if prompt_lens is not None:
                # not part of b_spec (the lowered sharding tree) — a small
                # replicated int32 vector placed with default sharding; the
                # jitted prefill retraces once for the extra pytree key
                batch_in["prompt_lens"] = jax.device_put(
                    jnp.asarray(prompt_lens, jnp.int32))
            cache = jax.device_put(
                model_lib.init_cache(cfg, B, n_prefix + S + decode_steps),
                shard_of(c_spec))

            t0 = time.time()
            logits, cache = prefill(params, batch_in, cache)
            logits.block_until_ready()
            t_prefill = time.time() - t0

            tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
            tok = jax.device_put(tok, t_spec.sharding)
            out_tokens = [tok]
            t0 = time.time()
            for i in range(decode_steps):
                if decode_hook is not None:
                    # continuous sync: the hook may apply fresh wire records
                    # (bumping the params version) between decode steps
                    decode_hook(i)
                    if self._serve_params[0] != self._params_version:
                        self._serve_params = (
                            self._params_version,
                            jax.device_put(self.serve_source(),
                                           shard_of(p_spec)))
                        params = self._serve_params[1]
                pos = jnp.asarray(n_prefix + S + i, jnp.int32)
                logits, cache = decode(params, cache, tok, pos)
                tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
                out_tokens.append(tok)
            jax.block_until_ready(tok)
            t_decode = time.time() - t0

        gen = jnp.concatenate(out_tokens, axis=1)
        cache_bytes = sum(x.size * x.dtype.itemsize
                          for x in jax.tree_util.tree_leaves(cache))
        return {
            "tokens": jax.device_get(gen),
            "prefill_s": t_prefill, "decode_s": t_decode,
            "prefill_tok_s": B * S / max(t_prefill, 1e-9),
            "decode_tok_s": decode_steps * B / max(t_decode, 1e-9),
            "cache_bytes": cache_bytes,
        }

    # --------------------------------------------------------------- dry-run
    def lower(self, shape_name: Optional[str] = None):
        """The dry-run artifact: ``jax.jit(step).lower(*input_specs)`` for the
        named InputShape (default: ``spec.shape``; None → the spec's custom
        train geometry) on the session mesh. ``.compile()`` the result under
        ``self.mesh_context()`` for memory/HLO analysis (launch/dryrun.py)."""
        name = shape_name if shape_name is not None else self.spec.shape
        if name is not None:
            shape = cb.INPUT_SHAPES[name]
        else:
            shape = cb.InputShape("train_custom", self.spec.seq_len,
                                  self.spec.global_batch, "train")
        with self.mesh_context():
            if shape.kind == "train":
                efc = ef_config(self.spec, self.mesh, self.plan)
                fn, specs = build_lib.build_step(
                    self.cfg, shape, self.mesh, self.plan, efc,
                    optimizer_name=self.spec.optimizer, lr=self.spec.lr)
            else:
                fn, specs = build_lib.build_step(
                    self.cfg, shape, self.mesh, self.plan)
            return jax.jit(fn).lower(*specs)

    # ---------------------------------------------------------- checkpointing
    def save(self, path: Optional[str] = None) -> str:
        """Write the FULL training state — params, opt_state, ef_state, the
        data cursor, and the spec itself — so resume needs nothing else.
        The ``step`` in meta IS the data cursor: the pipeline is
        stateless-addressable (``pipe.batch(step)``), so restoring step
        resumes the exact data stream."""
        tr = self._ensure_train()
        if path is None:
            assert self.spec.ckpt_dir, "no ckpt_dir in spec and no path given"
            path = os.path.join(self.spec.ckpt_dir,
                                f"step_{self.step:08d}.npz")
        state = {"params": tr["params"], "opt_state": tr["opt_state"],
                 "ef_state": tr["ef_state"]}
        ckpt_lib.save(path, state, step=self.step, spec=self.spec)
        self._last_saved_step = self.step
        return path

    def restore_from(self, path: str, allow_spec_mismatch: bool = False
                     ) -> None:
        """Restore full state from ``path`` into this session. Refuses a
        checkpoint written by a different RunSpec (hash recorded by save)
        unless ``allow_spec_mismatch``."""
        meta = ckpt_lib.read_meta(path)
        stored = meta.get("spec_hash")
        if stored is not None and stored != self.spec.spec_hash() \
                and not allow_spec_mismatch:
            diff = ""
            if "spec" in meta:
                other = spec_lib.RunSpec.from_dict(meta["spec"])
                diff = "\n  - " + "\n  - ".join(self.spec.diff(other))
            raise ValueError(
                f"checkpoint {path} was written by a different RunSpec "
                f"(hash {stored} != {self.spec.spec_hash()}); refusing to "
                f"resume across experiment definitions.{diff}\n"
                "Pass allow_spec_mismatch=True / --allow-spec-mismatch to "
                "override.")
        # template=True: the like-tree only needs structure/shapes/dtypes —
        # never pay init_params + a full batch-0 gradient pass just to
        # overwrite every leaf from the checkpoint
        created = self._tr is None
        tr = self._ensure_train(template=True)
        like = {"params": tr["params"], "opt_state": tr["opt_state"],
                "ef_state": tr["ef_state"]}
        try:
            state, meta = ckpt_lib.restore(path, like)
        except BaseException:
            if created:
                # never leave abstract template leaves behind a failed
                # restore — the session must stay usable (fresh init)
                self._tr = None
            raise
        tr["params"] = state["params"]
        tr["opt_state"] = state["opt_state"]
        tr["ef_state"] = state["ef_state"]
        self.step = int(meta["step"])
        # restored params are a new serving truth even when the step counter
        # did not move — the version counter is what serve() keys on, and an
        # injected serve tree (set_serve_params) is superseded by the restore
        self._serve_src = None
        self._params_version += 1

    # -------------------------------------------------------- wire streaming
    def publish_to(self, stream_dir: str, bootstrap_every: int = 0):
        """Attach a core/stream.py Publisher: every subsequent step_once
        appends this round's downlink wire records to ``stream_dir`` (one
        per transport leg, verified bit-exact against the step's own h).
        Writes a full-state bootstrap checkpoint into the stream whenever
        the log has no records at or past the current step, so a replica can
        join from the stream directory alone (checkpoint + replay);
        ``bootstrap_every`` adds periodic re-bootstraps for cheaper
        mid-stream joins and gap resyncs. Returns the WireLog."""
        from repro.core import stream as stream_lib
        tr = self._ensure_train()
        efc = tr["efc"]
        legs = stream_lib.resolve_legs(
            tr["params"], schedule=efc.schedule,
            down_carrier=efc.down_carrier,
            down_compressor=efc.down_compressor)
        log = stream_lib.WireLog(stream_dir)
        self._publish_log = log
        self._bootstrap_every = int(bootstrap_every)
        last = log.last_step()
        if last is None or last < self.step:
            # nothing in the log can reach this trainer's state by replay:
            # anchor the stream here so subscribers have a join point
            self._write_bootstrap()
        self._publisher = stream_lib.Publisher(
            log, self.spec.spec_hash(), legs, tr["rng"])
        return log

    def _write_bootstrap(self) -> str:
        """One full-state checkpoint INSIDE the stream directory — what
        replicas join from and resync to (spec embedded, foreign-spec
        refusal included, exactly like ckpt_dir checkpoints)."""
        tr = self._ensure_train()
        path = self._publish_log.bootstrap_path(self.step)
        if not os.path.exists(path):
            state = {"params": tr["params"], "opt_state": tr["opt_state"],
                     "ef_state": tr["ef_state"]}
            ckpt_lib.save(path, state, step=self.step, spec=self.spec)
        return path

    @classmethod
    def resume(cls, ckpt_dir: str, spec: Optional[spec_lib.RunSpec] = None,
               overrides: Optional[Dict[str, Any]] = None,
               allow_spec_mismatch: bool = False) -> "Session":
        """Reconstruct a run from its latest checkpoint WITHOUT re-passing
        flags: the RunSpec embedded in checkpoint meta is the source of
        truth. ``overrides`` layers individual field changes ON TOP of the
        embedded spec (the driver maps explicitly passed flags here, so
        '--resume --eta 0.2' means 'the same run, new eta' — never 'defaults
        plus eta'); experiment-defining overrides still require
        ``allow_spec_mismatch``. Pass ``spec`` to insist on an exact spec
        instead — it must hash-match the checkpoint unless overridden."""
        path = ckpt_lib.latest(ckpt_dir)
        if path is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
        meta = ckpt_lib.read_meta(path)
        if spec is None:
            if "spec" not in meta:
                raise ValueError(
                    f"checkpoint {path} has no embedded RunSpec (pre-Session "
                    "format); pass spec= explicitly")
            embedded = spec_lib.RunSpec.from_dict(meta["spec"])
            spec = dataclasses.replace(embedded, ckpt_dir=ckpt_dir,
                                       **(overrides or {}))
            if spec.spec_hash() == embedded.spec_hash():
                allow_spec_mismatch = True  # no experiment-defining change
        elif overrides:
            raise ValueError("pass either spec= or overrides=, not both")
        sess = cls(spec)
        sess.restore_from(path, allow_spec_mismatch=allow_spec_mismatch)
        return sess
