"""One serving replica per PROCESS (DESIGN.md §12).

``python -m repro.launch.replica_worker --stream ADDR --name r1 --lag 0``
runs a single ``ServeReplica`` that joins the wire stream over a transport
tail (``launch/transport.py``: a shared directory or ``tcp://host:port``)
and then speaks a line protocol with its parent over stdin/stdout:

  parent → worker (stdin, one JSON object per line):
    {"cmd": "sync",   "id": n, "upto": step?}
    {"cmd": "serve",  "id": n, "requests": [{"rid", "tokens",
                      "max_new_tokens"}], "decode_steps": D,
                      "prompt_len": P?, "sync_during_decode": bool?}
    {"cmd": "digest", "id": n}            # sha256 over the served params
    {"cmd": "stop",   "id": n}

  worker → parent (stdout, lines prefixed ``@@rw `` so stray library prints
  never corrupt the channel):
    {"type": "ready", "name", "step", "pid"}          once, after join
    {"type": "hb", "name", "step", "t"}               heartbeat thread
    {"type": "reply", "id", "ok", ...}                one per command

The serve command runs CONTINUOUS sync: between decode steps the replica
polls the tail and applies any fresh records through the exact train-step
tail (``Session.serve``'s decode hook), so a long decode never pins the
whole batch to the params it started with — the reply reports how many
steps were applied mid-decode. A killed worker rejoins via checkpoint +
replay and lands bit-identical to the trainer (the PR 8 anchor invariant
across a process boundary — ``params_digest`` is how the parent checks it
without shipping a weight tree).

``WorkerHandle`` is the parent side: spawn, speak the protocol, track
heartbeats, kill/restart. ``launch/fleet.py::ProcessFleet`` drives a set of
handles as one serving fleet.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

MAGIC = "@@rw "


def params_digest(tree) -> str:
    """sha256 over every leaf's dtype/shape/bytes in tree order — equal
    digests ⟺ bit-identical trees (the cross-process identity check)."""
    import jax
    import numpy as np

    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(jax.device_get(leaf))
        h.update(f"{arr.dtype.str}{arr.shape}".encode())
        h.update(arr.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# worker side
# ---------------------------------------------------------------------------

def _emit(obj: Dict[str, Any]) -> None:
    sys.stdout.write(MAGIC + json.dumps(obj) + "\n")
    sys.stdout.flush()


def _heartbeat_loop(rep, interval: float, stop: threading.Event) -> None:
    while not stop.wait(interval):
        _emit({"type": "hb", "name": rep.name, "step": rep.step,
               "t": time.time()})


def _handle(rep, cmd: Dict[str, Any], default_prompt_len: int
            ) -> Dict[str, Any]:
    import numpy as np

    from repro.launch import fleet as fleet_lib

    op = cmd.get("cmd")
    if op == "sync":
        applied = rep.sync(upto=cmd.get("upto"))
        return {"ok": True, "step": rep.step, "applied": applied,
                "head": rep.tail.last_step()}
    if op == "digest":
        return {"ok": True, "step": rep.step,
                "digest": params_digest(rep.params)}
    if op == "serve":
        reqs = [fleet_lib.Request(
                    rid=int(r["rid"]),
                    tokens=np.asarray(r["tokens"], dtype=np.int64),
                    max_new_tokens=int(r.get("max_new_tokens", 16)))
                for r in cmd["requests"]]
        decode_steps = int(cmd["decode_steps"])
        out = rep.serve_batch(
            reqs, int(cmd.get("prompt_len", default_prompt_len)),
            decode_steps,
            sync_during_decode=bool(cmd.get("sync_during_decode", True)))
        for req, row in zip(reqs, out["tokens"]):
            fleet_lib.finalize_request(req, row)
        head = rep.tail.last_step()
        return {"ok": True, "step": rep.step, "head": head,
                "mid_applied": out.get("mid_applied", 0),
                "rids": [r.rid for r in reqs],
                "tokens": [r.tokens_out.tolist() for r in reqs],
                "tokens_generated": [r.tokens_generated for r in reqs]}
    return {"ok": False, "error": f"unknown cmd {op!r}"}


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser("repro.launch.replica_worker")
    ap.add_argument("--stream", required=True,
                    help="stream directory or tcp://host:port")
    ap.add_argument("--name", default="w0")
    ap.add_argument("--lag", type=int, default=0)
    ap.add_argument("--bootstrap-step", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--heartbeat", type=float, default=0.25,
                    help="heartbeat interval in seconds (0 = off)")
    args = ap.parse_args(argv)

    from repro.launch import fleet as fleet_lib  # defer the jax-heavy import

    rep = fleet_lib.ServeReplica(args.stream, name=args.name, lag=args.lag,
                                 bootstrap_step=args.bootstrap_step)
    _emit({"type": "ready", "name": rep.name, "step": rep.step,
           "pid": os.getpid()})
    stop_hb = threading.Event()
    if args.heartbeat > 0:
        threading.Thread(target=_heartbeat_loop,
                         args=(rep, args.heartbeat, stop_hb),
                         daemon=True).start()
    try:
        for line in sys.stdin:
            line = line.strip()
            if not line:
                continue
            cmd = json.loads(line)
            if cmd.get("cmd") == "stop":
                _emit({"type": "reply", "id": cmd.get("id"), "ok": True})
                break
            try:
                reply = _handle(rep, cmd, args.prompt_len)
            except Exception as e:                 # noqa: BLE001 — protocol edge
                reply = {"ok": False, "error": repr(e)}
            _emit({"type": "reply", "id": cmd.get("id"), **reply})
    finally:
        stop_hb.set()


# ---------------------------------------------------------------------------
# parent side
# ---------------------------------------------------------------------------

class WorkerDied(RuntimeError):
    """The worker process exited (or never came up) — the fleet layer
    restarts it and replays any in-flight batch."""


class WorkerHandle:
    """Parent-side handle on one replica worker process: spawn, speak the
    line protocol, track heartbeats, kill, restart. ``call`` is the blocking
    request/reply path; ``submit``/``take_reply`` the async pair
    ``ProcessFleet.run`` multiplexes over."""

    def __init__(self, stream: str, name: str = "w0", lag: int = 0,
                 bootstrap_step: Optional[int] = None, prompt_len: int = 32,
                 heartbeat_s: float = 0.25, start_timeout_s: float = 300.0,
                 spawn: bool = True):
        self.stream = str(stream)
        self.name = name
        self.lag = int(lag)
        self.bootstrap_step = bootstrap_step
        self.prompt_len = int(prompt_len)
        self.heartbeat_s = float(heartbeat_s)
        self.start_timeout_s = float(start_timeout_s)
        self.restarts = 0
        self.proc: Optional[subprocess.Popen] = None
        if spawn:
            self.spawn()

    # ------------------------------------------------------------- lifecycle
    def _argv(self) -> List[str]:
        argv = [sys.executable, "-m", "repro.launch.replica_worker",
                "--stream", self.stream, "--name", self.name,
                "--lag", str(self.lag), "--prompt-len", str(self.prompt_len),
                "--heartbeat", str(self.heartbeat_s)]
        if self.bootstrap_step is not None:
            argv += ["--bootstrap-step", str(self.bootstrap_step)]
        return argv

    def _env(self) -> Dict[str, str]:
        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        pp = env.get("PYTHONPATH", "")
        if src not in pp.split(os.pathsep):
            env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
        return env

    def spawn(self) -> None:
        assert self.proc is None or self.proc.poll() is not None, \
            f"worker {self.name!r} is already running"
        self._ready = threading.Event()
        self._replies: deque = deque()
        self._reply_cv = threading.Condition()
        self._stderr_tail: deque = deque(maxlen=50)
        self.last_hb: float = time.time()
        self.step: Optional[int] = None
        self._next_id = 0
        self.proc = subprocess.Popen(
            self._argv(), stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True, bufsize=1, env=self._env())
        threading.Thread(target=self._read_stdout, daemon=True).start()
        threading.Thread(target=self._read_stderr, daemon=True).start()

    def _read_stdout(self) -> None:
        proc = self.proc
        for line in proc.stdout:
            if not line.startswith(MAGIC):
                continue                     # stray library print — ignored
            try:
                msg = json.loads(line[len(MAGIC):])
            except json.JSONDecodeError:
                continue
            t = msg.get("type")
            if t == "ready":
                self.step = msg.get("step")
                self.last_hb = time.time()
                self._ready.set()
            elif t == "hb":
                self.last_hb = time.time()
                self.step = msg.get("step", self.step)
            elif t == "reply":
                with self._reply_cv:
                    self._replies.append(msg)
                    self._reply_cv.notify_all()

    def _read_stderr(self) -> None:
        proc = self.proc
        for line in proc.stderr:
            self._stderr_tail.append(line.rstrip())

    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    def wait_ready(self, timeout: Optional[float] = None) -> None:
        timeout = self.start_timeout_s if timeout is None else timeout
        deadline = time.time() + timeout
        while not self._ready.wait(timeout=0.2):
            if not self.alive():
                raise WorkerDied(
                    f"worker {self.name!r} exited during startup "
                    f"(rc={self.proc.returncode}); stderr tail:\n  "
                    + "\n  ".join(self._stderr_tail))
            if time.time() > deadline:
                self.kill()
                raise WorkerDied(
                    f"worker {self.name!r} did not come up within "
                    f"{timeout:.0f}s")

    def kill(self) -> None:
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait(timeout=10)

    def stop(self, timeout: float = 10.0) -> None:
        """Graceful stop, falling back to kill."""
        if not self.alive():
            return
        try:
            self.submit({"cmd": "stop"})
            self.proc.wait(timeout=timeout)
        except (OSError, subprocess.TimeoutExpired, WorkerDied):
            self.kill()

    def restart(self) -> None:
        """Kill (if needed) and respawn: the fresh process rejoins the
        stream via checkpoint + replay — bit-identical by the §12 anchor
        invariant, which tests/test_replica_worker.py proves by digest."""
        self.kill()
        self.restarts += 1
        self.spawn()
        self.wait_ready()

    # --------------------------------------------------------------- protocol
    def submit(self, cmd: Dict[str, Any]) -> int:
        if not self.alive():
            raise WorkerDied(f"worker {self.name!r} is not running")
        self._next_id += 1
        cmd = {**cmd, "id": self._next_id}
        try:
            self.proc.stdin.write(json.dumps(cmd) + "\n")
            self.proc.stdin.flush()
        except (OSError, ValueError) as e:
            raise WorkerDied(f"worker {self.name!r} pipe closed: {e}") from e
        return self._next_id

    def take_reply(self, timeout: float = 0.0) -> Optional[Dict[str, Any]]:
        """Pop one reply if available within ``timeout`` (0 = poll)."""
        with self._reply_cv:
            if not self._replies and timeout > 0:
                self._reply_cv.wait(timeout=timeout)
            return self._replies.popleft() if self._replies else None

    def call(self, cmd: Dict[str, Any], timeout: float = 600.0
             ) -> Dict[str, Any]:
        """Blocking request/reply; raises WorkerDied if the process exits
        first and RuntimeError on an ok=False reply."""
        mid = self.submit(cmd)
        deadline = time.time() + timeout
        while True:
            msg = self.take_reply(timeout=0.2)
            if msg is not None and msg.get("id") == mid:
                if not msg.get("ok"):
                    raise RuntimeError(
                        f"worker {self.name!r} {cmd.get('cmd')!r} failed: "
                        f"{msg.get('error')}")
                return msg
            if msg is None and not self.alive():
                raise WorkerDied(
                    f"worker {self.name!r} died awaiting "
                    f"{cmd.get('cmd')!r} (rc={self.proc.returncode}); "
                    "stderr tail:\n  " + "\n  ".join(self._stderr_tail))
            if time.time() > deadline:
                raise TimeoutError(
                    f"worker {self.name!r} {cmd.get('cmd')!r} timed out "
                    f"after {timeout:.0f}s")

    def hb_age(self) -> float:
        return time.time() - self.last_hb


if __name__ == "__main__":
    main()
