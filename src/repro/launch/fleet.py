"""Serving fleet fed by the downlink wire (DESIGN.md §12).

A ``ServeReplica`` is a serving ``Session`` whose parameters are kept
bit-identical to the trainer's by SUBSCRIBING to the wire stream a training
session publishes (``Session.publish_to`` → core/stream.py): it joins from
the stream's bootstrap checkpoint, replays every record (checkpoint +
replay), and between request batches applies new records through the exact
train-step tail — never a dense f32 weight push. A ``Fleet`` runs several
replicas against ONE stream at different lags behind the trainer head,
dispatching a request queue through a decode-budget scheduler:

    sess = Session(spec); sess.publish_to("/tmp/wire"); sess.train(100)
    fleet = Fleet("/tmp/wire", n_replicas=2, lags=(0, 4))
    results = fleet.run(synthetic_requests(32, rate=8.0))

Scheduling: requests are admitted FIFO into one serving batch while
``B × decode_steps ≤ decode_budget`` (decode steps bucketed to powers of two
so the jitted serve geometries stay bounded — ``Session.serve`` caches its
compiled prefill/decode per (B, S, D)). The per-arch serving carve-outs of
DESIGN.md §5 (sliding-window caches, prefix-embed frontends) are enforced by
``build_prefill``/``build_decode`` underneath ``Session.serve``; the
scheduler's job is only to keep every serving step inside the decode budget
those builds were sized for.

Staleness: a replica at lag L serves the trainer's step-(head−L) model —
exact, never drifted (gaps resync via a later bootstrap, or fail loudly).
This is SERVING staleness, distinct from the async TRAINING staleness cap of
DESIGN.md §11 — see §12 for the contrast.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import stream as stream_lib
from repro.launch import session as session_lib
from repro.launch import spec as spec_lib
from repro.launch import transport as transport_lib
from repro.models import model as model_lib
from repro.optim import optimizer as opt_lib

PyTree = Any


# ---------------------------------------------------------------------------
# requests + decode-budget scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One serving request. ``arrival_s`` is relative to the run's t0; the
    completion fields are filled by ``Fleet.run``."""

    rid: int
    tokens: np.ndarray                  # 1-D prompt token ids
    max_new_tokens: int = 16
    arrival_s: float = 0.0
    # filled on completion
    t_done: float = 0.0
    latency_s: float = 0.0
    replica: str = ""
    staleness: int = 0
    tokens_out: Optional[np.ndarray] = None
    tokens_generated: int = 0           # may be < max_new_tokens (capped)


def finalize_request(req: Request, row) -> None:
    """Fill a request's generated tokens from one served row: at most
    ``max_new_tokens`` tokens, and ``tokens_generated`` records how many the
    decode budget actually allowed — an oversized lone request admitted with
    capped decode completes SHORT, and the shortfall must be visible on the
    request (and in ``run()``'s summary), never silently swallowed."""
    avail = np.asarray(row)
    take = min(req.max_new_tokens, int(avail.size))
    req.tokens_out = avail[:take]
    req.tokens_generated = take


def _bucket(n: int) -> int:
    """Next power of two ≥ n — decode geometries are bucketed so the jitted
    serve cache stays small (log2 many entries, not one per request mix)."""
    return 1 << max(0, int(n - 1).bit_length())


@dataclasses.dataclass
class DecodeBudgetScheduler:
    """FIFO batcher under a decode budget: admit the longest queue prefix
    whose batched decode cost ``B × D`` stays within ``decode_budget``,
    where D is the power-of-two bucket of the batch's largest
    ``max_new_tokens``. An oversized lone request is still admitted alone
    with its decode capped at the budget (starving it forever would turn a
    budget into a deadlock)."""

    decode_budget: int = 64
    max_batch: int = 4

    def admit(self, queue: Deque[Request]) -> Tuple[List[Request], int]:
        """Pop and return ``(batch, decode_steps)``; empty queue → ([], 0)."""
        if not queue:
            return [], 0
        batch: List[Request] = []
        d = 1
        for req in list(queue):
            cand_d = max(d, _bucket(max(req.max_new_tokens, 1)))
            if batch and (len(batch) + 1 > self.max_batch
                          or (len(batch) + 1) * cand_d > self.decode_budget):
                break
            batch.append(req)
            d = cand_d
            if len(batch) * d >= self.decode_budget:
                break
        for _ in batch:
            queue.popleft()
        return batch, min(d, max(self.decode_budget, 1))


def synthetic_requests(n: int, rate: float = 0.0, prompt_len: int = 32,
                       max_new_tokens: int = 8, vocab_size: int = 256,
                       seed: int = 0) -> List[Request]:
    """A deterministic load: ``n`` requests with exponential inter-arrivals
    at ``rate`` req/s (rate ≤ 0 → everything arrives at t=0)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab_size, size=(n, prompt_len), dtype=np.int64)
    if rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    else:
        arrivals = np.zeros(n)
    return [Request(rid=i, tokens=toks[i], arrival_s=float(arrivals[i]),
                    max_new_tokens=max_new_tokens) for i in range(n)]


# ---------------------------------------------------------------------------
# one replica
# ---------------------------------------------------------------------------

class ServeReplica:
    """subscribe → apply → serve → resync (DESIGN.md §12). Joins from the
    stream's bootstrap checkpoint (never loading per-client EF state — a
    replica restores only params + opt_state + h), replays the record log,
    and serves through ``Session.serve`` with the subscriber's params
    injected as the serve source. On a gap it resyncs from the newest
    bootstrap past the gap and replays; with no such bootstrap it raises —
    the replica keeps serving its last CONSISTENT model (stale is honest,
    drift is not)."""

    def __init__(self, stream, name: str = "r0", lag: int = 0,
                 bootstrap_step: Optional[int] = None):
        self.tail = transport_lib.make_tail(stream)
        self.name = name
        self.lag = int(lag)
        if bootstrap_step is not None:
            path = self.tail.bootstrap_path(bootstrap_step)
        else:
            # a lagged replica joins at a bootstrap at-or-below its target
            # (head − lag) when one exists, so it starts BEHIND and stays
            # there; fall back to the newest bootstrap otherwise
            head = self.tail.last_step()
            path = None
            if self.lag > 0 and head is not None:
                path = self.tail.latest_bootstrap(
                    upto=max(head - self.lag, 0))
            if path is None:
                path = self.tail.latest_bootstrap()
        if path is None:
            raise stream_lib.StreamError(
                f"stream {stream!r} has no bootstrap checkpoint — a "
                "replica cannot join (params never travel on the wire); "
                "attach the trainer with Session.publish_to first")
        meta = ckpt_lib.read_meta(path)
        if "spec" not in meta:
            raise stream_lib.StreamError(
                f"bootstrap {path} has no embedded RunSpec")
        self.spec = spec_lib.RunSpec.from_dict(meta["spec"])
        self.spec_hash = self.spec.spec_hash()
        self.session = session_lib.Session(self.spec)
        self.optimizer = opt_lib.make(self.spec.optimizer, lr=self.spec.lr)
        self._likes, self.legs = self._like_trees()
        self.sub = self._load_bootstrap(path)
        self.session.set_serve_params(self.sub.params)

    @property
    def log(self):
        """Back-compat alias: the read side of the stream (a StreamTail)."""
        return self.tail

    # -------------------------------------------------------------- loading
    def _like_trees(self) -> Tuple[Dict[str, PyTree], List[Any]]:
        """Shape/dtype templates via eval_shape — a replica restore never
        pays init_params, and never materializes the per-CLIENT EF state
        (``ef_state/clients``): only params, opt_state, and the broadcast
        memory h leave the checkpoint. The transport legs are resolved once
        against the same template and reused everywhere (they decide whether
        the stream carries an h at all)."""
        cfg = self.session.cfg
        params_like = jax.eval_shape(
            lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
        opt_like = jax.eval_shape(self.optimizer.init, params_like)
        legs = stream_lib.resolve_legs(
            params_like,
            schedule=session_lib.make_schedule(self.spec),
            down_carrier=self.spec.downlink_carrier,
            down_compressor=session_lib.make_down_compressor(self.spec))
        likes = {"params": params_like, "opt_state": opt_like}
        if any(leg.carrier is not None for leg in legs):
            likes["h"] = params_like
        return likes, legs

    def _load_bootstrap(self, path: str) -> stream_lib.Subscriber:
        meta = ckpt_lib.read_meta(path)
        stored = meta.get("spec_hash")
        if stored is not None and stored != self.spec_hash:
            raise stream_lib.StreamSpecMismatch(
                f"bootstrap {path} was written by a different RunSpec "
                f"(hash {stored} != {self.spec_hash}); refusing to join a "
                "foreign stream")
        like = {"params": self._likes["params"],
                "opt_state": self._likes["opt_state"]}
        if "h" in self._likes:
            like["ef_state"] = {"h": self._likes["h"]}
        state, meta = ckpt_lib.restore(path, like)
        return stream_lib.Subscriber(
            self.tail, self.spec_hash, self.legs, state["params"],
            state["opt_state"], state.get("ef_state", {}).get("h"),
            int(meta["step"]), self.optimizer)

    # ------------------------------------------------------------------ sync
    @property
    def step(self) -> int:
        return self.sub.step

    @property
    def params(self) -> PyTree:
        return self.sub.params

    def _target(self, upto: Optional[int]) -> Optional[int]:
        last = self.tail.last_step()
        if last is None:
            return None
        target = max(0, last - self.lag)
        return target if upto is None else min(target, int(upto))

    def sync(self, upto: Optional[int] = None) -> int:
        """Apply every record up to (head − lag); on a gap, resync via
        checkpoint + replay. Returns steps advanced. The served params are
        refreshed exactly once per path: the in-order path pushes them here,
        the resync path pushes them itself (it may land on a different
        Subscriber object)."""
        target = self._target(upto)
        if target is None or target <= self.step:
            return 0
        start = self.step
        try:
            if self.sub.sync(upto=target):
                self.session.set_serve_params(self.sub.params)
        except stream_lib.StreamGapError:
            self.resync(target)
        return self.step - start

    def resync(self, target: int) -> int:
        """Gap recovery: reload the newest bootstrap PAST the replica's
        current step and replay forward — the replica re-enters the stream
        bit-identical, never having applied records out of order. Raises
        ``StreamGapError`` when no bootstrap bridges the gap (the replica
        keeps its last consistent, honestly-stale model)."""
        before = self.step
        for b in sorted(self.tail.bootstrap_steps(), reverse=True):
            if b <= self.step or b > target:
                continue
            sub = self._load_bootstrap(self.tail.bootstrap_path(b))
            try:
                sub.sync(upto=target)
            except stream_lib.StreamGapError:
                continue
            self.sub = sub
            self.session.set_serve_params(self.sub.params)
            return self.step - before
        raise stream_lib.StreamGapError(
            f"replica {self.name!r} is at step {before} with a gap before "
            f"step {target} and no bootstrap bridges it; refusing to skip "
            "records (serving stays on the last consistent model)")

    def staleness(self) -> int:
        """Head − replica step, explicitly 0 for an empty log (no records
        published yet means there is nothing to be stale AGAINST — the old
        ``last_step() or 0`` falsy coercion would have made a replica at
        step 5 look −5 stale)."""
        last = self.tail.last_step()
        if last is None:
            return 0
        return max(int(last) - self.step, 0)

    # ----------------------------------------------------------------- serve
    def serve_batch(self, requests: Sequence[Request], prompt_len: int,
                    decode_steps: int,
                    sync_during_decode: bool = False) -> Dict[str, Any]:
        """One batched prefill+decode over ``requests`` at the replica's
        current (synced) params. Prompts are right-padded/truncated to the
        fleet's fixed ``prompt_len`` bucket; the TRUE prompt lengths travel
        with the batch, so the first generated token is read at each row's
        real last prompt position — a prompt containing a genuine token 0 is
        never conflated with padding. With ``sync_during_decode`` the
        replica polls the tail between decode steps and applies any fresh
        records (the remaining decode runs on the updated params); the
        result carries ``mid_applied`` = steps applied mid-decode."""
        assert requests, "serve_batch needs at least one request"
        vocab = self.session.cfg.vocab_size
        toks = np.zeros((len(requests), prompt_len), dtype=np.int64)
        lens = np.zeros((len(requests),), dtype=np.int32)
        for j, req in enumerate(requests):
            row = np.asarray(req.tokens)[:prompt_len] % vocab
            toks[j, :row.size] = row
            lens[j] = max(int(row.size), 1)
        applied = {"n": 0}
        hook = None
        if sync_during_decode:
            def hook(i):
                applied["n"] += self.sync()
        out = self.session.serve(tokens=jax.numpy.asarray(toks),
                                 prompt_lens=jax.numpy.asarray(lens),
                                 decode_steps=decode_steps,
                                 decode_hook=hook)
        out["mid_applied"] = applied["n"]
        return out


# ---------------------------------------------------------------------------
# the fleet
# ---------------------------------------------------------------------------

class Fleet:
    """N replicas subscribed to ONE wire stream at per-replica lags, served
    round-robin under a shared decode-budget scheduler."""

    def __init__(self, stream, n_replicas: int = 2,
                 lags: Optional[Sequence[int]] = None,
                 decode_budget: int = 64, max_batch: int = 4,
                 prompt_len: int = 32,
                 bootstrap_step: Optional[int] = None):
        lags = list(lags) if lags is not None else [0] * n_replicas
        if len(lags) != n_replicas:
            raise ValueError(f"{n_replicas} replicas but {len(lags)} lags")
        self.replicas = [
            ServeReplica(stream, name=f"r{i}", lag=lags[i],
                         bootstrap_step=bootstrap_step)
            for i in range(n_replicas)]
        self.scheduler = DecodeBudgetScheduler(decode_budget=decode_budget,
                                               max_batch=max_batch)
        self.prompt_len = int(prompt_len)

    def sync(self) -> List[int]:
        return [rep.sync() for rep in self.replicas]

    def run(self, requests: Sequence[Request], sync_every: int = 1,
            sync_during_decode: bool = False) -> Dict[str, Any]:
        """Drive the request load through the fleet: arrivals are honored
        against the wall clock, each replica syncs (applies fresh wire
        records) on its OWN batch cadence — every ``sync_every`` batches IT
        serves, counted per replica, so every replica syncs before its first
        batch and no replica can be starved of syncs by the round-robin
        phase (the old global ``batches % sync_every`` check advanced in
        lockstep with the round-robin index, which left whole replicas
        never-synced for ``n_replicas == sync_every``). Each completed
        request records its latency, the staleness (head − replica step) it
        was served at, and ``tokens_generated``; a request whose decode was
        capped by the budget surfaces in the ``short_requests`` /
        ``tokens_short`` summary fields. ``sync_during_decode`` additionally
        applies fresh records BETWEEN decode steps (continuous sync).
        Returns the completed requests plus a QPS/p50/p99 summary."""
        todo = collections.deque(sorted(requests, key=lambda r: r.arrival_s))
        pending: Deque[Request] = collections.deque()
        done: List[Request] = []
        t0 = time.time()
        batches = ri = 0
        served = [0] * len(self.replicas)   # per-replica batch counts
        while todo or pending:
            now = time.time() - t0
            while todo and todo[0].arrival_s <= now:
                pending.append(todo.popleft())
            if not pending:
                time.sleep(min(0.002, max(todo[0].arrival_s - now, 1e-4)))
                continue
            idx = ri % len(self.replicas)
            rep = self.replicas[idx]
            ri += 1
            if sync_every and served[idx] % sync_every == 0:
                rep.sync()
            batch, decode_steps = self.scheduler.admit(pending)
            out = rep.serve_batch(batch, self.prompt_len, decode_steps,
                                  sync_during_decode=sync_during_decode)
            t_done = time.time() - t0
            staleness = rep.staleness()
            for req, row in zip(batch, out["tokens"]):
                req.t_done = t_done
                req.latency_s = t_done - req.arrival_s
                finalize_request(req, row)
                req.replica = rep.name
                req.staleness = staleness
                done.append(req)
            batches += 1
            served[idx] += 1
        return _summary(done, batches)


def _summary(done: List[Request], batches: int, **extra) -> Dict[str, Any]:
    """The shared run-summary schema (in-process Fleet and ProcessFleet):
    QPS/p50/p99, staleness, and the decode-budget shortfall accounting."""
    lat = np.array(sorted(r.latency_s for r in done)) if done \
        else np.zeros(1)
    wall = max((r.t_done for r in done), default=0.0)
    stal = np.array([r.staleness for r in done]) if done else np.zeros(1)
    short = [r for r in done if r.tokens_generated < r.max_new_tokens]
    return {
        "requests": done,
        "batches": batches,
        "qps": len(done) / max(wall, 1e-9),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "staleness_mean": float(stal.mean()),
        "staleness_max": int(stal.max()),
        "short_requests": len(short),
        "tokens_short": int(sum(r.max_new_tokens - r.tokens_generated
                                for r in short)),
        **extra,
    }


# ---------------------------------------------------------------------------
# the multi-process fleet
# ---------------------------------------------------------------------------

class ProcessFleet:
    """N replica WORKER PROCESSES on one wire stream (DESIGN.md §12): each
    worker is a ``python -m repro.launch.replica_worker`` subprocess running
    its own ``ServeReplica`` over a transport tail, reporting heartbeats to
    this parent. The parent admits request batches under the shared
    decode-budget scheduler and dispatches them to idle workers — batches
    genuinely overlap across processes, which is what "past one process"
    buys. Workers serve with CONTINUOUS sync (records applied between decode
    steps), a crashed worker is restarted and rejoins via checkpoint +
    replay (bit-identical — the §12 anchor invariant across a process
    boundary), and its in-flight batch is requeued at the head of the
    pending queue, so a crash costs latency, never a lost or
    drifted-weights request."""

    def __init__(self, stream, n_workers: int = 2,
                 lags: Optional[Sequence[int]] = None,
                 decode_budget: int = 64, max_batch: int = 4,
                 prompt_len: int = 32,
                 bootstrap_step: Optional[int] = None,
                 heartbeat_s: float = 0.25, hb_timeout_s: float = 120.0,
                 start_timeout_s: float = 300.0):
        from repro.launch import replica_worker as worker_lib

        lags = list(lags) if lags is not None else [0] * n_workers
        if len(lags) != n_workers:
            raise ValueError(f"{n_workers} workers but {len(lags)} lags")
        self.workers = [
            worker_lib.WorkerHandle(
                str(stream), name=f"w{i}", lag=lags[i],
                bootstrap_step=bootstrap_step, prompt_len=prompt_len,
                heartbeat_s=heartbeat_s, start_timeout_s=start_timeout_s)
            for i in range(n_workers)]
        self.scheduler = DecodeBudgetScheduler(decode_budget=decode_budget,
                                               max_batch=max_batch)
        self.prompt_len = int(prompt_len)
        self.hb_timeout_s = float(hb_timeout_s)
        for w in self.workers:
            w.wait_ready()

    # ------------------------------------------------------------- lifecycle
    def __enter__(self) -> "ProcessFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def stop(self) -> None:
        for w in self.workers:
            w.stop()

    def sync(self) -> List[int]:
        return [w.call({"cmd": "sync"})["applied"] for w in self.workers]

    def digests(self) -> List[str]:
        return [w.call({"cmd": "digest"})["digest"] for w in self.workers]

    # ------------------------------------------------------------------- run
    def _restart(self, w, inflight: Dict[Any, Any],
                 pending: Deque[Request]) -> None:
        """Restart a dead/hung worker; its in-flight batch (if any) goes
        back to the FRONT of the queue so those requests are served next."""
        entry = inflight.pop(w, None)
        if entry is not None:
            for req in reversed(entry["batch"]):
                pending.appendleft(req)
        w.restart()

    def run(self, requests: Sequence[Request],
            sync_during_decode: bool = True) -> Dict[str, Any]:
        """Drive the load: arrivals against the wall clock, batches admitted
        under the decode budget and dispatched to IDLE workers (true
        multi-process overlap), results collected as they complete. Workers
        sync continuously during decode; staleness is reported by the worker
        at batch completion. Summary schema matches ``Fleet.run`` plus
        ``restarts`` and ``mid_applied``."""
        from repro.launch import replica_worker as worker_lib

        todo = collections.deque(sorted(requests, key=lambda r: r.arrival_s))
        pending: Deque[Request] = collections.deque()
        done: List[Request] = []
        inflight: Dict[Any, Dict[str, Any]] = {}
        t0 = time.time()
        batches = 0
        mid_applied = 0
        while todo or pending or inflight:
            now = time.time() - t0
            while todo and todo[0].arrival_s <= now:
                pending.append(todo.popleft())
            # health: restart dead (or heartbeat-silent) workers, requeueing
            # their in-flight batch
            for w in self.workers:
                dead = not w.alive()
                hung = (w in inflight and self.hb_timeout_s
                        and w.hb_age() > self.hb_timeout_s)
                if dead or hung:
                    self._restart(w, inflight, pending)
            # dispatch to every idle worker while there is work
            for w in self.workers:
                if not pending:
                    break
                if w in inflight or not w.alive():
                    continue
                batch, decode_steps = self.scheduler.admit(pending)
                if not batch:
                    break
                cmd = {"cmd": "serve",
                       "requests": [{"rid": r.rid,
                                     "tokens": np.asarray(r.tokens).tolist(),
                                     "max_new_tokens": r.max_new_tokens}
                                    for r in batch],
                       "decode_steps": decode_steps,
                       "prompt_len": self.prompt_len,
                       "sync_during_decode": sync_during_decode}
                try:
                    mid = w.submit(cmd)
                except worker_lib.WorkerDied:
                    for req in reversed(batch):
                        pending.appendleft(req)
                    continue                   # health pass restarts it
                inflight[w] = {"batch": batch, "id": mid,
                               "decode_steps": decode_steps}
            # collect
            got_reply = False
            for w in list(inflight):
                msg = w.take_reply(timeout=0.0)
                if msg is None:
                    continue
                entry = inflight[w]
                if msg.get("id") != entry["id"] or not msg.get("ok"):
                    # a failed serve (or stale reply) — requeue and restart
                    self._restart(w, inflight, pending)
                    continue
                inflight.pop(w)
                got_reply = True
                t_done = time.time() - t0
                head, step = msg.get("head"), msg.get("step", 0)
                staleness = 0 if head is None else max(int(head) - step, 0)
                mid_applied += int(msg.get("mid_applied", 0))
                by_rid = {r.rid: r for r in entry["batch"]}
                for rid, toks, ngen in zip(msg["rids"], msg["tokens"],
                                           msg["tokens_generated"]):
                    req = by_rid[rid]
                    req.t_done = t_done
                    req.latency_s = t_done - req.arrival_s
                    req.tokens_out = np.asarray(toks, dtype=np.int64)
                    req.tokens_generated = int(ngen)
                    req.replica = w.name
                    req.staleness = staleness
                    done.append(req)
                batches += 1
            if not got_reply:
                time.sleep(0.002)
        return _summary(done, batches,
                        restarts=sum(w.restarts for w in self.workers),
                        mid_applied=mid_applied,
                        workers=[w.name for w in self.workers])
