"""Deterministic synthetic token pipeline.

Offline container → no real corpora; the pipeline synthesizes a *learnable*
token stream (orderful Markov-ish structure, so training loss actually falls)
with properties a production pipeline needs:

  * deterministic in (seed, step) — restart-safe, checkpoint-consistent
  * host-sharded: each host materializes only its slice of the global batch
    (host h of H takes rows [h·B/H, (h+1)·B/H))
  * per-client heterogeneity knob: data-parallel group i samples from a shifted
    token distribution (the paper's heterogeneous-clients regime, §1.1 "we allow
    the distributions D₁…D_n to be arbitrarily different")
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np


# frontend prefix padding (DESIGN.md §5): modality-frontend archs (musicgen,
# internvl2) are trained/served with precomputed patch/frame embeddings
# prepended to the token sequence. Drivers pad the prefix to at least
# PREFIX_PAD_MIN tokens; the dry-run input specs (launch/shardings.py) pad to
# the production alignment PREFIX_PAD_SPEC.
PREFIX_PAD_MIN = 8
PREFIX_PAD_SPEC = 64


def prefix_token_count(cfg, pad_to: int = PREFIX_PAD_MIN) -> int:
    """Number of prefix-embedding tokens a batch for ``cfg`` carries (0 for
    archs without a modality frontend)."""
    if cfg.frontend is None:
        return 0
    return max(cfg.frontend_tokens, pad_to)


def with_prefix_embeds(cfg, batch: Dict, pad_to: int = PREFIX_PAD_MIN) -> Dict:
    """Attach the zero ``prefix_embeds`` stub to ``batch`` when ``cfg`` has a
    modality frontend. The single implementation of the padding rule shared by
    every driver (Session train/serve) and the dry-run input specs — the shape
    logic must never diverge between them."""
    nt = prefix_token_count(cfg, pad_to)
    if nt == 0:
        return batch
    batch = dict(batch)
    batch["prefix_embeds"] = jnp.zeros(
        (batch["tokens"].shape[0], nt, cfg.d_model), jnp.bfloat16)
    return batch


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    hosts: int = 1
    host_id: int = 0
    dp_groups: int = 1            # number of EF clients (heterogeneity granularity)
    heterogeneity: float = 0.5    # 0 = iid clients, 1 = disjoint token ranges


def _batch_np(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    lo = B * cfg.host_id // cfg.hosts
    hi = B * (cfg.host_id + 1) // cfg.hosts
    rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % (2 ** 31))
    # draw the FULL global batch from one stream, then slice the host's rows —
    # guarantees host-count-invariant data (tested in test_substrate.py)
    rows = np.arange(B)
    group = rows * cfg.dp_groups // B                       # client id per row
    width = max(16, int(V * (1.0 - cfg.heterogeneity * (1 - 1 / cfg.dp_groups))))
    base = (group * (V - width) // max(cfg.dp_groups - 1, 1)).astype(np.int64)
    toks = np.empty((B, S + 1), np.int64)
    toks[:, 0] = rng.randint(0, width, size=B)
    a, c = 31, 17
    noise = rng.randint(0, 3, size=(B, S))
    for t in range(S):
        toks[:, t + 1] = (toks[:, t] * a + c + noise[:, t]) % width
    toks = (toks + base[:, None])[lo:hi]
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


class SyntheticTokens:
    """Stateless-addressable iterator: ``pipeline.batch(step)`` for any step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        return {k: jnp.asarray(v) for k, v in _batch_np(self.cfg, step).items()}

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
