"""npz-based pytree checkpointing with step metadata and atomic writes."""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


_NATIVE_KINDS = set("biufc")


def save(path: str, tree: PyTree, step: int = 0, meta: Optional[dict] = None,
         spec: Optional[Any] = None) -> None:
    """``spec`` (anything with ``to_dict()`` / ``spec_hash()`` — a
    launch.spec.RunSpec) is embedded in ``__meta__`` so a checkpoint names the
    exact experiment that wrote it: ``restore``/``Session.resume`` can rebuild
    the run without re-passing flags, and refuse a checkpoint written by a
    different RunSpec (the hash comparison)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    if spec is not None:
        meta = dict(meta or {})
        meta.setdefault("spec", spec.to_dict())
        meta.setdefault("spec_hash", spec.spec_hash())
    flat = _flatten(tree)
    # extension dtypes (bfloat16, fp8) round-trip poorly through npz: store as
    # f32 — restore() casts back to the target leaf dtype (lossless for bf16)
    flat = {k: (v if v.dtype.kind in _NATIVE_KINDS
                else np.asarray(jax.device_get(v), np.float32))
            for k, v in flat.items()}
    flat["__meta__"] = np.frombuffer(
        json.dumps({"step": step, **(meta or {})}).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp.npz")
    os.close(fd)
    # the mkstemp suffix already ends in .npz, so np.savez never renames the
    # temp file (and latest() relies on the '.tmp.npz' suffix to skip
    # partials from killed saves)
    np.savez(tmp, **flat)
    os.replace(tmp, path)


def restore(path: str, like: PyTree) -> Tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path_keys, leaf in leaves_like:
            key = _SEP.join(_part(p) for p in path_keys)
            arr = z[key]
            if arr.shape != leaf.shape:
                raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
            # cast via jax (numpy lacks native bf16 cast support)
            out.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out), meta


def read_meta(path: str) -> dict:
    """The ``__meta__`` dict alone, without materializing any arrays."""
    with np.load(path) as z:
        return json.loads(bytes(z["__meta__"]).decode())


def parse_step(filename: str) -> Optional[int]:
    """Step number encoded in a checkpoint filename (the LAST digit run in the
    stem, so ``run2/step_100.npz`` → 100), or None for digit-free names."""
    stem = os.path.splitext(os.path.basename(filename))[0]
    groups = re.findall(r"\d+", stem)
    return int(groups[-1]) if groups else None


def latest(ckpt_dir: str) -> Optional[str]:
    """Newest checkpoint by PARSED step number — ``max()`` over filenames is
    lexicographic and would rank step_2 above step_10 when the zero padding
    ever differs. Digit-free names sort before any numbered checkpoint and
    fall back to lexicographic order among themselves."""
    if not os.path.isdir(ckpt_dir):
        return None
    # a save() killed mid-write leaves a mkstemp '*.tmp.npz' partial behind;
    # it must never win over the last complete checkpoint (resume would die
    # on a truncated zip, or silently adopt stale state)
    cands = [f for f in os.listdir(ckpt_dir)
             if f.endswith(".npz") and not f.endswith(".tmp.npz")]
    if not cands:
        return None
    best = max(cands, key=lambda f: (parse_step(f) is not None,
                                     parse_step(f) or 0, f))
    return os.path.join(ckpt_dir, best)
