"""npz-based pytree checkpointing with step metadata and atomic writes."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


_NATIVE_KINDS = set("biufc")


def save(path: str, tree: PyTree, step: int = 0, meta: Optional[dict] = None
         ) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    # extension dtypes (bfloat16, fp8) round-trip poorly through npz: store as
    # f32 — restore() casts back to the target leaf dtype (lossless for bf16)
    flat = {k: (v if v.dtype.kind in _NATIVE_KINDS
                else np.asarray(jax.device_get(v), np.float32))
            for k, v in flat.items()}
    flat["__meta__"] = np.frombuffer(
        json.dumps({"step": step, **(meta or {})}).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp if tmp.endswith(".npz") else tmp, path)


def restore(path: str, like: PyTree) -> Tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path_keys, leaf in leaves_like:
            key = _SEP.join(_part(p) for p in path_keys)
            arr = z[key]
            if arr.shape != leaf.shape:
                raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
            # cast via jax (numpy lacks native bf16 cast support)
            out.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out), meta


def latest(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    cands = [f for f in os.listdir(ckpt_dir) if f.endswith(".npz")]
    if not cands:
        return None
    return os.path.join(ckpt_dir, max(cands))
