"""State-space sequence mixers: Mamba1 (selective scan) and Mamba2 (SSD).

TPU adaptation (DESIGN.md §4): instead of a length-S sequential scan (latency-bound)
or a full associative scan (O(B·S·d_inner·N) live memory), both mixers use a
**chunked scan**: an outer ``lax.scan`` over S/chunk steps carries the (B, ..., N)
state, and within a chunk either an associative scan (Mamba1) or the matmul-rich SSD
block decomposition (Mamba2) does the parallel work. Mamba2's intra-chunk compute is
pure (chunk × chunk) matmuls — MXU-friendly by construction.

Decode paths are single-token recurrences over carried (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, shard

Array = jax.Array


# ---------------------------------------------------------------------------
# causal depthwise conv (k small, unrolled shifts)
# ---------------------------------------------------------------------------

def causal_conv(x: Array, w: Array, state: Optional[Array] = None
                ) -> Tuple[Array, Array]:
    """x: (B,S,D); w: (k,D) depthwise. Returns (y, new_state=(B,k-1,D))."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)               # (B, S+k-1, D)
    y = sum(xp[:, j:j + x.shape[1]] * w[j] for j in range(k))
    return y, xp[:, -(k - 1):]


# ---------------------------------------------------------------------------
# Mamba1 — selective scan
# ---------------------------------------------------------------------------

def mamba1_init(rng, d: int, d_inner: int, state: int, dt_rank: int, conv: int,
                dtype) -> dict:
    ks = jax.random.split(rng, 6)
    A = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32), (d_inner, 1))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * d_inner)) * d ** -0.5
                    ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (conv, d_inner)) * 0.1).astype(dtype),
        "x_proj": (jax.random.normal(ks[2], (d_inner, dt_rank + 2 * state))
                   * d_inner ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dt_rank, d_inner))
                    * dt_rank ** -0.5).astype(dtype),
        "dt_bias": jnp.full((d_inner,), -4.0, dtype),
        "A_log": jnp.log(A),                                    # f32 (d_inner, state)
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (d_inner, d)) * d_inner ** -0.5
                     ).astype(dtype),
        "norm": jnp.zeros((d,), dtype),
    }


def _mamba1_core(p, xc: Array, dt_rank: int, N: int, h0: Array, chunk: int
                 ) -> Tuple[Array, Array]:
    """xc: (B,S,Di) post-conv/silu. Chunked selective scan. h0: (B,Di,N)."""
    B, S, Di = xc.shape
    proj = jnp.einsum("bsd,de->bse", xc, p["x_proj"].astype(xc.dtype))
    dt_in, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, p["dt_proj"].astype(dt_in.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))   # (B,S,Di)
    A = -jnp.exp(p["A_log"])                                       # (Di,N)

    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S, f"seq {S} % chunk {chunk} != 0"

    def rs(t):  # (B,S,...) → (nc,B,chunk,...)
        return t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    dt_c, x_c, B_c, C_c = rs(dt), rs(xc.astype(jnp.float32)), \
        rs(Bm.astype(jnp.float32)), rs(Cm.astype(jnp.float32))

    def step(h, inp):
        dt_i, x_i, B_i, C_i = inp                 # (B,ch,Di) ×2, (B,ch,N) ×2
        a = jnp.exp(dt_i[..., None] * A)          # (B,ch,Di,N)
        b = (dt_i * x_i)[..., None] * B_i[:, :, None, :]
        Ac, Bc = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], l[1] * r[0] + r[1]), (a, b), axis=1)
        hs = Ac * h[:, None] + Bc                 # (B,ch,Di,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, C_i) + p["D"] * x_i
        return hs[:, -1], y

    h_fin, ys = jax.lax.scan(step, h0, (dt_c, x_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(B, S, Di)
    return y.astype(xc.dtype), h_fin


def mamba1_apply(p: dict, x: Array, cfg, *,
                 ssm_state: Optional[Array] = None,
                 conv_state: Optional[Array] = None,
                 ) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """Pre-norm Mamba1 block. x: (B,S,d) (S=1 decode when states given)."""
    B, S, d = x.shape
    Di, N = cfg.d_inner, cfg.ssm_state
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, None, None, "model")

    decode = ssm_state is not None and S == 1
    xc, conv_new = causal_conv(xin, p["conv_w"].astype(xin.dtype), conv_state)
    xc = jax.nn.silu(xc)

    if decode:
        # single-step recurrence
        proj = jnp.einsum("bsd,de->bse", xc, p["x_proj"].astype(xc.dtype))
        dt_in, Bm, Cm = jnp.split(proj, [cfg.dt_rank, cfg.dt_rank + N], axis=-1)
        dt = jax.nn.softplus(
            jnp.einsum("bsr,rd->bsd", dt_in, p["dt_proj"].astype(dt_in.dtype))
            .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,Di)
        A = -jnp.exp(p["A_log"])
        a = jnp.exp(dt[..., None] * A)                         # (B,Di,N)
        b = (dt * xc.astype(jnp.float32)[:, 0])[..., None] * \
            Bm.astype(jnp.float32)[:, 0, None, :]
        h_new = a * ssm_state + b
        y = jnp.einsum("bdn,bn->bd", h_new, Cm.astype(jnp.float32)[:, 0]) \
            + p["D"] * xc.astype(jnp.float32)[:, 0]
        y = y[:, None].astype(xc.dtype)
        states = (h_new, conv_new)
    else:
        h0 = ssm_state if ssm_state is not None \
            else jnp.zeros((B, Di, N), jnp.float32)
        y, h_fin = _mamba1_core(p, xc, cfg.dt_rank, N, h0, cfg.attn_chunk)
        states = (h_fin, conv_new)

    y = y * jax.nn.silu(z)
    y = shard(y, None, None, "model")
    return jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(y.dtype)), states


# ---------------------------------------------------------------------------
# Mamba2 — SSD (scalar-A multihead state space duality)
# ---------------------------------------------------------------------------

def mamba2_init(rng, d: int, d_inner: int, state: int, head_dim: int, conv: int,
                dtype) -> dict:
    ks = jax.random.split(rng, 6)
    nh = d_inner // head_dim
    return {
        "in_x": (jax.random.normal(ks[0], (d, d_inner)) * d ** -0.5).astype(dtype),
        "in_z": (jax.random.normal(ks[1], (d, d_inner)) * d ** -0.5).astype(dtype),
        "in_B": (jax.random.normal(ks[2], (d, state)) * d ** -0.5).astype(dtype),
        "in_C": (jax.random.normal(ks[3], (d, state)) * d ** -0.5).astype(dtype),
        "in_dt": (jax.random.normal(ks[4], (d, nh)) * d ** -0.5).astype(dtype),
        "dt_bias": jnp.full((nh,), -4.0, dtype),
        "conv_w": (jax.random.normal(ks[5], (conv, d_inner + 2 * state)) * 0.1
                   ).astype(dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": (jax.random.normal(ks[0], (d_inner, d)) * d_inner ** -0.5
                     ).astype(dtype),
        "norm": jnp.zeros((d,), dtype),
        "out_norm": jnp.zeros((d_inner,), dtype),
    }


def _ssd_chunk_scan(x, dt, Bm, Cm, A, D, h0, chunk):
    """SSD chunked scan.
    x: (B,S,H,P) f32; dt: (B,S,H); Bm/Cm: (B,S,N); A: (H,) negative; h0: (B,H,P,N).
    """
    B_, S, H, Pd = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S

    def rs(t):
        return t.reshape(B_, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    x_c, dt_c, B_c, C_c = rs(x), rs(dt), rs(Bm), rs(Cm)

    def step(h, inp):
        xi, dti, Bi, Ci = inp                      # (B,ch,H,P),(B,ch,H),(B,ch,N)
        a = dti * A                                # (B,ch,H) log-decay increments
        L = jnp.cumsum(a, axis=1)                  # (B,ch,H)
        # intra-chunk: scores[t,s] = (C_t·B_s)·exp(L_t−L_s)·dt_s,  s ≤ t
        cb = jnp.einsum("btn,bsn->bts", Ci, Bi)    # (B,ch,ch)
        dec = jnp.exp(jnp.clip(L[:, :, None, :] - L[:, None, :, :], -60, 0))
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = cb[:, :, :, None] * dec * dti[:, None, :, :]
        w = jnp.where(tri[None, :, :, None], w, 0.0)          # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xi)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.exp(L)[..., None] * jnp.einsum("bhpn,btn->bthp", h, Ci)
        # state update: h' = exp(ΣA)·h + Σ_s exp(L_end−L_s)·dt_s·x_s B_sᵀ
        wl = jnp.exp(jnp.clip(L[:, -1:, :] - L, -60, None)) * dti   # (B,ch,H)
        h_new = jnp.exp(L[:, -1])[..., None, None] * h + \
            jnp.einsum("bsh,bshp,bsn->bhpn", wl, xi, Bi)
        y = y_intra + y_inter + D[:, None] * xi
        return h_new, y

    h_fin, ys = jax.lax.scan(step, h0, (x_c, dt_c, B_c, C_c))
    return ys.swapaxes(0, 1).reshape(B_, S, H, Pd), h_fin


def mamba2_apply(p: dict, x: Array, cfg, *,
                 ssm_state: Optional[Array] = None,
                 conv_state: Optional[Array] = None,
                 ) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """Pre-norm Mamba2 (SSD) block. x: (B,S,d)."""
    B, S, d = x.shape
    Di, N, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    H = Di // Pd
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xin = jnp.einsum("bsd,de->bse", h, p["in_x"].astype(h.dtype))
    z = jnp.einsum("bsd,de->bse", h, p["in_z"].astype(h.dtype))
    Bm = jnp.einsum("bsd,dn->bsn", h, p["in_B"].astype(h.dtype))
    Cm = jnp.einsum("bsd,dn->bsn", h, p["in_C"].astype(h.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["in_dt"].astype(h.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    xin = shard(xin, None, None, "model")

    decode = ssm_state is not None and S == 1
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc, conv_new = causal_conv(xbc, p["conv_w"].astype(xbc.dtype), conv_state)
    xbc = jax.nn.silu(xbc)
    xin, Bm, Cm = jnp.split(xbc, [Di, Di + N], axis=-1)

    A = -jnp.exp(p["A_log"])                                   # (H,)
    xh = xin.astype(jnp.float32).reshape(B, S, H, Pd)

    if decode:
        a = jnp.exp(dt[:, 0] * A)                              # (B,H)
        h_new = a[..., None, None] * ssm_state + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0], Bm.astype(jnp.float32)[:, 0])
        y = jnp.einsum("bhpn,bn->bhp", h_new, Cm.astype(jnp.float32)[:, 0]) \
            + p["D"][:, None] * xh[:, 0]
        y = y[:, None]
        states = (h_new, conv_new)
    else:
        h0 = ssm_state if ssm_state is not None \
            else jnp.zeros((B, H, Pd, N), jnp.float32)
        y, h_fin = _ssd_chunk_scan(xh, dt, Bm.astype(jnp.float32),
                                   Cm.astype(jnp.float32), A, p["D"], h0,
                                   cfg.attn_chunk)
        states = (h_fin, conv_new)

    y = y.reshape(B, S, Di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    y = shard(y, None, None, "model")
    return jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(y.dtype)), states
