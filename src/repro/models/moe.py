"""Mixture-of-Experts FFN with sort-based capacity routing (GShard-style, but
without the O(N·E·C) one-hot dispatch tensor).

Routing pipeline (all static shapes, scan/vmap-safe):
  1. router logits → softmax → top-k (weights, expert ids) per token
  2. flatten the (N·k) assignments, argsort by expert id
  3. position-within-expert via searchsorted; drop tokens beyond the per-expert
     capacity C = ⌈N·k/E⌉·capacity_factor (token dropping, counted in aux stats)
  4. scatter into a dense (E, C, d) buffer → batched expert einsum (active-expert
     FLOPs only: 2·3·N·k·cf·d·ff) → gather back, weighted combine

Expert weights are sharded expert-parallel (experts over the 'model' axis) when
E % tp == 0, else tensor-parallel inside each expert (ff over 'model') — see
model.param_pspecs. The dispatch scatter/gather turns into all-to-all-style
collectives on the mesh.

Aux losses: switch-style load-balance loss + router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, shard

Array = jax.Array


def moe_init(rng, d: int, ff: int, E: int, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "router": (jax.random.normal(k1, (d, E)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (E, d, ff)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(k3, (E, d, ff)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(k4, (E, ff, d)) * ff ** -0.5).astype(dtype),
        "norm": jnp.zeros((d,), dtype),
    }


def _capacity(N: int, E: int, k: int, cf: float) -> int:
    return max(1, int(-(-N * k // E) * cf))


def moe_apply(p: dict, x: Array, *, k: int, cf: float, eps: float
              ) -> Tuple[Array, dict]:
    """x: (B,S,d) → (out (B,S,d), aux dict with load-balance/z losses)."""
    B, S, d = x.shape
    E = p["router"].shape[1]
    N = B * S
    h = rms_norm(x, p["norm"], eps).reshape(N, d)

    logits = (h.astype(jnp.float32) @ p["router"])              # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                      # (N, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch-style) ----------------------------------------
    me = probs.mean(0)                                          # (E,)
    ce = jnp.zeros((E,)).at[top_e.reshape(-1)].add(1.0) / (N * k)
    aux = {
        "load_balance": E * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }

    # ---- sort-based dispatch ----------------------------------------------
    C = _capacity(N, E, k, cf)
    flat_e = top_e.reshape(-1)                                  # (N·k,)
    order = jnp.argsort(flat_e)                                 # stable
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(N * k) - first[sorted_e]
    keep = pos_in_e < C
    tok = order // k                                            # source token id

    # 2-D scatter straight into the EXPERT-SHARDED (E, C, d) buffer — capacity
    # overflow relies on JAX dropping out-of-bounds scatter updates. A flat
    # (E·C, d) scatter leaves the output unshardable over experts and XLA
    # replicates + all-reduces the whole buffer (≈2 TB/device at olmoe
    # prefill_32k — measured; see EXPERIMENTS.md §Perf/olmoe).
    xe = shard(jnp.zeros((E, C, d), h.dtype), "model", None, None)
    xe = xe.at[sorted_e, pos_in_e].add(jnp.where(keep[:, None], h[tok], 0))
    xe = shard(xe, "model", None, None)

    # ---- expert FFN (active tokens only) ----------------------------------
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(xe.dtype))
    y = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", y, p["w_down"].astype(y.dtype))
    ye = shard(ye, "model", None, None)

    # ---- combine -----------------------------------------------------------
    gathered = ye[sorted_e, jnp.minimum(pos_in_e, C - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    # unsort back to (N, k) order, weight, and sum over k
    unsorted = jnp.zeros((N * k, d), gathered.dtype).at[order].set(gathered)
    out = (unsorted.reshape(N, k, d)
           * top_w[..., None].astype(gathered.dtype)).sum(1)
    aux["dropped_frac"] = 1.0 - keep.mean()
    return out.reshape(B, S, d), aux


def moe_apply_dense(p: dict, x: Array, *, k: int, cf: float, eps: float,
                    chunk: int = 2048) -> Tuple[Array, dict]:
    """Dense-expert MoE: compute EVERY expert for every token and combine with
    the (N, E) top-k routing weights — no dispatch scatter/gather at all.

    Beyond-paper §Perf option for high-activation MoEs (olmoe: k/E = 8/64 →
    dense costs 8× the active FLOPs, but removes the dispatch buffer that XLA
    replicates + all-reduces, which dominated the collective roofline term by
    ~50×). Token chunking bounds the (E, chunk, ff) live intermediate.
    """
    B, S, d = x.shape
    E = p["router"].shape[1]
    N = B * S
    h = rms_norm(x, p["norm"], eps).reshape(N, d)

    logits = h.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    w_ne = jnp.zeros((N, E), jnp.float32).at[
        jnp.arange(N)[:, None], top_e].set(top_w)           # routing weights

    me = probs.mean(0)
    ce = jnp.zeros((E,)).at[top_e.reshape(-1)].add(1.0) / (N * k)
    aux = {"load_balance": E * jnp.sum(me * ce),
           "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
           "dropped_frac": jnp.zeros(())}

    cs = min(chunk, N)
    ncs = -(-N // cs)
    pad = ncs * cs - N
    hp = jnp.pad(h, ((0, pad), (0, 0))).reshape(ncs, cs, d)
    wp = jnp.pad(w_ne, ((0, pad), (0, 0))).reshape(ncs, cs, E)

    def body(_, xs):
        hc, wc = xs
        g = jnp.einsum("nd,edf->enf", hc, p["w_gate"].astype(hc.dtype))
        u = jnp.einsum("nd,edf->enf", hc, p["w_up"].astype(hc.dtype))
        g = shard(g, "model", None, None)
        y = jax.nn.silu(g) * u
        ye = jnp.einsum("enf,efd->end", y, p["w_down"].astype(y.dtype))
        out = jnp.einsum("end,ne->nd", ye, wc.astype(ye.dtype))
        return 0, out

    _, outs = jax.lax.scan(body, 0, (hp, wp))
    out = outs.reshape(ncs * cs, d)[:N]
    return out.reshape(B, S, d), aux
