"""Shared transformer building blocks: RMSNorm, RoPE, GQA attention (full /
sliding-window banded / decode-with-cache), SwiGLU MLP.

Attention is implemented as *chunked* attention: an outer ``lax.scan`` over query
chunks keeps the HLO small and the live score tensor bounded at
``(B, H, chunk_q, S_kv)`` — the pure-JAX analogue of the Pallas flash kernel
(kernels/flash_attention.py), which is used on real TPU. Sliding-window layers use a
*banded* schedule: each query chunk attends only to a ``window + chunk`` KV slice →
O(S·W) FLOPs instead of O(S²).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def shard(x: Array, *spec) -> Array:
    """Activation sharding hint; no-op when no mesh is active."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        names = set(mesh.axis_names)
        spec = tuple(s if (s in names or s is None or isinstance(s, tuple)) else None
                     for s in spec)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(jax.sharding.get_mesh(), P(*spec)))
    except Exception:
        return x


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, H, hd); positions: (..., S) absolute positions."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def _gqa_scores(q: Array, k: Array, cap: Optional[float]) -> Array:
    """q: (B,Sq,KV,G,hd), k: (B,Skv,KV,hd) → scores (B,KV,G,Sq,Skv) in f32."""
    s = jnp.einsum("bqngd,bknd->bngqk", q.astype(jnp.float32) / (q.shape[-1] ** 0.5),
                   k.astype(jnp.float32))
    return softcap(s, cap)


def _gqa_out(p: Array, v: Array) -> Array:
    """p: (B,KV,G,Sq,Skv), v: (B,Skv,KV,hd) → (B,Sq,KV*G,hd)."""
    o = jnp.einsum("bngqk,bknd->bqngd", p, v)  # (B,Sq,KV,G,hd)
    B, Sq, KV, G, hd = o.shape
    return o.reshape(B, Sq, KV * G, hd)


def chunked_attention(q: Array, k: Array, v: Array, *, chunk: int = 512,
                      window: Optional[int] = None, cap: Optional[float] = None,
                      q_offset: int = 0) -> Array:
    """Causal GQA attention. q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd).

    window=None → full causal (scores for one q-chunk vs full KV, masked).
    window=W    → banded: each q-chunk sees a (W + chunk)-wide KV slice.
    q_offset: absolute position of q[0] relative to k[0] (prefill: 0).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    chunk = min(chunk, Sq)
    nq = -(-Sq // chunk)
    pad = nq * chunk - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q5 = qp.reshape(B, nq, chunk, KV, G, hd)

    kv_pos = jnp.arange(Skv)

    # NB: masks are applied as low-rank additive f32 biases (never rank-of-scores
    # predicates): XLA hoists loop-invariant masks out of the q-chunk scan, and a
    # broadcast pred at score rank would materialize O(nq·B·H·cq·Skv) bytes.
    if window is None:
        def body(_, qi_i):
            qi, i = qi_i
            q_pos = q_offset + i * chunk + jnp.arange(chunk)
            s = _gqa_scores(qi, k, cap)                       # (B,KV,G,cq,Skv)
            bias = jnp.where(kv_pos[None, :] <= q_pos[:, None], 0.0, -1e30)
            p = jax.nn.softmax(s + bias[None, None, None], axis=-1).astype(v.dtype)
            return 0, _gqa_out(p, v)
        body = jax.checkpoint(body)   # flash-style: recompute probs in backward
        _, outs = jax.lax.scan(body, 0, (q5.swapaxes(0, 1), jnp.arange(nq)))
    else:
        ws = min(window + chunk, Skv)

        def body(_, qi_i):
            qi, i = qi_i
            q_pos = q_offset + i * chunk + jnp.arange(chunk)
            start = jnp.clip(q_offset + (i + 1) * chunk - ws, 0, Skv - ws)
            ks = jax.lax.dynamic_slice_in_dim(k, start, ws, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, ws, axis=1)
            k_pos = start + jnp.arange(ws)
            s = _gqa_scores(qi, ks, cap)
            bias = jnp.where((k_pos[None, :] <= q_pos[:, None])
                             & (k_pos[None, :] > q_pos[:, None] - window),
                             0.0, -1e30)
            p = jax.nn.softmax(s + bias[None, None, None], axis=-1).astype(v.dtype)
            return 0, _gqa_out(p, vs)
        body = jax.checkpoint(body)   # flash-style: recompute probs in backward
        _, outs = jax.lax.scan(body, 0, (q5.swapaxes(0, 1), jnp.arange(nq)))

    out = outs.swapaxes(0, 1).reshape(B, nq * chunk, H, hd)
    return out[:, :Sq]


def decode_attention(q: Array, k_cache: Array, v_cache: Array, pos: Array, *,
                     window: Optional[int] = None,
                     cap: Optional[float] = None) -> Array:
    """One-token attention against a cache.

    q: (B,1,H,hd); caches: (B,S,KV,hd). ``pos`` is the absolute position of the new
    token. Full caches store position p at slot p; sliding-window caches are ring
    buffers of size W storing position p at slot p mod W.
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    s = _gqa_scores(q.reshape(B, 1, KV, H // KV, hd), k_cache, cap)  # (B,KV,G,1,S)
    slot = jnp.arange(S)
    if window is None:
        valid = slot <= pos
    else:
        valid = (slot <= pos) | (pos >= S)      # ring buffer: all slots once full
    bias = jnp.where(valid, 0.0, -1e30)
    p = jax.nn.softmax(s + bias[None, None, None, None, :],
                       axis=-1).astype(v_cache.dtype)
    return _gqa_out(p, v_cache).reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# attention block (params + apply)
# ---------------------------------------------------------------------------

def attn_init(rng, d: int, H: int, KV: int, hd: int, dtype,
              h_eff: Optional[int] = None, kv_eff: Optional[int] = None) -> dict:
    """h_eff/kv_eff > H/KV → TP head padding (MHA-expand): kv head j//G is
    replicated under query head j (< H); padded q heads get zero wo rows, so
    the function is EXACTLY that of the unpadded layer."""
    h_eff = h_eff or H
    kv_eff = kv_eff or KV
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    sd = d ** -0.5
    wq = (jax.random.normal(k1, (d, h_eff, hd)) * sd).astype(dtype)
    wo = (jax.random.normal(k4, (h_eff, hd, d)) * (H * hd) ** -0.5).astype(dtype)
    if kv_eff == KV:
        wk = (jax.random.normal(k2, (d, KV, hd)) * sd).astype(dtype)
        wv = (jax.random.normal(k3, (d, KV, hd)) * sd).astype(dtype)
    else:
        assert kv_eff == h_eff, "MHA-expand pads kv to the q-head count"
        G = H // KV
        base_k = jax.random.normal(k2, (d, KV, hd)) * sd
        base_v = jax.random.normal(k3, (d, KV, hd)) * sd
        idx = jnp.minimum(jnp.arange(h_eff) // G, KV - 1)
        pad_mask = (jnp.arange(h_eff) < H)[None, :, None]
        wk = (base_k[:, idx] * pad_mask).astype(dtype)
        wv = (base_v[:, idx] * pad_mask).astype(dtype)
        wo = wo * pad_mask.reshape(h_eff, 1, 1)     # zero rows for padded heads
    return {"wq": wq, "wk": wk, "wv": wv, "wo": wo,
            "norm": jnp.zeros((d,), dtype)}


def attn_apply(p: dict, x: Array, positions: Array, *, rope_theta: float,
               eps: float, chunk: int, window: Optional[int] = None,
               cap: Optional[float] = None,
               cache: Optional[Tuple[Array, Array]] = None,
               pos_scalar: Optional[Array] = None,
               ) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """Pre-norm attention sub-block. Returns (residual_delta, new_cache).

    Modes:
      cache is None               → train/prefill without cache output
      cache=(k,v), x has S tokens → prefill: fill slots [0,S)
      cache=(k,v), x has 1 token  → decode at ``pos_scalar``
    """
    h = rms_norm(x, p["norm"], eps)
    q = jnp.einsum("bsd,dnh->bsnh", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dnh->bsnh", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", h, p["wv"].astype(h.dtype))
    q = shard(q, None, None, "model", None)
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)

    new_cache = None
    if cache is not None and x.shape[1] == 1:
        kc, vc = cache
        S = kc.shape[1]
        slot = pos_scalar if window is None else pos_scalar % S
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, axis=1)
        out = decode_attention(q, kc, vc, pos_scalar, window=window, cap=cap)
        new_cache = (kc, vc)
    else:
        out = chunked_attention(q, k, v, chunk=chunk, window=window, cap=cap)
        if cache is not None:     # prefill: write the (possibly windowed) tail
            kc, vc = cache
            S = kc.shape[1]
            if window is not None and x.shape[1] > S:
                # keep last W keys; ring-buffer alignment: slot p mod S
                tail_k, tail_v = k[:, -S:], v[:, -S:]
                roll = (x.shape[1] % S)
                kc = jnp.roll(tail_k.astype(kc.dtype), roll, axis=1)
                vc = jnp.roll(tail_v.astype(vc.dtype), roll, axis=1)
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(
                    kc, k[:, :S].astype(kc.dtype), 0, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    vc, v[:, :S].astype(vc.dtype), 0, axis=1)
            new_cache = (kc, vc)

    out = shard(out, None, None, "model", None)
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(out.dtype)), new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(rng, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d, ff)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, ff)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (ff, d)) * ff ** -0.5).astype(dtype),
        "norm": jnp.zeros((d,), dtype),
    }


def mlp_apply(p: dict, x: Array, eps: float) -> Array:
    h = rms_norm(x, p["norm"], eps)
    g = jnp.einsum("bsd,df->bsf", h, p["w_gate"].astype(h.dtype))
    u = jnp.einsum("bsd,df->bsf", h, p["w_up"].astype(h.dtype))
    g = shard(g, None, None, "model")
    out = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", out, p["w_down"].astype(out.dtype))
